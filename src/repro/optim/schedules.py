"""Learning-rate schedules as step -> lr callables (jit-traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def sched(step):
        return jnp.asarray(lr, jnp.float32)
    return sched


def cosine_decay(lr: float, decay_steps: int, final_frac: float = 0.1):
    def sched(step):
        frac = jnp.clip(step.astype(jnp.float32) / decay_steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return lr * (final_frac + (1.0 - final_frac) * cos)
    return sched


def linear_warmup_cosine(lr: float, warmup_steps: int, decay_steps: int, final_frac: float = 0.1):
    cos = cosine_decay(lr, max(decay_steps - warmup_steps, 1), final_frac)
    def sched(step):
        step_f = step.astype(jnp.float32)
        warm = lr * step_f / max(warmup_steps, 1)
        return jnp.where(step_f < warmup_steps, warm, cos(step - warmup_steps))
    return sched
