"""Adam/AdamW implemented directly on pytrees (no optax in this container).

The paper uses Adam both for network training (lr β = 3e-4) and for the
F_grad minimization in Algorithm 2 (lr α = 8e-3); this module serves both.

``SlabAdamState`` is the slab-view variant for the slab-native
distributed step (DESIGN.md §3.10): both moments live as ONE flat f32
slab instead of a pytree, the update runs as three fused elementwise
passes over that slab, and the parameter pytree is touched exactly once
per step — at the model-apply boundary, where the updated slab is
sliced back into leaf shapes. n_leaves-independent dispatch: a 100-leaf
trunk costs the same number of ops as a single tensor.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array      # scalar int32
    mu: object           # first-moment pytree
    nu: object           # second-moment pytree


def adam_init(params) -> AdamState:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def adam_update(
    grads,
    state: AdamState,
    params,
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    """One Adam(W) step. Returns (new_params, new_state)."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)

    def _moment1(m, g):
        return b1 * m + (1.0 - b1) * g.astype(jnp.float32)

    def _moment2(v, g):
        g32 = g.astype(jnp.float32)
        return b2 * v + (1.0 - b2) * g32 * g32

    mu = jax.tree.map(_moment1, state.mu, grads)
    nu = jax.tree.map(_moment2, state.nu, grads)

    def _upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(_upd, params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu)


# ---------------------------------------------------------------------------
# slab-view Adam (the slab-native distributed step — DESIGN.md §3.10)
# ---------------------------------------------------------------------------

class SlabAdamState(NamedTuple):
    step: jax.Array      # scalar int32
    mu: jax.Array        # (L,) f32 — flat concat of the param tree's leaves
    nu: jax.Array        # (L,) f32


def tree_to_slab(tree) -> jax.Array:
    """Flatten a pytree into one (L,) f32 slab (leaves in flatten order,
    butt-packed). Built as a chain of static dynamic_update_slices, the
    same idiom ``flatpack.TreePacker.pack`` measured ~10x faster than a
    wide concatenate of odd-sized segments on CPU — these boundary
    copies are shard-local (L = the per-device slab), but they run every
    step, so the idiom matters."""
    leaves = jax.tree.leaves(tree)
    if len(leaves) == 1:
        return leaves[0].reshape(-1).astype(jnp.float32)
    n = sum(int(l.size) for l in leaves)
    slab = jnp.zeros((n,), jnp.float32)
    off = 0
    for l in leaves:
        slab = jax.lax.dynamic_update_slice(
            slab, l.reshape(-1).astype(jnp.float32), (off,))
        off += int(l.size)
    return slab


def slab_to_tree(slab: jax.Array, like):
    """Slice an (L,) slab back into ``like``'s leaf shapes/dtypes — the
    one unpack at the model-apply boundary."""
    leaves, treedef = jax.tree.flatten(like)
    out, off = [], 0
    for l in leaves:
        n = int(l.size)
        piece = jax.lax.slice(slab, (off,), (off + n,))
        out.append(piece.reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def slab_adam_init(params) -> SlabAdamState:
    n = sum(int(l.size) for l in jax.tree.leaves(params))
    return SlabAdamState(step=jnp.zeros((), jnp.int32),
                         mu=jnp.zeros((n,), jnp.float32),
                         nu=jnp.zeros((n,), jnp.float32))


def slab_adam_update(
    grads,
    state: SlabAdamState,
    params,
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    """One Adam(W) step on the slab view. ``grads``/``params`` are
    pytrees (or already-flat (L,) slabs); moments never leave the slab
    and the updated params unpack once. Identical math to
    ``adam_update`` — elementwise, so layout cannot change values."""
    g_slab = grads if isinstance(grads, jax.Array) else tree_to_slab(grads)
    p_slab = params if isinstance(params, jax.Array) else tree_to_slab(params)
    inner = AdamState(step=state.step, mu=state.mu, nu=state.nu)
    new_p_slab, inner = adam_update(g_slab, inner, p_slab, lr, b1, b2, eps,
                                    weight_decay)
    new_state = SlabAdamState(step=inner.step, mu=inner.mu, nu=inner.nu)
    if isinstance(params, jax.Array):
        return new_p_slab, new_state
    return slab_to_tree(new_p_slab, params), new_state
