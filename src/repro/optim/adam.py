"""Adam/AdamW implemented directly on pytrees (no optax in this container).

The paper uses Adam both for network training (lr β = 3e-4) and for the
F_grad minimization in Algorithm 2 (lr α = 8e-3); this module serves both.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array      # scalar int32
    mu: object           # first-moment pytree
    nu: object           # second-moment pytree


def adam_init(params) -> AdamState:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def adam_update(
    grads,
    state: AdamState,
    params,
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    """One Adam(W) step. Returns (new_params, new_state)."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - jnp.power(b1, t)
    bc2 = 1.0 - jnp.power(b2, t)

    def _moment1(m, g):
        return b1 * m + (1.0 - b1) * g.astype(jnp.float32)

    def _moment2(v, g):
        g32 = g.astype(jnp.float32)
        return b2 * v + (1.0 - b2) * g32 * g32

    mu = jax.tree.map(_moment1, state.mu, grads)
    nu = jax.tree.map(_moment2, state.nu, grads)

    def _upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(_upd, params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu)
