"""Plain SGD (used for the τ_h / τ_ω local updates in Algorithm 1 when
configured, and as a cheap baseline optimizer)."""
from __future__ import annotations

import jax


def sgd_update(grads, params, lr):
    return jax.tree.map(lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype), params, grads)
