from repro.optim.adam import AdamState, adam_init, adam_update
from repro.optim.sgd import sgd_update
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine
from repro.optim.clip import clip_by_global_norm

__all__ = [
    "AdamState", "adam_init", "adam_update", "sgd_update",
    "constant", "cosine_decay", "linear_warmup_cosine", "clip_by_global_norm",
]
