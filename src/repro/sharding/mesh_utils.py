"""Mesh helpers: the FL-refined view and axis bookkeeping.

``make_production_mesh()`` (repro.launch.mesh) returns the assignment's
meshes: (16,16) ("data","model") and (2,16,16) ("pod","data","model").
The HOTA trainer needs to distinguish *clients within a cluster* (LAN
aggregation) from *clusters* (over-the-air MAC). ``fl_view`` reshapes the
same devices, in the same order, splitting "data" into
("cluster", "client") — global array layouts are unchanged, only collective
scoping differs. This mirrors the dp/fsdp axis split in MaxText.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
from jax.sharding import Mesh


def fl_view(mesh: Mesh, n_clients: int) -> Mesh:
    """Refine a production mesh's 'data' axis into ('cluster','client')."""
    names = list(mesh.axis_names)
    assert "data" in names and "model" in names, mesh
    data_idx = names.index("data")
    shape = list(mesh.devices.shape)
    data_size = shape[data_idx]
    assert data_size % n_clients == 0, (data_size, n_clients)
    n_clusters = data_size // n_clients
    new_shape = shape[:data_idx] + [n_clusters, n_clients] + shape[data_idx + 1:]
    new_names = names[:data_idx] + ["cluster", "client"] + names[data_idx + 1:]
    return Mesh(mesh.devices.reshape(new_shape), tuple(new_names))


def data_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    """All batch-like axes of a mesh, in major-to-minor order."""
    out = []
    for name in mesh.axis_names:
        if name in ("pod", "data", "cluster", "client"):
            out.append(name)
    return tuple(out)


def flat_client_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that enumerate FL clients (cluster x client, plus pod)."""
    out = []
    for name in mesh.axis_names:
        if name in ("pod", "cluster", "client"):
            out.append(name)
    return tuple(out)


def cluster_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that enumerate clusters (the OTA MAC sums over these)."""
    return tuple(n for n in mesh.axis_names if n in ("pod", "cluster"))


def total_clients(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in ("pod", "cluster", "client"):
        n *= sizes.get(a, 1)
    return n
