"""Mesh helpers: the FL-refined view, scenario axis, and axis bookkeeping.

``make_production_mesh()`` (repro.launch.mesh) returns the assignment's
meshes: (16,16) ("data","model") and (2,16,16) ("pod","data","model").
The HOTA trainer needs to distinguish *clients within a cluster* (LAN
aggregation) from *clusters* (over-the-air MAC). ``fl_view`` reshapes the
same devices, in the same order, splitting "data" into
("cluster", "client") — global array layouts are unchanged, only collective
scoping differs. This mirrors the dp/fsdp axis split in MaxText.

The SCENARIO axis (DESIGN.md §3.8) is orthogonal to the FL axes: a sweep
bank's (S,) leading dimension lives on a 1-D ("scenario",) mesh
(``repro.launch.mesh.make_scenario_mesh``); ``bank_sharding`` /
``replicated_sharding`` below are the two placements a sharded bank uses —
scenario-split state vs. replicated batch/PRNG (common random numbers).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

SCENARIO_AXIS = "scenario"


def fl_view(mesh: Mesh, n_clients: int) -> Mesh:
    """Refine a production mesh's 'data' axis into ('cluster','client')."""
    names = list(mesh.axis_names)
    assert "data" in names and "model" in names, mesh
    data_idx = names.index("data")
    shape = list(mesh.devices.shape)
    data_size = shape[data_idx]
    assert data_size % n_clients == 0, (data_size, n_clients)
    n_clusters = data_size // n_clients
    new_shape = shape[:data_idx] + [n_clusters, n_clients] + shape[data_idx + 1:]
    new_names = names[:data_idx] + ["cluster", "client"] + names[data_idx + 1:]
    return Mesh(mesh.devices.reshape(new_shape), tuple(new_names))


def data_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    """All batch-like axes of a mesh, in major-to-minor order."""
    out = []
    for name in mesh.axis_names:
        if name in ("pod", "data", "cluster", "client"):
            out.append(name)
    return tuple(out)


def flat_client_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that enumerate FL clients (cluster x client, plus pod)."""
    out = []
    for name in mesh.axis_names:
        if name in ("pod", "cluster", "client"):
            out.append(name)
    return tuple(out)


def cluster_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that enumerate clusters (the OTA MAC sums over these)."""
    return tuple(n for n in mesh.axis_names if n in ("pod", "cluster"))


def total_clients(mesh: Mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in ("pod", "cluster", "client"):
        n *= sizes.get(a, 1)
    return n


# --------------------------------------------------------------------------
# scenario axis (sharded sweep banks — DESIGN.md §3.8)
# --------------------------------------------------------------------------

def shard_map_compat(f, mesh, in_specs, out_specs, axis_names):
    """jax.shard_map appeared in newer jax; fall back to the experimental
    API. The fallback goes fully manual (no ``auto`` axes): on old
    jax/jaxlib, axis_index inside a partially-manual region lowers to a
    PartitionId op the SPMD partitioner rejects."""
    import jax
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def scenario_axis_size(mesh: Mesh) -> int:
    """Device count along the scenario axis of a sweep mesh."""
    assert SCENARIO_AXIS in mesh.axis_names, mesh
    return int(mesh.devices.shape[mesh.axis_names.index(SCENARIO_AXIS)])


def scenario_banked_spec(spec: PartitionSpec) -> PartitionSpec:
    """Prepend the scenario axis to a single-scenario PartitionSpec: an
    FL-sharded leaf P(*dims) becomes the bank leaf P("scenario", *dims) —
    the 2-D (scenario × client) layout of ``DistScenarioBank``'s
    (S,)-leading state/metric/ChannelParams leaves."""
    return PartitionSpec(SCENARIO_AXIS, *tuple(spec))


def scenario_banked_tree(spec_tree):
    """``scenario_banked_spec`` over a pytree of PartitionSpecs."""
    import jax
    return jax.tree.map(scenario_banked_spec, spec_tree,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def bank_sharding(mesh: Mesh) -> NamedSharding:
    """Placement for (S, ...) bank leaves: leading axis scenario-split."""
    return NamedSharding(mesh, PartitionSpec(SCENARIO_AXIS))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Placement for the shared batch/PRNG inputs: fully replicated, so
    every scenario shard consumes identical data and keys (the common-
    random-numbers contract of the sweep engine)."""
    return NamedSharding(mesh, PartitionSpec())
