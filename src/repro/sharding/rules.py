"""Logical-axis -> mesh-axis sharding rules.

Every parameter/activation in the framework carries a tuple of *logical*
axis names (e.g. ``("layer", "embed", "mlp")``). A ``ShardingRules`` maps
each logical name to an ordered list of candidate mesh axes. Rule
application enforces the two GSPMD constraints automatically:

* divisibility — a dim is only sharded if its size is divisible by the
  product of the mesh axes assigned to it;
* exclusivity — a mesh axis may appear at most once per tensor; later
  logical axes fall back to their next candidate (or replication).

This mirrors how MaxText/levanter handle logical axis rules, and it is what
lets one model zoo serve meshes of shape (16,16), (2,16,16) and the refined
FL view (pod, cluster, client, model) without per-model sharding code.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# A candidate is a tuple of mesh axis names sharding one tensor dim jointly,
# e.g. ("data",) or ("cluster", "client").
Candidate = Tuple[str, ...]


@dataclass(frozen=True)
class ShardingRules:
    rules: Dict[str, Tuple[Candidate, ...]] = field(default_factory=dict)

    def candidates(self, logical: Optional[str]) -> Tuple[Candidate, ...]:
        if logical is None:
            return ()
        return self.rules.get(logical, ())


def _mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _translate(cand: Candidate, mesh: Mesh) -> Optional[Candidate]:
    """Translate the generic 'data' axis to whatever data-like axes the mesh
    actually has (supports the FL-refined view and the pod axis)."""
    sizes = _mesh_axis_sizes(mesh)
    out = []
    for ax in cand:
        if ax in sizes:
            out.append(ax)
        elif ax == "data" and "cluster" in sizes and "client" in sizes:
            out.extend(["cluster", "client"])
        else:
            return None
    return tuple(out)


def spec_for(
    logical_axes: Sequence[Optional[str]],
    rules: ShardingRules,
    shape: Sequence[int],
    mesh: Mesh,
) -> P:
    """Build a PartitionSpec for one tensor."""
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    spec = []
    for dim, name in zip(shape, logical_axes):
        chosen = None
        for cand in rules.candidates(name):
            cand = _translate(cand, mesh)
            if cand is None:
                continue
            prod = int(np.prod([sizes[a] for a in cand]))
            if any(a in used for a in cand):
                continue
            if prod == 0 or dim % prod != 0:
                continue
            chosen = cand
            break
        if chosen is None:
            spec.append(None)
        else:
            used.update(chosen)
            spec.append(chosen if len(chosen) > 1 else chosen[0])
    return P(*spec)


def tree_specs(axes_tree, shapes_tree, rules: ShardingRules, mesh: Mesh):
    """Map spec_for over parallel pytrees of logical-axes tuples and shapes."""
    return jax.tree.map(
        lambda axes, shape: spec_for(axes, rules, shape, mesh),
        axes_tree,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and (len(x) == 0 or not isinstance(x[0], tuple)),
    )


def tree_shardings(axes_tree, shapes_tree, rules: ShardingRules, mesh: Mesh):
    specs = tree_specs(axes_tree, shapes_tree, rules, mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


def _mk(rules: Dict[str, Sequence[Sequence[str]]]) -> ShardingRules:
    return ShardingRules({k: tuple(tuple(c) for c in v) for k, v in rules.items()})


# --- canonical rule sets ------------------------------------------------------

# Training: FSDP over the data axis on the embed dim, tensor parallel on
# mlp/heads/vocab/expert dims. The "pod" axis replicates parameters (clusters
# never span pods; see DESIGN.md §3.2) and shards the batch.
TRAIN_RULES = _mk({
    "batch":    [("pod", "data"), ("data",), ("pod",)],
    "seq":      [],
    "embed":    [("data",)],
    "embed2":   [],                      # second embed-sized dim (e.g. out-proj rows)
    "vocab":    [("model",)],
    "mlp":      [("model",)],
    "heads":    [("model",)],
    "kv_heads": [("model",)],
    "expert":   [("model",), ("data",)],
    "clients":  [("pod", "data"), ("data",)],   # per-client personalized heads
    "qkv":      [("model",)],
    "state":    [],
    "head_dim": [],
    "layer":    [],
    "conv":     [],
    "cache_seq": [],
})

# Serving (prefill/decode): weights stay FSDP+TP sharded; batch over
# (pod, data). The KV cache shards its *sequence* dim over "model" (kv-head
# counts of 2-8 never divide a 16-way model axis; sequence always does) —
# decode attention then runs as partial scores + GSPMD softmax collectives.
SERVE_RULES = _mk({
    "batch":    [("pod", "data"), ("data",), ("pod",)],
    "seq":      [],
    "embed":    [("data",)],
    "embed2":   [],
    "vocab":    [("model",)],
    "mlp":      [("model",)],
    "heads":    [("model",)],
    "kv_heads": [],
    "expert":   [("model",), ("data",)],
    "clients":  [("pod", "data"), ("data",)],
    "qkv":      [("model",)],
    "state":    [],
    "head_dim": [],
    "layer":    [],
    "conv":     [],
    "cache_seq": [("model",)],
})

# Long-context serving (batch=1): batch is unshardable, so the KV cache
# sequence dim takes the model axis (distributed attention: partial scores +
# global softmax via GSPMD collectives); kv heads often indivisible anyway.
LONGCTX_SERVE_RULES = _mk({
    "batch":    [],
    "seq":      [("data",)],
    "embed":    [("data",)],
    "embed2":   [],
    "vocab":    [("model",)],
    "mlp":      [("model",)],
    "heads":    [("model",)],
    "kv_heads": [],
    "expert":   [("model",), ("data",)],
    "clients":  [],
    "qkv":      [("model",)],
    "state":    [],
    "head_dim": [],
    "layer":    [],
    "conv":     [],
    "cache_seq": [("model",)],
})
