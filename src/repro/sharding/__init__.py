from repro.sharding.rules import (
    ShardingRules,
    TRAIN_RULES,
    SERVE_RULES,
    LONGCTX_SERVE_RULES,
    spec_for,
    tree_specs,
    tree_shardings,
)
from repro.sharding.mesh_utils import fl_view, flat_client_axes, data_axes_of

__all__ = [
    "ShardingRules", "TRAIN_RULES", "SERVE_RULES", "LONGCTX_SERVE_RULES",
    "spec_for", "tree_specs", "tree_shardings", "fl_view",
    "flat_client_axes", "data_axes_of",
]
