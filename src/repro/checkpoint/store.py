"""Checkpointing without orbax: msgpack envelope + raw npy payloads.

Layout::

    <dir>/step_<k>/manifest.msgpack   # treedef, shapes, dtypes, metadata
    <dir>/step_<k>/arr_<i>.npy        # one file per leaf (np.save format)

Arrays are gathered to host before save (fine at example scale; sharded
save would use a per-shard layout keyed by PartitionSpec — noted in
DESIGN.md §3.9 as the production extension point).
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional, Tuple

import jax
import msgpack
import numpy as np


def _leaf_paths(tree) -> Tuple[Any, list]:
    leaves, treedef = jax.tree.flatten(tree)
    return treedef, leaves


def save_checkpoint(ckpt_dir: str, step: int, tree, metadata: Optional[dict] = None) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    treedef, leaves = _leaf_paths(tree)
    manifest = {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "step": step,
        "metadata": metadata or {},
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(path, f"arr_{i}.npy"), np.asarray(leaf))
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    return path


def restore_checkpoint(ckpt_dir: str, step: int, like_tree):
    """Restore into the structure of ``like_tree`` (shape/dtype-checked)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    treedef, like_leaves = _leaf_paths(like_tree)
    assert manifest["n_leaves"] == len(like_leaves), "checkpoint/tree mismatch"
    leaves = []
    for i, like in enumerate(like_leaves):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        assert list(arr.shape) == list(like.shape), (i, arr.shape, like.shape)
        leaves.append(arr.astype(like.dtype))
    return jax.tree.unflatten(treedef, leaves)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None
