"""Checkpointing without orbax: msgpack envelope + raw npy payloads.

Layout::

    <dir>/step_<k>/manifest.msgpack   # treedef, shapes, dtypes, metadata
    <dir>/step_<k>/arr_<i>.npy        # one file per leaf (np.save format)

Arrays are gathered to host before save (fine at example scale; sharded
save would use a per-shard layout keyed by PartitionSpec — noted in
DESIGN.md §3.9 as the production extension point). Sweep-aware
checkpointing (DESIGN.md §3.9): bank states with a leading (S,) scenario
axis — vmapped, scenario-sharded or 2-D (scenario × client) — save
through the same envelope (``np.asarray`` gathers a sharded global array
on a single process), restore shape-checked against the bank's abstract
state, and re-place onto the bank's shardings via ``restore_checkpoint``'s
``shardings`` pytree; the scenario count is pinned in ``metadata`` so a
bank never silently restores another bank's state (see
``repro.core.sweep.ScenarioBank.save/restore``).
"""
from __future__ import annotations

import os
import re
import shutil
from typing import Any, Optional, Tuple

import jax
import msgpack
import numpy as np


def _leaf_paths(tree) -> Tuple[Any, list]:
    leaves, treedef = jax.tree.flatten(tree)
    return treedef, leaves


def save_checkpoint(ckpt_dir: str, step: int, tree, metadata: Optional[dict] = None) -> str:
    """Atomically write ``<dir>/step_<k>``: payloads land in a temp dir
    (``.tmp-step_<k>``, invisible to ``latest_step``'s name filter), the
    manifest is written LAST, then one ``os.replace`` publishes the dir.
    A crash mid-save leaves either the previous complete checkpoint or a
    manifest-less temp/partial dir — both skipped on restore, so the
    RoundGuard recovery path (DESIGN.md §3.14) never reads torn state."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp-step_{step:08d}")
    if os.path.isdir(tmp):          # stale temp from a crashed save
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    treedef, leaves = _leaf_paths(tree)
    manifest = {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "step": step,
        "metadata": metadata or {},
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"arr_{i}.npy"), np.asarray(leaf))
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.isdir(path):         # re-save of the same step
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


def restore_checkpoint(ckpt_dir: str, step: int, like_tree, shardings=None,
                       expected_layout: Optional[dict] = None):
    """Restore into the structure of ``like_tree`` (shape/dtype-checked).

    ``like_tree`` may hold arrays or ShapeDtypeStructs (only shape/dtype
    are read). ``shardings``: optional placement for the restored leaves —
    a single ``Sharding`` applied to every leaf, or a same-structure
    pytree of them (the sweep banks pass their banked layout so a restore
    lands scenario-split exactly like a fresh ``init``).

    ``expected_layout``: the restoring run's packed-layout metadata
    (``LayoutChoice.to_metadata()`` — DESIGN.md §3.13). Section folds,
    and therefore every channel stream, depend on the layout, so a
    checkpoint saved under one layout must not silently continue under
    another: if the manifest pins a ``"layout"`` metadata entry and it
    differs from ``expected_layout``, the restore raises with both
    layouts named."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    treedef, like_leaves = _leaf_paths(like_tree)
    if manifest["n_leaves"] != len(like_leaves):
        raise ValueError(
            f"checkpoint/tree mismatch restoring {path}: the manifest "
            f"records {manifest['n_leaves']} leaves but the supplied "
            f"like_tree has {len(like_leaves)} — the checkpoint was saved "
            f"from a different model/bank structure.")
    saved_layout = (manifest.get("metadata") or {}).get("layout")
    if expected_layout is not None and saved_layout is not None \
            and dict(saved_layout) != dict(expected_layout):
        raise ValueError(
            f"packed-layout mismatch restoring {path}: the checkpoint was "
            f"saved under layout {dict(saved_layout)} but this run uses "
            f"layout {dict(expected_layout)}. Section folds — and so every "
            f"channel stream — depend on the layout (DESIGN.md §3.13); "
            f"rebuild the run with the checkpoint's layout "
            f"(repro.common.layout_tune.apply_layout) or start fresh.")
    if shardings is None:
        shard_leaves = None
    elif hasattr(shardings, "device_set"):        # one Sharding for all
        shard_leaves = [shardings] * len(like_leaves)
    else:                                         # same-structure pytree
        shard_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "device_set"))
        assert len(shard_leaves) == len(like_leaves), \
            (len(shard_leaves), len(like_leaves))
    leaves = []
    for i, like in enumerate(like_leaves):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        if list(arr.shape) != list(like.shape):
            raise ValueError(
                f"checkpoint/tree mismatch restoring {path}: leaf {i} was "
                f"saved with shape {tuple(arr.shape)} but the like_tree "
                f"expects {tuple(like.shape)}.")
        arr = arr.astype(like.dtype)
        if shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        leaves.append(arr)
    return jax.tree.unflatten(treedef, leaves)


def checkpoint_metadata(ckpt_dir: str, step: int) -> dict:
    """The metadata dict a checkpoint was saved with (empty if none)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        return msgpack.unpackb(f.read()).get("metadata", {})


def latest_step(ckpt_dir: str) -> Optional[int]:
    """The newest COMPLETE checkpoint step (None when there is none).
    A dir only counts when its manifest exists — the manifest is written
    last and the dir published by ``os.replace``, so anything without one
    is a torn pre-atomic-era partial and must not be restored."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.isfile(
                os.path.join(ckpt_dir, name, "manifest.msgpack")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None
