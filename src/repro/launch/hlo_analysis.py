"""Roofline-term extraction from compiled XLA artifacts (assignment §ROOFLINE).

Terms (per device, per step):
    compute term    = HLO_FLOPs / peak_FLOPs_per_chip
    memory term     = HLO_bytes / HBM_bw_per_chip
    collective term = collective_bytes / link_bw_per_chip

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are
NOT in cost_analysis: they come from the shared HLO text parser in
``launch/hlo_cost.py`` (result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, with
while-loop trip-count multipliers recovered from loop condition
constants — scan-over-layers makes nearly all collectives sit inside
while bodies). This module used to carry a second, divergent regex
dialect for that walk; it now delegates (DESIGN.md §3.17).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-provided).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.launch.hlo_cost import COLLECTIVES, DTYPE_BYTES, analyze

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Total per-device collective bytes per step, loop-multiplied."""
    return dict(analyze(hlo).coll_bytes)


@dataclass
class Roofline:
    flops: float                 # per device per step
    bytes_accessed: float
    coll_bytes: Dict[str, float]
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        return self.flops / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return sum(self.coll_bytes.values()) / self.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def extract_roofline(compiled) -> Roofline:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x returns [per-device dict]
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    cb = collective_bytes(hlo)
    return Roofline(flops=flops, bytes_accessed=bytes_acc, coll_bytes=cb)


def memory_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    return {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
        "output_bytes": getattr(ma, "output_size_in_bytes", 0),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
    }


def model_flops(n_params_active: float, n_tokens: float,
                train: bool) -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference forward."""
    per_tok = 6.0 if train else 2.0
    return per_tok * n_params_active * n_tokens
