"""Roofline-term extraction from compiled XLA artifacts (assignment §ROOFLINE).

Terms (per device, per step):
    compute term    = HLO_FLOPs / peak_FLOPs_per_chip
    memory term     = HLO_bytes / HBM_bw_per_chip
    collective term = collective_bytes / link_bw_per_chip

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are
NOT in cost_analysis: we parse the post-partitioning HLO text, summing the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, with while-loop trip-count multipliers
recovered from loop condition constants (scan-over-layers makes nearly all
collectives sit inside while bodies).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-provided).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:%(\S+)|(\S+))\s+\([^)]*\)\s*->", re.M)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * DTYPE_BYTES[dtype]


@dataclass
class Computation:
    name: str
    text: List[str] = field(default_factory=list)
    collective_bytes: Dict[str, int] = field(default_factory=dict)
    whiles: List[Tuple[str, str]] = field(default_factory=list)  # (body, cond)
    calls: List[str] = field(default_factory=list)


def _parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\([^)]*\)\s*->", line)
        if m and not line.startswith(" "):
            cur = Computation(name=m.group(2))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        cur.text.append(stripped)
        # while loops: body=%name, condition=%name
        if "while(" in stripped or " while(" in stripped:
            b = re.search(r"body=%?([\w\.\-]+)", stripped)
            c = re.search(r"condition=%?([\w\.\-]+)", stripped)
            if b and c:
                cur.whiles.append((b.group(1), c.group(1)))
        for cname in re.findall(r"(?:to_apply|calls)=%?([\w\.\-]+)", stripped):
            cur.calls.append(cname)
        # collectives: result shape(s) appear before the op name
        for op in COLLECTIVES:
            if re.search(rf"=\s*(?:\([^)]*\)\s*)?{op}[\(\.]", stripped) or \
               re.search(rf"=\s*\S+\s+{op}\(", stripped):
                lhs = stripped.split("=")[1] if "=" in stripped else stripped
                head = lhs.split(op)[0]
                total = sum(_shape_bytes(d, dims)
                            for d, dims in _SHAPE_RE.findall(head))
                cur.collective_bytes[op] = cur.collective_bytes.get(op, 0) + total
                break
    return comps


def _trip_count(cond: Computation) -> int:
    """Best-effort static trip count from the loop condition constants."""
    consts = []
    for line in cond.text:
        if "constant(" in line and ("compare" in "".join(cond.text) or True):
            for m in re.finditer(r"constant\((\d+)\)", line):
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Total per-device collective bytes per step, loop-multiplied."""
    comps = _parse_computations(hlo)
    conds = {}

    def visit(name: str, mult: float, seen: Tuple[str, ...]) -> Dict[str, float]:
        if name not in comps or name in seen:
            return {}
        comp = comps[name]
        out: Dict[str, float] = {}
        for op, b in comp.collective_bytes.items():
            out[op] = out.get(op, 0.0) + b * mult
        for body, cond in comp.whiles:
            tc = _trip_count(comps[cond]) if cond in comps else 1
            sub = visit(body, mult * max(tc, 1), seen + (name,))
            for op, b in sub.items():
                out[op] = out.get(op, 0.0) + b
        for callee in comp.calls:
            sub = visit(callee, mult, seen + (name,))
            for op, b in sub.items():
                out[op] = out.get(op, 0.0) + b
        return out

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: sum everything without multipliers
        total: Dict[str, float] = {}
        for comp in comps.values():
            for op, b in comp.collective_bytes.items():
                total[op] = total.get(op, 0.0) + b
        return total
    return visit(entry, 1.0, ())


@dataclass
class Roofline:
    flops: float                 # per device per step
    bytes_accessed: float
    coll_bytes: Dict[str, float]
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        return self.flops / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return sum(self.coll_bytes.values()) / self.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def extract_roofline(compiled) -> Roofline:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    cb = collective_bytes(hlo)
    return Roofline(flops=flops, bytes_accessed=bytes_acc, coll_bytes=cb)


def memory_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    return {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
        "output_bytes": getattr(ma, "output_size_in_bytes", 0),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
    }


def model_flops(n_params_active: float, n_tokens: float,
                train: bool) -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference forward."""
    per_tok = 6.0 if train else 2.0
    return per_tok * n_params_active * n_tokens
