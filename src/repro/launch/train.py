"""Real training driver (CPU-scale meshes; the production mesh path is
exercised by dryrun.py on this container).

Runs HOTA-FedGradNorm training of any --arch's reduced (smoke) config on a
debug mesh using host devices, with checkpointing and metric logging:

    XLA_FLAGS="--xla_force_host_platform_device_count=8" \\
    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \\
        --steps 50 --mesh 2,2,2

(mesh = clusters,clients,model). For the paper's own experiment use
examples/paper_reproduction.py, which runs the faithful C=10/N=3 simulator.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import save_checkpoint
from repro.checkpoint.store import latest_step, restore_checkpoint
from repro.common.config import FLConfig, TrainConfig
from repro.configs import ALIASES, get_smoke_config
from repro.core.hota_step import make_hota_train_step
from repro.data.lm import synthetic_lm_batches
from repro.models.model import build_model


class RoundGuard:
    """Host-side divergence recovery (DESIGN.md §3.14).

    The traced guard inside the step already degrades a non-finite or
    grad-spike round to a bit-exact skip (state frozen, ``skipped``
    metric set). This class watches that metric across rounds: after
    ``patience`` CONSECUTIVE skipped rounds it restores the full train
    state from the newest complete checkpoint — the traced skip handles
    transients, the guard handles a wedged run (e.g. a persistently
    tripping spike threshold on corrupted optimizer state). Any clean
    round resets the streak.
    """

    def __init__(self, ckpt_dir: str, abstract_state, shardings=None,
                 patience: int = 3):
        if patience < 1:
            raise ValueError(f"patience must be >= 1, got {patience}")
        self.ckpt_dir = ckpt_dir
        self.abstract_state = abstract_state
        self.shardings = shardings
        self.patience = patience
        self.streak = 0
        self.n_restores = 0

    def observe(self, skipped, state):
        """Feed one round's ``skipped`` metric; returns
        ``(state, restored)`` where ``state`` is the checkpoint-restored
        train state when the streak hit ``patience`` (and a complete
        checkpoint exists), else the state passed in, untouched."""
        if float(skipped) < 0.5:
            self.streak = 0
            return state, False
        self.streak += 1
        if self.streak < self.patience:
            return state, False
        self.streak = 0
        step = None if not self.ckpt_dir else latest_step(self.ckpt_dir)
        if step is None:          # nothing to restore from: keep going
            return state, False   # (the traced skip still froze the state)
        self.n_restores += 1
        return restore_checkpoint(self.ckpt_dir, step, self.abstract_state,
                                  shardings=self.shardings), True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-per-client", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--mesh", default="2,2,2",
                    help="clusters,clients,model (needs that many devices)")
    ap.add_argument("--weighting", default="fedgradnorm",
                    choices=["fedgradnorm", "equal"])
    ap.add_argument("--ota-mode", default="scatter", choices=["scatter", "naive"])
    ap.add_argument("--no-ota", action="store_true")
    # section-streaming engines (DESIGN.md §3.15/§3.16). Neither flag is
    # ever silently inert: --ota-streaming is a SIMULATOR engine and the
    # distributed step rejects it by name (make_hota_step_parts guard);
    # --ota-sectioned/--max-section-rows are validated against the
    # layout gates the same way. Explicit flags skip the autotuner so
    # the tuned layout cannot clobber the requested engine.
    ap.add_argument("--ota-streaming", action="store_true",
                    help="simulator-only cluster-scan engine; the "
                         "distributed round rejects it with the reason "
                         "named (use --ota-sectioned here)")
    ap.add_argument("--ota-sectioned", action="store_true",
                    help="section-streaming slab aggregation: peak live "
                         "channel memory is one section, not the slab")
    ap.add_argument("--max-section-rows", type=int, default=0,
                    help="split packed sections above this many 128-lane "
                         "slab rows (0 = off); bounds --ota-sectioned's "
                         "peak section size")
    ap.add_argument("--memory-budget-mb", type=int, default=0,
                    help="aggregation working-set budget for the layout "
                         "autotuner (MB, 0 = unconstrained): full-slab "
                         "candidates over budget are excluded and a "
                         "budget-sized sectioned candidate is added")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save the FULL train state every K rounds "
                         "(0 = only the final omega snapshot)")
    ap.add_argument("--seed", type=int, default=0)
    # fault injection (DESIGN.md §3.14) — traced knobs, one static gate
    ap.add_argument("--faults", action="store_true",
                    help="enable the fault-injection round path")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-client dropout rate")
    ap.add_argument("--blackout", type=float, default=0.0,
                    help="per-cluster blackout rate")
    ap.add_argument("--straggler", type=float, default=0.0,
                    help="per-client straggler rate")
    ap.add_argument("--staleness", type=int, default=1,
                    help="straggler staleness depth in rounds")
    ap.add_argument("--spike-norm", type=float, default=float("inf"),
                    help="skip a round whose aggregate grad norm exceeds this")
    ap.add_argument("--guard-patience", type=int, default=3,
                    help="consecutive skipped rounds before the RoundGuard "
                         "restores from the latest checkpoint")
    # section-layout autotuner (DESIGN.md §3.13) — default ON: a one-shot
    # calibration bench per template, persisted across runs
    ap.add_argument("--no-tune-layout", action="store_true",
                    help="skip the layout autotuner and keep FLConfig's "
                         "default packed layout")
    ap.add_argument("--layout-cache", default=None,
                    help="path of the persisted calibration cache "
                         "(default ~/.cache/repro/layout_tune.json or "
                         "$REPRO_LAYOUT_CACHE; pass '' to disable "
                         "persistence)")
    args = ap.parse_args()

    shape = tuple(int(x) for x in args.mesh.split(","))
    n_dev = int(np.prod(shape))
    devs = np.array(jax.devices())
    assert devs.size >= n_dev, (
        f"need {n_dev} devices; set "
        f'XLA_FLAGS="--xla_force_host_platform_device_count={n_dev}"')
    mesh = Mesh(devs[:n_dev].reshape(shape), ("cluster", "client", "model"))

    cfg = get_smoke_config(ALIASES.get(args.arch, args.arch))
    model = build_model(cfg)
    fl = FLConfig(n_clusters=shape[0], n_clients=shape[1],
                  weighting=args.weighting, ota=not args.no_ota,
                  ota_mode=args.ota_mode, noise_std=0.1,
                  ota_streaming=args.ota_streaming,
                  ota_sectioned=args.ota_sectioned,
                  max_section_rows=args.max_section_rows,
                  faults=args.faults, dropout_rate=args.dropout,
                  blackout_rate=args.blackout,
                  straggler_rate=args.straggler,
                  staleness_rounds=args.staleness,
                  spike_norm=args.spike_norm)
    tcfg = TrainConfig(lr=args.lr)

    explicit_layout = (args.ota_streaming or args.ota_sectioned
                       or bool(args.max_section_rows))
    if not args.no_tune_layout and not explicit_layout:
        # tuned section layout, default on: the same {final, trunk}
        # template the step builds its packer from, so the tuned folds
        # are exactly the streams the run draws (checkpoint-pinned)
        from repro.common.layout_tune import layout_of, tuned_fl
        from repro.models.params import abstract_params
        template = {"final": abstract_params(model.final_specs()),
                    "trunk": abstract_params(model.trunk_specs())}
        budget = args.memory_budget_mb * (1 << 20) or None
        fl = tuned_fl(fl, template, cache_path=args.layout_cache,
                      memory_budget_bytes=budget)
        print(f"layout: {layout_of(fl).describe()}", flush=True)
    elif explicit_layout:
        from repro.common.layout_tune import layout_of
        print(f"layout: {layout_of(fl).describe()} (explicit; "
              "autotuner skipped)", flush=True)

    init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
        model, mesh, fl, tcfg, loss_kind="lm")
    state = init_fn(jax.random.PRNGKey(args.seed))
    state = jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        state, state_specs, is_leaf=lambda x: isinstance(x, P))

    guard = None
    if args.faults and args.ckpt_dir:
        state_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), state_specs,
            is_leaf=lambda x: isinstance(x, P))
        guard = RoundGuard(args.ckpt_dir,
                           jax.eval_shape(init_fn, jax.random.PRNGKey(0)),
                           shardings=state_shardings,
                           patience=args.guard_patience)

    n_clients_total = shape[0] * shape[1]
    batches = synthetic_lm_batches(
        cfg.vocab_size, n_clients_total * args.batch_per_client,
        args.seq_len, seed=args.seed)
    jstep = jax.jit(step_fn)

    t0 = time.time()
    for step in range(args.steps):
        toks, labs = next(batches)
        toks = jax.device_put(jnp.asarray(toks), NamedSharding(mesh, batch_spec[0]))
        labs = jax.device_put(jnp.asarray(labs), NamedSharding(mesh, batch_spec[1]))
        state, m = jstep(state, toks, labs, jax.random.PRNGKey(args.seed + 1))
        if guard is not None:
            state, restored = guard.observe(m["skipped"], state)
            if restored:
                print(f"step {step:4d} RoundGuard: {args.guard_patience} "
                      f"consecutive skipped rounds — restored from "
                      f"checkpoint step {latest_step(args.ckpt_dir)}",
                      flush=True)
        if args.ckpt_dir and args.ckpt_every \
                and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, int(state.step),
                            jax.tree.map(np.asarray, state),
                            {"arch": args.arch, "kind": "full_state"})
        if step % 10 == 0 or step == args.steps - 1:
            faulty = (f" part {float(m['n_participants']):.0f}"
                      f" skip {float(m['skipped']):.0f}"
                      if args.faults else "")
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"p [{float(m['p_min']):.3f},{float(m['p_max']):.3f}] "
                  f"fgrad {float(m['fgrad']):.4f}{faulty} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True)
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps,
                               jax.tree.map(np.asarray, state.omega),
                               {"arch": args.arch})
        print("checkpoint:", path)


if __name__ == "__main__":
    main()
