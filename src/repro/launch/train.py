"""Real training driver (CPU-scale meshes; the production mesh path is
exercised by dryrun.py on this container).

Runs HOTA-FedGradNorm training of any --arch's reduced (smoke) config on a
debug mesh using host devices, with checkpointing and metric logging:

    XLA_FLAGS="--xla_force_host_platform_device_count=8" \\
    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \\
        --steps 50 --mesh 2,2,2

(mesh = clusters,clients,model). For the paper's own experiment use
examples/paper_reproduction.py, which runs the faithful C=10/N=3 simulator.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import save_checkpoint
from repro.common.config import FLConfig, TrainConfig
from repro.configs import ALIASES, get_smoke_config
from repro.core.hota_step import make_hota_train_step
from repro.data.lm import synthetic_lm_batches
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-per-client", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--mesh", default="2,2,2",
                    help="clusters,clients,model (needs that many devices)")
    ap.add_argument("--weighting", default="fedgradnorm",
                    choices=["fedgradnorm", "equal"])
    ap.add_argument("--ota-mode", default="scatter", choices=["scatter", "naive"])
    ap.add_argument("--no-ota", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    shape = tuple(int(x) for x in args.mesh.split(","))
    n_dev = int(np.prod(shape))
    devs = np.array(jax.devices())
    assert devs.size >= n_dev, (
        f"need {n_dev} devices; set "
        f'XLA_FLAGS="--xla_force_host_platform_device_count={n_dev}"')
    mesh = Mesh(devs[:n_dev].reshape(shape), ("cluster", "client", "model"))

    cfg = get_smoke_config(ALIASES.get(args.arch, args.arch))
    model = build_model(cfg)
    fl = FLConfig(n_clusters=shape[0], n_clients=shape[1],
                  weighting=args.weighting, ota=not args.no_ota,
                  ota_mode=args.ota_mode, noise_std=0.1)
    tcfg = TrainConfig(lr=args.lr)

    init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
        model, mesh, fl, tcfg, loss_kind="lm")
    state = init_fn(jax.random.PRNGKey(args.seed))
    state = jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        state, state_specs, is_leaf=lambda x: isinstance(x, P))

    n_clients_total = shape[0] * shape[1]
    batches = synthetic_lm_batches(
        cfg.vocab_size, n_clients_total * args.batch_per_client,
        args.seq_len, seed=args.seed)
    jstep = jax.jit(step_fn)

    t0 = time.time()
    for step in range(args.steps):
        toks, labs = next(batches)
        toks = jax.device_put(jnp.asarray(toks), NamedSharding(mesh, batch_spec[0]))
        labs = jax.device_put(jnp.asarray(labs), NamedSharding(mesh, batch_spec[1]))
        state, m = jstep(state, toks, labs, jax.random.PRNGKey(args.seed + 1))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"p [{float(m['p_min']):.3f},{float(m['p_max']):.3f}] "
                  f"fgrad {float(m['fgrad']):.4f} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True)
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps,
                               jax.tree.map(np.asarray, state.omega),
                               {"arch": args.arch})
        print("checkpoint:", path)


if __name__ == "__main__":
    main()
