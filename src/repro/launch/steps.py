"""Step builders + abstract input specs for the dry-run and real runs.

``input_specs(arch, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (no device allocation), matching the assignment's pattern.
For the audio/VLM architectures the modality frontend is stubbed: specs
carry precomputed frame/patch *embeddings* (B, S, d_model) instead of raw
audio/pixels (the decoder consumes embeddings; DESIGN.md §3.4).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import (
    FLConfig, INPUT_SHAPES, InputShape, ModelConfig, TrainConfig,
)
from repro.models.model import Model, build_model
from repro.models.params import abstract_params, logical_axes
from repro.sharding.rules import (
    LONGCTX_SERVE_RULES, SERVE_RULES, TRAIN_RULES, ShardingRules, spec_for,
)
from repro.sharding.mesh_utils import fl_view


# --------------------------------------------------------------------------
# abstract inputs
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Abstract inputs for one (arch, input-shape) pair."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.modality in ("audio",):
            # EnCodec tokens are discrete — the stub supplies token ids
            tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
        elif cfg.modality == "vision":
            # stub vision frontend supplies projected patch embeddings
            tokens = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        else:
            tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return {"tokens": tokens,
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.modality == "vision":
            tokens = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        else:
            tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return {"tokens": tokens}
    # decode: one new token against a cache of seq_len
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "positions": jax.ShapeDtypeStruct((b,), jnp.int32)}


# --------------------------------------------------------------------------
# sharding helpers
# --------------------------------------------------------------------------

def _is_axes(x):
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def param_specs_tree(model: Model, rules: ShardingRules, mesh,
                     include_head: bool = True, n_out=None):
    ax = {"trunk": logical_axes(model.trunk_specs()),
          "final": logical_axes(model.final_specs())}
    shapes = {"trunk": jax.tree.map(lambda s: s.shape, model.trunk_specs(),
                                    is_leaf=_is_spec),
              "final": jax.tree.map(lambda s: s.shape, model.final_specs(),
                                    is_leaf=_is_spec)}
    specs = jax.tree.map(lambda a, sh: spec_for(a, rules, sh, mesh),
                         ax, shapes, is_leaf=_is_axes)
    if include_head:
        hs = model.head_specs(n_out)
        hax = logical_axes(hs)
        hshapes = jax.tree.map(lambda s: s.shape, hs, is_leaf=_is_spec)
        specs = {"backbone": specs,
                 "head": jax.tree.map(
                     lambda a, sh: spec_for(a, rules, sh, mesh),
                     hax, hshapes, is_leaf=_is_axes)}
    return specs


def _is_spec(x):
    from repro.models.params import ParamSpec
    return isinstance(x, ParamSpec)


def cache_specs_tree(model: Model, cache_abs, rules: ShardingRules, mesh):
    """PartitionSpecs for a cache pytree from the model's cache_axes()."""
    axes = model.cache_axes()

    def one(a, leaf):
        # `a` may have fewer entries than leaf.ndim (double-stacked leads)
        assert len(a) == leaf.ndim, (a, leaf.shape)
        return spec_for(a, rules, leaf.shape, mesh)
    return jax.tree.map(one, axes, cache_abs, is_leaf=_is_axes)


# --------------------------------------------------------------------------
# serve steps
# --------------------------------------------------------------------------

def make_prefill_step(model: Model, cache_len: Optional[int] = None):
    cfg = model.cfg

    def prefill_step(backbone, head, tokens):
        s = tokens.shape[1]
        logits, aux, cache = model.forward_logits(
            backbone, head, tokens, positions=jnp.arange(s), mode="prefill",
            cache_len=cache_len or s + 1)
        return logits[:, -1], cache
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(backbone, head, cache, tokens, positions):
        logits, aux, new_cache = model.forward_logits(
            backbone, head, tokens, positions=positions, mode="decode",
            cache=cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits[:, -1], new_cache
    return decode_step


# --------------------------------------------------------------------------
# abstract state builders (dry-run)
# --------------------------------------------------------------------------

def abstract_serve_state(model: Model, shape: InputShape, dtype=jnp.bfloat16):
    backbone = {"trunk": abstract_params(model.trunk_specs(), dtype),
                "final": abstract_params(model.final_specs(), dtype)}
    head = abstract_params(model.head_specs(), dtype)
    cache = None
    if shape.kind == "decode":
        cache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     jnp.bfloat16))
    return backbone, head, cache


def serve_rules_for(shape: InputShape) -> ShardingRules:
    return LONGCTX_SERVE_RULES if shape.name == "long_500k" else SERVE_RULES
