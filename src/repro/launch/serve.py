"""Serving driver: prefill + batched greedy decode for any --arch (reduced
config on CPU; the production-mesh serve path is exercised by dryrun.py).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \\
        --batch 4 --prefill-len 32 --decode-steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, get_smoke_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.model import build_model
from repro.models.params import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prefill-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(ALIASES.get(args.arch, args.arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    # split, don't fold literals: bare fold salts are reserved for the
    # DESIGN.md §4 registry (repro-lint bare-fold-salt); a demo's streams
    # carry no parity contract, so independent split keys are the right
    # spelling here
    k_trunk, k_final, k_head, k_prompt = jax.random.split(key, 4)
    backbone = {"trunk": init_params(model.trunk_specs(), k_trunk),
                "final": init_params(model.final_specs(), k_final)}
    head = init_params(model.head_specs(), k_head)

    cache_len = args.prefill_len + args.decode_steps + 1
    prefill = jax.jit(make_prefill_step(model, cache_len=cache_len))
    decode = jax.jit(make_decode_step(model))

    prompt = jax.random.randint(k_prompt,
                                (args.batch, args.prefill_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    logits, cache = prefill(backbone, head, prompt)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    print(f"prefill({args.batch}x{args.prefill_len}) "
          f"{time.time()-t0:.2f}s -> first tokens {np.asarray(next_tok)}")

    toks = [next_tok]
    pos = jnp.full((args.batch,), args.prefill_len, jnp.int32)
    t0 = time.time()
    for i in range(args.decode_steps - 1):
        next_tok, _, cache = decode(backbone, head, cache,
                                    next_tok[:, None], pos)
        toks.append(next_tok)
        pos = pos + 1
    dt = time.time() - t0
    out = np.stack([np.asarray(t) for t in toks], axis=1)
    print(f"decoded {args.decode_steps-1} steps in {dt:.2f}s "
          f"({dt/max(args.decode_steps-1,1)*1000:.0f} ms/tok)")
    for b in range(min(args.batch, 2)):
        print(f"  request {b}: {out[b][:16]}")


if __name__ == "__main__":
    main()
