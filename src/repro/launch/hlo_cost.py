"""HLO-text cost model with while-loop trip-count multipliers.

``compiled.cost_analysis()`` on XLA:CPU counts each while-loop *body once*,
which under scan-over-layers undercounts a 56-layer model by 56x. This
module reparses the post-partitioning, post-fusion HLO text and computes:

* FLOPs   — dot_general ops (2 x result elems x contracting elems),
            descending into fusions/whiles with multipliers;
* bytes   — per top-level op: operand + result bytes (fusions counted at
            the fusion boundary — post-fusion traffic, which is the right
            roofline quantity);
* collective bytes — result-shape bytes per collective op kind.

Approximations (documented in EXPERIMENTS.md §Roofline): non-dot FLOPs
(exp/tanh, rsqrt...) are ignored — matmul-dominated models; dynamic trip
counts fall back to the largest constant in the loop condition; operand
bytes for tuple-typed vars use the tuple's total size.

Shapes in partitioned HLO are per-device, so every number is per-chip.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")


def _parse_shape_list(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dtype, dims in _SHAPE_TOKEN.findall(s):
        if dtype not in DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d.strip())
        out.append((dtype, shape))
    return out


# public alias — repro.analysis (hlo_audit, roofline) and this module
# share ONE shape-token dialect; see DESIGN.md §3.17
parse_shape_tokens = _parse_shape_list


def _bytes_of(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dtype, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * DTYPE_BYTES[dtype]
    return total


@dataclass
class Op:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = field(default_factory=dict)


_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->")


def parse_hlo(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        if not raw.strip():
            continue
        if not raw.startswith((" ", "\t")):
            m = _COMP_HEADER.match(raw.strip())
            if m:
                cur = Computation(name=m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                continue
        if cur is None:
            continue
        line = raw.strip()
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, result_str, opcode, rest = m.groups()
        result_shapes = _parse_shape_list(result_str)
        # operands: %var references before any attr section
        paren = rest.split("),")[0] if ")," in rest else rest.rstrip(")")
        operands = re.findall(r"%([\w\.\-]+)", paren)
        op = Op(name=name, opcode=opcode, result_shapes=result_shapes,
                operands=operands, attrs=rest)
        cur.ops.append(op)
        cur.shapes[name] = result_shapes
    # parameters: appear as ops with opcode 'parameter'
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 x result elems x contracted elems for dot/dot-general."""
    res_elems = 0
    for _, shape in op.result_shapes:
        n = 1
        for d in shape:
            n *= d
        res_elems += n
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    if not m or not op.operands:
        return 2.0 * res_elems          # assume contract dim ~1 unknown
    cdims = [int(x) for x in m.group(1).split(",") if x.strip()]
    lhs = comp.shapes.get(op.operands[0])
    if not lhs:
        return 2.0 * res_elems
    _, lshape = lhs[0]
    contracted = 1
    for cd in cdims:
        if cd < len(lshape):
            contracted *= lshape[cd]
    return 2.0 * res_elems * contracted


def _trip_count(cond: Computation) -> int:
    consts = []
    for op in cond.ops:
        if op.opcode == "constant":
            # attrs is the text after "constant(" — the literal comes first
            m = re.match(r"(\d+)\)", op.attrs.strip())
            if m:
                consts.append(int(m.group(1)))
        for m in re.finditer(r"constant\((\d+)\)", op.attrs):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


_CALL_RE = re.compile(r"(?:to_apply|calls|body)=%?([\w\.\-]+)")


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0        # fusion-pessimistic upper bound (all ops)
    bytes_major: float = 0.0  # fusion-optimistic lower bound (dot/reduce/
    #                           collective/slice/gather/scatter/fusion ops)
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_detail: List[Tuple[str, str, float, float]] = field(default_factory=list)
    # (computation, opkind, bytes_once, multiplier)


def analyze(hlo: str) -> CostTotals:
    comps, entry = parse_hlo(hlo)
    totals = CostTotals()
    if entry is None:
        entry = next(iter(comps)) if comps else None
    if entry is None:
        return totals

    def op_operand_bytes(op: Op, comp: Computation) -> int:
        b = 0
        for o in op.operands:
            shapes = comp.shapes.get(o)
            if shapes:
                b += _bytes_of(shapes)
        return b

    SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "copy", "after-all", "partition-id"}
    MAJOR = {"dot", "dot-general", "convolution", "reduce", "fusion",
             "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
             "sort", "reduce-window"} | set(COLLECTIVES)

    def visit(name: str, mult: float, stack: Tuple[str, ...],
              count_bytes: bool):
        if name not in comps or name in stack or mult <= 0:
            return
        comp = comps[name]
        for op in comp.ops:
            if op.opcode in ("dot", "dot-general"):
                totals.flops += _dot_flops(op, comp) * mult
            if count_bytes and op.opcode not in SKIP_BYTES:
                b = (_bytes_of(op.result_shapes)
                     + op_operand_bytes(op, comp)) * mult
                totals.bytes += b
                if op.opcode in MAJOR:
                    totals.bytes_major += b
            if op.opcode in COLLECTIVES:
                b = _bytes_of(op.result_shapes)
                totals.coll_bytes[op.opcode] = (
                    totals.coll_bytes.get(op.opcode, 0.0) + b * mult)
                totals.coll_detail.append((name, op.opcode, b, mult))
            if op.opcode == "while":
                body = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                cond = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                tc = 1
                if cond and cond.group(1) in comps:
                    tc = _trip_count(comps[cond.group(1)])
                if body:
                    visit(body.group(1), mult * max(tc, 1), stack + (name,),
                          count_bytes)
            elif op.opcode == "fusion":
                callee = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
                if callee:
                    # flops descend into the fusion; bytes counted at boundary
                    visit(callee.group(1), mult, stack + (name,), False)
            elif op.opcode in ("call", "custom-call", "conditional"):
                for callee in _CALL_RE.findall(op.attrs):
                    visit(callee, mult, stack + (name,), count_bytes)

    visit(entry, 1.0, (), True)
    return totals
