import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment §MULTI-POD DRY-RUN).

For every (architecture x input shape x mesh) combination, lower + compile
the real step function on the production mesh with ShapeDtypeStruct inputs
(no allocation), then record:

* memory_analysis()  — proves the program fits per device,
* cost_analysis() + HLO reparse (repro.launch.hlo_cost) — FLOPs / bytes /
  collective bytes per device with loop multipliers,
* the roofline terms (§ROOFLINE) and the dominant bottleneck.

Usage:
    python -m repro.launch.dryrun --arch starcoder2-3b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all [--multi-pod both]
Results land in results/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import FLConfig, INPUT_SHAPES, InputShape, TrainConfig
from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.core.hota_step import HotaState, make_hota_train_step
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    abstract_serve_state, cache_specs_tree, input_specs, make_decode_step,
    make_prefill_step, param_specs_tree, serve_rules_for,
)
from repro.models.model import build_model
from repro.models.params import abstract_params, logical_axes, param_count
from repro.sharding.mesh_utils import fl_view
from repro.sharding.rules import TRAIN_RULES, spec_for
from repro.optim.adam import AdamState

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9
N_CLIENTS = 4
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

SERVE_ARCH_OVERRIDES = dict(compute_dtype="bfloat16", remat_policy="none")
TRAIN_ARCH_OVERRIDES = dict(compute_dtype="bfloat16",
                            remat_policy="nothing_saveable")


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def _sds(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def active_params(cfg) -> float:
    """Parameter count with MoE experts scaled to top-k/E (6·N_active·D)."""
    model = build_model(cfg)
    total = param_count({"t": model.trunk_specs(), "f": model.final_specs()})
    if cfg.moe is not None:
        from repro.models.moe import moe_specs
        expert_per_layer = sum(
            int(np.prod(s.shape)) for k, s in moe_specs(cfg).items()
            if k.startswith("w_"))
        n_layers_moe = cfg.n_layers
        inactive = expert_per_layer * n_layers_moe * (
            1.0 - cfg.moe.top_k / cfg.moe.n_experts)
        total -= inactive
    return float(total)


def hota_state_shardings(model, mesh, state_abs, n_out=None):
    """Full (FL + model axes) shardings for the HotaState pytree."""
    client_axes = tuple(a for a in mesh.axis_names
                        if a in ("pod", "cluster", "client"))

    def omega_spec(axes, shape):
        sp = spec_for(axes, TRAIN_RULES, shape, mesh)
        # params use CLIENT-major FSDP piece order (scatter-region
        # alignment — repro.core.hota.make_ota_gather)
        return P(*[("client", "cluster") if p_ == ("cluster", "client")
                   else p_ for p_ in sp])

    def tree_spec(specs_tree):
        ax = logical_axes(specs_tree)
        return jax.tree.map(
            lambda a, s: omega_spec(a, s.shape), ax, specs_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(i, (str, type(None))) for i in x))

    omega = {"final": tree_spec(model.final_specs()),
             "trunk": tree_spec(model.trunk_specs())}
    head_specs = model.head_specs(n_out)
    heads = jax.tree.map(
        lambda s: spec_for(("clients",) + s.axes, TRAIN_RULES,
                           (int(np.prod([mesh.devices.shape[
                               mesh.axis_names.index(a)] for a in client_axes])),)
                           + s.shape, mesh),
        head_specs, is_leaf=lambda x: hasattr(x, "axes"))
    sc = P(client_axes)
    specs = HotaState(
        omega=omega,
        opt=AdamState(step=P(), mu=omega, nu=omega),
        heads=heads,
        head_opt=AdamState(step=P(), mu=heads, nu=heads),
        p=sc, fgn_mu=sc, fgn_nu=sc, fgn_t=P(), f0=sc, step=P())
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _pick_microbatches(cfg, shape: InputShape, n_total_clients: int) -> int:
    """Smallest power-of-2 microbatch count keeping saved layer-boundary
    activations (L x B_mb x S x d x 2B) under ~4 GiB per device."""
    b_loc = shape.global_batch // n_total_clients
    budget = 4 * 2**30
    act = cfg.n_layers * b_loc * shape.seq_len * cfg.d_model * 2
    mb = 1
    while act / mb > budget and mb < b_loc:
        mb *= 2
    return mb


def lower_train(cfg, mesh_prod, shape: InputShape):
    cfg = cfg.replace(**TRAIN_ARCH_OVERRIDES)
    model = build_model(cfg)
    mesh = fl_view(mesh_prod, N_CLIENTS)
    n_total_clients = int(np.prod(
        [s for s, a in zip(mesh.devices.shape, mesh.axis_names)
         if a in ("pod", "cluster", "client")]))
    fl = FLConfig(n_clients=N_CLIENTS, ota_mode="scatter",
                  microbatches=_pick_microbatches(cfg, shape, n_total_clients))
    tcfg = TrainConfig(lr=3e-4, global_batch=shape.global_batch,
                       seq_len=shape.seq_len, fl=fl)
    init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
        model, mesh, fl, tcfg, loss_kind="lm")
    state_abs = jax.eval_shape(init_fn, jax.ShapeDtypeStruct((2,), jnp.uint32))
    state_sh = hota_state_shardings(model, mesh, state_abs)

    ins = input_specs(cfg, shape)
    tok_spec = ins["tokens"]
    client_axes = tuple(a for a in mesh.axis_names
                        if a in ("pod", "cluster", "client"))
    tok_sh = NamedSharding(mesh, P(client_axes))
    lab_sh = NamedSharding(mesh, P(client_axes))
    key_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)

    # donate the train state: params/opt buffers update in place
    jf = jax.jit(step_fn, in_shardings=(state_sh, tok_sh, lab_sh,
                                        NamedSharding(mesh, P())),
                 donate_argnums=(0,))
    lowered = jf.lower(state_abs, tok_spec, ins["labels"], key_abs)
    return lowered


def lower_serve(cfg, mesh, shape: InputShape):
    cfg = cfg.replace(**SERVE_ARCH_OVERRIDES)
    model = build_model(cfg)
    rules = serve_rules_for(shape)
    backbone_abs, head_abs, cache_abs = abstract_serve_state(model, shape)
    pspecs = param_specs_tree(model, rules, mesh, include_head=True)
    bb_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                         pspecs["backbone"], is_leaf=lambda x: isinstance(x, P))
    head_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs["head"],
                           is_leaf=lambda x: isinstance(x, P))
    ins = input_specs(cfg, shape)

    if shape.kind == "prefill":
        step = make_prefill_step(model, cache_len=shape.seq_len + 1)
        tok_axes = ("batch", "seq") if ins["tokens"].ndim == 2 else \
            ("batch", "seq", None)
        tok_sh = NamedSharding(mesh, spec_for(tok_axes, rules,
                                              ins["tokens"].shape, mesh))
        jf = jax.jit(step, in_shardings=(bb_sh, head_sh, tok_sh))
        return jf.lower(backbone_abs, head_abs, ins["tokens"])

    # decode
    step = make_decode_step(model)
    cache_sp = cache_specs_tree(model, cache_abs, rules, mesh)
    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cache_sp,
                            is_leaf=lambda x: isinstance(x, P))
    tok_sh = NamedSharding(mesh, spec_for(("batch", None), rules,
                                          ins["tokens"].shape, mesh))
    pos_sh = NamedSharding(mesh, spec_for(("batch",), rules,
                                          ins["positions"].shape, mesh))
    # donate the KV cache: the in-place update must not double-buffer
    jf = jax.jit(step, in_shardings=(bb_sh, head_sh, cache_sh, tok_sh, pos_sh),
                 donate_argnums=(2,))
    return jf.lower(backbone_abs, head_abs, cache_abs, ins["tokens"],
                    ins["positions"])


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR, force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{_mesh_tag(multi_pod)}"
    out_path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    result = {"arch": arch, "shape": shape_name,
              "mesh": _mesh_tag(multi_pod), "status": "?"}

    if shape_name == "long_500k" and not cfg.is_subquadratic:
        result["status"] = "skipped"
        result["reason"] = ("pure full-attention arch; long_500k requires "
                            "sub-quadratic attention (DESIGN.md §3.6)")
        _write(out_path, result)
        return result

    t0 = time.time()
    try:
        mesh_prod = make_production_mesh(multi_pod=multi_pod)
        n_dev = int(np.prod(mesh_prod.devices.shape))
        if shape.kind == "train":
            lowered = lower_train(cfg, mesh_prod, shape)
        else:
            lowered = lower_serve(cfg, mesh_prod, shape)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        }
        mem["total_bytes"] = (mem["argument_bytes"] + mem["output_bytes"]
                              + mem["temp_bytes"] - mem["alias_bytes"])

        totals = hlo_cost.analyze(compiled.as_text())
        ca = compiled.cost_analysis() or {}

        n_tok = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        mf = (6.0 if shape.kind == "train" else 2.0) * active_params(cfg) * n_tok
        compute_s = totals.flops / PEAK_FLOPS
        # memory term uses the fusion-optimistic (major-ops) byte count —
        # XLA:TPU fuses elementwise chains the CPU backend leaves separate;
        # the all-ops upper bound is recorded alongside.
        memory_s = totals.bytes_major / HBM_BW
        coll_s = sum(totals.coll_bytes.values()) / ICI_BW
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": coll_s}
        result.update({
            "status": "ok",
            "n_devices": n_dev,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": mem,
            "flops_per_device": totals.flops,
            "bytes_per_device": totals.bytes_major,
            "bytes_per_device_upper": totals.bytes,
            "memory_s_upper": totals.bytes / HBM_BW,
            "collective_bytes": {k: float(v) for k, v in totals.coll_bytes.items()},
            "collective_sites": sorted(
                [{"comp": c, "op": o, "bytes_once": b, "mult": m,
                  "total": b * m} for c, o, b, m in totals.coll_detail],
                key=lambda d: -d["total"])[:12],
            "roofline": {**terms,
                         "dominant": max(terms, key=terms.get).replace("_s", "")},
            "model_flops_global": mf,
            "hlo_flops_global": totals.flops * n_dev,
            "useful_flops_ratio": mf / max(totals.flops * n_dev, 1.0),
            "cost_analysis_raw_flops": float(ca.get("flops", 0.0)),
        })
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    _write(out_path, result)
    return result


def _write(path: str, obj: dict):
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["on", "off", "both"], default="off")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    args = ap.parse_args()

    archs = ([a for a in ARCH_IDS if a != "paper_mlp"]
             if args.arch == "all" else [ALIASES.get(args.arch, args.arch)])
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    pods = {"on": [True], "off": [False], "both": [False, True]}[args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in pods:
                r = run_pair(arch, shape, mp, args.out_dir, args.force)
                dom = r.get("roofline", {}).get("dominant", "-")
                print(f"{arch:20s} {shape:12s} {_mesh_tag(mp):10s} "
                      f"{r['status']:8s} dom={dom} "
                      f"mem={r.get('memory', {}).get('total_bytes', 0)/2**30:.2f}GiB "
                      f"compile={r.get('compile_s', 0)}s", flush=True)


if __name__ == "__main__":
    main()
