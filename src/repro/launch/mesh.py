"""Production meshes (assignment §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod: (16, 16) ("data", "model") = 256 chips.
Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips.

The HOTA trainer refines the data axis into ("cluster", "client") via
``repro.sharding.fl_view`` — same devices, same order (DESIGN.md §3.2).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(shape=(2, 2, 2), axes=("cluster", "client", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    import numpy as np
    devs = np.array(jax.devices())[: int(np.prod(shape))].reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def make_dist_scenario_mesh(n_clusters: int, n_clients: int,
                            n_scenario_devices=None):
    """2-D (scenario × client) mesh for distributed sweep banks
    (DESIGN.md §3.10): axes ("scenario", "cluster", "client").

    ``DistScenarioBank`` shard_maps scenario slices over the leading axis
    while each slice runs the full distributed HOTA round's client/cluster
    collectives on the trailing FL axes — one mesh, one compiled step for
    every scenario. Uses ``n_scenario_devices`` scenario rows (default:
    every visible device / (n_clusters·n_clients)). On CPU, force host
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count``.
    """
    import numpy as np
    devs = jax.devices()
    per_row = n_clusters * n_clients
    if n_scenario_devices is None:
        n_scenario_devices = len(devs) // per_row
    need = n_scenario_devices * per_row
    if n_scenario_devices < 1 or need > len(devs):
        raise ValueError(
            f"make_dist_scenario_mesh needs {per_row} devices per scenario "
            f"row × {n_scenario_devices} rows = {need}, but only "
            f"{len(devs)} devices are visible")
    return jax.sharding.Mesh(
        np.array(devs[:need]).reshape(n_scenario_devices, n_clusters,
                                      n_clients),
        ("scenario", "cluster", "client"))


def make_scenario_mesh(n_devices=None):
    """1-D ("scenario",) mesh for sharded sweep banks (DESIGN.md §3.8).

    ``ShardedScenarioBank`` lays its (S,)-batched states and ChannelParams
    bank over this axis while batch/PRNG inputs stay replicated (common
    random numbers preserved across shards). Defaults to every visible
    device; pass ``n_devices`` to take a prefix. On CPU, force multiple
    host devices with ``XLA_FLAGS=--xla_force_host_platform_device_count``.
    """
    import numpy as np
    devs = jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return jax.sharding.Mesh(np.array(devs), ("scenario",))
