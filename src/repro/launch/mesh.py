"""Production meshes (assignment §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod: (16, 16) ("data", "model") = 256 chips.
Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips.

The HOTA trainer refines the data axis into ("cluster", "client") via
``repro.sharding.fl_view`` — same devices, same order (DESIGN.md §3.2).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(shape=(2, 2, 2), axes=("cluster", "client", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    import numpy as np
    devs = np.array(jax.devices())[: int(np.prod(shape))].reshape(shape)
    return jax.sharding.Mesh(devs, axes)
