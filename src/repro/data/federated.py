"""Federated batching: per-(cluster, client) minibatch streams.

Produces stacked arrays of shape (C, N, B, ...) for the vmap simulator and
flat (C*N*B, ...) global batches (client-major) for the sharded dist path,
so the same underlying stream feeds both execution paths (used by the
sim-vs-dist equivalence tests).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class FederatedBatcher:
    def __init__(self, partitions: List[List[Dict[str, np.ndarray]]], batch: int, seed: int = 0):
        self.partitions = partitions
        self.batch = batch
        self.n_clusters = len(partitions)
        self.n_clients = len(partitions[0])
        self._rng = np.random.default_rng(seed)

    def next_stacked(self):
        """Returns x (C,N,B,d) float32, y (C,N,B) int32."""
        xs, ys = [], []
        for cluster in self.partitions:
            cx, cy = [], []
            for client in cluster:
                idx = self._rng.integers(0, client["x"].shape[0], size=self.batch)
                cx.append(client["x"][idx])
                cy.append(client["y"][idx])
            xs.append(np.stack(cx))
            ys.append(np.stack(cy))
        return np.stack(xs).astype(np.float32), np.stack(ys).astype(np.int32)

    def tasks(self) -> List[List[str]]:
        return [[cl["task"] for cl in cluster] for cluster in self.partitions]

    @staticmethod
    def flatten(x: np.ndarray) -> np.ndarray:
        """(C,N,B,...) -> (C*N*B, ...) client-major, matching the FL mesh
        device order (cluster major, then client, then within-client batch)."""
        return x.reshape((-1,) + x.shape[3:])
