"""Synthetic RadComDynamic.

The real RadComDynamic dataset [Jagannath & Jagannath, ICC'21] is not
available offline (DESIGN.md §2). This module generates a synthetic stand-in
with the same schema and the statistical structure the paper's experiments
rely on:

* 125,000 points, 256-dim features (the paper's shared net is FC(256,512)...),
* task 1 — modulation classification, 6 classes
  (amdsb, amssb, ask, bpsk, fmcw, pcw),
* task 2 — signal-type classification, 8 classes
  (AM radio, short-range, radar-altimeter, air-ground-MTI,
  airborne-detection, airborne-range, ground-mapping, +1 to total 8),
* task 3 — anomaly detection: SNR < -4 dB is anomalous (SNR is drawn per
  sample and baked into the features, so the task is learnable),
* tasks have *different difficulty* (class-dependent feature scale and
  noise), which is exactly the statistical heterogeneity FedGradNorm exists
  to balance.

Features are built from class-conditional random prototypes + per-class
nonlinear mixing + noise whose level differs per task, so the three tasks
train at different speeds — reproducing the paper's setting where task 1
(modulation) is initially slower (Fig. 2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

TASKS = ("modulation", "signal", "anomaly")
N_CLASSES = {"modulation": 6, "signal": 8, "anomaly": 2}
FEATURE_DIM = 256


@dataclass(frozen=True)
class RadComConfig:
    n_points: int = 125_000
    feature_dim: int = FEATURE_DIM
    seed: int = 1234
    snr_threshold_db: float = -4.0
    # per-task feature signal-to-noise (controls task difficulty / speed):
    # modulation is made the hardest (lowest scale), matching Fig. 2 where
    # task 1's loss moves slowest at the start.
    task_scale: Tuple[float, float, float] = (0.55, 1.0, 1.4)


def make_radcom_dataset(cfg: RadComConfig = RadComConfig()) -> Dict[str, np.ndarray]:
    """Returns dict with 'x' (n,256) float32 and one label array per task."""
    rng = np.random.default_rng(cfg.seed)
    n, d = cfg.n_points, cfg.feature_dim

    mod = rng.integers(0, N_CLASSES["modulation"], size=n)
    sig = rng.integers(0, N_CLASSES["signal"], size=n)
    snr_db = rng.uniform(-10.0, 16.0, size=n)
    anomaly = (snr_db < cfg.snr_threshold_db).astype(np.int64)

    # class prototypes living in disjoint-ish subspaces per task
    proto_mod = rng.normal(size=(N_CLASSES["modulation"], d)).astype(np.float32)
    proto_sig = rng.normal(size=(N_CLASSES["signal"], d)).astype(np.float32)
    mix = rng.normal(size=(d, d)).astype(np.float32) / np.sqrt(d)

    s_mod, s_sig, s_snr = cfg.task_scale
    x = (
        s_mod * proto_mod[mod]
        + s_sig * proto_sig[sig]
    ).astype(np.float32)
    # nonlinear mixing makes the tasks non-trivially coupled
    x = np.tanh(x @ mix) + 0.5 * x
    # SNR enters multiplicatively (low SNR -> attenuated + noisier signal),
    # making anomaly detection learnable from feature statistics.
    snr_lin = (10.0 ** (snr_db / 20.0)).astype(np.float32)[:, None]
    gain = snr_lin / (1.0 + snr_lin)
    x = x * (0.25 + s_snr * gain)
    x = x + rng.normal(size=(n, d)).astype(np.float32) * 0.35
    # normalize
    x = (x - x.mean(0)) / (x.std(0) + 1e-6)

    return {
        "x": x.astype(np.float32),
        "modulation": mod.astype(np.int64),
        "signal": sig.astype(np.int64),
        "anomaly": anomaly,
        "snr_db": snr_db.astype(np.float32),
    }


def client_partition(
    data: Dict[str, np.ndarray],
    n_clusters: int,
    n_clients: int,
    seed: int = 0,
    noniid_alpha: float = 0.5,
) -> List[List[Dict[str, np.ndarray]]]:
    """Partition the dataset across C clusters x N clients, non-iid.

    Client i of every cluster owns task TASKS[i % 3] (paper: tasks within a
    cluster are distinct). Non-iid-ness: each client's sample pool is drawn
    with Dirichlet(alpha) class skew over its own task's classes.
    """
    rng = np.random.default_rng(seed)
    n = data["x"].shape[0]
    perm = rng.permutation(n)
    shards = np.array_split(perm, n_clusters * n_clients)

    out: List[List[Dict[str, np.ndarray]]] = []
    k = 0
    for c in range(n_clusters):
        cluster_clients = []
        for i in range(n_clients):
            task = TASKS[i % len(TASKS)]
            idx = shards[k]
            k += 1
            labels = data[task][idx]
            n_cls = N_CLASSES[task]
            # Dirichlet reweighting for non-iid class skew
            weights = rng.dirichlet([noniid_alpha] * n_cls)
            p = weights[labels]
            p = p / p.sum()
            take = rng.choice(idx, size=len(idx), replace=True, p=p)
            cluster_clients.append({
                "x": data["x"][take],
                "y": data[task][take],
                "task": task,
                "n_classes": n_cls,
            })
        out.append(cluster_clients)
    return out
