from repro.data.radcom import RadComConfig, TASKS, make_radcom_dataset, client_partition
from repro.data.lm import synthetic_lm_batches
from repro.data.federated import FederatedBatcher

__all__ = [
    "RadComConfig", "TASKS", "make_radcom_dataset", "client_partition",
    "synthetic_lm_batches", "FederatedBatcher",
]
