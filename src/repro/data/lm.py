"""Synthetic language-model token pipeline.

For the assigned LM architectures there is no offline corpus; training
examples/smoke tests use a synthetic Zipf-distributed token stream with
deterministic per-step generation (pure function of (seed, step)), which is
enough to exercise the full training path (loss decreases as the model
learns the marginal/bigram statistics).
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np


def _zipf_probs(vocab: int, s: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-s)
    return (p / p.sum()).astype(np.float64)


def synthetic_lm_batches(
    vocab_size: int,
    batch: int,
    seq_len: int,
    seed: int = 0,
    zipf_s: float = 1.1,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yields (tokens, labels) with a learnable markov-ish structure."""
    probs = _zipf_probs(min(vocab_size, 4096), zipf_s)
    support = len(probs)
    step = 0
    while True:
        rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
        base = rng.choice(support, size=(batch, seq_len + 1), p=probs)
        # inject bigram structure: with prob .5, next token = f(prev)
        follow = (base[:, :-1] * 7 + 3) % support
        coin = rng.random((batch, seq_len)) < 0.5
        seq = base.copy()
        seq[:, 1:] = np.where(coin, follow, base[:, 1:])
        tokens = seq[:, :-1].astype(np.int32)
        labels = seq[:, 1:].astype(np.int32)
        yield tokens, labels
        step += 1
