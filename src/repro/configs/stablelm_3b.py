"""StableLM-3B (stablelm-2 family) [hf:stabilityai/stablelm-2-1_6b] —
dense, MHA-as-GQA (kv=32), RoPE, full attention."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
    vocab_size=50304, rope_theta=1e4,
    source="hf:stabilityai/stablelm-2-1_6b",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=384,
    vocab_size=512, attn_block_q=16, attn_block_kv=16,
    remat_policy="none", compute_dtype="float32", max_seq_len=128)
