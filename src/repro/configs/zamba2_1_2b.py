"""Zamba2-1.2B [arXiv:2411.15242] — hybrid: Mamba2 backbone + one *shared*
attention block applied every 6 layers (weight reuse = the Zamba trick).
ssm_state=64. The shared attn uses sliding window 4096 in long-context
serving (TPU adaptation, DESIGN.md §3.6)."""
from repro.common.config import ModelConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, sliding_window=4096,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256, n_groups=1),
    hybrid=HybridConfig(attn_every=6, shared_attn_n_heads=32,
                        shared_attn_n_kv=32),
    source="arXiv:2411.15242",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=5, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=512, sliding_window=32,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                  chunk_size=16, n_groups=1),
    hybrid=HybridConfig(attn_every=2, shared_attn_n_heads=4,
                        shared_attn_n_kv=2),
    attn_block_q=16, attn_block_kv=16,
    remat_policy="none", compute_dtype="float32", max_seq_len=128)
