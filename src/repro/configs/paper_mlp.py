"""The paper's own shared network (Table I): 5-layer FC MLP, 256-dim
RadComDynamic features, personalized linear heads per task."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="paper-mlp", family="mlp", d_model=256, vocab_size=8,
    source="HOTA-FedGradNorm Table I",
)

SMOKE_CONFIG = CONFIG
