"""Assigned architecture configs (public-literature pool) + the paper's MLP.

Every config cites its source. ``get_config(name)`` returns the full-size
ModelConfig; ``get_smoke_config(name)`` a reduced same-family variant
(≤2 layers + the family's minimum structural multiple, d_model ≤ 512,
≤4 experts) for CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.common.config import ModelConfig

ARCH_IDS: List[str] = [
    "starcoder2_3b",
    "stablelm_3b",
    "musicgen_medium",
    "phi3_vision_4_2b",
    "gemma3_12b",
    "zamba2_1_2b",
    "phi3_5_moe_42b",
    "xlstm_1_3b",
    "mixtral_8x22b",
    "qwen2_5_14b",
    "paper_mlp",
]

# CLI-friendly aliases matching the assignment sheet
ALIASES: Dict[str, str] = {
    "starcoder2-3b": "starcoder2_3b",
    "stablelm-3b": "stablelm_3b",
    "musicgen-medium": "musicgen_medium",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "gemma3-12b": "gemma3_12b",
    "zamba2-1.2b": "zamba2_1_2b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "xlstm-1.3b": "xlstm_1_3b",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen2.5-14b": "qwen2_5_14b",
    "paper-mlp": "paper_mlp",
}


def _module(name: str):
    name = ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE_CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_IDS}
