"""Phi-3.5-MoE-42B (6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct] —
16 experts, top-2 routing, GQA kv=8, d_ff=6400 per expert."""
from repro.common.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab_size=32064, rope_theta=1e4,
    moe=MoEConfig(n_experts=16, top_k=2, capacity_factor=1.25,
                  aux_loss_weight=0.01),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=192,
    vocab_size=512, moe=MoEConfig(n_experts=4, top_k=2),
    attn_block_q=16, attn_block_kv=16,
    remat_policy="none", compute_dtype="float32", max_seq_len=128)
