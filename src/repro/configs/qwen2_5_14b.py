"""Qwen2.5-14B [hf:Qwen/Qwen2.5-0.5B family] — dense, GQA kv=8, QKV bias,
full attention, 152k vocab."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
    vocab_size=152064, rope_theta=1e6, qkv_bias=True,
    source="hf:Qwen/Qwen2.5-0.5B",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=160, n_heads=8, n_kv_heads=2, d_ff=448,
    vocab_size=512, attn_block_q=16, attn_block_kv=16,
    remat_policy="none", compute_dtype="float32", max_seq_len=128)
