"""Phi-3-vision-4.2B [hf:microsoft/Phi-3-vision-128k-instruct] — phi3-mini
text backbone + CLIP vision tower. The vision encoder + projector are the
stubbed frontend (assignment carve-out): input_specs supplies projected
patch embeddings of shape (B, S, d_model); this module is the 32-layer
decoder consuming interleaved text/image embeddings."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="dense", modality="vision",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32064, rope_theta=1e4,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=512, attn_block_q=16, attn_block_kv=16,
    remat_policy="none", compute_dtype="float32", max_seq_len=128)
