"""xLSTM-1.3B [arXiv:2405.04517] — sLSTM + mLSTM blocks, 4 heads,
d_ff=0 (blocks carry their own up/down projections). 7:1 mLSTM:sLSTM."""
from repro.common.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="xlstm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=8, proj_factor_mlstm=2.0,
                      proj_factor_slstm=1.333),
    source="arXiv:2405.04517",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
    vocab_size=512, xlstm=XLSTMConfig(slstm_every=2),
    remat_policy="none", compute_dtype="float32", max_seq_len=128)
