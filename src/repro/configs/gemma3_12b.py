"""Gemma-3-12B [hf:google/gemma-3-1b-pt family] — dense, GQA (kv=8),
5:1 local:global attention pattern (local window 1024, global full),
dual RoPE theta (10k local / 1M global), 128k context, 262k vocab."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
    vocab_size=262144, head_dim=240,
    rope_theta=1e4, rope_theta_global=1e6,
    local_global_ratio=5, local_window=1024,
    source="hf:google/gemma-3-1b-pt",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=6, d_model=120, n_heads=4, n_kv_heads=2, d_ff=256, head_dim=30,
    vocab_size=512, local_global_ratio=2, local_window=32,
    attn_block_q=16, attn_block_kv=16,
    remat_policy="none", compute_dtype="float32", max_seq_len=128)
