"""MusicGen-medium [arXiv:2306.05284] — decoder-only transformer over
EnCodec tokens (audio modality). The EnCodec tokenizer/codec is the stubbed
frontend (assignment carve-out): input_specs supplies token ids / frame
embeddings; this module is the 48-layer decoder that consumes them."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="dense", modality="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab_size=2048, rope_theta=1e4, mlp_act="gelu",
    source="arXiv:2306.05284",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=256, attn_block_q=16, attn_block_kv=16,
    remat_policy="none", compute_dtype="float32", max_seq_len=128)
