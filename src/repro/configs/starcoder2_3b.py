"""StarCoder2-3B [arXiv:2402.19173] — dense, GQA (kv=2), RoPE.

The real model uses sliding-window attention (w=4096), which we keep: it is
what makes long_500k decode feasible for this arch (DESIGN.md §3.6).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12288,
    vocab_size=49152, rope_theta=1e5, sliding_window=4096,
    mlp_act="gelu",                      # starcoder2 uses gelu MLP
    source="arXiv:2402.19173",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=192, n_heads=6, n_kv_heads=2, d_ff=512,
    vocab_size=512, sliding_window=32, attn_block_q=16, attn_block_kv=16,
    remat_policy="none", compute_dtype="float32", max_seq_len=128)
