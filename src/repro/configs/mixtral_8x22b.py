"""Mixtral-8x22B [arXiv:2401.04088] — 8 experts top-2, GQA kv=8, SWA 4096.
(The 8x7B paper describes SWA; kept here as the assignment notes — it is
also what qualifies this arch for long_500k decode.)"""
from repro.common.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="dense",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32768, rope_theta=1e6, sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25,
                  aux_loss_weight=0.01),
    source="arXiv:2401.04088",
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=96, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=512, sliding_window=32, moe=MoEConfig(n_experts=4, top_k=2),
    attn_block_q=16, attn_block_kv=16,
    remat_policy="none", compute_dtype="float32", max_seq_len=128)
