"""The paper's contribution: HOTA-FedGradNorm.

* channel.py      — traced ChannelParams pytree (the scenario axis)
* ota.py          — fading-MAC channel model + OTA aggregation (eqs. 3-10)
* fedgradnorm.py  — channel-sparsified FedGradNorm (Alg. 2, eqs. 5-6)
* sim.py          — paper-scale faithful simulator (Alg. 1; vmap C x N)
* sweep.py        — ScenarioBank / ShardedScenarioBank: multi-scenario
                    sweeps, one jit (vmap'd or scenario-sharded)
* hota.py         — distributed machinery: custom-vjp OTA-FSDP gather
* hota_slab.py    — slab-native whole-model gather (zero-copy, §3.10)
* hota_step.py    — the production shard_map training step
* power.py        — eq. (4): expected transmit power + H_th calibration
"""
from repro.core.channel import (
    ChannelParams, channel_params, cluster_channel, stack_channel_params,
)
from repro.core.fedgradnorm import (
    FGNState, fgn_init, fgn_update, fgn_update_gated, fgn_grad_p,
    fgn_targets, fgrad_value, masked_tree_norm,
)
from repro.core.ota import (
    final_layer_masks_packed, gain_mask, ota_aggregate_leaf,
    ota_aggregate_packed, ota_aggregate_tree, packed_gain_bits,
    power_allocation, sample_gain, transmit_signal, tree_channel,
)
from repro.core.sim import HotaSim, SimState, masked_cls_loss
from repro.core.sweep import DistScenarioBank, ScenarioBank, \
    ShardedScenarioBank
from repro.core.hota import (
    OTACtx, build_axes_registry, make_ota_gather, make_packed_final_gather,
    make_param_hook, packed_final_norm,
)
from repro.core.hota_slab import (
    make_packed_omega_gather, packed_omega_key, sectioned_final_norm,
)
from repro.core.hota_step import HotaState, StepParts, \
    make_hota_step_parts, make_hota_train_step
from repro.core.power import (
    calibrate_h_threshold, expected_transmit_power, pass_rate,
)

__all__ = [
    "ChannelParams", "channel_params", "cluster_channel",
    "stack_channel_params", "ScenarioBank", "ShardedScenarioBank",
    "FGNState", "fgn_init", "fgn_update", "fgn_update_gated", "fgn_grad_p",
    "fgn_targets", "fgrad_value", "masked_tree_norm", "gain_mask",
    "final_layer_masks_packed", "ota_aggregate_leaf", "ota_aggregate_packed",
    "ota_aggregate_tree", "packed_gain_bits", "power_allocation",
    "sample_gain", "transmit_signal", "tree_channel", "HotaSim", "SimState",
    "masked_cls_loss", "OTACtx", "build_axes_registry", "make_ota_gather",
    "make_packed_final_gather", "make_param_hook", "packed_final_norm",
    "make_packed_omega_gather", "packed_omega_key", "sectioned_final_norm",
    "HotaState", "StepParts", "make_hota_step_parts", "make_hota_train_step",
    "DistScenarioBank",
    "calibrate_h_threshold", "expected_transmit_power", "pass_rate",
]
