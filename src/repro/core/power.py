"""Average transmit-power constraint (paper eq. 4) and H_th calibration.

Under channel inversion (eq. 3), the IS transmits x = Σ_i (p_i/H)·g_i on
entries with |H|² ≥ H_th. For H ~ N(0, σ²) the per-entry expected power of
one client's signal is

    E[ p² g² / H² ; |H|² ≥ t ]  =  p² E[g²] · (2/σ²) ( φ(a)/a − Q(a) ),
    a = √t / σ,   φ = std normal pdf,   Q(a) = 1 − Φ(a),

(by parts: ∫_a^∞ x⁻²φ(x)dx = φ(a)/a − Q(a)). The threshold exists exactly
because E → ∞ as t → 0 (inverting deep fades is unboundedly expensive) —
the paper's motivation for sparsification. ``calibrate_h_threshold`` solves
eq. (4) for H_th given a power budget P̄ by bisection (E is monotone ↓ in t).

The paper fixes H_th = 3.2e-2 empirically; with σ²=1 and unit-variance
gradients that corresponds to P̄/entry ≈ 1.27 per unit weight² (validated
by Monte Carlo in tests/test_power.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


SQRT2 = math.sqrt(2.0)


def _phi(x):
    return jnp.exp(-0.5 * x * x) / math.sqrt(2.0 * math.pi)


def _q(x):
    return 0.5 * jax.scipy.special.erfc(x / SQRT2)


def inv_h2_truncated_mean(h_th, sigma2):
    """E[ 1/H² ; |H|² ≥ H_th ] for H ~ N(0, σ²)."""
    a = jnp.sqrt(h_th / sigma2)
    return (2.0 / sigma2) * (_phi(a) / jnp.maximum(a, 1e-12) - _q(a))


def expected_entry_power(p_weight, grad_second_moment, h_th, sigma2):
    """Per-entry E‖x‖² for one client's channel-inverted signal (eq. 3/4)."""
    return (p_weight ** 2) * grad_second_moment * inv_h2_truncated_mean(
        h_th, sigma2)


def expected_transmit_power(p_weights, grad_second_moments, h_th, sigma2,
                            n_entries):
    """Cluster-level E‖x_k^(l)‖² ≈ n_entries · Σ_i per-entry power
    (independent-entry approximation; cross terms vanish for zero-mean,
    independently-faded entries)."""
    per = sum(expected_entry_power(p, g2, h_th, sigma2)
              for p, g2 in zip(p_weights, grad_second_moments))
    return n_entries * per


def calibrate_h_threshold(power_budget, p_weights, grad_second_moments,
                          sigma2, n_entries, *, tol=1e-9, iters=80):
    """Solve eq. (4): smallest H_th whose expected power ≤ P̄ (bisection —
    expected power is monotone decreasing in the threshold)."""
    lo, hi = jnp.asarray(1e-12), jnp.asarray(1e3)

    def body(_, bounds):
        lo, hi = bounds
        mid = jnp.sqrt(lo * hi)          # geometric: spans decades
        p = expected_transmit_power(p_weights, grad_second_moments, mid,
                                    sigma2, n_entries)
        too_hot = p > power_budget
        return (jnp.where(too_hot, mid, lo), jnp.where(too_hot, hi, mid))

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


def pass_rate(h_th, sigma2):
    """P(|H|² ≥ H_th) = 2Q(√H_th/σ) — the fraction of entries transmitted
    (the paper's implicit sparsification level)."""
    return 2.0 * _q(jnp.sqrt(h_th / sigma2))
