"""HOTA-FedGradNorm, distributed (the production integration — DESIGN.md §3.1).

The paper's two-level aggregation is attached to the FSDP parameter gather
as a ``jax.custom_vjp``:

    forward : shard --all-gather over ("cluster","client")--> full param
              (= PS -> IS -> client broadcast, Alg. 1 lines 3-6)
    backward: per-client full grad
              --weighted psum over "client"-->        x^(l) at the IS (eq. 3)
              --masked psum over ("pod","cluster")--> MAC superposition (eq. 8)
              + AWGN, / (|M|·N)                       PS estimate     (eq. 10)
              --slice own shard-->                    FSDP reduce-scatter

so autodiff of any scan-stacked backbone routes every parameter gradient
through the paper's aggregation, one layer at a time (no full per-client
gradient is ever materialized). The shard_map is *manual* over the FL axes
(pod/cluster/client) and *auto* over "model": tensor-parallel sharding
inside each client remains GSPMD's job.

Channel keys: fold(step_key, class_salt, *layer_tags, leaf_idx) then, in
the backward, fold(cluster) — one i.i.d. gain per parameter entry per
cluster per iteration (paper Sec. III-A), reproducible across the FGN
phase (mask in eq. 5) and the transmission (eq. 8).

Model code cooperates through an optional ``param_hook(subtree, klass,
*tags)`` called right before each layer's parameters are used; without a
hook the models behave as plain (non-FL) networks.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.channel import ChannelParams
from repro.models.model import Model, lm_loss
from repro.models.params import logical_axes
from repro.optim.adam import adam_init, adam_update

CLIENT_AXIS = "client"

KLASS_SALT = {
    "embed": 1, "layers": 2, "final": 3, "mamba": 4,
    "shared_attn": 5, "shared_mlp": 6, "mlstm": 7, "slstm": 8,
}


def _is_axes(x):
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def _strip_layer(axes: tuple) -> tuple:
    return tuple(a for a in axes if a != "layer")


def _fsdp_axis(axes: tuple) -> int:
    stripped = _strip_layer(axes)
    return stripped.index("embed") if "embed" in stripped else -1


def _zero_cot(x):
    if jnp.issubdtype(x.dtype, jnp.floating):
        return jnp.zeros_like(x)
    return np.zeros(x.shape, jax.dtypes.float0)


def _axis_size(name):
    """jax.lax.axis_size is newer jax; psum of a literal 1 constant-folds
    to the axis size on older versions."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


class OTACtx(NamedTuple):
    """Traced context for the OTA backward. Passed as explicit custom_vjp
    arguments (closures over tracers break under scan)."""
    p_weight: jax.Array      # this client's FedGradNorm weight p_k^(l,i)
    key: jax.Array           # folded key for this leaf
    sigma2: jax.Array        # this cluster's channel variance σ_l²
    h_th: jax.Array          # threshold H_th
    noise_std: jax.Array     # AWGN std
    ota_on: jax.Array        # 1.0 = fading MAC; 0.0 = error-free baseline


def fold_tags(key: jax.Array, klass: str, tags, leaf_idx: int) -> jax.Array:
    k = jax.random.fold_in(key, KLASS_SALT[klass])
    for t in tags:
        k = jax.random.fold_in(k, t)
    return jax.random.fold_in(k, leaf_idx)


def cluster_index(cluster_axes: Tuple[str, ...]) -> jax.Array:
    cidx = jax.lax.axis_index(cluster_axes[0])
    for a in cluster_axes[1:]:
        cidx = cidx * _axis_size(a) + jax.lax.axis_index(a)
    return cidx


def channel_mask_for(key: jax.Array, shape, sigma2, h_th, ota_on,
                     cluster_axes) -> jax.Array:
    """The mask M_k^(l) this device's cluster sees for one leaf (eq. 7)."""
    ckey = jax.random.fold_in(key, cluster_index(cluster_axes))
    h = jax.random.normal(ckey, shape, jnp.float32) * jnp.sqrt(sigma2)
    return jnp.logical_or((h * h) >= h_th, ota_on < 0.5)


REGION_SALT = 0xC0


def region_mask_key(leaf_key: jax.Array, region) -> jax.Array:
    """Key for one scatter region's channel draw (scatter mode). Region
    indices partition the FSDP axis client-major; the full-tensor mask is
    the concatenation of region masks (see full_transmission_mask)."""
    return jax.random.fold_in(jax.random.fold_in(leaf_key, REGION_SALT),
                              region)


def full_transmission_mask(leaf_key, shape, axis, n_regions, sigma2, h_th,
                           ota_on, cluster_axes, scatter_mode: bool):
    """The full-tensor mask M_k^(l) exactly as the transmission draws it —
    used by the FGN phase (eq. 5) so F_grad sees the channel the MAC will
    apply. In scatter mode, sharded leaves draw per-region; replicated
    leaves (and all leaves in naive mode) draw whole-tensor."""
    if not scatter_mode or axis < 0:
        return channel_mask_for(leaf_key, shape, sigma2, h_th, ota_on,
                                cluster_axes)
    sub = list(shape)
    assert sub[axis] % n_regions == 0, (shape, axis, n_regions)
    sub[axis] //= n_regions
    pieces = [
        channel_mask_for(region_mask_key(leaf_key, r), tuple(sub), sigma2,
                         h_th, ota_on, cluster_axes)
        for r in range(n_regions)
    ]
    return jnp.concatenate(pieces, axis=axis)


def make_ota_gather(data_axes: Tuple[str, ...],
                    cluster_axes: Tuple[str, ...],
                    n_clients: int, n_shards: int, compute_dtype,
                    mode: str = "scatter"):
    """Build the custom-vjp FSDP gather for one mesh topology.

    ``data_axes`` MUST be ("client", "cluster") — client-major piece order
    is what makes the scatter pipeline's regions align with FSDP pieces.

    axis >= 0 leaves are FSDP-sharded on that dim; axis == -1 leaves are
    replicated over the data axes (identity fwd, full-size OTA bwd).

    Backward = Algorithm 1's aggregation, two implementations:

    * mode="naive"   (paper-literal): weighted psum over "client" (LAN,
      eq. 3) at FULL tensor size, masked psum over clusters (MAC, eq. 8)
      at full size, estimate (eq. 10), slice own shard. 2 full-size
      all-reduces + a full-size count per parameter per round.
    * mode="scatter" (optimized, identical math): psum_scatter the
      weighted gradients over "client" — the LAN sum arrives pre-split
      into per-client regions (1/N size); per-region channel masks; the
      MAC psum over clusters runs on regions; slice my cluster's sub-piece.
      ~3x fewer collective bytes, no full-size intermediate.

    Round semantics under gradient accumulation: channel keys fold only
    (step, layer, leaf) — masks and AWGN are IDENTICAL across microbatches,
    so averaging microbatch estimates equals one MAC transmission of the
    round-averaged x^(l) (eq. 8 applied once per iteration k).
    """
    assert data_axes[0] == CLIENT_AXIS, data_axes

    @partial(jax.custom_vjp, nondiff_argnums=(0,))
    def ota_gather(axis: int, shard, ctx: OTACtx):
        if axis >= 0:
            full = jax.lax.all_gather(shard, data_axes, axis=axis, tiled=True)
        else:
            full = shard
        return full.astype(compute_dtype)

    def _fwd(axis, shard, ctx):
        return ota_gather(axis, shard, ctx), (ctx,)

    def _estimate(y, cnt, z, n):
        return jnp.where(cnt > 0, (y + z) / (jnp.maximum(cnt, 1.0) * n), 0.0)

    def _bwd(axis, res, g):
        (ctx,) = res
        g = g.astype(jnp.float32)

        if mode == "scatter" and axis >= 0:
            # LAN via reduce-scatter: region i of x^(l) lands on client i
            x_reg = jax.lax.psum_scatter(ctx.p_weight * g, CLIENT_AXIS,
                                         scatter_dimension=axis, tiled=True)
            my_region = jax.lax.axis_index(CLIENT_AXIS)
            mkey = region_mask_key(ctx.key, my_region)
            mask = channel_mask_for(mkey, x_reg.shape, ctx.sigma2, ctx.h_th,
                                    ctx.ota_on, cluster_axes)
            cnt = jax.lax.psum(mask.astype(jnp.float32), cluster_axes)
            y = jax.lax.psum(jnp.where(mask, x_reg, 0.0), cluster_axes)
            z = (jax.random.normal(
                jax.random.fold_in(mkey, 0xBEEF), x_reg.shape, jnp.float32)
                * ctx.noise_std * ctx.ota_on)
            ghat_reg = _estimate(y, cnt, z, n_clients)
            # my FSDP piece = my cluster's sub-slice of my region
            cidx = jax.lax.axis_index(data_axes[1])
            for a in data_axes[2:]:
                cidx = cidx * _axis_size(a) + jax.lax.axis_index(a)
            n_sub = n_shards // n_clients   # CLIENT_AXIS size by construction
            sz = ghat_reg.shape[axis] // n_sub
            my = jax.lax.dynamic_slice_in_dim(ghat_reg, cidx * sz, sz, axis)
            return (my, jax.tree.map(_zero_cot, ctx))

        # naive / replicated-leaf path: full-size psums
        x = jax.lax.psum(ctx.p_weight * g, CLIENT_AXIS)
        mask = channel_mask_for(ctx.key, g.shape, ctx.sigma2, ctx.h_th,
                                ctx.ota_on, cluster_axes)
        cnt = jax.lax.psum(mask.astype(jnp.float32), cluster_axes)
        y = jax.lax.psum(jnp.where(mask, x, 0.0), cluster_axes)
        z = (jax.random.normal(jax.random.fold_in(ctx.key, 0xBEEF), g.shape,
                               jnp.float32) * ctx.noise_std * ctx.ota_on)
        ghat = _estimate(y, cnt, z, n_clients)
        if axis >= 0:
            me = jax.lax.axis_index(data_axes[0])
            for a in data_axes[1:]:
                me = me * _axis_size(a) + jax.lax.axis_index(a)
            sz = g.shape[axis] // n_shards
            ghat = jax.lax.dynamic_slice_in_dim(ghat, me * sz, sz, axis)
        return (ghat, jax.tree.map(_zero_cot, ctx))

    ota_gather.defvjp(_fwd, _bwd)
    return ota_gather


# --------------------------------------------------------------------------
# axes registry + param hook
# --------------------------------------------------------------------------

def build_axes_registry(model: Model) -> Dict[str, List[tuple]]:
    """klass -> list of per-leaf logical-axes tuples ('layer' dims stripped),
    in the flatten order the hook will see."""
    cfg = model.cfg
    ax = logical_axes(model.trunk_specs())
    reg: Dict[str, List[tuple]] = {}

    def leaves_of(subtree):
        return [t for t in jax.tree.leaves(subtree, is_leaf=_is_axes)]

    if cfg.family == "mlp":
        reg["layers"] = []      # mlp trunk hooked per-fc via "embed"? no:
        # the MLP trunk is hooked as one flat subtree under "embed" klass?
        # Simpler: treat the whole mlp trunk as klass "layers" (single call).
        reg["layers"] = leaves_of(ax)
    elif cfg.family in ("dense", "moe"):
        reg["embed"] = [ax["embed"]]
        key = "layers" if "layers" in ax else "global"
        reg["layers"] = leaves_of(ax[key] if "layers" in ax else ax["global"])
    elif cfg.family == "hybrid":
        reg["embed"] = [ax["embed"]]
        reg["mamba"] = leaves_of(ax["mamba"])
        reg["shared_attn"] = leaves_of(ax["shared_attn"])
        reg["shared_mlp"] = leaves_of(ax["shared_mlp"])
    elif cfg.family == "xlstm":
        reg["embed"] = [ax["embed"]]
        reg["mlstm"] = leaves_of(ax["mlstm"])
        reg["slstm"] = leaves_of(ax["slstm"])
    elif cfg.family == "ssm":
        reg["embed"] = [ax["embed"]]
        reg["layers"] = leaves_of(ax["layers"])
    reg["final"] = leaves_of(logical_axes(model.final_specs()))
    return reg


def make_param_hook(gather, registry: Dict[str, List[tuple]],
                    base_key: jax.Array, p_weight, chan: ChannelParams):
    """hook(subtree, klass, *tags) -> gathered/OTA-wrapped subtree.

    ``chan`` is this cluster's traced channel view (scalar σ² — see
    ``repro.core.channel.cluster_channel``); its knobs become the OTACtx
    consts, so sweeping scenarios never re-traces the gather."""
    consts = dict(
        p_weight=jnp.asarray(p_weight, jnp.float32),
        sigma2=jnp.asarray(chan.sigma2, jnp.float32),
        h_th=jnp.asarray(chan.h_threshold, jnp.float32),
        noise_std=jnp.asarray(chan.noise_std, jnp.float32),
        ota_on=jnp.asarray(chan.ota_on, jnp.float32),
    )

    def hook(lp, klass, *tags):
        leaves, treedef = jax.tree.flatten(lp)
        axes = registry[klass]
        assert len(leaves) == len(axes), (klass, len(leaves), len(axes))
        out = []
        for i, leaf in enumerate(leaves):
            ctx = OTACtx(key=fold_tags(base_key, klass, tags, i), **consts)
            out.append(gather(_fsdp_axis(axes[i]), leaf, ctx))
        return jax.tree.unflatten(treedef, out)
    return hook


def identity_hook(lp, klass, *tags):
    return lp


def shard_specs_for(model: Model, mesh) -> Any:
    """Manual PartitionSpecs (FL axes only) for the trunk+final shards."""
    from jax.sharding import PartitionSpec as P
    data_axes = _mesh_data_axes(mesh)

    def spec(axes):
        # position of embed in the FULL (unstripped) axes tuple
        if "embed" in axes:
            full_i = axes.index("embed")
            parts = [None] * len(axes)
            parts[full_i] = data_axes if len(data_axes) > 1 else data_axes[0]
            return P(*parts)
        return P()

    ax = {"trunk": logical_axes(model.trunk_specs()),
          "final": logical_axes(model.final_specs())}
    return jax.tree.map(spec, ax, is_leaf=_is_axes)


def _mesh_data_axes(mesh) -> Tuple[str, ...]:
    """FSDP axes in CLIENT-major order (scatter-region alignment)."""
    assert "client" in mesh.axis_names and "cluster" in mesh.axis_names
    return ("client", "cluster")


def _mesh_cluster_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "cluster"))


def _mesh_client_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "cluster", "client"))

