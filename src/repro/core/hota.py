"""HOTA-FedGradNorm, distributed (the production integration — DESIGN.md §3.1).

The paper's two-level aggregation is attached to the FSDP parameter gather
as a ``jax.custom_vjp``:

    forward : shard --all-gather over ("cluster","client")--> full param
              (= PS -> IS -> client broadcast, Alg. 1 lines 3-6)
    backward: per-client full grad
              --weighted psum over "client"-->        x^(l) at the IS (eq. 3)
              --masked psum over ("pod","cluster")--> MAC superposition (eq. 8)
              + AWGN, / (|M|·N)                       PS estimate     (eq. 10)
              --slice own shard-->                    FSDP reduce-scatter

so autodiff of any scan-stacked backbone routes every parameter gradient
through the paper's aggregation, one layer at a time (no full per-client
gradient is ever materialized). The shard_map is *manual* over the FL axes
(pod/cluster/client) and *auto* over "model": tensor-parallel sharding
inside each client remains GSPMD's job.

Channel keys: fold(step_key, class_salt, *layer_tags, leaf_idx) then, in
the backward, fold(cluster) — one i.i.d. gain per parameter entry per
cluster per iteration (paper Sec. III-A), reproducible across the FGN
phase (mask in eq. 5) and the transmission (eq. 8).

Model code cooperates through an optional ``param_hook(subtree, klass,
*tags)`` called right before each layer's parameters are used; without a
hook the models behave as plain (non-FL) networks.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.ota import HOTA_MASK_SALT
from repro.common.flatpack import check_tree_matches_packer, packer_for
from repro.core.channel import ChannelParams
from repro.kernels.ota_channel.ops import _ota_channel_impl
from repro.kernels.slab import flat_to_slab, on_tpu
from repro.models.model import Model, lm_loss
from repro.models.params import logical_axes
from repro.optim.adam import adam_init, adam_update

CLIENT_AXIS = "client"

KLASS_SALT = {
    "embed": 1, "layers": 2, "final": 3, "mamba": 4,
    "shared_attn": 5, "shared_mlp": 6, "mlstm": 7, "slstm": 8,
}


def _is_axes(x):
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def _strip_layer(axes: tuple) -> tuple:
    return tuple(a for a in axes if a != "layer")


def _fsdp_axis(axes: tuple) -> int:
    stripped = _strip_layer(axes)
    return stripped.index("embed") if "embed" in stripped else -1


def _zero_cot(x):
    if jnp.issubdtype(x.dtype, jnp.floating):
        return jnp.zeros_like(x)
    return np.zeros(x.shape, jax.dtypes.float0)


def _axis_size(name):
    """jax.lax.axis_size is newer jax; psum of a literal 1 constant-folds
    to the axis size on older versions."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


class OTACtx(NamedTuple):
    """Traced context for the OTA backward. Passed as explicit custom_vjp
    arguments (closures over tracers break under scan)."""
    p_weight: jax.Array      # this client's FedGradNorm weight p_k^(l,i)
    key: jax.Array           # folded key for this leaf
    sigma2: jax.Array        # this cluster's channel variance σ_l²
    h_th: jax.Array          # threshold H_th
    noise_std: jax.Array     # AWGN std
    ota_on: jax.Array        # 1.0 = fading MAC; 0.0 = error-free baseline
    # Partial participation (DESIGN.md §3.14). None = full participation
    # (an empty pytree node — the custom_vjp residual tree stays legal).
    live: Optional[jax.Array] = None    # (C,) cluster participation flags
    n_eff: Optional[jax.Array] = None   # () traced effective N of eq. 10


def fold_tags(key: jax.Array, klass: str, tags, leaf_idx: int) -> jax.Array:
    k = jax.random.fold_in(key, KLASS_SALT[klass])
    for t in tags:
        k = jax.random.fold_in(k, t)
    return jax.random.fold_in(k, leaf_idx)


def cluster_index(cluster_axes: Tuple[str, ...]) -> jax.Array:
    cidx = jax.lax.axis_index(cluster_axes[0])
    for a in cluster_axes[1:]:
        cidx = cidx * _axis_size(a) + jax.lax.axis_index(a)
    return cidx


def channel_mask_for(key: jax.Array, shape, sigma2, h_th, ota_on,
                     cluster_axes) -> jax.Array:
    """The mask M_k^(l) this device's cluster sees for one leaf (eq. 7)."""
    ckey = jax.random.fold_in(key, cluster_index(cluster_axes))
    h = jax.random.normal(ckey, shape, jnp.float32) * jnp.sqrt(sigma2)
    return jnp.logical_or((h * h) >= h_th, ota_on < 0.5)


REGION_SALT = 0xC0


def region_mask_key(leaf_key: jax.Array, region) -> jax.Array:
    """Key for one scatter region's channel draw (scatter mode). Region
    indices partition the FSDP axis client-major; the full-tensor mask is
    the concatenation of region masks (see full_transmission_mask)."""
    return jax.random.fold_in(jax.random.fold_in(leaf_key, REGION_SALT),
                              region)


def full_transmission_mask(leaf_key, shape, axis, n_regions, sigma2, h_th,
                           ota_on, cluster_axes, scatter_mode: bool):
    """The full-tensor mask M_k^(l) exactly as the transmission draws it —
    used by the FGN phase (eq. 5) so F_grad sees the channel the MAC will
    apply. In scatter mode, sharded leaves draw per-region; replicated
    leaves (and all leaves in naive mode) draw whole-tensor."""
    if not scatter_mode or axis < 0:
        return channel_mask_for(leaf_key, shape, sigma2, h_th, ota_on,
                                cluster_axes)
    sub = list(shape)
    assert sub[axis] % n_regions == 0, (shape, axis, n_regions)
    sub[axis] //= n_regions
    pieces = [
        channel_mask_for(region_mask_key(leaf_key, r), tuple(sub), sigma2,
                         h_th, ota_on, cluster_axes)
        for r in range(n_regions)
    ]
    return jnp.concatenate(pieces, axis=axis)


def make_ota_gather(data_axes: Tuple[str, ...],
                    cluster_axes: Tuple[str, ...],
                    n_clients: int, n_shards: int, compute_dtype,
                    mode: str = "scatter"):
    """Build the custom-vjp FSDP gather for one mesh topology.

    ``data_axes`` MUST be ("client", "cluster") — client-major piece order
    is what makes the scatter pipeline's regions align with FSDP pieces.

    axis >= 0 leaves are FSDP-sharded on that dim; axis == -1 leaves are
    replicated over the data axes (identity fwd, full-size OTA bwd).

    Backward = Algorithm 1's aggregation, two implementations:

    * mode="naive"   (paper-literal): weighted psum over "client" (LAN,
      eq. 3) at FULL tensor size, masked psum over clusters (MAC, eq. 8)
      at full size, estimate (eq. 10), slice own shard. 2 full-size
      all-reduces + a full-size count per parameter per round.
    * mode="scatter" (optimized, identical math): psum_scatter the
      weighted gradients over "client" — the LAN sum arrives pre-split
      into per-client regions (1/N size); per-region channel masks; the
      MAC psum over clusters runs on regions; slice my cluster's sub-piece.
      ~3x fewer collective bytes, no full-size intermediate.

    Round semantics under gradient accumulation: channel keys fold only
    (step, layer, leaf) — masks and AWGN are IDENTICAL across microbatches,
    so averaging microbatch estimates equals one MAC transmission of the
    round-averaged x^(l) (eq. 8 applied once per iteration k).
    """
    assert data_axes[0] == CLIENT_AXIS, data_axes

    @partial(jax.custom_vjp, nondiff_argnums=(0,))
    def ota_gather(axis: int, shard, ctx: OTACtx):
        if axis >= 0:
            full = jax.lax.all_gather(shard, data_axes, axis=axis, tiled=True)
        else:
            full = shard
        return full.astype(compute_dtype)

    def _fwd(axis, shard, ctx):
        return ota_gather(axis, shard, ctx), (ctx,)

    def _estimate(y, cnt, z, n):
        return jnp.where(cnt > 0, (y + z) / (jnp.maximum(cnt, 1.0) * n), 0.0)

    def _bwd(axis, res, g):
        (ctx,) = res
        g = g.astype(jnp.float32)

        if mode == "scatter" and axis >= 0:
            # LAN via reduce-scatter: region i of x^(l) lands on client i
            x_reg = jax.lax.psum_scatter(ctx.p_weight * g, CLIENT_AXIS,
                                         scatter_dimension=axis, tiled=True)
            my_region = jax.lax.axis_index(CLIENT_AXIS)
            mkey = region_mask_key(ctx.key, my_region)
            mask = channel_mask_for(mkey, x_reg.shape, ctx.sigma2, ctx.h_th,
                                    ctx.ota_on, cluster_axes)
            cnt = jax.lax.psum(mask.astype(jnp.float32), cluster_axes)
            y = jax.lax.psum(jnp.where(mask, x_reg, 0.0), cluster_axes)
            z = (jax.random.normal(
                jax.random.fold_in(mkey, HOTA_MASK_SALT), x_reg.shape,
                jnp.float32)
                * ctx.noise_std * ctx.ota_on)
            ghat_reg = _estimate(y, cnt, z, n_clients)
            # my FSDP piece = my cluster's sub-slice of my region
            cidx = jax.lax.axis_index(data_axes[1])
            for a in data_axes[2:]:
                cidx = cidx * _axis_size(a) + jax.lax.axis_index(a)
            n_sub = n_shards // n_clients   # CLIENT_AXIS size by construction
            sz = ghat_reg.shape[axis] // n_sub
            my = jax.lax.dynamic_slice_in_dim(ghat_reg, cidx * sz, sz, axis)
            return (my, jax.tree.map(_zero_cot, ctx))

        # naive / replicated-leaf path: full-size psums
        x = jax.lax.psum(ctx.p_weight * g, CLIENT_AXIS)
        mask = channel_mask_for(ctx.key, g.shape, ctx.sigma2, ctx.h_th,
                                ctx.ota_on, cluster_axes)
        cnt = jax.lax.psum(mask.astype(jnp.float32), cluster_axes)
        y = jax.lax.psum(jnp.where(mask, x, 0.0), cluster_axes)
        z = (jax.random.normal(jax.random.fold_in(ctx.key, HOTA_MASK_SALT),
                               g.shape, jnp.float32)
             * ctx.noise_std * ctx.ota_on)
        ghat = _estimate(y, cnt, z, n_clients)
        if axis >= 0:
            me = jax.lax.axis_index(data_axes[0])
            for a in data_axes[1:]:
                me = me * _axis_size(a) + jax.lax.axis_index(a)
            sz = g.shape[axis] // n_shards
            ghat = jax.lax.dynamic_slice_in_dim(ghat, me * sz, sz, axis)
        return (ghat, jax.tree.map(_zero_cot, ctx))

    ota_gather.defvjp(_fwd, _bwd)
    return ota_gather


# --------------------------------------------------------------------------
# flat-packed final-subtree gather (ω̃ as ONE slab through the OTA MAC)
# --------------------------------------------------------------------------
# The last shared layer is where FedGradNorm reads its masked norms (eq. 5)
# and where the per-leaf machinery costs the most bookkeeping: every leaf
# used to pay its own mask draw + 3 collectives in the backward. Packing
# ω̃'s full-size gradients into one lane-aligned slab runs the whole
# subtree through ONE fused Pallas mask+apply kernel and ONE set of psums,
# and gives the FGN phase bit-identical masks from the same flat draw.

PACKED_FINAL_FOLD = 0x7FFF00F1   # reserved fold — disjoint from leaf indices


def packed_final_key(base_key: jax.Array) -> jax.Array:
    """The single channel key of the packed ω̃ slab (replaces per-leaf
    fold_tags(base_key, "final", (), i))."""
    return jax.random.fold_in(
        jax.random.fold_in(base_key, KLASS_SALT["final"]), PACKED_FINAL_FOLD)


def _packed_mask_apply(x_slab: jax.Array, key: jax.Array, sigma2, h_th,
                       ota_on, cluster_axes):
    """This cluster's fused bits→gaussian→threshold→apply on a (P,) slab.

    Returns (masked_x, mask) as (P,) f32 — the Pallas ota_channel kernel
    on the packed layout. Both the gather backward and the FGN norm call
    this with the same key, so eq. 5 sees exactly the transmission masks.
    """
    ckey = jax.random.fold_in(key, cluster_index(cluster_axes))
    bits = jax.random.bits(ckey, x_slab.shape, jnp.uint32)
    out, mask = _ota_channel_impl(
        flat_to_slab(x_slab), flat_to_slab(bits), sigma2, h_th, ota_on,
        interpret=not on_tpu())
    p = x_slab.shape[-1]
    return out.reshape(p), mask.reshape(p)


def make_packed_final_gather(data_axes: Tuple[str, ...],
                             cluster_axes: Tuple[str, ...],
                             n_clients: int, n_shards: int, compute_dtype,
                             axes_list: List[tuple], template=None):
    """Custom-vjp gather for the WHOLE final subtree.

    forward : per-leaf all-gather of the FSDP shards (as before)
    backward: pack full-size cotangents -> (P,) slab; weighted psum over
              "client" (LAN, eq. 3); fused Pallas mask+apply; masked psum
              over clusters (MAC, eq. 8) + AWGN; guarded |M|·N estimate
              (eq. 10); unpack; slice each leaf's own FSDP shard.

    3 collectives + 1 kernel for the subtree instead of 3·L psums and L
    mask draws. Masks are whole-tensor draws (the scatter-mode per-region
    scheme does not apply to the packed slab); ω̃ is small, so the full-
    size psums cost less than the per-leaf dispatch they replace.

    ``template`` (optional, full-size ω̃ shapes — e.g.
    ``abstract_params(model.final_specs())``) turns a mismatched
    gradient pytree into a readable error naming the leaf path and its
    expected section, instead of an opaque downstream shape error.
    """
    tpl_packer = (packer_for(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(tuple(l.shape), jnp.float32),
        template), tail=None) if template is not None else None)

    def _check(tree, what):
        if tpl_packer is not None:
            check_tree_matches_packer(tpl_packer, tree, what)
        elif len(jax.tree.leaves(tree)) != len(axes_list):
            raise ValueError(
                f"{what}: got {len(jax.tree.leaves(tree))} leaves but this "
                f"gather was built over {len(axes_list)} ω̃ leaves (the "
                f"tail section 'final') — the pytree must mirror "
                f"model.final_specs() exactly.")

    @jax.custom_vjp
    def gather_final(shard_tree, ctx: OTACtx):
        if tpl_packer is not None:   # structure only — shards are smaller
            check_tree_matches_packer(tpl_packer, shard_tree,
                                      "parameter pytree (packed final "
                                      "gather)", check_shapes=False)
        leaves, treedef = jax.tree.flatten(shard_tree)
        out = []
        for leaf, axes in zip(leaves, axes_list):
            ax = _fsdp_axis(axes)
            if ax >= 0:
                leaf = jax.lax.all_gather(leaf, data_axes, axis=ax,
                                          tiled=True)
            out.append(leaf.astype(compute_dtype))
        return jax.tree.unflatten(treedef, out)

    def _fwd(shard_tree, ctx):
        return gather_final(shard_tree, ctx), (ctx,)

    def _bwd(res, g_tree):
        (ctx,) = res
        _check(g_tree, "gradient pytree (packed final gather)")
        g_tree = jax.tree.map(lambda g: g.astype(jnp.float32), g_tree)
        packer = packer_for(g_tree, tail=None)
        g_slab = packer.pack(g_tree)                       # (P,) full-size
        x = jax.lax.psum(ctx.p_weight * g_slab, CLIENT_AXIS)
        xm, mask = _packed_mask_apply(x, ctx.key, ctx.sigma2, ctx.h_th,
                                      ctx.ota_on, cluster_axes)
        y = jax.lax.psum(xm, cluster_axes)
        cnt = jax.lax.psum(mask, cluster_axes)
        z = (jax.random.normal(jax.random.fold_in(ctx.key, HOTA_MASK_SALT),
                               g_slab.shape, jnp.float32)
             * ctx.noise_std * ctx.ota_on)
        ghat = jnp.where(cnt > 0,
                         (y + z) / (jnp.maximum(cnt, 1.0) * n_clients), 0.0)
        gh_tree = packer.unpack(ghat)
        me = jax.lax.axis_index(data_axes[0])
        for a in data_axes[1:]:
            me = me * _axis_size(a) + jax.lax.axis_index(a)
        leaves = jax.tree.leaves(gh_tree)
        out = []
        for leaf, axes in zip(leaves, axes_list):
            ax = _fsdp_axis(axes)
            if ax >= 0:
                sz = leaf.shape[ax] // n_shards
                leaf = jax.lax.dynamic_slice_in_dim(leaf, me * sz, sz, ax)
            out.append(leaf)
        grads = jax.tree.unflatten(jax.tree.structure(gh_tree), out)
        return (grads, jax.tree.map(_zero_cot, ctx))

    gather_final.defvjp(_fwd, _bwd)
    return gather_final


def packed_final_norm(g_final, base_key: jax.Array, chan_c: ChannelParams,
                      cluster_axes) -> jax.Array:
    """n_i = ‖M ∘ ∇_{ω̃}F_i‖ (eq. 6) on the packed slab — the SAME flat
    mask draw the packed gather backward applies (one fused kernel, no
    per-leaf loop)."""
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), g_final)
    packer = packer_for(g32, tail=None)
    g_slab = packer.pack(g32)
    masked, _ = _packed_mask_apply(
        g_slab, packed_final_key(base_key), chan_c.sigma2, chan_c.h_threshold,
        chan_c.ota_on, cluster_axes)
    return jnp.sqrt(jnp.sum(jnp.square(masked)))


# --------------------------------------------------------------------------
# axes registry + param hook
# --------------------------------------------------------------------------

def build_axes_registry(model: Model) -> Dict[str, List[tuple]]:
    """klass -> list of per-leaf logical-axes tuples ('layer' dims stripped),
    in the flatten order the hook will see."""
    cfg = model.cfg
    ax = logical_axes(model.trunk_specs())
    reg: Dict[str, List[tuple]] = {}

    def leaves_of(subtree):
        return [t for t in jax.tree.leaves(subtree, is_leaf=_is_axes)]

    if cfg.family == "mlp":
        reg["layers"] = []      # mlp trunk hooked per-fc via "embed"? no:
        # the MLP trunk is hooked as one flat subtree under "embed" klass?
        # Simpler: treat the whole mlp trunk as klass "layers" (single call).
        reg["layers"] = leaves_of(ax)
    elif cfg.family in ("dense", "moe"):
        reg["embed"] = [ax["embed"]]
        key = "layers" if "layers" in ax else "global"
        reg["layers"] = leaves_of(ax[key] if "layers" in ax else ax["global"])
    elif cfg.family == "hybrid":
        reg["embed"] = [ax["embed"]]
        reg["mamba"] = leaves_of(ax["mamba"])
        reg["shared_attn"] = leaves_of(ax["shared_attn"])
        reg["shared_mlp"] = leaves_of(ax["shared_mlp"])
    elif cfg.family == "xlstm":
        reg["embed"] = [ax["embed"]]
        reg["mlstm"] = leaves_of(ax["mlstm"])
        reg["slstm"] = leaves_of(ax["slstm"])
    elif cfg.family == "ssm":
        reg["embed"] = [ax["embed"]]
        reg["layers"] = leaves_of(ax["layers"])
    reg["final"] = leaves_of(logical_axes(model.final_specs()))
    return reg


def make_param_hook(gather, registry: Dict[str, List[tuple]],
                    base_key: jax.Array, p_weight, chan: ChannelParams,
                    final_packed_gather=None):
    """hook(subtree, klass, *tags) -> gathered/OTA-wrapped subtree.

    ``chan`` is this cluster's traced channel view (scalar σ² — see
    ``repro.core.channel.cluster_channel``); its knobs become the OTACtx
    consts, so sweeping scenarios never re-traces the gather.

    When ``final_packed_gather`` is set (see make_packed_final_gather),
    the "final" klass routes the WHOLE ω̃ subtree through one packed
    gather under one channel key instead of per-leaf calls."""
    consts = dict(
        p_weight=jnp.asarray(p_weight, jnp.float32),
        sigma2=jnp.asarray(chan.sigma2, jnp.float32),
        h_th=jnp.asarray(chan.h_threshold, jnp.float32),
        noise_std=jnp.asarray(chan.noise_std, jnp.float32),
        ota_on=jnp.asarray(chan.ota_on, jnp.float32),
    )

    def hook(lp, klass, *tags):
        if klass == "final" and final_packed_gather is not None:
            ctx = OTACtx(key=packed_final_key(base_key), **consts)
            return final_packed_gather(lp, ctx)
        leaves, treedef = jax.tree.flatten(lp)
        axes = registry[klass]
        assert len(leaves) == len(axes), (klass, len(leaves), len(axes))
        out = []
        for i, leaf in enumerate(leaves):
            ctx = OTACtx(key=fold_tags(base_key, klass, tags, i), **consts)
            out.append(gather(_fsdp_axis(axes[i]), leaf, ctx))
        return jax.tree.unflatten(treedef, out)
    return hook


def identity_hook(lp, klass, *tags):
    return lp


def shard_specs_for(model: Model, mesh) -> Any:
    """Manual PartitionSpecs (FL axes only) for the trunk+final shards."""
    from jax.sharding import PartitionSpec as P
    data_axes = _mesh_data_axes(mesh)

    def spec(axes):
        # position of embed in the FULL (unstripped) axes tuple
        if "embed" in axes:
            full_i = axes.index("embed")
            parts = [None] * len(axes)
            parts[full_i] = data_axes if len(data_axes) > 1 else data_axes[0]
            return P(*parts)
        return P()

    ax = {"trunk": logical_axes(model.trunk_specs()),
          "final": logical_axes(model.final_specs())}
    return jax.tree.map(spec, ax, is_leaf=_is_axes)


def _mesh_data_axes(mesh) -> Tuple[str, ...]:
    """FSDP axes in CLIENT-major order (scatter-region alignment)."""
    assert "client" in mesh.axis_names and "cluster" in mesh.axis_names
    return ("client", "cluster")


def _mesh_cluster_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "cluster"))


def _mesh_client_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "cluster", "client"))

