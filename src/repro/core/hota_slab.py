"""Slab-native distributed HOTA aggregation (DESIGN.md §3.10).

PR 2 packed the *simulator's* whole-model channel into one fused kernel,
but the distributed step still aggregated the trunk per leaf: every leaf
paid its own gain draw and its own set of psums in the custom-vjp
backward, with only the ω̃ tail riding the packed path. This module makes
the WHOLE shared model slab-native:

* the parameter template is laid out by a multi-section ``TreePacker``
  (``sections="toplevel"``: one ROW_QUANTUM-aligned section per top-level
  layer stack, ω̃ last — ``repro.common.flatpack``);
* the (P,) slab is NEVER materialized — ``TreePacker.leaf_runs()`` maps
  each leaf's storage to a static slice of its section's chunk-quantized
  bit stream (DESIGN.md §4), and the fused mask+weighted-apply kernel
  (``ota_mask_weight_apply``) consumes each leaf in place. This is the
  zero-copy layout: the dynamic-update-slice pack chain that lost to
  XLA's per-leaf path at 16M params simply does not exist here;
* the FedGradNorm weight folds INTO the kernel (w·g·M in one pass), so
  the backward needs exactly ONE psum set for the whole model: a single
  pytree psum of the masked weighted gradients over (client ∪ cluster)
  axes — eqs. 3 and 8 combined, since M_l ∘ Σ_i p_i g_i = Σ_i M_l ∘
  (p_i·g_i) with M constant across a cluster — plus one mask-count psum
  over the cluster axes for the |M|·N estimate (eq. 10).

``sectioned_final_norm`` re-draws ONLY the ω̃ section's stream (the tail
keeps ``PACKED_TAIL_FOLD`` in every layout), so the FGN phase (eq. 5)
sees bit-identical masks to the ones the transmission backward applies.

The per-leaf path (``repro.core.hota.make_ota_gather``) stays as the
numerical oracle behind ``FLConfig.use_pallas_ota=False``; memory trade:
this path materializes the full per-client gradient tree at the pack
point (fine up to ~1B params — the per-leaf path remains the
layer-at-a-time option for the 14B+ configs, DESIGN.md §3.7).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.flatpack import TreePacker, check_tree_matches_packer, \
    packer_for
from repro.core.channel import ChannelParams
from repro.core.hota import OTACtx, _axis_size, _zero_cot, cluster_index
from repro.core.ota import (
    _chunked_stream, packed_section_folds, section_gain_key,
    section_noise_key,
)
from repro.kernels.ota_channel.ops import ota_mask_count_apply, \
    ota_mask_weight_apply
from repro.kernels.ota_channel.ref import bits_to_gaussian, bits_to_mask
from repro.kernels.slab import on_tpu

CLIENT_AXIS = "client"


def _fsdp_axis_full(axes: tuple) -> int:
    """FSDP dim index in the FULL logical-axes tuple (scan-stacked leaves
    keep their leading 'layer' dim here, unlike the per-layer hook view
    that ``hota._fsdp_axis`` serves)."""
    return axes.index("embed") if "embed" in axes else -1


def plain_gather_full(shard_tree, fsdp_axes: List[int],
                      data_axes: Tuple[str, ...], compute_dtype):
    """Per-leaf all-gather of a whole shard tree (no custom vjp) —
    phases 0/B of the slab-native step, which never backprop through the
    channel. ``fsdp_axes`` are full-tuple dim indices (-1 = replicated)."""
    leaves, treedef = jax.tree.flatten(shard_tree)
    out = []
    for leaf, ax in zip(leaves, fsdp_axes):
        if ax >= 0:
            leaf = jax.lax.all_gather(leaf, data_axes, axis=ax, tiled=True)
        out.append(leaf.astype(compute_dtype))
    return jax.tree.unflatten(treedef, out)

# the whole-model slab's channel key domain — reserved fold near 2³¹,
# disjoint from PACKED_FINAL_FOLD (the PR-2 packed-ω̃ gather) and every
# cluster/leaf index (DESIGN.md §4)
PACKED_OMEGA_FOLD = 0x7FFF00F2


def packed_omega_key(base_key: jax.Array) -> jax.Array:
    """The single channel key of the slab-native whole-model round."""
    return jax.random.fold_in(base_key, PACKED_OMEGA_FOLD)


def omega_packer(template, sections: str = "toplevel",
                 min_section_rows: int = 0,
                 max_section_rows: int = 0) -> TreePacker:
    """The slab-native layout of one omega template, all-f32. Defaults
    to multi-section (per layer-stack trunk sections, ω̃ tail last);
    ``sections``/``min_section_rows``/``max_section_rows`` come from the
    tuned LayoutChoice (repro.common.layout_tune) so the engine, the
    simulator and the checkpoint manifest agree on one stream layout."""
    f32 = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(tuple(l.shape), jnp.float32), template)
    return packer_for(f32, tail="final", sections=sections,
                      min_section_rows=min_section_rows,
                      max_section_rows=max_section_rows)


# ---------------------------------------------------------------------------
# the whole-model custom-vjp gather
# ---------------------------------------------------------------------------

def make_packed_omega_gather(data_axes: Tuple[str, ...],
                             cluster_axes: Tuple[str, ...],
                             n_clients: int, n_shards: int, compute_dtype,
                             template, axes_list: List[tuple],
                             n_clusters: Optional[int] = None,
                             interpret: Optional[bool] = None,
                             count_mode: Optional[str] = None,
                             sections: str = "toplevel",
                             min_section_rows: int = 0,
                             max_section_rows: int = 0,
                             sectioned: bool = False):
    """Custom-vjp FSDP gather for the ENTIRE shared model {trunk, final}.

    forward : per-leaf all-gather of the FSDP shards -> full tree
    backward: per leaf (IN PLACE — no slab pack): fused mask+weighted
              apply on the leaf's static slice of its section's
              chunk-quantized streams (``ota_mask_count_apply``: the
              FedGradNorm weight folds into the kernel, and because
              EVERY cluster's mask is a pure function of the
              counter-based streams, the |M| count is computed locally —
              zero mask collectives); then one collective pipeline:
              per-FSDP-leaf ``psum_scatter`` over "client" (the LAN sum
              of eq. 3 arrives pre-split into 1/N regions) + ONE pytree
              psum of all regions over the cluster axes (the MAC of
              eq. 8), replicated leaves in one full-size psum; AWGN from
              the per-section noise streams; guarded |M|·N estimate
              (eq. 10) on local counts; slice each leaf's own shard.

    ``ctx.sigma2`` must be the FULL (n_clusters,) per-cluster vector.
    Masks are whole-tensor draws positioned by the layout, so a region's
    mask is literally a slice of the same stream — ``ota_mode`` does not
    apply to this engine (DESIGN.md §3.11). Gain/noise bits for each
    section are drawn once per round and sliced per leaf, so two leaves
    never recompute a chunk.

    ``count_mode`` picks how |M| reaches the estimate (identical values
    either way — masks are pure stream functions):

    * ``"psum"``: draw only THIS cluster's stream; the region-sliced
      mask rides the same pytree MAC psum as the data. Minimal PRNG
      volume — right on CPU and small meshes.
    * ``"local"``: draw EVERY cluster's stream and count locally via the
      fused ``ota_mask_count_apply`` kernel — zero mask collectives at
      C× the PRNG. Right where collectives cross pods and PRNG is
      hardware (TPU, DESIGN.md §3.10).
    * ``None`` (default): by platform — "local" on TPU, "psum"
      elsewhere, resolved at gather build time like ``interpret``.

    ``sectioned`` (DESIGN.md §3.16) makes the Section partition the unit
    of scheduling: the backward walks the layout one section at a time
    — draw that section's streams, mask/apply its leaf runs, ISSUE its
    psums — and finalizes (AWGN + guarded estimate + shard slice) each
    section one step LATE, so section s's collectives are in flight
    while section s+1 draws and packs (double-buffered carry). Peak live
    streams are one section's (bounded by ``max_section_rows``), never
    the (P,) or (C,P) slab; per-leaf values are bit-identical to the
    full-slab schedule (same streams, same kernels — only stream
    lifetime and psum grouping change, and psum is per-leaf
    elementwise).
    """
    if count_mode is None:
        # default-by-platform (ROADMAP): zero-mask-collective local
        # counting where the PRNG is hardware; minimal PRNG volume
        # where it is not. Resolved at gather build time (post backend
        # selection), never at module import.
        count_mode = "local" if on_tpu() else "psum"
    assert count_mode in ("psum", "local"), count_mode
    interp = (not on_tpu()) if interpret is None else interpret
    packer = omega_packer(template, sections=sections,
                          min_section_rows=min_section_rows,
                          max_section_rows=max_section_rows)
    folds = packed_section_folds(packer)
    runs = {run.leaf: run for run in packer.leaf_runs()}
    n_leaves = len(packer.slots)
    assert len(axes_list) == n_leaves, (len(axes_list), n_leaves)
    # full-tuple FSDP dims: whole-tree leaves keep their 'layer' dim
    fsdp_axes = [_fsdp_axis_full(ax) for ax in axes_list]
    n_sub = n_shards // n_clients      # cluster sub-shards per region

    @jax.custom_vjp
    def gather_omega(shard_tree, ctx: OTACtx):
        return plain_gather_full(shard_tree, fsdp_axes, data_axes,
                                 compute_dtype)

    def _fwd(shard_tree, ctx):
        return gather_omega(shard_tree, ctx), (ctx,)

    # a region (1/n_clients slice along the FSDP dim) is a CONTIGUOUS
    # range of the leaf's stream slice iff every dim before the FSDP dim
    # is trivial — then region r of leaf i occupies stream positions
    # [offset + r·(size/N), offset + (r+1)·(size/N)) and a device can
    # draw ONLY its region's chunks (lax.switch over the N static
    # offsets — 1/N the PRNG volume, same values as the full draw)
    def _contig(i):
        ax = fsdp_axes[i]
        shape = packer.slots[i].shape
        return ax >= 0 and all(s == 1 for s in shape[:ax])

    def _bwd(res, g_tree):
        (ctx,) = res
        check_tree_matches_packer(packer, g_tree,
                                  "gradient pytree (packed omega gather)")
        leaves = packer.treedef.flatten_up_to(g_tree)
        cidx = cluster_index(cluster_axes)
        n_cl = (int(ctx.sigma2.shape[0]) if n_clusters is None
                else n_clusters)
        sig_me = ctx.sigma2[cidx]
        my_reg = jax.lax.axis_index(CLIENT_AXIS)
        sub_idx = jax.lax.axis_index(data_axes[1])
        for a in data_axes[2:]:
            sub_idx = sub_idx * _axis_size(a) + jax.lax.axis_index(a)

        def _region(a, i):
            sz_r = a.shape[fsdp_axes[i]] // n_clients
            return jax.lax.dynamic_slice_in_dim(a, my_reg * sz_r, sz_r,
                                                fsdp_axes[i])

        def _range_draw(key, start, length):
            # my region's slice of a stream: one statically-drawn branch
            # per region offset, selected by the traced region index
            from repro.core.ota import stream_range_bits
            return jax.lax.switch(
                my_reg,
                [(lambda s=s: stream_range_bits(key, s, length))
                 for s in range(start, start + n_clients * length, length)])

        # Partial participation (DESIGN.md §3.14): a dead cluster (ctx.live
        # = 0) contributes neither data nor mask count to the MAC psums —
        # its local y/mask are zeroed pre-collective (psum mode) or masked
        # inside the fused count kernel (local mode) — and the traced
        # N_eff replaces the static N denominator of eq. 10.
        live_me = None if ctx.live is None else ctx.live[cidx]
        denom = (jnp.float32(n_clients) if ctx.n_eff is None
                 else jnp.maximum(ctx.n_eff, 1.0))
        grads = [None] * n_leaves

        def _collect(idxs):
            """Local channel work + the group's collectives for the
            leaves ``idxs``. Returns ({leaf: y}, {leaf: cnt}), post-psum
            for FSDP leaves. A group is the whole model (full-slab
            schedule) or ONE section (sectioned schedule): per-leaf
            values are bit-identical either way — only the stream
            lifetime and the psum grouping differ, and the psums are
            per-leaf elementwise."""
            reg_idx = [i for i in idxs if fsdp_axes[i] >= 0]
            rep_idx = [i for i in idxs if fsdp_axes[i] < 0]
            if count_mode == "local":
                # TPU-oriented variant: draw EVERY cluster's stream and
                # count |M| locally via the fused kernel — zero mask
                # collectives at C× the (hardware-cheap) PRNG; cnt is
                # exact because masks are pure stream functions.
                secs = sorted({runs[i].section for i in idxs})
                gbits_all = {s: jnp.stack([
                    _chunked_stream(
                        section_gain_key(ctx.key, folds[s], c),
                        packer.sections[s].length)
                    for c in range(n_cl)]) for s in secs}
                outs, cnts = {}, {}
                for i in idxs:
                    run = runs[i]
                    b = jax.lax.slice(gbits_all[run.section],
                                      (0, run.offset),
                                      (n_cl, run.offset + run.size))
                    o, c = ota_mask_count_apply(
                        leaves[i].astype(jnp.float32), b, cidx, ctx.sigma2,
                        ctx.h_th, ctx.ota_on, ctx.p_weight,
                        live_all=ctx.live, interpret=interp)
                    outs[i], cnts[i] = o, c
                y_reg = [jax.lax.psum_scatter(outs[i], CLIENT_AXIS,
                                              scatter_dimension=fsdp_axes[i],
                                              tiled=True) for i in reg_idx]
                cnt_reg = [_region(cnts[i], i) for i in reg_idx]
                cnt_rep = [cnts[i] for i in rep_idx]
                if reg_idx:
                    y_reg = jax.lax.psum(y_reg, tuple(cluster_axes))
                y_rep = (jax.lax.psum([outs[i] for i in rep_idx],
                                      (CLIENT_AXIS,) + tuple(cluster_axes))
                         if rep_idx else [])
            else:
                # default pipeline: LAN psum_scatter FIRST (mask commutes
                # with the client sum — it is cluster-constant), then this
                # cluster's REGION mask on a region-sized stream draw; the
                # mask rides the same pytree MAC psum as the data.
                y_reg, mask_reg = [], []
                full_bits = {}          # sections needing a full draw
                for i in rep_idx + [i for i in reg_idx if not _contig(i)]:
                    s = runs[i].section
                    if s not in full_bits:
                        full_bits[s] = _chunked_stream(
                            section_gain_key(ctx.key, folds[s], cidx),
                            packer.sections[s].length)
                for i in reg_idx:
                    run, ax = runs[i], fsdp_axes[i]
                    g32 = leaves[i].astype(jnp.float32)
                    if _contig(i):
                        x_reg = jax.lax.psum_scatter(
                            ctx.p_weight * g32, CLIENT_AXIS,
                            scatter_dimension=ax, tiled=True)
                        lreg = run.size // n_clients
                        b = _range_draw(
                            section_gain_key(ctx.key, folds[run.section],
                                             cidx), run.offset, lreg)
                        o, m = ota_mask_weight_apply(
                            x_reg, b, sig_me, ctx.h_th, ctx.ota_on, 1.0,
                            interpret=interp)
                        if live_me is not None:
                            o, m = o * live_me, m * live_me
                        y_reg.append(o)
                        mask_reg.append(m)
                    else:
                        b = jax.lax.slice(full_bits[run.section],
                                          (run.offset,),
                                          (run.offset + run.size,))
                        o, m = ota_mask_weight_apply(
                            g32, b, sig_me, ctx.h_th, ctx.ota_on,
                            ctx.p_weight, interpret=interp)
                        if live_me is not None:
                            o, m = o * live_me, m * live_me
                        y_reg.append(jax.lax.psum_scatter(
                            o, CLIENT_AXIS, scatter_dimension=ax,
                            tiled=True))
                        mask_reg.append(_region(m, i))
                rep_out, rep_mask = [], []
                for i in rep_idx:
                    run = runs[i]
                    b = jax.lax.slice(full_bits[run.section], (run.offset,),
                                      (run.offset + run.size,))
                    o, m = ota_mask_weight_apply(
                        leaves[i].astype(jnp.float32), b, sig_me, ctx.h_th,
                        ctx.ota_on, ctx.p_weight, interpret=interp)
                    if live_me is not None:
                        o, m = o * live_me, m * live_me
                    rep_out.append(o)
                    rep_mask.append(m)
                if reg_idx:
                    y_reg, cnt_reg = jax.lax.psum((y_reg, mask_reg),
                                                  tuple(cluster_axes))
                else:
                    cnt_reg = []
                if rep_idx:
                    y_rep = jax.lax.psum(rep_out,
                                         (CLIENT_AXIS,) + tuple(cluster_axes))
                    cnt_rep = jax.lax.psum(rep_mask, tuple(cluster_axes))
                else:
                    y_rep, cnt_rep = [], []

            y, cnt = {}, {}
            y.update(zip(reg_idx, y_reg))
            y.update(zip(rep_idx, y_rep))
            cnt.update(zip(reg_idx, cnt_reg))
            cnt.update(zip(rep_idx, cnt_rep))
            return y, cnt

        def _finalize(idxs, y, cnt):
            """AWGN (section noise streams; contiguous-region leaves draw
            only their region's slice — same switch trick), guarded
            estimate, own-shard slice. Consumes the group's psum results
            — the sectioned schedule calls this one section LATE so the
            collectives overlap the next section's local work."""
            full_nbits = {}
            for i in [i for i in idxs if fsdp_axes[i] < 0 or not _contig(i)]:
                s = runs[i].section
                if s not in full_nbits:
                    full_nbits[s] = _chunked_stream(
                        section_noise_key(ctx.key, folds[s]),
                        packer.sections[s].length)
            for i in idxs:
                run, ax = runs[i], fsdp_axes[i]
                if ax >= 0:
                    if _contig(i):
                        lreg = run.size // n_clients
                        nb = _range_draw(
                            section_noise_key(ctx.key, folds[run.section]),
                            run.offset, lreg)
                        z = bits_to_gaussian(nb, 1.0).reshape(y[i].shape)
                    else:
                        nb = jax.lax.slice(full_nbits[run.section],
                                           (run.offset,),
                                           (run.offset + run.size,))
                        z = _region(bits_to_gaussian(nb, 1.0).reshape(
                            leaves[i].shape), i)
                    z = z * ctx.noise_std * ctx.ota_on
                    ghat = jnp.where(
                        cnt[i] > 0,
                        (y[i] + z) / (jnp.maximum(cnt[i], 1.0) * denom),
                        0.0)
                    sz = ghat.shape[ax] // n_sub
                    ghat = jax.lax.dynamic_slice_in_dim(ghat, sub_idx * sz,
                                                        sz, ax)
                else:
                    nb = jax.lax.slice(full_nbits[run.section],
                                       (run.offset,),
                                       (run.offset + run.size,))
                    z = (bits_to_gaussian(nb, 1.0).reshape(leaves[i].shape)
                         * ctx.noise_std * ctx.ota_on)
                    ghat = jnp.where(
                        cnt[i] > 0,
                        (y[i] + z) / (jnp.maximum(cnt[i], 1.0) * denom),
                        0.0)
                grads[i] = ghat

        if sectioned:
            # section-streaming schedule (DESIGN.md §3.16): walk the
            # Section partition in layout order, double-buffered — issue
            # section s's psums, then finalize section s-1 while they
            # are in flight, so the latency-hiding scheduler overlaps
            # each section's collectives with the next one's stream draw
            # + mask/apply. Peak live streams: one section.
            pending = None
            for sec in packer.sections:
                idxs = list(sec.leaf_indices)
                if not idxs:
                    continue
                y, cnt = _collect(idxs)
                if pending is not None:
                    _finalize(*pending)
                pending = (idxs, y, cnt)
            if pending is not None:
                _finalize(*pending)
        else:
            idxs = list(range(n_leaves))
            y, cnt = _collect(idxs)
            _finalize(idxs, y, cnt)
        return (packer.treedef.unflatten(grads),
                jax.tree.map(_zero_cot, ctx))

    gather_omega.defvjp(_fwd, _bwd)
    return gather_omega, packer


# ---------------------------------------------------------------------------
# FGN inputs from the same round draw (eq. 5)
# ---------------------------------------------------------------------------

def sectioned_final_norm(g_final, slab_key: jax.Array,
                         chan_c: ChannelParams, cluster_axes,
                         packer: TreePacker) -> jax.Array:
    """n_i = ‖M ∘ ∇_{ω̃}F_i‖ (eq. 6) from the ω̃ SECTION of the round's
    slab draw — bit-identical masks to the ones ``make_packed_omega_
    gather``'s backward applies to the same entries (the tail keeps
    ``PACKED_TAIL_FOLD`` in every layout, so only this one stream is
    re-drawn — no full-model draw in the FGN phase)."""
    folds = packed_section_folds(packer)
    tail_secs = [s for s in packer.sections if s.name == packer.tail_name]
    assert tail_secs, packer.sections
    sec = tail_secs[0]
    cidx = cluster_index(cluster_axes)
    bits = _chunked_stream(
        section_gain_key(slab_key, folds[sec.index], cidx), sec.length)
    leaves = jax.tree.leaves(g_final)
    assert len(leaves) == len(sec.leaf_indices), \
        (len(leaves), sec.leaf_indices)
    runs = {r.leaf: r for r in packer.leaf_runs()}
    total = jnp.zeros((), jnp.float32)
    for leaf, i in zip(leaves, sec.leaf_indices):
        run = runs[i]
        b = jax.lax.slice(bits, (run.offset,), (run.offset + run.size,))
        mask = bits_to_mask(b, chan_c.sigma2, chan_c.h_threshold,
                            chan_c.ota_on).reshape(leaf.shape)
        total = total + jnp.sum(
            jnp.where(mask, leaf.astype(jnp.float32), 0.0) ** 2)
    return jnp.sqrt(total)


# ---------------------------------------------------------------------------
# jnp oracle on the identical streams (tests — DESIGN.md §3.10)
# ---------------------------------------------------------------------------

def packed_omega_aggregate_ref(g_tree, slab_key: jax.Array,
                               chan: ChannelParams, n_clients: int,
                               packer: TreePacker,
                               live=None, n_eff=None):
    """Single-process oracle of the slab backward for ONE weighted-grad
    tree with leading (C,) cluster axes on every leaf: same section
    streams, same mask law, same guarded estimate — plain jnp, so the
    forced-multi-device slab step can be pinned to it on shared keys.
    ``live``/``n_eff`` mirror the backward's partial-participation flow
    (DESIGN.md §3.14); None is the full-participation identity."""
    folds = packed_section_folds(packer)
    n_clusters = int(chan.sigma2.shape[0])
    leaves = packer.treedef.flatten_up_to(g_tree)
    runs = {run.leaf: run for run in packer.leaf_runs()}
    gbits = [jnp.stack([
        _chunked_stream(section_gain_key(slab_key, folds[s.index], c),
                        s.length) for c in range(n_clusters)])
        for s in packer.sections]
    nbits = [_chunked_stream(section_noise_key(slab_key, folds[s.index]),
                             s.length) for s in packer.sections]
    denom = (jnp.float32(n_clients) if n_eff is None
             else jnp.maximum(jnp.asarray(n_eff, jnp.float32), 1.0))
    out = []
    for i in range(len(leaves)):
        run = runs[i]
        b = jax.lax.slice(gbits[run.section], (0, run.offset),
                          (n_clusters, run.offset + run.size))
        sig = chan.sigma2.reshape((n_clusters,) + (1,))
        masks = bits_to_mask(b, sig, chan.h_threshold, chan.ota_on)
        if live is not None:
            masks = jnp.logical_and(
                masks, jnp.asarray(live, jnp.float32)
                .reshape(n_clusters, 1) > 0.5)
        wg = leaves[i].astype(jnp.float32).reshape(n_clusters, -1)
        y = jnp.sum(jnp.where(masks, wg, 0.0), axis=0)
        nb = jax.lax.slice(nbits[run.section], (run.offset,),
                           (run.offset + run.size,))
        z = bits_to_gaussian(nb, 1.0) * chan.noise_std * chan.ota_on
        cnt = jnp.sum(masks.astype(jnp.float32), axis=0)
        ghat = jnp.where(cnt > 0,
                         (y + z) / (jnp.maximum(cnt, 1.0) * denom), 0.0)
        out.append(ghat.reshape(leaves[i].shape[1:]))
    return packer.treedef.unflatten(out)
