"""Traced channel/weighting parameters — the scenario axis of the sweep engine.

``FLConfig`` is a frozen Python dataclass: its channel knobs (``sigma2``,
``noise_std``, ``h_threshold``, ``ota``, ``weighting``) are hashed into the
jit cache key, so every scenario historically meant a fresh trace. This
module lifts exactly those knobs into ``ChannelParams``, a pytree of
*arrays* that flows through the traced computation instead:

* ``sigma2``      — (C,) per-cluster channel variance σ_l² (Sec. III-A)
* ``h_threshold`` — scalar H_th of eq. (7)
* ``noise_std``   — scalar AWGN std of eq. (8)
* ``ota_on``      — 1.0 = fading MAC, 0.0 = error-free baseline (mask forced
                    all-pass, noise zeroed) — the paper's "no channel" ablation
* ``fgn_on``      — 1.0 = FedGradNorm dynamic weights (Alg. 2), 0.0 = equal
                    weighting (the Fig. 2 naive baseline)

Because every field is traced, a bank of S scenarios is just a
``ChannelParams`` whose leaves carry a leading (S,) axis — ``vmap`` over it
and one jit serves every scenario (see ``repro.core.sweep``); shard the
same leading axis over a ("scenario",) mesh and the bank scales past
one device (``ShardedScenarioBank``, DESIGN.md §3.8). The distributed
step consumes the SAME pytree: ``make_hota_train_step``'s step_fn takes
an optional ``ChannelParams`` whose ``fgn_on`` gate selects dynamic vs.
equal weighting inside one compiled step.

Topology knobs (``n_clusters``, ``n_clients``, ``tau_h``, ``tau_w``) and
optimizer hyper-parameters (``gamma``, ``alpha``, ``p_min``) stay static in
``FLConfig``: they change array shapes or scan lengths and genuinely require
a re-trace.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.common.config import FLConfig


class ChannelParams(NamedTuple):
    """Runtime channel + weighting knobs as a traced pytree (see module doc)."""
    sigma2: jax.Array        # (C,) — or scalar once cluster-indexed
    h_threshold: jax.Array   # ()
    noise_std: jax.Array     # ()
    ota_on: jax.Array        # () 1.0 | 0.0
    fgn_on: jax.Array        # () 1.0 | 0.0


def channel_params(fl: FLConfig, n_clusters: Optional[int] = None) -> ChannelParams:
    """Materialize the traced channel knobs of a static ``FLConfig``."""
    c = n_clusters if n_clusters is not None else fl.n_clusters
    return ChannelParams(
        sigma2=jnp.asarray([fl.cluster_sigma2(i) for i in range(c)],
                           jnp.float32),
        h_threshold=jnp.asarray(fl.h_threshold, jnp.float32),
        noise_std=jnp.asarray(fl.noise_std, jnp.float32),
        ota_on=jnp.asarray(1.0 if fl.ota else 0.0, jnp.float32),
        fgn_on=jnp.asarray(1.0 if fl.weighting == "fedgradnorm" else 0.0,
                           jnp.float32),
    )


def cluster_channel(chan: ChannelParams, cluster: jax.Array | int) -> ChannelParams:
    """This cluster's view: σ² narrowed from (C,) to a scalar."""
    return chan._replace(sigma2=chan.sigma2[cluster])


def stack_channel_params(chans: Sequence[ChannelParams]) -> ChannelParams:
    """Stack S scenarios into one bank with leading (S,) on every leaf."""
    if not chans:
        raise ValueError("empty scenario list")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *chans)


class FaultParams(NamedTuple):
    """Traced fault-injection knobs — sibling of ``ChannelParams``.

    Per-slot participation draws come from the reserved ``PART_FOLD``
    stream domain (DESIGN.md §4), so the draw for slot (l, n) depends only
    on the round key and the slot — resampling the *rates* below never
    perturbs channel masks, noise, or any other stream (CRN across fault
    scenarios), and the knobs vmap through the scenario banks exactly like
    channel knobs. Semantics in DESIGN.md §3.14.
    """
    dropout: jax.Array     # () per-client drop probability
    blackout: jax.Array    # () per-cluster blackout probability
    straggler: jax.Array   # () per-client straggler probability
    staleness: jax.Array   # () straggler staleness depth τ (rounds, float)
    spike_norm: jax.Array  # () skip-round guard threshold on ‖ĝ‖ (inf = off)
    faults_on: jax.Array   # () 1.0 = inject faults, 0.0 = full participation


def fault_params(fl: FLConfig) -> FaultParams:
    """Materialize the traced fault knobs of a static ``FLConfig``."""
    return FaultParams(
        dropout=jnp.asarray(fl.dropout_rate, jnp.float32),
        blackout=jnp.asarray(fl.blackout_rate, jnp.float32),
        straggler=jnp.asarray(fl.straggler_rate, jnp.float32),
        staleness=jnp.asarray(float(fl.staleness_rounds), jnp.float32),
        spike_norm=jnp.asarray(fl.spike_norm, jnp.float32),
        faults_on=jnp.asarray(1.0 if fl.faults else 0.0, jnp.float32),
    )


def stack_fault_params(faults: Sequence[FaultParams]) -> FaultParams:
    """Stack S fault scenarios into one bank with leading (S,) per leaf."""
    if not faults:
        raise ValueError("empty fault-scenario list")
    return jax.tree.map(lambda *xs: jnp.stack(xs), *faults)
