"""ScenarioBank — vectorized multi-scenario sweeps in a single jit.

The paper's headline results (Figs. 2-4) are comparisons *across channel
scenarios*: dynamic vs. equal weighting, one bad-channel cluster, diverse
σ². Historically each scenario was its own ``FLConfig`` — and because the
frozen config is part of the jit cache key, a figure meant a Python loop of
re-traced, re-compiled sims.

``ScenarioBank`` instead stacks the scenarios' traced knobs
(``repro.core.channel.ChannelParams``) into one bank with a leading (S,)
axis and ``vmap``s ``HotaSim.step_with_channel`` over it inside one jit:

* one trace + one compile for the whole figure;
* the batch/PRNG inputs are *shared* (``in_axes=None``) across scenarios —
  common random numbers by construction, so every scenario sees identical
  data order, channel gains (scaled by its own σ), masks-before-threshold
  and AWGN draws. Paired contrasts like Fig. 2's dynamic-vs-equal curves
  are variance-reduced for free;
* XLA batches the S scenarios through the same fused kernels, so the sweep
  costs far less than S sequential runs even ignoring compile time.

``ShardedScenarioBank`` (DESIGN.md §3.8) puts the same (S,) axis on a
1-D ``("scenario",)`` device mesh: scenario-batched state and ChannelParams
leaves are scenario-split, while the batch/PRNG inputs stay replicated on
every shard — common random numbers are preserved ACROSS shards, and the
plain ``vmap`` memory ceiling (all S states resident on one device) becomes
S/n_devices per device, so S ≫ 8 banks scale out instead of OOMing. The
packed OTA path's ``ota_bits_mode="supplied"`` draw depends only on the
shared key, so every shard computes the identical bit stream its scenarios
would see unsharded — the draw never varies per scenario or per shard.

``DistScenarioBank`` (DESIGN.md §3.10) lifts the *distributed* step onto
a 2-D ``("scenario", "cluster", "client")`` mesh: the raw Alg.-1 round
body (``repro.core.hota_step.make_hota_step_parts``) is vmapped over each
device row's local S/n_rows scenario slice INSIDE one shard_map, so the
client/cluster collectives (LAN psum, MAC psum, FSDP gathers) run
per-scenario on the trailing FL axes while scenario rows stay
embarrassingly parallel. Batch/PRNG enter replicated along the scenario
axis and nothing in the step reads a scenario coordinate, so CRN holds
across scenario shards by construction.

Every bank checkpoints through ``save``/``restore`` (DESIGN.md §3.9):
the (S,)-banked state rides the generic msgpack+npy envelope, the
scenario count is pinned in the manifest metadata, and restore re-places
leaves on the bank's own shardings — bit-identical trajectories across a
save/restore boundary.

Scenarios may vary only the traced knobs (``sigma2``, ``h_threshold``,
``noise_std``, ``ota``, ``weighting``); every other ``FLConfig`` field —
topology, local steps, FGN hyper-params, ``ota_mode``, ... — is baked into
the trace, and the bank rejects any scenario that differs in one.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Iterable, List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.common.config import FLConfig
from repro.core.channel import ChannelParams, FaultParams, channel_params, \
    fault_params, stack_channel_params, stack_fault_params
from repro.core.sim import HotaSim, SimState
from repro.sharding.mesh_utils import SCENARIO_AXIS, bank_sharding, \
    replicated_sharding, scenario_axis_size, scenario_banked_spec, \
    scenario_banked_tree, shard_map_compat

# the ONLY FLConfig fields a scenario may vary — everything else is baked
# into the trace (topology, local steps, FGN hyper-params, ota_mode, ...).
# Fault knobs (DESIGN.md §3.14) are traced VALUES like the channel knobs,
# but ``faults`` itself is the static gate and must match the base config.
_FAULT_FIELDS = ("dropout_rate", "blackout_rate", "straggler_rate",
                 "staleness_rounds", "spike_norm")
TRACED_FIELDS = frozenset(
    {"sigma2", "h_threshold", "noise_std", "ota", "weighting",
     *_FAULT_FIELDS})

Scenario = Union[FLConfig, ChannelParams, FaultParams, Dict[str, Any]]


def _as_channel_params(sc: Scenario, base: FLConfig) -> ChannelParams:
    if isinstance(sc, ChannelParams):
        if sc.sigma2.shape != (base.n_clusters,):
            raise ValueError(
                f"scenario sigma2 shape {sc.sigma2.shape} != "
                f"(n_clusters,) = ({base.n_clusters},)")
        return sc
    if isinstance(sc, FaultParams):
        return channel_params(base)      # fault-only scenario: base channel
    if isinstance(sc, dict):
        sc = dataclasses.replace(base, **sc)
    if not isinstance(sc, FLConfig):
        raise TypeError(f"scenario must be FLConfig | ChannelParams | dict "
                        f"of FLConfig overrides, got {type(sc)}")
    for f in dataclasses.fields(FLConfig):
        if f.name in TRACED_FIELDS:
            continue
        sc_val, base_val = getattr(sc, f.name), getattr(base, f.name)
        if sc_val != base_val:
            raise ValueError(
                f"scenario field {f.name!r} differs from the bank's base "
                f"config: scenario has {f.name}={sc_val!r}, base has "
                f"{f.name}={base_val!r}; only traced knobs "
                f"{sorted(TRACED_FIELDS)} may vary within a ScenarioBank — "
                f"build a second bank for static changes")
    return channel_params(sc)


def _as_fault_params(sc: Scenario, base: FLConfig) -> FaultParams:
    """The scenario's FaultParams (DESIGN.md §3.14). Channel-only
    scenarios inherit the base config's fault knobs; static-field
    validation already happened in ``_as_channel_params``."""
    if isinstance(sc, FaultParams):
        if not base.faults:
            raise ValueError(
                "FaultParams scenario in a bank whose base config has "
                "faults=False — the fault gate is static (it changes the "
                "trace), so build the bank from a faults=True base")
        return sc
    if isinstance(sc, ChannelParams):
        return fault_params(base)
    if isinstance(sc, dict):
        sc = dataclasses.replace(base, **sc)
    if not base.faults:
        for f in _FAULT_FIELDS:
            if getattr(sc, f) != getattr(base, f):
                raise ValueError(
                    f"scenario varies fault knob {f!r} but the bank's base "
                    f"config has faults=False — the knob would be silently "
                    f"inert; build the bank from a faults=True base")
    return fault_params(sc)


class _BankCheckpoint:
    """Sweep-aware checkpointing shared by every bank flavor (DESIGN.md
    §3.9): one envelope for the whole (S,)-banked state, scenario count
    pinned in the manifest, restore re-placed on the bank's shardings."""

    def _abstract_states(self):
        raise NotImplementedError

    def _state_shardings(self):
        return None          # default placement (single-device banks)

    def _bank_fl(self):
        """The bank's FLConfig (sim banks hold it on the sim)."""
        fl = getattr(self, "fl", None)
        if fl is None and getattr(self, "sim", None) is not None:
            fl = self.sim.fl
        return fl

    def _layout_metadata(self):
        """The bank's packed-layout pin (DESIGN.md §3.13): section folds
        — and so every channel stream — depend on the layout, so it is
        saved with, and checked against, every bank checkpoint."""
        from repro.common.layout_tune import layout_of
        fl = self._bank_fl()
        return None if fl is None else layout_of(fl).to_metadata()

    def save(self, ckpt_dir: str, step: int, states) -> str:
        from repro.checkpoint.store import save_checkpoint
        md = {"kind": type(self).__name__,
              "n_scenarios": self.n_scenarios}
        layout = self._layout_metadata()
        if layout is not None:
            md["layout"] = layout
        return save_checkpoint(ckpt_dir, step, states, md)

    def restore(self, ckpt_dir: str, step: int):
        """Restore a state saved by ``save`` into THIS bank's layout —
        shape-checked against the bank's abstract state and re-placed on
        its shardings, so a restored bank continues bit-identically.
        Raises if the checkpoint pins a different scenario count or a
        different packed layout (the streams would silently change)."""
        from repro.checkpoint.store import checkpoint_metadata, \
            restore_checkpoint
        s = checkpoint_metadata(ckpt_dir, step).get("n_scenarios")
        if s is not None and s != self.n_scenarios:
            raise ValueError(
                f"checkpoint at step {step} was saved from a {s}-scenario "
                f"bank but this bank has S={self.n_scenarios} — a bank "
                f"only restores states with a matching scenario axis")
        return restore_checkpoint(ckpt_dir, step, self._abstract_states(),
                                  shardings=self._state_shardings(),
                                  expected_layout=self._layout_metadata())


class ScenarioBank(_BankCheckpoint):
    """An (S,)-batched bank of channel scenarios over one ``HotaSim``.

    >>> sim = HotaSim(model, base_fl, tcfg, n_cls)
    >>> bank = ScenarioBank(sim, [dict(weighting="equal"),
    ...                           dict(sigma2=(0.05, 1.0)),
    ...                           base_fl])
    >>> states = bank.init(jax.random.PRNGKey(0))
    >>> states, m = bank.step(states, xb, yb, jax.random.PRNGKey(1))
    >>> m["loss"].shape      # (S, C, N)
    """

    def __init__(self, sim: HotaSim, scenarios: Sequence[Scenario]):
        self.sim = sim
        self.chan_bank = stack_channel_params(
            [_as_channel_params(sc, sim.fl) for sc in scenarios])
        # fault knobs bank exactly like channel knobs (DESIGN.md §3.14);
        # with faults=False the bank is inert (the legacy trace never
        # reads it) but keeps the step arity uniform
        self.fault_bank = stack_fault_params(
            [_as_fault_params(sc, sim.fl) for sc in scenarios])
        self.n_scenarios = int(self.chan_bank.ota_on.shape[0])

    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> SimState:
        """(S,)-batched initial state. All scenarios start from the SAME
        model/optimizer state (common random numbers extend to init)."""
        state = self.sim.init(key)
        s = self.n_scenarios
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (s,) + x.shape), state)

    # ------------------------------------------------------------------
    def step(self, states: SimState, xb, yb, key: jax.Array):
        """One Alg.-1 round for every scenario at once. ``xb``/``yb``/``key``
        are UNBATCHED and shared across scenarios (common random numbers);
        states and the returned metrics carry the leading (S,) axis."""
        return self._step(states, xb, yb, key, self.chan_bank,
                          self.fault_bank)

    def _vmapped_step(self, states, xb, yb, key, chan_bank, fault_bank):
        # supplied bits mode: the OTA stream draw is a function of the
        # shared key only, so it hoists out of the scenario vmap — one
        # draw per round, not per scenario. The client-folded sim path
        # (DESIGN.md §3.12) draws key-only in either mode; the flag is
        # kept so the per-slab kernel path composes identically.
        # The participation draw (PART_FOLD) likewise depends only on
        # the shared key — scenarios vary the fault RATES the shared
        # uniforms are compared against, so participation is monotone-
        # coupled across the bank (CRN for fault sweeps).
        def step(st, x, y, k, ch, fp):
            return self.sim.step_with_channel(
                st, x, y, k, ch, ota_bits_mode="supplied", faults=fp)
        return jax.vmap(step, in_axes=(0, None, None, None, 0, 0))(
            states, xb, yb, key, chan_bank, fault_bank)

    @partial(jax.jit, static_argnums=0)
    def _step(self, states, xb, yb, key, chan_bank, fault_bank):
        return self._vmapped_step(states, xb, yb, key, chan_bank,
                                  fault_bank)

    # ------------------------------------------------------------------
    def run(self, states: SimState, batches: Iterable[Tuple[Any, Any]],
            keys: Sequence[jax.Array]):
        """Drive the bank over an iterable of (x, y) batches; returns the
        final states and metrics stacked along a leading time axis:
        leaves (T, S, ...)."""
        history: List[Any] = []
        for (x, y), k in zip(batches, keys):
            states, m = self.step(states, jnp.asarray(x), jnp.asarray(y), k)
            history.append(m)
        if not history:
            raise ValueError("no batches supplied")
        return states, jax.tree.map(lambda *xs: jnp.stack(xs), *history)

    # ------------------------------------------------------------------
    def scenario_state(self, states: SimState, s: int) -> SimState:
        """Slice one scenario's unbatched SimState out of the bank."""
        return jax.tree.map(lambda x: x[s], states)

    # ------------------------------------------------------------------
    def _abstract_states(self):
        # the PLAIN init's shapes (placement-free): subclasses re-place
        # via _state_shardings, so eval_shape must not hit device_put
        return jax.eval_shape(lambda k: ScenarioBank.init(self, k),
                              jax.random.PRNGKey(0))


class ShardedScenarioBank(ScenarioBank):
    """A ScenarioBank whose (S,) axis is sharded over a "scenario" mesh.

    Same single-trace vmapped step as the base class, but wrapped in a
    manual ``shard_map`` over the 1-D ``("scenario",)`` mesh: each device
    runs the step on its LOCAL S/n_devices slice of the scenario-batched
    state and ChannelParams bank, while the per-step batch and PRNG key
    enter replicated (``P()``) — every shard consumes bit-identical
    data/keys, so the CRN contract survives sharding. The step body has
    no cross-scenario collectives, so the shards run embarrassingly
    parallel (manual mode — GSPMD never gets the chance to replicate the
    compute or insert all-gathers). See DESIGN.md §3.8.

    >>> mesh = make_scenario_mesh()                 # repro.launch.mesh
    >>> bank = ShardedScenarioBank(sim, scenarios, mesh)
    >>> states = bank.init(jax.random.PRNGKey(0))   # leaves (S,...) sharded
    >>> states, m = bank.step(states, xb, yb, key)  # m: (S, C, N) sharded
    """

    def __init__(self, sim: HotaSim, scenarios: Sequence[Scenario],
                 mesh=None):
        super().__init__(sim, scenarios)
        if mesh is None:
            from repro.launch.mesh import make_scenario_mesh
            mesh = make_scenario_mesh()
        n_dev = scenario_axis_size(mesh)
        if self.n_scenarios % n_dev:
            raise ValueError(
                f"scenario count S={self.n_scenarios} must divide evenly "
                f"over the {n_dev}-device scenario mesh — pad the bank or "
                f"shrink the mesh (make_scenario_mesh(n_devices=...))")
        self.mesh = mesh
        self._banked = bank_sharding(mesh)
        self._shared = replicated_sharding(mesh)
        self.chan_bank = jax.device_put(self.chan_bank, self._banked)
        self.fault_bank = jax.device_put(self.fault_bank, self._banked)

    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> SimState:
        """(S,)-batched initial state, scenario-split across the mesh.
        Init itself is shared (CRN extends to init): each shard holds its
        scenarios' identical copy of the same model/optimizer state."""
        return jax.device_put(super().init(key), self._banked)

    # ------------------------------------------------------------------
    def step(self, states: SimState, xb, yb, key: jax.Array):
        """One Alg.-1 round for every scenario, scenario-parallel across
        devices. ``xb``/``yb``/``key`` are committed replicated so every
        shard reads identical data and keys; the supplied-bits channel
        draw depends only on the shared key, so each shard computes the
        same stream its scenarios would see unsharded."""
        xb = jax.device_put(jnp.asarray(xb), self._shared)
        yb = jax.device_put(jnp.asarray(yb), self._shared)
        key = jax.device_put(key, self._shared)
        return self._step(states, xb, yb, key, self.chan_bank,
                          self.fault_bank)

    @partial(jax.jit, static_argnums=0)
    def _step(self, states, xb, yb, key, chan_bank, fault_bank):
        from jax.sharding import PartitionSpec as P
        banked, shared = P(SCENARIO_AXIS), P()
        f = shard_map_compat(
            self._vmapped_step,
            mesh=self.mesh,
            in_specs=(banked, shared, shared, shared, banked, banked),
            out_specs=(banked, banked),
            axis_names={SCENARIO_AXIS})
        return f(states, xb, yb, key, chan_bank, fault_bank)

    # ------------------------------------------------------------------
    def _state_shardings(self):
        return self._banked


class DistScenarioBank(_BankCheckpoint):
    """The DISTRIBUTED step on a 2-D (scenario × client) mesh.

    Where ``ScenarioBank`` sweeps the vmap *simulator*, this bank sweeps
    the production shard_map step (``repro.core.hota_step``): the mesh is
    ("scenario", "cluster", "client") — ``repro.launch.mesh.
    make_dist_scenario_mesh`` — and ONE shard_map covers all three axes.
    Each scenario row vmaps the raw Alg.-1 round body over its local
    S/n_rows scenario slice while the body's client/cluster collectives
    (LAN psum, MAC psum, FSDP gathers — slab-native per DESIGN.md §3.10)
    run on the trailing FL axes. Scenario rows never communicate.

    CRN across scenario shards: batch and PRNG enter replicated along
    the scenario axis, channel keys fold only (step, section, cluster,
    chunk) — no scenario coordinate exists in the step — so every
    scenario sees bit-identical data and channel draws whether it lives
    on row 0 or row k, and a bank sharded S-ways reproduces the 1-row
    bank exactly.

    >>> mesh = make_dist_scenario_mesh(n_clusters=1, n_clients=2)
    >>> bank = DistScenarioBank(model, fl, tcfg, scenarios, mesh,
    ...                         loss_kind="cls", n_out=8)
    >>> states = bank.init(jax.random.PRNGKey(0))
    >>> states, m = bank.step(states, tokens, labels, key)  # m: (S, ...)
    """

    def __init__(self, model, fl: FLConfig, tcfg, scenarios:
                 Sequence[Scenario], mesh=None, *, loss_kind: str = "lm",
                 n_out=None):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.hota import _mesh_client_axes
        from repro.core.hota_step import make_hota_step_parts
        if mesh is None:
            from repro.launch.mesh import make_dist_scenario_mesh
            mesh = make_dist_scenario_mesh(fl.n_clusters, fl.n_clients)
        assert SCENARIO_AXIS in mesh.axis_names, mesh
        self.mesh = mesh
        self.fl = fl
        parts = make_hota_step_parts(model, mesh, fl, tcfg,
                                     loss_kind=loss_kind, n_out=n_out)
        if parts.n_total_clusters != fl.n_clusters:
            raise ValueError(
                f"mesh has {parts.n_total_clusters} clusters but "
                f"fl.n_clusters={fl.n_clusters}")
        self._parts = parts
        self.chan_bank = stack_channel_params(
            [_as_channel_params(sc, fl) for sc in scenarios])
        self.fault_bank = stack_fault_params(
            [_as_fault_params(sc, fl) for sc in scenarios])
        self.n_scenarios = int(self.chan_bank.ota_on.shape[0])
        n_rows = scenario_axis_size(mesh)
        if self.n_scenarios % n_rows:
            raise ValueError(
                f"scenario count S={self.n_scenarios} must divide evenly "
                f"over the {n_rows}-row scenario axis — pad the bank or "
                f"shrink the mesh")

        self._state_banked = scenario_banked_tree(parts.state_specs)
        self._metric_banked = scenario_banked_tree(parts.metric_spec)
        chan_banked = scenario_banked_tree(parts.chan_spec)
        faults_banked = scenario_banked_tree(parts.faults_spec)

        def body(states, tokens, labels, key, chan_bank, fault_bank):
            # local scenario slice: vmap the single-scenario round body;
            # its client/cluster collectives batch over the vmap axis
            return jax.vmap(parts.step,
                            in_axes=(0, None, None, None, 0, 0))(
                states, tokens, labels, key, chan_bank, fault_bank)

        self._inner = shard_map_compat(
            body, mesh=mesh,
            in_specs=(self._state_banked, parts.batch_spec[0],
                      parts.batch_spec[1], P(), chan_banked, faults_banked),
            out_specs=(self._state_banked, self._metric_banked),
            axis_names=set(_mesh_client_axes(mesh)) | {SCENARIO_AXIS})
        self._jstep = jax.jit(self._inner)
        self.chan_bank = jax.tree.map(
            lambda a: jax.device_put(
                a, NamedSharding(mesh, P(SCENARIO_AXIS))), self.chan_bank)
        self.fault_bank = jax.tree.map(
            lambda a: jax.device_put(
                a, NamedSharding(mesh, P(SCENARIO_AXIS))), self.fault_bank)

    # ------------------------------------------------------------------
    def _init_states(self, key: jax.Array):
        st = self._parts.init_fn(key)
        s = self.n_scenarios
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (s,) + x.shape), st)

    def init(self, key: jax.Array):
        """(S,)-banked initial HotaState, scenario-split over the rows
        and FSDP-sharded inside each row (CRN extends to init: every
        scenario starts from the same state)."""
        return self._place(self._init_states(key))

    def _place(self, states):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.tree.map(
            lambda a, sp: jax.device_put(a, NamedSharding(self.mesh, sp)),
            states, self._state_banked,
            is_leaf=lambda x: isinstance(x, P))

    # ------------------------------------------------------------------
    def step(self, states, tokens, labels, key: jax.Array):
        """One distributed Alg.-1 round for every scenario. ``tokens``/
        ``labels`` are the GLOBAL flat client batch (the 1-D step's
        layout), committed replicated along the scenario axis; ``key``
        is shared — CRN across scenarios and across scenario rows."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        tokens = jax.device_put(
            jnp.asarray(tokens),
            NamedSharding(self.mesh, self._parts.batch_spec[0]))
        labels = jax.device_put(
            jnp.asarray(labels),
            NamedSharding(self.mesh, self._parts.batch_spec[1]))
        key = jax.device_put(key, NamedSharding(self.mesh, P()))
        return self._jstep(states, tokens, labels, key, self.chan_bank,
                           self.fault_bank)

    # ------------------------------------------------------------------
    def scenario_state(self, states, s: int):
        """Slice one scenario's unbatched HotaState out of the bank."""
        return jax.tree.map(lambda x: x[s], states)

    # ------------------------------------------------------------------
    def _abstract_states(self):
        return jax.eval_shape(self._init_states, jax.random.PRNGKey(0))

    def _state_shardings(self):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.tree.map(
            lambda sp: NamedSharding(self.mesh, sp), self._state_banked,
            is_leaf=lambda x: isinstance(x, P))
