"""Over-the-air aggregation over the wireless fading MAC (paper Sec. III-B).

The channel model, exactly as the paper defines it:

* channel gains  H_k^(l)(j) ~ N(0, σ_l²) i.i.d. per entry j, per cluster l,
  per iteration k                                                  (Sec. III-A)
* threshold mask M_k^(l)(j) = 1{ |H(j)|² ≥ H_th }                  (eq. 7)
* power allocation β_k^(l,i)(j) = p_k^(l,i) / H(j) on passing entries,
  0 otherwise (channel inversion)                                   (eq. 3)
* MAC superposition y(j) = Σ_{l∈M(j)} H(j) x^(l)(j) + z(j), z ~ N(0,1) (eq. 8)
* PS estimator ĝ(j) = y(j) / (|M_k(j)| · N)                         (eq. 10)

Because β inverts the channel, H·(β∘g) = p·g on passing entries — the
faithful-but-redundant inversion is implemented in ``faithful=True`` mode
(used by property tests to verify the cancellation); the fast path sums the
masked weighted gradients directly, which is bit-for-bit the same math.

Two implementations share the math:

* the **per-leaf path** (this module's historical core) walks the pytree,
  drawing gains/masks/noise per leaf per cluster with ``jax.random`` —
  the readable oracle the property tests pin everything to;
* the **flat-packed path** (``ota_aggregate_packed``) ravels the whole
  tree into a lane-aligned slab (``repro.common.flatpack.TreePacker``)
  and runs eqs. 7-10 for every parameter of every cluster in ONE fused
  Pallas kernel (``repro.kernels.ota_channel.ota_aggregate``); the
  last-shared-layer masks FedGradNorm needs (eq. 5) are the tail slice
  of the same flat draw (``final_layer_masks_packed``);
* the **client-folded zero-copy path** (``ota_aggregate_client_folded``,
  the simulator's hot path — DESIGN.md §3.12) folds eq. 3's Σ_i p_i g_i
  INTO the masked MAC sum and consumes each raw (C, N, ·) gradient leaf
  in place against the multi-section stream layout — no weighted tree,
  no (C, P) pack copy;
* the **section-streaming path** (``ota_aggregate_sectioned`` —
  DESIGN.md §3.16) schedules the client-folded math one SECTION at a
  time (optionally with the §3.15 cluster scan inside each section), so
  peak live streams are one section of the layout — the
  billion-parameter memory shape.

Per-leaf channel keys are derived with ``fold_in(cluster_key, leaf_index)``,
which realizes the paper's "one i.i.d. gain per parameter entry" over an
arbitrary pytree. Noise keys live in a disjoint fold-in domain
(``NOISE_FOLD``, near 2³¹) so they can never collide with a cluster
index; the packed path folds section salts (``PACKED_*_FOLD``) from the
same reserved range.
"""
from __future__ import annotations

from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.flatpack import TreePacker, check_tree_matches_packer
from repro.core.channel import ChannelParams
from repro.kernels.ota_channel.kernel import CHUNK_ROWS
from repro.kernels.ota_channel.ops import (
    _ota_aggregate_fused_impl, ota_client_fold_apply, ota_stream_fold_apply,
)
from repro.kernels.ota_channel.ref import bits_to_gaussian, bits_to_mask
from repro.kernels.slab import LANE, on_tpu


# --------------------------------------------------------------------------
# key schedule
# --------------------------------------------------------------------------
# Reserved fold-in values near 2³¹ — structurally disjoint from cluster and
# leaf indices (both bounded by topology sizes far below 2³¹). The noise
# fold used to be 999, which collided with cluster_key(ks, 999) once
# n_clusters > 999.
NOISE_FOLD = 0x7FFFFFFF          # AWGN stream (per-leaf AND packed)
PACKED_HEAD_FOLD = 0x7FFF0001    # gain bits for the packed head section
PACKED_TAIL_FOLD = 0x7FFF0002    # gain bits for the packed tail (ω̃) section
# the simulator round's channel-key domain (DESIGN.md §4): HotaSim derives
# its per-round channel key as fold_in(step_key, SIM_CHAN_FOLD) — a
# reserved value, NOT a bare literal, so no future fold of the step key
# (data order, head init, ...) can collide with the channel streams.
SIM_CHAN_FOLD = 0x7FFF0003
# the participation-draw domain (DESIGN.md §4): every per-slot fault draw
# — client dropout, cluster blackout, straggler flags — folds off
# fold_in(round_key, PART_FOLD). The draws depend ONLY on the round key
# and the slot position, never on the fault rates themselves, so
# resampling FaultParams perturbs no channel stream (CRN across fault
# scenarios) and raising a rate only grows the dropped set (monotone
# coupling u < rate on a shared uniform).
PART_FOLD = 0x7FFF0004
# the client-sampling domain (DESIGN.md §4): the per-round client-id
# draw — which population member fills each (cluster, slot) position —
# folds off fold_in(round_key, SAMPLE_FOLD). Channel and participation
# streams key off the SLOT position, never the drawn ids, so resampling
# the population (or growing it) perturbs no mask, no noise and no fault
# draw: CRN survives resampling byte-for-byte (the position-determinism
# rule; pinned in tests/test_sampling.py).
SAMPLE_FOLD = 0x7FFF0005
# multi-section layouts (DESIGN.md §3.10): trunk section s folds BASE + s;
# the tail (ω̃) section keeps PACKED_TAIL_FOLD in EVERY layout, so eq.-5
# consumers re-draw only the ω̃ stream without knowing the trunk split.
PACKED_SECTION_FOLD_BASE = 0x7FFF0100
# ---- aux salts (DESIGN.md §4, class ``aux``) -----------------------------
# Small-valued salts folded off keys that never meet the per-round channel
# key domain, registered here (with their historical values, so no stream
# moves) rather than spelled as bare literals at the call sites — the
# `bare-fold-salt` lint rule (§3.17) rejects the literal spelling.
FINAL_INIT_FOLD = 7      # ω̃ (final shared layer) init off the trunk key
SAMPLE_INIT_FOLD = 11    # population client-bank init off the sim init key
HOTA_MASK_SALT = 0xBEEF  # dist backward's AWGN z off the round mask key
TUNE_PROBE_FOLD = 99     # layout autotuner's probe-weight draw
# participation sub-streams: per-kind uniforms fold off the PART_FOLD
# key (draw_participation), one sub-fold per fault kind
PART_DROP_FOLD = 0       # client dropout uniforms
PART_BLACK_FOLD = 1      # cluster blackout uniforms
PART_STRAG_FOLD = 2      # straggler-flag uniforms


def cluster_key(key: jax.Array, cluster: jax.Array | int) -> jax.Array:
    return jax.random.fold_in(key, cluster)


def leaf_key(ckey: jax.Array, leaf_idx: int) -> jax.Array:
    return jax.random.fold_in(ckey, leaf_idx)


def noise_key(key: jax.Array) -> jax.Array:
    """AWGN key in a fold-in domain no cluster index can reach."""
    return jax.random.fold_in(key, NOISE_FOLD)


def sim_channel_key(key: jax.Array) -> jax.Array:
    """The simulator round's channel key (DESIGN.md §4): every channel
    stream of a ``HotaSim.step_with_channel`` round — per-leaf gains,
    packed section bits, AWGN — folds off this key, in a reserved domain
    disjoint from any other fold of the step key."""
    return jax.random.fold_in(key, SIM_CHAN_FOLD)


def participation_key(key: jax.Array) -> jax.Array:
    """The round's participation-draw key (DESIGN.md §4): every fault
    draw — dropout, blackout, straggler — folds off this key, in a
    reserved domain disjoint from every channel stream."""
    return jax.random.fold_in(key, PART_FOLD)


def sample_key(key: jax.Array) -> jax.Array:
    """The round's client-sample key (DESIGN.md §4): the id draw that
    fills each (cluster, slot) position from its subpopulation folds off
    this key, in a reserved domain disjoint from every channel and
    participation stream — so resampling moves no mask, noise or fault
    draw (position determinism)."""
    return jax.random.fold_in(key, SAMPLE_FOLD)


def draw_client_sample(key: jax.Array, n_clusters: int, n_clients: int,
                       population: int) -> jax.Array:
    """(C, N) int32 ids in [0, population): which member of each
    (cluster, slot) subpopulation participates this round (DESIGN.md
    §3.15). One uniform id per slot — O(C·N) work regardless of the
    population size, so rounds/sec stays flat as the population grows
    (BENCH_sample.json). Slots draw from DISJOINT subpopulations (a
    slot is a task), so two slots can never select the same client and
    the post-round scatter back into the ``ClientBank`` is
    conflict-free. Ids are a pure function of (round key, slot) — host
    callers can recompute them without threading state."""
    return jax.random.randint(sample_key(key), (n_clusters, n_clients),
                              0, population, jnp.int32)


class Participation(NamedTuple):
    """One round's fault realization (all f32, all traced).

    ``part`` is the P of the |M∩P| estimator: the guarded PS estimate
    counts only LIVE clusters (``live`` masks the per-cluster eq.-7
    masks) and divides by ``n_eff`` — the mean participant count over
    live clusters — instead of the static N. With no faults injected
    ``part`` is all-ones, ``live`` all-ones and ``n_eff == N`` exactly,
    so the generalized estimator is bit-identical to eq. 10.
    """
    part: jax.Array      # (C, N) 1.0 = client participates this round
    stale: jax.Array     # (C, N) 1.0 = participates with a stale gradient
    live: jax.Array      # (C,)   1.0 = cluster has ≥ 1 participant
    n_live: jax.Array    # ()     live-cluster count
    total: jax.Array     # ()     total participant count
    n_eff: jax.Array     # ()     total / max(n_live, 1) — the N of eq. 10


def draw_participation(key: jax.Array, faults, n_clusters: int,
                       n_clients: int) -> Participation:
    """Per-slot participation draws for one round (DESIGN.md §3.14).

    ``faults`` is a ``repro.core.channel.FaultParams``. Uniforms are
    drawn once per (kind, slot) under sub-folds of ``participation_key``
    and compared against the traced rates, so the fault knobs vmap
    through the scenario banks without retracing and resampling a rate
    never moves another scenario's draw."""
    pk = participation_key(key)
    u_drop = jax.random.uniform(jax.random.fold_in(pk, PART_DROP_FOLD),
                                (n_clusters, n_clients))
    u_black = jax.random.uniform(jax.random.fold_in(pk, PART_BLACK_FOLD),
                                 (n_clusters,))
    u_strag = jax.random.uniform(jax.random.fold_in(pk, PART_STRAG_FOLD),
                                 (n_clusters, n_clients))
    on = faults.faults_on >= 0.5
    drop = jnp.logical_and(on, u_drop < faults.dropout)
    black = jnp.logical_and(on, u_black < faults.blackout)
    part = jnp.logical_and(~drop, ~black[:, None]).astype(jnp.float32)
    stale = part * jnp.logical_and(
        on, u_strag < faults.straggler).astype(jnp.float32)
    live = (jnp.sum(part, axis=1) > 0).astype(jnp.float32)
    n_live = jnp.sum(live)
    total = jnp.sum(part)
    n_eff = total / jnp.maximum(n_live, 1.0)
    return Participation(part=part, stale=stale, live=live, n_live=n_live,
                         total=total, n_eff=n_eff)


def sample_gain(key: jax.Array, shape, sigma2) -> jax.Array:
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(
        jnp.asarray(sigma2, jnp.float32))


def gain_mask(h: jax.Array, h_threshold: float) -> jax.Array:
    """eq. (7): pass entries with |H|² ≥ H_th."""
    return (h * h) >= h_threshold


def tree_channel(key: jax.Array, tree, sigma2, h_threshold: float):
    """Draw (gains, masks) trees matching ``tree``'s structure/shapes."""
    leaves, treedef = jax.tree.flatten(tree)
    gains, masks = [], []
    for i, leaf in enumerate(leaves):
        h = sample_gain(leaf_key(key, i), leaf.shape, sigma2)
        gains.append(h)
        masks.append(gain_mask(h, h_threshold))
    return jax.tree.unflatten(treedef, gains), jax.tree.unflatten(treedef, masks)


# --------------------------------------------------------------------------
# power allocation + transmission (single cluster)
# --------------------------------------------------------------------------

def power_allocation(p_i: jax.Array, h: jax.Array, mask: jax.Array) -> jax.Array:
    """eq. (3): β = p / H where the channel passes, else 0."""
    safe_h = jnp.where(mask, h, 1.0)
    return jnp.where(mask, p_i / safe_h, 0.0)


def transmit_signal(p_i, g, h, mask):
    """x^(l,i) = β ∘ g (the signal a cluster's IS puts on the air for one
    client's gradient). Faithful path (channel inversion explicit)."""
    return power_allocation(p_i, h, mask) * g


def transmit_power(x: jax.Array) -> jax.Array:
    """E-free instantaneous ||x||² for the average power constraint (eq. 4)."""
    return jnp.sum(jnp.square(x))


# --------------------------------------------------------------------------
# full OTA aggregation across clusters (sim path)
# --------------------------------------------------------------------------

def ota_aggregate_leaf(
    weighted_grads: jax.Array,   # (C, ...) already Σ_i p_i g_i per cluster
    masks: jax.Array,            # (C, ...) bool
    noise: jax.Array,            # (...)
    n_clients: int,
    gains: Optional[jax.Array] = None,      # (C, ...) — faithful mode
    cluster_grads_scaled: Optional[jax.Array] = None,  # (C,...) β∘g sums
    live: Optional[jax.Array] = None,       # (C,) participation (§3.14)
    n_eff: Optional[jax.Array] = None,      # () traced effective N
):
    """eqs. (8)-(10) for one pytree leaf.

    Fast path: y = Σ_l mask_l * wg_l + z. Faithful path: y = Σ_l mask_l *
    H_l * (β∘g)_l + z (identical up to float assoc.; property-tested).

    Partial participation (DESIGN.md §3.14): ``live`` ANDs into the
    per-cluster masks — a blacked-out cluster transmits nothing and
    never reaches the |M| count, even under the ``ota_on`` all-pass gate
    — and the traced ``n_eff`` replaces the static N in the |M∩P|·N_eff
    denominator. Both default to the full-participation identity.
    """
    if live is not None:
        lv = live.reshape((masks.shape[0],) + (1,) * (masks.ndim - 1))
        masks = jnp.logical_and(masks, lv > 0.5)
    if gains is not None and cluster_grads_scaled is not None:
        y = jnp.sum(jnp.where(masks, gains * cluster_grads_scaled, 0.0), axis=0)
    else:
        y = jnp.sum(jnp.where(masks, weighted_grads, 0.0), axis=0)
    y = y + noise
    cnt = jnp.sum(masks.astype(jnp.float32), axis=0)
    denom = n_clients if n_eff is None else jnp.maximum(n_eff, 1.0)
    # |M_k(j)| = 0 -> nothing received but noise; estimator guarded to 0
    ghat = jnp.where(cnt > 0, y / (jnp.maximum(cnt, 1.0) * denom), 0.0)
    return ghat


def ota_aggregate_tree(
    key: jax.Array,
    weighted_grads,              # pytree with leading (C, ...) leaves
    chan: ChannelParams,         # traced knobs; chan.sigma2 is (C,)
    n_clients: int,
    live: Optional[jax.Array] = None,   # (C,) cluster participation
    n_eff: Optional[jax.Array] = None,  # () traced effective N
):
    """Sim-path OTA aggregation over a pytree of per-cluster weighted grads.

    The ``ota_on`` gate is traced (no Python branch): off forces every mask
    all-pass and zeroes the AWGN, so one jit serves fading and error-free
    scenarios alike. ``live``/``n_eff`` inject partial participation
    (DESIGN.md §3.14); None keeps the full-participation trace bit-exact.
    """
    leaves, treedef = jax.tree.flatten(weighted_grads)
    n_clusters = leaves[0].shape[0]
    out = []
    for i, wg in enumerate(leaves):
        ks = leaf_key(key, i)
        # per-cluster gains: vmap the draw over the cluster axis
        hs = jax.vmap(
            lambda c: sample_gain(cluster_key(ks, c), wg.shape[1:],
                                  chan.sigma2[c])
        )(jnp.arange(n_clusters))
        masks = jnp.logical_or(gain_mask(hs, chan.h_threshold),
                               chan.ota_on < 0.5)
        noise = (jax.random.normal(noise_key(ks), wg.shape[1:])
                 * chan.noise_std * chan.ota_on)
        out.append(ota_aggregate_leaf(wg, masks, noise, n_clients,
                                      live=live, n_eff=n_eff))
    return jax.tree.unflatten(treedef, out)


# --------------------------------------------------------------------------
# flat-packed OTA aggregation (the fused hot path)
# --------------------------------------------------------------------------
#
# Key schedule: one gain-bit stream per (section, cluster) —
#   bits_sec[c] = random.bits(fold_in(fold_in(key, PACKED_*_FOLD), c))
# — and one AWGN stream per round (fold_in(key, NOISE_FOLD)). Sections are
# the TreePacker's head (trunk) and tail (ω̃) slices, each lane-aligned,
# so ``final_layer_masks_packed`` re-draws ONLY the tail stream and gets
# bit-identical masks to the full aggregation's tail slice — no second
# per-leaf loop, no full-model draw in the FGN phase.

CHUNK = CHUNK_ROWS * LANE    # the stream quantum (entries per chunk draw)


def _chunked_stream(key: jax.Array, length: int) -> jax.Array:
    """(length,) uint32 of the chunk-quantized stream: chunk j is
    ``bits(fold_in(key, j), (CHUNK,))``; a partial last chunk is
    truncated — exactly the draws the fused kernel generates in-kernel
    (one chunk per grid step), independent of kernel blocking."""
    n_chunks = -(-length // CHUNK)
    chunks = jax.vmap(
        lambda j: jax.random.bits(jax.random.fold_in(key, j), (CHUNK,),
                                  jnp.uint32)
    )(jnp.arange(n_chunks))
    return chunks.reshape(-1)[:length]


def _section_bits(key: jax.Array, fold: int, n_clusters: int, length: int):
    """(C, length) uint32 gain bits for one packed section: cluster c's
    stream is chunk-quantized under ``fold_in(section_key, c)`` — the
    fused kernel's in-kernel draw at grid steps (·, c), and the section
    fold keeps head/tail streams disjoint so the FGN phase re-draws just
    the tail."""
    skey = jax.random.fold_in(key, fold)
    return jax.vmap(
        lambda c: _chunked_stream(cluster_key(skey, c), length)
    )(jnp.arange(n_clusters))


def packed_section_folds(packer: TreePacker) -> List[int]:
    """The stream fold of each ``packer.sections`` entry (DESIGN.md §4).

    Legacy two-section layouts keep PACKED_HEAD_FOLD / PACKED_TAIL_FOLD
    (streams bit-identical to PR 2); multi-section ("toplevel") layouts
    fold PACKED_SECTION_FOLD_BASE + index per trunk section while the
    tail section always keeps PACKED_TAIL_FOLD."""
    folds = []
    for sec in packer.sections:
        if sec.name == packer.tail_name:
            folds.append(PACKED_TAIL_FOLD)
        elif packer.layout == "tail":
            folds.append(PACKED_HEAD_FOLD)
        else:
            folds.append(PACKED_SECTION_FOLD_BASE + sec.index)
    return folds


def stream_range_bits(key: jax.Array, start: int, length: int) -> jax.Array:
    """uint32 elements [start, start+length) of ``key``'s chunk-quantized
    stream (chunk j is ``bits(fold_in(key, j), (CHUNK,))`` — DESIGN.md §4).

    ``start``/``length`` are STATIC: only the chunks intersecting the
    range are drawn, and because the kernel's partial-chunk rule is
    truncation, a mid-chunk slice here is bit-identical to what a kernel
    sweeping the whole section would apply at these positions. This is
    the zero-copy executor's bit source: a leaf's run (see
    ``TreePacker.leaf_runs``) maps to exactly one such range."""
    j0 = start // CHUNK
    j1 = (start + length - 1) // CHUNK
    chunks = jax.vmap(
        lambda j: jax.random.bits(jax.random.fold_in(key, j), (CHUNK,),
                                  jnp.uint32)
    )(jnp.arange(j0, j1 + 1))
    a = start - j0 * CHUNK
    return jax.lax.slice(chunks.reshape(-1), (a,), (a + length,))


def section_gain_key(slab_key: jax.Array, fold: int,
                     cluster: jax.Array | int) -> jax.Array:
    """Gain-bit stream key for one (section, cluster) — the same
    fold_in(fold_in(key, section_fold), cluster) scheme as
    ``_section_bits``, usable with a TRACED cluster index (the
    distributed path folds the mesh position)."""
    return cluster_key(jax.random.fold_in(slab_key, fold), cluster)


def section_noise_key(slab_key: jax.Array, fold: int) -> jax.Array:
    """AWGN stream key for one section (``packed_noise_bits``' scheme)."""
    return jax.random.fold_in(noise_key(slab_key), fold)


def section_gain_streams(key: jax.Array, packer: TreePacker,
                         n_clusters: int) -> List[jax.Array]:
    """One (C, length) gain-bit stream per ``packer.sections`` entry,
    drawn under the fold ``packed_section_folds`` assigns it. The SINGLE
    source of the packed gain schedule: ``packed_gain_bits`` concatenates
    these, the zero-copy consumers (``ota_aggregate_client_folded``,
    ``repro.core.hota_slab``) slice them per leaf — so sim and
    distributed paths draw identical bits for identical layouts (pinned
    in tests/test_client_folded.py)."""
    folds = packed_section_folds(packer)
    return [_section_bits(key, folds[sec.index], n_clusters, sec.length)
            for sec in packer.sections]


def section_noise_streams(key: jax.Array,
                          packer: TreePacker) -> List[jax.Array]:
    """One (length,) AWGN bit stream per section — the noise twin of
    ``section_gain_streams`` (same fold schedule, noise-key domain)."""
    folds = packed_section_folds(packer)
    return [_chunked_stream(section_noise_key(key, folds[sec.index]),
                            sec.length)
            for sec in packer.sections]


def packed_gain_bits(key: jax.Array, packer: TreePacker, n_clusters: int):
    """The whole round's (C, P) gain-bit slab: the per-section streams of
    ``section_gain_streams`` in layout order — the legacy head ++ tail
    pair for two-section layouts (bit-identical to PR 2), one stream per
    trunk section for multi-section layouts."""
    parts = section_gain_streams(key, packer, n_clusters)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-1)


def packed_noise_bits(key: jax.Array, packer: TreePacker) -> jax.Array:
    """The round's (P,) AWGN bit stream (per-section, chunk-quantized —
    the fused kernel's in-kernel draw at each section's final steps)."""
    parts = section_noise_streams(key, packer)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def ota_aggregate_packed(
    key: jax.Array,
    weighted_grads,              # pytree with leading (C, ...) leaves
    chan: ChannelParams,         # traced knobs; chan.sigma2 is (C,)
    n_clients: int,
    packer: TreePacker,
    bits_mode: str = "fused",    # "fused" | "supplied" (see below)
):
    """Fused-path OTA aggregation: pack -> one Pallas kernel -> unpack.

    Same math as ``ota_aggregate_tree`` (eqs. 8-10, traced ``ota_on``
    gate included), but the per-cluster gains, masks and the noise tree
    never materialize in HBM — property-tested against the per-leaf
    oracle on a shared bit stream (tests/test_ota_packed.py).

    ``bits_mode="fused"`` generates the bit streams in-kernel (no (C, P)
    bits slab — the single-scenario fast path); ``"supplied"`` pre-draws
    the IDENTICAL streams outside and feeds them to the kernel, which
    only depends on ``key`` — under ``ScenarioBank``'s vmap the draw
    hoists out of the scenario axis, paying the RNG once per round
    instead of once per scenario. Both modes return the same values.
    """
    leaves = jax.tree.leaves(weighted_grads)
    n_clusters = leaves[0].shape[0]
    wg = packer.pack(weighted_grads)                       # (C, P)
    if bits_mode == "supplied":
        bits = packed_gain_bits(key, packer, n_clusters)
        nbits = packed_noise_bits(key, packer)
    elif bits_mode == "fused":
        bits = nbits = None
    else:
        raise ValueError(bits_mode)
    # per-section stream schedule from the packer's own layout (DESIGN.md
    # §4): NOT a hard-coded head/tail pair — a "toplevel" packer's trunk
    # sections fold PACKED_SECTION_FOLD_BASE + s here exactly as the
    # slab-native distributed engine (repro.core.hota_slab) draws them
    folds = packed_section_folds(packer)
    nk = noise_key(key)
    section_keys = jnp.stack([
        jnp.stack([jax.random.fold_in(key, f), jax.random.fold_in(nk, f)])
        for f in folds]).astype(jnp.uint32)                # (S, 2, 2)
    ghat = _ota_aggregate_fused_impl(
        wg, section_keys, tuple(sec.length for sec in packer.sections),
        chan.sigma2, chan.h_threshold, chan.noise_std, chan.ota_on,
        n_clients, interpret=not on_tpu(), bits=bits, nbits=nbits)
    return packer.unpack(ghat)


def ota_aggregate_client_folded(
    key: jax.Array,
    grads,                       # pytree with leading (C, N, ...) leaves
    p: jax.Array,                # (C, N) loss weights
    chan: ChannelParams,         # traced knobs; chan.sigma2 is (C,)
    n_clients: int,
    packer: TreePacker,
    bits_mode: str = "fused",    # accepted for API symmetry (see below)
    live: Optional[jax.Array] = None,   # (C,) cluster participation (§3.14)
    n_eff: Optional[jax.Array] = None,  # () traced effective N
):
    """Slab-native sim-path OTA aggregation (DESIGN.md §3.12): fold the
    client-weight einsum INTO the channel and consume every gradient
    leaf's storage in place.

    Same math as ``einsum("cn,cn...->c...", p, g)`` followed by
    ``ota_aggregate_packed`` on a matching layout — eqs. 3 + 8-10 with
    the traced ``ota_on`` gate — but computed leaf by leaf against the
    static zero-copy maps (``TreePacker.leaf_runs``): neither the
    client-weighted tree nor the (C, P) packed slab is ever
    materialized. Streams are the per-section chunk-quantized draws of
    ``packed_section_folds`` — identical bits to the packed kernel and
    to the slab-native distributed engine on the same layout — drawn
    once per (section, cluster) and sliced per leaf, so leaves sharing a
    chunk never redraw it.

    ``bits_mode``: "fused" | "supplied" — both return identical values.
    In this zero-copy formulation the draw always happens outside the
    kernel and depends only on ``key``, so under ``ScenarioBank``'s
    scenario vmap (shared key, ``in_axes=None``) it hoists out of the
    scenario axis in EITHER mode; the parameter survives so the sweep
    engines compose unchanged.
    """
    if bits_mode not in ("fused", "supplied"):
        raise ValueError(bits_mode)
    check_tree_matches_packer(packer, grads,
                              "gradient pytree (client-folded OTA)",
                              batch_ndim=2)
    n_clusters = int(chan.sigma2.shape[0])
    gbits = section_gain_streams(key, packer, n_clusters)
    nbits = section_noise_streams(key, packer)
    leaves = packer.treedef.flatten_up_to(grads)
    out = [None] * len(leaves)
    for run in packer.leaf_runs():
        b = jax.lax.slice(gbits[run.section], (0, run.offset),
                          (n_clusters, run.offset + run.size))
        nb = jax.lax.slice(nbits[run.section], (run.offset,),
                           (run.offset + run.size,))
        out[run.leaf] = ota_client_fold_apply(
            leaves[run.leaf], p, b, nb, chan.sigma2, chan.h_threshold,
            chan.noise_std, chan.ota_on, n_clients,
            live=live, n_eff=n_eff,
            interpret=not on_tpu())
    return packer.treedef.unflatten(out)


class OTAStreamAcc(NamedTuple):
    """Running state of the streaming aggregator (DESIGN.md §3.15): the
    masked MAC sum and the |M∩P| pass count, one leaf-shaped f32 array
    each — NO cluster axis. Peak memory of a streaming round is one
    cluster's contribution plus this accumulator (HLO-pinned in
    tests/test_sampling.py)."""
    y: Any       # pytree, leaf-shaped f32: Σ_{folded l} M_l ∘ (Σ_n p g)
    cnt: Any     # pytree, leaf-shaped f32: Σ_{folded l} M_l


def ota_stream_init(packer: TreePacker) -> OTAStreamAcc:
    """Zeroed accumulator matching ``packer``'s tree."""
    def zeros():
        return packer.treedef.unflatten(
            [jnp.zeros(packer.slots[i].shape, jnp.float32)
             for i in range(len(packer.slots))])
    return OTAStreamAcc(y=zeros(), cnt=zeros())


def ota_stream_fold(
    key: jax.Array,
    acc: OTAStreamAcc,
    grads_c,                     # pytree with leading (N, ...) leaves
    p_c: jax.Array,              # (N,) this cluster's loss weights
    chan: ChannelParams,
    cluster: jax.Array | int,    # traced cluster index
    packer: TreePacker,
    live_c=None,                 # () this cluster's participation flag
) -> OTAStreamAcc:
    """Fold ONE cluster's contribution into the running sum (DESIGN.md
    §3.15): draw only cluster ``cluster``'s per-section streams
    (``stream_range_bits`` under ``section_gain_key`` — byte-identical
    to the slice ``ota_aggregate_client_folded`` applies at the same
    positions, because partial chunks truncate), fold the client weights
    into the masked apply, and accumulate the masked sum + pass count.
    The cluster index is traced, so a ``lax.scan``/``fori_loop`` over
    arriving clusters compiles to ONE fold body — no (C, ·) stream or
    mask buffer ever exists."""
    folds = packed_section_folds(packer)
    sig_c = jnp.asarray(chan.sigma2, jnp.float32)[cluster]
    leaves = packer.treedef.flatten_up_to(grads_c)
    y = packer.treedef.flatten_up_to(acc.y)
    cnt = packer.treedef.flatten_up_to(acc.cnt)
    for run in packer.leaf_runs():
        gkey = section_gain_key(key, folds[run.section], cluster)
        b = stream_range_bits(gkey, run.offset, run.size)
        dy, dc = ota_stream_fold_apply(
            leaves[run.leaf], p_c, b, sig_c, chan.h_threshold,
            chan.ota_on, live_c=live_c, interpret=not on_tpu())
        y[run.leaf] = y[run.leaf] + dy
        cnt[run.leaf] = cnt[run.leaf] + dc
    return OTAStreamAcc(y=packer.treedef.unflatten(y),
                        cnt=packer.treedef.unflatten(cnt))


def ota_stream_finalize(
    key: jax.Array,
    acc: OTAStreamAcc,
    chan: ChannelParams,
    n_clients: int,
    packer: TreePacker,
    n_eff=None,                  # () traced effective N (§3.14)
):
    """Close a streaming round: add the AWGN (the same per-section noise
    streams ``section_noise_streams`` draws, sliced per leaf) and apply
    the guarded |M∩P|·N_eff estimate (eq. 10). Returns the ĝ pytree."""
    folds = packed_section_folds(packer)
    y = packer.treedef.flatten_up_to(acc.y)
    cnt = packer.treedef.flatten_up_to(acc.cnt)
    denom = (jnp.float32(n_clients) if n_eff is None
             else jnp.maximum(jnp.asarray(n_eff, jnp.float32), 1.0))
    out = [None] * len(y)
    for run in packer.leaf_runs():
        nkey = section_noise_key(key, folds[run.section])
        nb = stream_range_bits(nkey, run.offset, run.size)
        z = (bits_to_gaussian(nb, 1.0) * chan.noise_std
             * jnp.asarray(chan.ota_on, jnp.float32))
        yl = y[run.leaf].reshape(-1) + z
        cl = cnt[run.leaf].reshape(-1)
        g = jnp.where(cl > 0, yl / (jnp.maximum(cl, 1.0) * denom), 0.0)
        out[run.leaf] = g.reshape(y[run.leaf].shape)
    return packer.treedef.unflatten(out)


def ota_aggregate_streaming(
    key: jax.Array,
    grads,                       # pytree with leading (C, N, ...) leaves
    p: jax.Array,                # (C, N) loss weights
    chan: ChannelParams,         # traced knobs; chan.sigma2 is (C,)
    n_clients: int,
    packer: TreePacker,
    bits_mode: str = "fused",    # accepted for API symmetry (key-only draw)
    live: Optional[jax.Array] = None,   # (C,) cluster participation
    n_eff: Optional[jax.Array] = None,  # () traced effective N
):
    """Streaming OTA aggregation (DESIGN.md §3.15): same math and same
    streams as ``ota_aggregate_client_folded`` — eqs. 3 + 8-10 with the
    traced ``ota_on`` gate, partial participation included — but the
    cluster axis is a ``lax.scan`` over ``ota_stream_fold``, so peak
    memory holds ONE cluster's masked contribution plus the running
    accumulator instead of every cluster's stream and mask at once
    (HLO-pinned: no (C, section)-sized buffer compiles). This is the
    aggregation shape for rounds whose cluster contributions ARRIVE one
    at a time (million-client sampling, ROADMAP); the equivalence to the
    all-at-once path is property-tested."""
    if bits_mode not in ("fused", "supplied"):
        raise ValueError(bits_mode)
    check_tree_matches_packer(packer, grads,
                              "gradient pytree (streaming OTA)",
                              batch_ndim=2)
    n_clusters = int(chan.sigma2.shape[0])
    live_v = (jnp.ones((n_clusters,), jnp.float32) if live is None
              else jnp.asarray(live, jnp.float32).reshape(n_clusters))

    def body(acc, xs):
        c, g_c, p_c, lv_c = xs
        return ota_stream_fold(key, acc, g_c, p_c, chan, c, packer,
                               live_c=lv_c), None

    acc, _ = jax.lax.scan(
        body, ota_stream_init(packer),
        (jnp.arange(n_clusters), grads,
         jnp.asarray(p, jnp.float32), live_v))
    return ota_stream_finalize(key, acc, chan, n_clients, packer,
                               n_eff=n_eff)


def ota_aggregate_sectioned(
    key: jax.Array,
    grads,                       # pytree with leading (C, N, ...) leaves
    p: jax.Array,                # (C, N) loss weights
    chan: ChannelParams,         # traced knobs; chan.sigma2 is (C,)
    n_clients: int,
    packer: TreePacker,
    bits_mode: str = "fused",    # accepted for API symmetry (key-only draw)
    live: Optional[jax.Array] = None,   # (C,) cluster participation
    n_eff: Optional[jax.Array] = None,  # () traced effective N
    streaming: bool = False,     # compose with the cluster scan (§3.15)
):
    """Section-streaming OTA aggregation (DESIGN.md §3.16): the Section
    partition is the unit of scheduling. Sections are heterogeneous
    (length AND leaf set differ), so the scan over the section index is
    a STATIC unrolled schedule — per section, draw only that section's
    chunk-quantized gain/noise streams (the same ``packed_section_folds``
    folds, so the draws are byte-identical to the batch draw), fold only
    that section's leaf runs, then release the buffers. Peak live
    streams are one section — bounded by the layout's
    ``max_section_rows`` cap — never the (P,) or (C, P) slab
    (HLO-pinned in tests/test_sectioned.py).

    Equivalence: with ``streaming=False`` every per-leaf kernel call
    receives byte-identical inputs to ``ota_aggregate_client_folded``'s,
    so the result is BIT-identical (not just associativity-close). With
    ``streaming=True`` the cluster ``lax.scan`` runs INSIDE each
    section (one cluster's slice of one section live at a time) and
    every leaf accumulates in the same cluster order as
    ``ota_aggregate_streaming`` — bit-identical to that engine."""
    if bits_mode not in ("fused", "supplied"):
        raise ValueError(bits_mode)
    check_tree_matches_packer(packer, grads,
                              "gradient pytree (sectioned OTA)",
                              batch_ndim=2)
    n_clusters = int(chan.sigma2.shape[0])
    folds = packed_section_folds(packer)
    leaves = packer.treedef.flatten_up_to(grads)
    out = [None] * len(leaves)
    runs_by_sec: dict = {}
    for run in packer.leaf_runs():
        runs_by_sec.setdefault(run.section, []).append(run)

    def _fold_section(sec, runs):
        # all-clusters-at-once fold of ONE section: the client-folded
        # math restricted to this section's runs, on this section's draw
        gb = _section_bits(key, folds[sec.index], n_clusters, sec.length)
        nb = _chunked_stream(section_noise_key(key, folds[sec.index]),
                             sec.length)
        for run in runs:
            b = jax.lax.slice(gb, (0, run.offset),
                              (n_clusters, run.offset + run.size))
            nbs = jax.lax.slice(nb, (run.offset,),
                                (run.offset + run.size,))
            out[run.leaf] = ota_client_fold_apply(
                leaves[run.leaf], p, b, nbs, chan.sigma2,
                chan.h_threshold, chan.noise_std, chan.ota_on, n_clients,
                live=live, n_eff=n_eff, interpret=not on_tpu())

    def _stream_section(sec, runs, p_v, live_v, denom):
        # cluster scan INSIDE the section: one (cluster, section) slice
        # live at a time, leaf sums in ota_aggregate_streaming's order
        def body(acc, xs):
            c, gs, p_c, lv_c = xs
            sig_c = jnp.asarray(chan.sigma2, jnp.float32)[c]
            y, cnt = acc
            for k, run in enumerate(runs):
                gkey = section_gain_key(key, folds[sec.index], c)
                b = stream_range_bits(gkey, run.offset, run.size)
                dy, dc = ota_stream_fold_apply(
                    gs[k], p_c, b, sig_c, chan.h_threshold, chan.ota_on,
                    live_c=lv_c, interpret=not on_tpu())
                y[k] = y[k] + dy
                cnt[k] = cnt[k] + dc
            return (y, cnt), None

        zeros = [jnp.zeros(packer.slots[r.leaf].shape, jnp.float32)
                 for r in runs]
        (y, cnt), _ = jax.lax.scan(
            body, (list(zeros), list(zeros)),
            (jnp.arange(n_clusters), [leaves[r.leaf] for r in runs],
             p_v, live_v))
        nkey = section_noise_key(key, folds[sec.index])
        for k, run in enumerate(runs):
            nbs = stream_range_bits(nkey, run.offset, run.size)
            z = (bits_to_gaussian(nbs, 1.0) * chan.noise_std
                 * jnp.asarray(chan.ota_on, jnp.float32))
            yl = y[k].reshape(-1) + z
            cl = cnt[k].reshape(-1)
            g = jnp.where(cl > 0, yl / (jnp.maximum(cl, 1.0) * denom), 0.0)
            out[run.leaf] = g.reshape(y[k].shape)

    if streaming:
        p_v = jnp.asarray(p, jnp.float32)
        live_v = (jnp.ones((n_clusters,), jnp.float32) if live is None
                  else jnp.asarray(live, jnp.float32).reshape(n_clusters))
        denom = (jnp.float32(n_clients) if n_eff is None
                 else jnp.maximum(jnp.asarray(n_eff, jnp.float32), 1.0))
    for sec in packer.sections:
        runs = runs_by_sec.get(sec.index, [])
        if not runs:
            continue
        if streaming:
            _stream_section(sec, runs, p_v, live_v, denom)
        else:
            _fold_section(sec, runs)
    return packer.treedef.unflatten(out)


def final_layer_masks_packed(key: jax.Array, chan: ChannelParams,
                             packer: TreePacker):
    """Masks M^(l) on the last-shared-layer params ω̃ (eq. 5-7), drawn
    from the tail section's stream — bit-identical to the masks
    ``ota_aggregate_packed`` applies to the same entries.

    Consumes the stream per leaf through the SAME ``leaf_runs`` slices
    the zero-copy engines walk (the tail section is never coalesced, so
    its fold and runs are layout-stable): each mask leaf is a static
    slice of the tail draw reshaped in place — the full (C, tail_len)
    slab is never unpacked. ``bits_to_mask`` is elementwise, so slicing
    before masking is bit-identical to masking the whole tail.
    """
    if packer.tail_name is None or not packer.tail_len:
        raise ValueError(
            "final_layer_masks_packed needs a packer with a non-empty "
            f"tail section (tail={packer.tail_name!r}) — the eq.-5 masks "
            "are defined on the last-shared-layer params ω̃")
    n_clusters = chan.sigma2.shape[0]
    tail_sec = next(s for s in packer.sections
                    if s.name == packer.tail_name)
    bits = _section_bits(key, PACKED_TAIL_FOLD, n_clusters,
                         tail_sec.length)                       # (C, tail)
    sig = chan.sigma2.reshape(n_clusters, 1)
    sub_leaves = []
    for run in packer.leaf_runs():
        if run.section != tail_sec.index:
            continue
        b = jax.lax.slice(bits, (0, run.offset),
                          (n_clusters, run.offset + run.size))
        m = bits_to_mask(b, sig, chan.h_threshold, chan.ota_on)
        sub_leaves.append(
            m.reshape((n_clusters,) + packer.slots[run.leaf].shape))
    full = packer.treedef.unflatten(list(range(len(packer.slots))))
    _, tail_def = jax.tree_util.tree_flatten(full[packer.tail_name])
    return jax.tree_util.tree_unflatten(tail_def, sub_leaves)


def final_layer_masks(key: jax.Array, final_tree, chan: ChannelParams,
                      leaf_offset: int = 0):
    """Masks M^(l) restricted to the last-shared-layer params ω̃, for the
    sparsified F_grad (eq. 5-7). Uses the same per-leaf keys as the full
    aggregation so FGN sees exactly the channel the transmission will use."""
    leaves, treedef = jax.tree.flatten(final_tree)
    n_clusters = chan.sigma2.shape[0]
    masks = []
    for i, leaf in enumerate(leaves):
        ks = leaf_key(key, leaf_offset + i)
        hs = jax.vmap(
            lambda c: sample_gain(cluster_key(ks, c), leaf.shape,
                                  chan.sigma2[c])
        )(jnp.arange(n_clusters))
        m = jnp.logical_or(gain_mask(hs, chan.h_threshold),
                           chan.ota_on < 0.5)
        masks.append(m)
    return jax.tree.unflatten(treedef, masks)
