"""Over-the-air aggregation over the wireless fading MAC (paper Sec. III-B).

The channel model, exactly as the paper defines it:

* channel gains  H_k^(l)(j) ~ N(0, σ_l²) i.i.d. per entry j, per cluster l,
  per iteration k                                                  (Sec. III-A)
* threshold mask M_k^(l)(j) = 1{ |H(j)|² ≥ H_th }                  (eq. 7)
* power allocation β_k^(l,i)(j) = p_k^(l,i) / H(j) on passing entries,
  0 otherwise (channel inversion)                                   (eq. 3)
* MAC superposition y(j) = Σ_{l∈M(j)} H(j) x^(l)(j) + z(j), z ~ N(0,1) (eq. 8)
* PS estimator ĝ(j) = y(j) / (|M_k(j)| · N)                         (eq. 10)

Because β inverts the channel, H·(β∘g) = p·g on passing entries — the
faithful-but-redundant inversion is implemented in ``faithful=True`` mode
(used by property tests to verify the cancellation); the fast path sums the
masked weighted gradients directly, which is bit-for-bit the same math.

All functions operate leaf-wise on pytrees; per-leaf channel keys are
derived with ``fold_in(cluster_key, leaf_index)``, which realizes the
paper's "one i.i.d. gain per parameter entry" over an arbitrary pytree.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.channel import ChannelParams


# --------------------------------------------------------------------------
# per-leaf channel draws
# --------------------------------------------------------------------------

def cluster_key(key: jax.Array, cluster: jax.Array | int) -> jax.Array:
    return jax.random.fold_in(key, cluster)


def leaf_key(ckey: jax.Array, leaf_idx: int) -> jax.Array:
    return jax.random.fold_in(ckey, leaf_idx)


def sample_gain(key: jax.Array, shape, sigma2) -> jax.Array:
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(
        jnp.asarray(sigma2, jnp.float32))


def gain_mask(h: jax.Array, h_threshold: float) -> jax.Array:
    """eq. (7): pass entries with |H|² ≥ H_th."""
    return (h * h) >= h_threshold


def tree_channel(key: jax.Array, tree, sigma2, h_threshold: float):
    """Draw (gains, masks) trees matching ``tree``'s structure/shapes."""
    leaves, treedef = jax.tree.flatten(tree)
    gains, masks = [], []
    for i, leaf in enumerate(leaves):
        h = sample_gain(leaf_key(key, i), leaf.shape, sigma2)
        gains.append(h)
        masks.append(gain_mask(h, h_threshold))
    return jax.tree.unflatten(treedef, gains), jax.tree.unflatten(treedef, masks)


# --------------------------------------------------------------------------
# power allocation + transmission (single cluster)
# --------------------------------------------------------------------------

def power_allocation(p_i: jax.Array, h: jax.Array, mask: jax.Array) -> jax.Array:
    """eq. (3): β = p / H where the channel passes, else 0."""
    safe_h = jnp.where(mask, h, 1.0)
    return jnp.where(mask, p_i / safe_h, 0.0)


def transmit_signal(p_i, g, h, mask):
    """x^(l,i) = β ∘ g (the signal a cluster's IS puts on the air for one
    client's gradient). Faithful path (channel inversion explicit)."""
    return power_allocation(p_i, h, mask) * g


def transmit_power(x: jax.Array) -> jax.Array:
    """E-free instantaneous ||x||² for the average power constraint (eq. 4)."""
    return jnp.sum(jnp.square(x))


# --------------------------------------------------------------------------
# full OTA aggregation across clusters (sim path)
# --------------------------------------------------------------------------

def ota_aggregate_leaf(
    weighted_grads: jax.Array,   # (C, ...) already Σ_i p_i g_i per cluster
    masks: jax.Array,            # (C, ...) bool
    noise: jax.Array,            # (...)
    n_clients: int,
    gains: Optional[jax.Array] = None,      # (C, ...) — faithful mode
    cluster_grads_scaled: Optional[jax.Array] = None,  # (C,...) β∘g sums
):
    """eqs. (8)-(10) for one pytree leaf.

    Fast path: y = Σ_l mask_l * wg_l + z. Faithful path: y = Σ_l mask_l *
    H_l * (β∘g)_l + z (identical up to float assoc.; property-tested).
    """
    if gains is not None and cluster_grads_scaled is not None:
        y = jnp.sum(jnp.where(masks, gains * cluster_grads_scaled, 0.0), axis=0)
    else:
        y = jnp.sum(jnp.where(masks, weighted_grads, 0.0), axis=0)
    y = y + noise
    cnt = jnp.sum(masks.astype(jnp.float32), axis=0)
    # |M_k(j)| = 0 -> nothing received but noise; estimator guarded to 0
    ghat = jnp.where(cnt > 0, y / (jnp.maximum(cnt, 1.0) * n_clients), 0.0)
    return ghat


def ota_aggregate_tree(
    key: jax.Array,
    weighted_grads,              # pytree with leading (C, ...) leaves
    chan: ChannelParams,         # traced knobs; chan.sigma2 is (C,)
    n_clients: int,
):
    """Sim-path OTA aggregation over a pytree of per-cluster weighted grads.

    The ``ota_on`` gate is traced (no Python branch): off forces every mask
    all-pass and zeroes the AWGN, so one jit serves fading and error-free
    scenarios alike.
    """
    leaves, treedef = jax.tree.flatten(weighted_grads)
    n_clusters = leaves[0].shape[0]
    out = []
    for i, wg in enumerate(leaves):
        ks = leaf_key(key, i)
        # per-cluster gains: vmap the draw over the cluster axis
        hs = jax.vmap(
            lambda c: sample_gain(cluster_key(ks, c), wg.shape[1:],
                                  chan.sigma2[c])
        )(jnp.arange(n_clusters))
        masks = jnp.logical_or(gain_mask(hs, chan.h_threshold),
                               chan.ota_on < 0.5)
        noise = (jax.random.normal(jax.random.fold_in(ks, 999), wg.shape[1:])
                 * chan.noise_std * chan.ota_on)
        out.append(ota_aggregate_leaf(wg, masks, noise, n_clients))
    return jax.tree.unflatten(treedef, out)


def final_layer_masks(key: jax.Array, final_tree, chan: ChannelParams,
                      leaf_offset: int = 0):
    """Masks M^(l) restricted to the last-shared-layer params ω̃, for the
    sparsified F_grad (eq. 5-7). Uses the same per-leaf keys as the full
    aggregation so FGN sees exactly the channel the transmission will use."""
    leaves, treedef = jax.tree.flatten(final_tree)
    n_clusters = chan.sigma2.shape[0]
    masks = []
    for i, leaf in enumerate(leaves):
        ks = leaf_key(key, leaf_offset + i)
        hs = jax.vmap(
            lambda c: sample_gain(cluster_key(ks, c), leaf.shape,
                                  chan.sigma2[c])
        )(jnp.arange(n_clusters))
        m = jnp.logical_or(gain_mask(hs, chan.h_threshold),
                           chan.ota_on < 0.5)
        masks.append(m)
    return jax.tree.unflatten(treedef, masks)
