"""The distributed HOTA-FedGradNorm training step (shard_map + custom-vjp OTA).

``make_hota_train_step(model, mesh, fl, tcfg)`` returns (init_fn, step_fn,
state_specs) where step_fn is the *full* Algorithm 1 round:

  phase 0  trunk forward once (PS->IS->client broadcast = FSDP gather)
  phase A  τ_h personalized-head Adam steps on the frozen features
  phase B  FGN inputs: per-client tail loss + masked ‖∇_{ω̃}F‖ (eq. 6),
           then the distributed Alg. 2 update of p (psum-means over "client")
  phase C  full forward/backward; every shared-param gradient flows through
           the custom-vjp OTA gather (LAN psum -> masked MAC psum -> ĝ);
           Adam on the FSDP shards (the PS update), local Adam on heads.

Every channel/weighting knob is TRACED (DESIGN.md §3.8): ``step_fn`` takes
an optional ``ChannelParams`` whose leaves (σ², H_th, noise std, the
``ota_on`` gate AND the ``fgn_on`` weighting gate) are plain arrays, so one
compiled step serves every scenario — dynamic vs. equal weighting is a
``jnp.where`` blend of the Alg.-2 update and the p≡1 passthrough (the same
gating ``sim.step_with_channel`` uses via ``fgn_update_gated``), never a
retrace. Phases 0/A/B always run; the equal-weight scenario simply selects
the passthrough (collectives stay uniform across devices — no lax.cond).
Omitting ``chan`` uses the knobs baked from the factory's ``FLConfig`` —
and when that config is the naive baseline (equal weighting AND τ_h = 0),
default-chan calls take a statically-specialized trace with phases 0/A/B
removed entirely (their outputs could never be consumed).

Scale adaptations vs the paper (DESIGN.md §3.7): τ_ω = 1 (per-client local
ω copies are impossible at 14B-141B params); the loss over the vocab head
is computed in sequence chunks to bound logit memory. With τ_h = 0 there
is no phase A, so heads train on the phase-C gradient instead (for every
scenario — head training must be scenario-uniform under a traced gate).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import FLConfig, TrainConfig
from repro.core.channel import ChannelParams, channel_params, cluster_channel
from repro.core.hota import (
    OTACtx, build_axes_registry, channel_mask_for, cluster_index, fold_tags,
    full_transmission_mask, identity_hook, make_ota_gather,
    make_packed_final_gather, make_param_hook, packed_final_norm,
    shard_specs_for, _fsdp_axis, _is_axes, _mesh_client_axes,
    _mesh_cluster_axes, _mesh_data_axes,
)
from repro.models.model import Model
from repro.models.params import init_params, logical_axes
from repro.optim.adam import AdamState, adam_init, adam_update
from repro.sharding.mesh_utils import shard_map_compat

LOSS_CHUNK = 512


# no spec here references the "model" axis, so the compat fallback's
# full-manual mode is spec-equivalent for this step
_shard_map = shard_map_compat


def chunked_lm_loss(head, head_apply, feats, labels, chunk=LOSS_CHUNK):
    """CE over a big vocab computed in sequence chunks (remat'd)."""
    b, s, d = feats.shape
    if s % chunk != 0 or s <= chunk:
        logits = head_apply(head, feats)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
        return -jnp.mean(ll)
    n = s // chunk
    fc = feats.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, xs):
        f, l = xs
        logits = head_apply(head, f)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, l[..., None], -1)[..., 0]
        return acc + jnp.sum(ll), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (fc, lc))
    return -tot / (b * s)


def cls_head_loss(head, head_apply, feats, labels):
    logits = head_apply(head, feats)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1)[..., 0])


class HotaState(NamedTuple):
    omega: Any          # {"trunk","final"} — FSDP shards (global arrays)
    opt: Any            # AdamState over omega
    heads: Any          # per-client stacked: leaves (n_total_clients, ...)
    head_opt: Any
    p: jax.Array        # (n_total_clients,)
    fgn_mu: jax.Array   # (n_total_clients,)
    fgn_nu: jax.Array
    fgn_t: jax.Array    # scalar
    f0: jax.Array       # (n_total_clients,)
    step: jax.Array


def make_hota_train_step(
    model: Model,
    mesh,
    fl: FLConfig,
    tcfg: TrainConfig,
    *,
    loss_kind: str = "lm",
    n_out: Optional[int] = None,
):
    """Returns (init_fn, sharded_step_fn, state_sharding, batch_sharding).

    ``sharded_step_fn(state, tokens, labels, key, chan=None)``: ``chan`` is
    an optional traced ``ChannelParams`` (σ² of shape (n_total_clusters,))
    overriding the factory config's knobs for this call — scenario sweeps
    pass a different ``chan`` per call into ONE compiled step."""
    cfg = model.cfg
    data_axes = _mesh_data_axes(mesh)           # ("cluster","client")
    cluster_axes = _mesh_cluster_axes(mesh)     # ("pod","cluster") | ("cluster",)
    client_axes = _mesh_client_axes(mesh)       # all FL axes
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_clients = sizes["client"]
    n_shards = int(np.prod([sizes[a] for a in data_axes]))
    n_total_clients = int(np.prod([sizes[a] for a in client_axes]))
    n_total_clusters = int(np.prod([sizes[a] for a in cluster_axes]))
    manual_axes = set(client_axes)

    compute_dtype = jnp.dtype(cfg.compute_dtype)
    gather = make_ota_gather(data_axes, cluster_axes, n_clients, n_shards,
                             compute_dtype, mode=fl.ota_mode)
    registry = build_axes_registry(model)
    chan_all = channel_params(fl, n_clusters=n_total_clusters)

    head_specs = model.head_specs(n_out)
    final_axes = [a for a in jax.tree.leaves(
        logical_axes(model.final_specs()), is_leaf=_is_axes)]
    # ω̃ rides the flat-packed OTA path: one slab, one fused mask kernel,
    # one set of psums for the whole subtree (see make_packed_final_gather).
    final_gather = (make_packed_final_gather(
        data_axes, cluster_axes, n_clients, n_shards, compute_dtype,
        final_axes) if fl.use_pallas_ota else None)

    if loss_kind == "lm":
        loss_fn = lambda head, feats, labels: chunked_lm_loss(
            head, model.head_apply, feats, labels)
    else:
        loss_fn = lambda head, feats, labels: cls_head_loss(
            head, model.head_apply, feats, labels)

    # ---------------- shardings ----------------
    omega_manual = shard_specs_for(model, mesh)          # manual FL axes only
    heads_manual = jax.tree.map(
        lambda s: P(client_axes), head_specs,
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes"))
    scalar_clients = P(client_axes)

    state_specs = HotaState(
        omega=omega_manual,
        opt=AdamState(step=P(), mu=omega_manual, nu=omega_manual),
        heads=heads_manual,
        head_opt=AdamState(step=P(), mu=heads_manual, nu=heads_manual),
        p=scalar_clients, fgn_mu=scalar_clients, fgn_nu=scalar_clients,
        fgn_t=P(), f0=scalar_clients, step=P())
    batch_spec = (P(client_axes), P(client_axes))
    metric_spec = {"loss": P(), "p_mean": P(), "p_min": P(), "p_max": P(),
                   "fgrad": P(), "gnorm_mean": P()}

    # ---------------- init ----------------
    def init_fn(key: jax.Array) -> HotaState:
        k1, k2 = jax.random.split(key)
        omega = {
            "final": init_params(model.final_specs(), jax.random.fold_in(k1, 7)),
            "trunk": init_params(model.trunk_specs(), k1),
        }
        heads = jax.vmap(lambda kc: init_params(head_specs, kc))(
            jax.random.split(k2, n_total_clients))
        zc = jnp.zeros((n_total_clients,), jnp.float32)
        zeros32 = lambda t: jax.tree.map(
            lambda x: jnp.zeros_like(x, jnp.float32), t)
        head_opt = AdamState(step=jnp.zeros((), jnp.int32),
                             mu=zeros32(heads), nu=zeros32(heads))
        return HotaState(
            omega=omega, opt=adam_init(omega), heads=heads,
            head_opt=head_opt,
            p=jnp.ones((n_total_clients,), jnp.float32),
            fgn_mu=zc, fgn_nu=zc, fgn_t=jnp.zeros((), jnp.int32),
            f0=jnp.ones((n_total_clients,), jnp.float32),
            step=jnp.zeros((), jnp.int32))

    # ---------------- the sharded step ----------------
    def _step(state: HotaState, tokens, labels, key, chan: ChannelParams,
              fast: bool = False):
        base_key = jax.random.fold_in(key, state.step)
        cidx = cluster_index(cluster_axes)
        chan_c = cluster_channel(chan, cidx)
        head = jax.tree.map(lambda a: a[0], state.heads)
        head_opt = AdamState(step=state.head_opt.step,
                             mu=jax.tree.map(lambda a: a[0], state.head_opt.mu),
                             nu=jax.tree.map(lambda a: a[0], state.head_opt.nu))
        p_i = state.p[0]
        f0_i = state.f0[0]

        if fast:
            # statically-specialized naive baseline (equal weighting,
            # τ_h = 0, no chan override): phases 0/A/B vanish. Same
            # passthrough semantics as the traced gate below, minus the
            # discarded FGN inputs (f0 stays frozen — it is only read by
            # the FGN branch, which this trace can never take).
            p_new = p_i
            mu, nu = state.fgn_mu[0], state.fgn_nu[0]
            fgn_t_new = state.fgn_t
            fgrad_val = jnp.zeros(())
            n_i = jnp.zeros(())
            f0 = f0_i
        else:
            # ---- phase 0: trunk features (ω frozen; broadcast = gather) --
            hook_fwd = make_param_hook(gather, registry, base_key, 1.0,
                                       chan_c)
            hidden, _, _ = model.trunk_apply(state.omega["trunk"], tokens,
                                             mode="train",
                                             param_hook=hook_fwd)
            hidden = jax.lax.stop_gradient(hidden)

            final_full = _plain_gather_tree(state.omega["final"], final_axes,
                                            data_axes, compute_dtype)

            def tail_loss(ff, hd):
                feats = model.final_apply(ff, hidden)
                return loss_fn(hd, feats, labels)

            # ---- phase A: τ_h personalized-head steps (Alg. 1 l. 10-11) --
            def head_step(carry, _):
                hd, hopt = carry
                g = jax.grad(lambda h_: tail_loss(final_full, h_))(hd)
                hd, hopt = adam_update(g, hopt, hd, tcfg.lr)
                return (hd, hopt), None
            (head, head_opt), _ = jax.lax.scan(
                head_step, (head, head_opt), None, length=fl.tau_h)

            # ---- phase B: FGN inputs + distributed Alg. 2 ----
            F_i, g_final = jax.value_and_grad(
                lambda ff: tail_loss(ff, head))(final_full)
            if final_gather is not None:
                n_i = packed_final_norm(g_final, base_key, chan_c,
                                        cluster_axes)
            else:
                n_i = _masked_final_norm(g_final, final_axes, base_key,
                                         chan_c, fl, cluster_axes,
                                         n_clients)
            f0 = jnp.where(state.step == 0, F_i, f0_i)
            ratio = F_i / jnp.maximum(f0, 1e-12)

            # Alg. 2, computed unconditionally so the psums stay uniform
            # across devices, then selected by the traced weighting gate —
            # equal-weight scenarios take the passthrough of the SAME
            # trace (the distributed analogue of fgn_update_gated).
            gbar = jax.lax.pmean(p_i * n_i, CLIENT_AXIS_NAME)
            rmean = jax.lax.pmean(ratio, CLIENT_AXIS_NAME)
            target = jnp.power(
                jnp.maximum(ratio / jnp.maximum(rmean, 1e-12), 1e-12),
                fl.gamma)
            resid = p_i * n_i - gbar * target
            gp = jnp.sign(resid) * n_i
            fgrad_fgn = jax.lax.psum(jnp.abs(resid), CLIENT_AXIS_NAME)
            # scalar Adam on p_i (state shared-stepped)
            t = (state.fgn_t + 1).astype(jnp.float32)
            b1, b2, eps = 0.9, 0.999, 1e-8
            mu_fgn = b1 * state.fgn_mu[0] + (1 - b1) * gp
            nu_fgn = b2 * state.fgn_nu[0] + (1 - b2) * gp * gp
            p_fgn = p_i - fl.alpha * (mu_fgn / (1 - b1 ** t)) / (
                jnp.sqrt(nu_fgn / (1 - b2 ** t)) + eps)
            p_fgn = jnp.maximum(p_fgn, fl.p_min + 1e-6)
            p_fgn = p_fgn * n_clients / jnp.maximum(
                jax.lax.psum(p_fgn, CLIENT_AXIS_NAME), 1e-12)

            # gate off: p/mu/nu/t ALL pass through untouched — identical
            # to fgn_update_gated's FGNState gating, so a scenario
            # schedule that flips the gate mid-run sees the same p
            # trajectory (and the same Adam bias-correction t) as the
            # sim path. p starts at 1, so for pure-equal runs the
            # passthrough is the old static p≡1 branch.
            fgn_on = chan_c.fgn_on > 0.5
            p_new = jnp.where(fgn_on, p_fgn, p_i)
            mu = jnp.where(fgn_on, mu_fgn, state.fgn_mu[0])
            nu = jnp.where(fgn_on, nu_fgn, state.fgn_nu[0])
            fgn_t_new = jnp.where(fgn_on, state.fgn_t + 1, state.fgn_t)
            fgrad_val = jnp.where(fgn_on, fgrad_fgn, jnp.zeros(()))

        # ---- phase C: full backward through the OTA aggregation ----
        # Channel keys fold only (step, layer, leaf): masks and AWGN are
        # identical across microbatches, so averaging the per-microbatch
        # estimates equals ONE MAC transmission of the round-averaged
        # x^(l) — exact Alg.-1 round semantics under grad accumulation.
        hook = make_param_hook(gather, registry, base_key, p_new, chan_c,
                               final_packed_gather=final_gather)

        def mb_loss(omega, hd, tok_mb, lab_mb):
            h, aux, _ = model.trunk_apply(omega["trunk"], tok_mb,
                                          mode="train", param_hook=hook)
            ff = hook(omega["final"], "final")
            feats = model.final_apply(ff, h)
            return loss_fn(hd, feats, lab_mb) + aux

        n_mb = max(fl.microbatches, 1)
        b_loc = tokens.shape[0]
        assert b_loc % n_mb == 0, (b_loc, n_mb)
        if n_mb == 1:
            loss_val, (g_omega, g_head) = jax.value_and_grad(
                mb_loss, argnums=(0, 1))(state.omega, head, tokens, labels)
        else:
            tok_mb = tokens.reshape((n_mb, b_loc // n_mb) + tokens.shape[1:])
            lab_mb = labels.reshape((n_mb, b_loc // n_mb) + labels.shape[1:])

            def mb_body(carry, xs):
                g_acc, h_acc, l_acc = carry
                t_i, l_i = xs
                l_val, (g_om, g_hd) = jax.value_and_grad(
                    mb_loss, argnums=(0, 1))(state.omega, head, t_i, l_i)
                g_acc = jax.tree.map(jnp.add, g_acc, g_om)
                h_acc = jax.tree.map(jnp.add, h_acc, g_hd)
                return (g_acc, h_acc, l_acc + l_val), None

            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                              state.omega)
            h0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), head)
            (g_omega, g_head, l_sum), _ = jax.lax.scan(
                mb_body, (g0, h0, jnp.zeros((), jnp.float32)),
                (tok_mb, lab_mb))
            g_omega = jax.tree.map(lambda x: x / n_mb, g_omega)
            g_head = jax.tree.map(lambda x: x / n_mb, g_head)
            loss_val = l_sum / n_mb

        omega, opt = adam_update(g_omega, state.opt, state.omega, tcfg.lr,
                                 tcfg.betas[0], tcfg.betas[1], tcfg.eps,
                                 tcfg.weight_decay)
        # Alg. 1 trains heads only in the τ_h phase (lines 10-11); with
        # τ_h = 0 there is no phase A, so heads train on the phase-C
        # gradient instead — statically, for EVERY scenario, so the trace
        # stays weighting-polymorphic.
        if fl.tau_h == 0:
            head, head_opt = adam_update(g_head, head_opt, head, tcfg.lr)

        new_state = HotaState(
            omega=omega, opt=opt,
            heads=jax.tree.map(lambda a: a[None], head),
            head_opt=AdamState(step=head_opt.step,
                               mu=jax.tree.map(lambda a: a[None], head_opt.mu),
                               nu=jax.tree.map(lambda a: a[None], head_opt.nu)),
            p=p_new[None], fgn_mu=mu[None], fgn_nu=nu[None],
            fgn_t=fgn_t_new, f0=f0[None], step=state.step + 1)

        metrics = {
            "loss": jax.lax.pmean(loss_val, client_axes),
            "p_mean": jax.lax.pmean(p_new, client_axes),
            "p_min": -jax.lax.pmax(-p_new, client_axes),
            "p_max": jax.lax.pmax(p_new, client_axes),
            "fgrad": jax.lax.pmean(fgrad_val, client_axes),
            "gnorm_mean": jax.lax.pmean(n_i, client_axes),
        }
        return new_state, metrics

    chan_spec = ChannelParams(*([P()] * len(ChannelParams._fields)))
    in_specs = (state_specs, batch_spec[0], batch_spec[1], P(), chan_spec)
    sharded_inner = _shard_map(
        _step, mesh=mesh, in_specs=in_specs,
        out_specs=(state_specs, metric_spec), axis_names=manual_axes)
    # statically-specialized naive baseline: with equal weighting and no
    # head phase baked into the config, the FGN inputs can never be
    # consumed, so default-chan calls dispatch to a trace with phases
    # 0/A/B removed (the pre-traced-knobs fast path). A supplied chan
    # always takes the scenario-polymorphic trace.
    fast_inner = (_shard_map(
        partial(_step, fast=True), mesh=mesh, in_specs=in_specs,
        out_specs=(state_specs, metric_spec), axis_names=manual_axes)
        if fl.weighting == "equal" and fl.tau_h == 0 else None)

    def sharded_step(state: HotaState, tokens, labels, key,
                     chan: Optional[ChannelParams] = None):
        if chan is None:
            inner = fast_inner if fast_inner is not None else sharded_inner
            return inner(state, tokens, labels, key, chan_all)
        if chan.sigma2.shape != (n_total_clusters,):
            raise ValueError(
                f"chan.sigma2 shape {chan.sigma2.shape} != "
                f"(n_total_clusters,) = ({n_total_clusters},)")
        return sharded_inner(state, tokens, labels, key, chan)

    return init_fn, sharded_step, state_specs, batch_spec


CLIENT_AXIS_NAME = "client"


def _plain_gather_tree(shards, axes_list, data_axes, compute_dtype):
    leaves, treedef = jax.tree.flatten(shards)
    out = []
    for leaf, axes in zip(leaves, axes_list):
        ax = _fsdp_axis(axes)
        if ax >= 0:
            leaf = jax.lax.all_gather(leaf, data_axes, axis=ax, tiled=True)
        out.append(leaf.astype(compute_dtype))
    return jax.tree.unflatten(treedef, out)


def _masked_final_norm(g_final, axes_list, base_key, chan_c: ChannelParams,
                       fl, cluster_axes, n_clients):
    """n_i = ‖M ∘ ∇_{ω̃}F_i‖ with the same masks the transmission uses
    (per-region draws in scatter mode — full_transmission_mask mirrors the
    gather backward's key scheme exactly)."""
    leaves = jax.tree.leaves(g_final)
    total = jnp.zeros((), jnp.float32)
    for i, (g, axes) in enumerate(zip(leaves, axes_list)):
        key = fold_tags(base_key, "final", (), i)
        mask = full_transmission_mask(
            key, g.shape, _fsdp_axis(axes), n_clients, chan_c.sigma2,
            chan_c.h_threshold, chan_c.ota_on, cluster_axes,
            scatter_mode=(fl.ota_mode == "scatter"))
        total = total + jnp.sum(
            jnp.where(mask, g.astype(jnp.float32), 0.0) ** 2)
    return jnp.sqrt(total)
