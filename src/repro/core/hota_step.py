"""The distributed HOTA-FedGradNorm training step (shard_map + custom-vjp OTA).

``make_hota_train_step(model, mesh, fl, tcfg)`` returns (init_fn, step_fn,
state_specs) where step_fn is the *full* Algorithm 1 round:

  phase 0  trunk forward once (PS->IS->client broadcast = FSDP gather)
  phase A  τ_h personalized-head Adam steps on the frozen features
  phase B  FGN inputs: per-client tail loss + masked ‖∇_{ω̃}F‖ (eq. 6),
           then the distributed Alg. 2 update of p (psum-means over "client")
  phase C  full forward/backward; every shared-param gradient flows through
           the custom-vjp OTA gather (LAN psum -> masked MAC psum -> ĝ);
           Adam on the FSDP shards (the PS update), local Adam on heads.

Two phase-C/optimizer engines share the phases (DESIGN.md §3.10):

* **slab-native** (``fl.use_pallas_ota=True``, the default): the WHOLE
  shared model rides ONE packed multi-section layout — a single
  custom-vjp gather (``repro.core.hota_slab.make_packed_omega_gather``)
  whose backward runs the fused mask+weighted-apply kernel on each
  leaf's storage in place (zero-copy — the (P,) slab never
  materializes) and needs one psum set for the whole model; phase B's
  ‖M∘∇ω̃‖ re-draws only the ω̃ section stream; the PS Adam runs on the
  slab view (``repro.optim.adam.SlabAdamState`` — moments as one flat
  slab, params unpacked once at the model-apply boundary). ``ota_mode``
  does not apply to this engine (DESIGN.md §3.11).
* **per-leaf** (``use_pallas_ota=False``): the PR-1 oracle — per-leaf
  param hooks, per-leaf gain draws, 3 psums per leaf, pytree Adam.

Every channel/weighting knob is TRACED (DESIGN.md §3.8): ``step_fn`` takes
an optional ``ChannelParams`` whose leaves (σ², H_th, noise std, the
``ota_on`` gate AND the ``fgn_on`` weighting gate) are plain arrays, so one
compiled step serves every scenario — dynamic vs. equal weighting is a
``jnp.where`` blend of the Alg.-2 update and the p≡1 passthrough (the same
gating ``sim.step_with_channel`` uses via ``fgn_update_gated``), never a
retrace. Phases 0/A/B always run; the equal-weight scenario simply selects
the passthrough (collectives stay uniform across devices — no lax.cond).
Omitting ``chan`` uses the knobs baked from the factory's ``FLConfig`` —
and when that config is the naive baseline (equal weighting AND τ_h = 0),
default-chan calls take a statically-specialized trace with phases 0/A/B
removed entirely (their outputs could never be consumed).

``make_hota_step_parts`` exposes the raw (un-shard_mapped) step body plus
its specs so other harnesses can lay it on bigger meshes — the 2-D
(scenario × client) ``DistScenarioBank`` (``repro.core.sweep``) vmaps it
over scenario slices inside one shard_map.

Scale adaptations vs the paper (DESIGN.md §3.7): τ_ω = 1 (per-client local
ω copies are impossible at 14B-141B params); the loss over the vocab head
is computed in sequence chunks to bound logit memory. With τ_h = 0 there
is no phase A, so heads train on the phase-C gradient instead (for every
scenario — head training must be scenario-uniform under a traced gate).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import FLConfig, TrainConfig
from repro.core import ota
from repro.core.channel import (
    ChannelParams, FaultParams, channel_params, cluster_channel,
    fault_params,
)
from repro.core.hota import (
    OTACtx, build_axes_registry, cluster_index, fold_tags,
    full_transmission_mask, make_ota_gather, make_param_hook,
    shard_specs_for, _fsdp_axis, _is_axes, _mesh_client_axes,
    _mesh_cluster_axes, _mesh_data_axes,
)
from repro.core.hota_slab import (
    _fsdp_axis_full, make_packed_omega_gather, packed_omega_key,
    plain_gather_full, sectioned_final_norm,
)
from repro.models.model import Model
from repro.models.params import abstract_params, init_params, logical_axes
from repro.optim.adam import (
    AdamState, SlabAdamState, adam_init, adam_update, slab_adam_init,
    slab_adam_update,
)
from repro.sharding.mesh_utils import shard_map_compat

LOSS_CHUNK = 512

# One entry appended per TRACE of a step body (tag, ota_mode). Pinned by
# the retrace check in tests/dist_programs/dist_slab_step.py: sweeping
# ChannelParams VALUES through a compiled step must never grow this list —
# only genuinely static knobs (ota_mode, use_pallas_ota, topology) may
# (DESIGN.md §3.11).
TRACE_LOG: List[Tuple[str, str]] = []


# no spec here references the "model" axis, so the compat fallback's
# full-manual mode is spec-equivalent for this step
_shard_map = shard_map_compat


def chunked_lm_loss(head, head_apply, feats, labels, chunk=LOSS_CHUNK):
    """CE over a big vocab computed in sequence chunks (remat'd)."""
    b, s, d = feats.shape
    if s % chunk != 0 or s <= chunk:
        logits = head_apply(head, feats)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
        return -jnp.mean(ll)
    n = s // chunk
    fc = feats.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, xs):
        f, l = xs
        logits = head_apply(head, f)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, l[..., None], -1)[..., 0]
        return acc + jnp.sum(ll), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (fc, lc))
    return -tot / (b * s)


def cls_head_loss(head, head_apply, feats, labels):
    logits = head_apply(head, feats)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1)[..., 0])


class HotaState(NamedTuple):
    omega: Any          # {"trunk","final"} — FSDP shards (global arrays)
    opt: Any            # AdamState over omega
    heads: Any          # per-client stacked: leaves (n_total_clients, ...)
    head_opt: Any
    p: jax.Array        # (n_total_clients,)
    fgn_mu: jax.Array   # (n_total_clients,)
    fgn_nu: jax.Array
    fgn_t: jax.Array    # scalar
    f0: jax.Array       # (n_total_clients,)
    step: jax.Array
    # Stale-model state (DESIGN.md §3.15) — present only when fl.faults
    # (None = empty pytree node, legacy states and specs unchanged).
    # Trailing position matters: flatten order keeps the legacy prefix,
    # so fault-free states round-trip checkpoints bit-identically.
    omega_stale: Any = None   # delayed FSDP-sharded copy stragglers use
    stale_age: Any = None     # () rounds since omega_stale was refreshed


class StepParts(NamedTuple):
    """The raw distributed round, before any shard_map: everything a
    harness needs to lay the body on its own mesh (the 1-D wrapper below,
    or the 2-D scenario × client ``DistScenarioBank``)."""
    init_fn: Callable
    step: Callable          # step(state, tokens, labels, key, chan, faults[, fast])
    state_specs: Any        # HotaState of PartitionSpecs (FL axes only)
    batch_spec: Tuple
    metric_spec: Dict
    chan_spec: Any          # ChannelParams of P() (replicated knobs)
    chan_all: Any           # the factory FLConfig's baked ChannelParams
    n_total_clusters: int
    has_fast: bool          # statically-specialized naive baseline exists
    faults_spec: Any = None     # FaultParams of P() (replicated knobs)
    faults_all: Any = None      # the factory FLConfig's baked FaultParams


def make_hota_step_parts(
    model: Model,
    mesh,
    fl: FLConfig,
    tcfg: TrainConfig,
    *,
    loss_kind: str = "lm",
    n_out: Optional[int] = None,
) -> StepParts:
    """Build the un-shard_mapped Alg.-1 round body + its specs for ``mesh``
    (only the FL axes — cluster/client/pod — of the mesh are read, so the
    same body serves 1-D FL meshes and the 2-D scenario × client mesh)."""
    cfg = model.cfg
    data_axes = _mesh_data_axes(mesh)           # ("cluster","client")
    cluster_axes = _mesh_cluster_axes(mesh)     # ("pod","cluster") | ("cluster",)
    client_axes = _mesh_client_axes(mesh)       # all FL axes
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_clients = sizes["client"]
    n_shards = int(np.prod([sizes[a] for a in data_axes]))
    n_total_clients = int(np.prod([sizes[a] for a in client_axes]))
    n_total_clusters = int(np.prod([sizes[a] for a in cluster_axes]))

    compute_dtype = jnp.dtype(cfg.compute_dtype)
    gather = make_ota_gather(data_axes, cluster_axes, n_clients, n_shards,
                             compute_dtype, mode=fl.ota_mode)
    registry = build_axes_registry(model)
    chan_all = channel_params(fl, n_clusters=n_total_clusters)
    faults_all = fault_params(fl)
    if fl.faults and not fl.use_pallas_ota:
        raise ValueError(
            "fl.faults requires the slab engine (use_pallas_ota=True): the "
            "per-leaf distributed path has no participation-aware "
            "aggregation — use the per-leaf SIMULATOR (repro.core.sim) as "
            "the fault oracle instead (DESIGN.md §3.14)")
    if fl.ota_streaming:
        raise ValueError(
            "fl.ota_streaming is a SIMULATOR engine (DESIGN.md §3.15): the "
            "distributed round already holds one cluster per device group, "
            "so there is no cluster batch to stream — the flag would be "
            "silently inert here. Use fl.ota_sectioned for the section-"
            "streaming distributed schedule (DESIGN.md §3.16)")
    if fl.ota_sectioned and not fl.use_pallas_ota:
        raise ValueError(
            "fl.ota_sectioned requires the slab engine (use_pallas_ota="
            "True): the per-leaf distributed path has no section layout to "
            "stream — the flag would be silently inert (DESIGN.md §3.16)")
    if fl.ota_sectioned and fl.ota_sections != "toplevel":
        raise ValueError(
            "fl.ota_sectioned requires a multi-section layout "
            "(ota_sections='toplevel'): with the legacy two-section 'tail' "
            "layout the head IS the whole trunk, so section streaming "
            "cannot bound peak memory (DESIGN.md §3.16)")
    if fl.max_section_rows and not fl.use_pallas_ota:
        raise ValueError(
            "fl.max_section_rows splits the slab engine's section layout "
            "(use_pallas_ota=True); on the per-leaf path it would be "
            "silently inert (DESIGN.md §4)")

    head_specs = model.head_specs(n_out)
    final_axes = [a for a in jax.tree.leaves(
        logical_axes(model.final_specs()), is_leaf=_is_axes)]
    use_slab = fl.use_pallas_ota
    if use_slab:
        # slab-native engine: the ENTIRE shared model {final, trunk} rides
        # one multi-section packed gather — one fused kernel per leaf (in
        # place), ONE psum set for the whole model (DESIGN.md §3.10).
        omega_template = {"final": abstract_params(model.final_specs()),
                          "trunk": abstract_params(model.trunk_specs())}
        omega_axes = [a for a in jax.tree.leaves(
            {"final": logical_axes(model.final_specs()),
             "trunk": logical_axes(model.trunk_specs())}, is_leaf=_is_axes)]
        # section layout from the static FLConfig fields (normally the
        # tuned LayoutChoice — repro.common.layout_tune): the Section
        # partition decides the stream folds of every channel draw
        omega_gather, omega_pk = make_packed_omega_gather(
            data_axes, cluster_axes, n_clients, n_shards, compute_dtype,
            omega_template, omega_axes, n_clusters=n_total_clusters,
            sections=fl.ota_sections,
            min_section_rows=fl.min_section_rows,
            max_section_rows=fl.max_section_rows,
            sectioned=fl.ota_sectioned)
        # local (per-device) slab length: FSDP leaves contribute their
        # shard, replicated leaves their full size — the SlabAdamState
        # moments layout (repro.optim.adam)
        omega_fsdp = [_fsdp_axis_full(ax) for ax in omega_axes]
        slab_local_len = sum(
            int(np.prod(l.shape)) // (n_shards if ax >= 0 else 1)
            for l, ax in zip(jax.tree.leaves(omega_template), omega_fsdp))
    else:
        # the PR-2 combination (per-leaf trunk + packed-ω̃ gather) is
        # retired: use_pallas_ota=True now means the whole-model slab
        # engine, and False is the all-per-leaf oracle
        # (make_packed_final_gather stays exported + tested as the
        # subtree-scale reference of the packed formulation).
        omega_gather = omega_pk = None
        omega_axes = omega_fsdp = slab_local_len = None

    if loss_kind == "lm":
        loss_fn = lambda head, feats, labels: chunked_lm_loss(
            head, model.head_apply, feats, labels)
    else:
        loss_fn = lambda head, feats, labels: cls_head_loss(
            head, model.head_apply, feats, labels)

    # ---------------- shardings ----------------
    omega_manual = shard_specs_for(model, mesh)          # manual FL axes only
    heads_manual = jax.tree.map(
        lambda s: P(client_axes), head_specs,
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes"))
    scalar_clients = P(client_axes)

    if use_slab:
        # moments live as ONE flat slab per device; the global array is
        # the shard-major concatenation of the local slabs
        slab_spec = P(data_axes if len(data_axes) > 1 else data_axes[0])
        opt_spec = SlabAdamState(step=P(), mu=slab_spec, nu=slab_spec)
    else:
        opt_spec = AdamState(step=P(), mu=omega_manual, nu=omega_manual)
    state_specs = HotaState(
        omega=omega_manual,
        opt=opt_spec,
        heads=heads_manual,
        head_opt=AdamState(step=P(), mu=heads_manual, nu=heads_manual),
        p=scalar_clients, fgn_mu=scalar_clients, fgn_nu=scalar_clients,
        fgn_t=P(), f0=scalar_clients, step=P(),
        # the stale copy shards exactly like omega (same FSDP layout)
        omega_stale=(omega_manual if fl.faults else None),
        stale_age=(P() if fl.faults else None))
    batch_spec = (P(client_axes), P(client_axes))
    metric_spec = {"loss": P(), "p_mean": P(), "p_min": P(), "p_max": P(),
                   "fgrad": P(), "gnorm_mean": P()}
    if fl.faults:
        metric_spec = dict(metric_spec, skipped=P(), n_participants=P())

    # ---------------- init ----------------
    def init_fn(key: jax.Array) -> HotaState:
        k1, k2 = jax.random.split(key)
        omega = {
            "final": init_params(model.final_specs(),
                                 jax.random.fold_in(k1, ota.FINAL_INIT_FOLD)),
            "trunk": init_params(model.trunk_specs(), k1),
        }
        heads = jax.vmap(lambda kc: init_params(head_specs, kc))(
            jax.random.split(k2, n_total_clients))
        zc = jnp.zeros((n_total_clients,), jnp.float32)
        zeros32 = lambda t: jax.tree.map(
            lambda x: jnp.zeros_like(x, jnp.float32), t)
        head_opt = AdamState(step=jnp.zeros((), jnp.int32),
                             mu=zeros32(heads), nu=zeros32(heads))
        if use_slab:
            opt0 = SlabAdamState(
                step=jnp.zeros((), jnp.int32),
                mu=jnp.zeros((n_shards * slab_local_len,), jnp.float32),
                nu=jnp.zeros((n_shards * slab_local_len,), jnp.float32))
        else:
            opt0 = adam_init(omega)
        return HotaState(
            omega=omega, opt=opt0, heads=heads,
            head_opt=head_opt,
            p=jnp.ones((n_total_clients,), jnp.float32),
            fgn_mu=zc, fgn_nu=zc, fgn_t=jnp.zeros((), jnp.int32),
            f0=jnp.ones((n_total_clients,), jnp.float32),
            step=jnp.zeros((), jnp.int32),
            omega_stale=(jax.tree.map(jnp.array, omega) if fl.faults
                         else None),
            stale_age=(jnp.zeros((), jnp.float32) if fl.faults else None))

    # ---------------- the sharded step ----------------
    def _step(state: HotaState, tokens, labels, key, chan: ChannelParams,
              faults: FaultParams = None, fast: bool = False):
        TRACE_LOG.append(("slab" if use_slab else "leaf", fl.ota_mode))
        base_key = jax.random.fold_in(key, state.step)
        cidx = cluster_index(cluster_axes)
        chan_c = cluster_channel(chan, cidx)
        head = jax.tree.map(lambda a: a[0], state.heads)
        head_opt = AdamState(step=state.head_opt.step,
                             mu=jax.tree.map(lambda a: a[0], state.head_opt.mu),
                             nu=jax.tree.map(lambda a: a[0], state.head_opt.nu))
        head0, head_opt0 = head, head_opt
        p_i = state.p[0]
        f0_i = state.f0[0]

        # fault injection (DESIGN.md §3.14, static fl.faults gate): every
        # device draws the SAME (C, N) participation from base_key's
        # reserved PART_FOLD domain (disjoint from all channel streams —
        # resampling fault rates is CRN-safe), then reads its own slot.
        # Stragglers carry the stale-model variant (DESIGN.md §3.15):
        # the whole client round — features, head steps, FGN inputs and
        # the phase-C loss — evaluates against the delayed ``omega_stale``
        # copy, and the transmit weight takes the FedBuff 1/√(1+age)
        # discount from the carried age, exactly like the sim engine.
        partc = None
        stale_full = None
        if fl.faults:
            fp = faults_all if faults is None else faults
            partc = ota.draw_participation(base_key, fp, n_total_clusters,
                                           n_clients)
            client_idx = jax.lax.axis_index(CLIENT_AXIS_NAME)
            part_me = partc.part[cidx, client_idx]
            stale_me = partc.stale[cidx, client_idx]
            live_me = partc.live[cidx]

        if fast:
            # statically-specialized naive baseline (equal weighting,
            # τ_h = 0, no chan override): phases 0/A/B vanish. Same
            # passthrough semantics as the traced gate below, minus the
            # discarded FGN inputs (f0 stays frozen — it is only read by
            # the FGN branch, which this trace can never take).
            p_new = p_i
            mu, nu = state.fgn_mu[0], state.fgn_nu[0]
            fgn_t_new = state.fgn_t
            fgrad_val = jnp.zeros(())
            n_i = jnp.zeros(())
            f0 = f0_i
        else:
            # ---- phase 0: trunk features (ω frozen; broadcast = gather) --
            if use_slab:
                # one plain whole-model gather — phases 0/B never
                # backprop through the channel, so no custom vjp here
                omega_full0 = plain_gather_full(state.omega, omega_fsdp,
                                                data_axes, compute_dtype)
                if partc is not None:
                    # stale-model variant (§3.15): gather the delayed
                    # copy too and let each straggler's device see IT for
                    # the whole round — the dist analogue of the sim's
                    # per-client om_eff select. The gathers stay device-
                    # uniform; only the scalar select differs per client.
                    stale_full = plain_gather_full(
                        state.omega_stale, omega_fsdp, data_axes,
                        compute_dtype)
                    omega_full0 = jax.tree.map(
                        lambda f, s: jnp.where(stale_me > 0.5, s, f),
                        omega_full0, stale_full)
                hidden, _, _ = model.trunk_apply(omega_full0["trunk"],
                                                 tokens, mode="train")
                final_full = omega_full0["final"]
            else:
                hook_fwd = make_param_hook(gather, registry, base_key, 1.0,
                                           chan_c)
                hidden, _, _ = model.trunk_apply(state.omega["trunk"],
                                                 tokens, mode="train",
                                                 param_hook=hook_fwd)
                final_full = _plain_gather_tree(state.omega["final"],
                                                final_axes, data_axes,
                                                compute_dtype)
            hidden = jax.lax.stop_gradient(hidden)

            def tail_loss(ff, hd):
                feats = model.final_apply(ff, hidden)
                return loss_fn(hd, feats, labels)

            # ---- phase A: τ_h personalized-head steps (Alg. 1 l. 10-11) --
            def head_step(carry, _):
                hd, hopt = carry
                g = jax.grad(lambda h_: tail_loss(final_full, h_))(hd)
                hd, hopt = adam_update(g, hopt, hd, tcfg.lr)
                return (hd, hopt), None
            (head, head_opt), _ = jax.lax.scan(
                head_step, (head, head_opt), None, length=fl.tau_h)

            # ---- phase B: FGN inputs + distributed Alg. 2 ----
            F_i, g_final = jax.value_and_grad(
                lambda ff: tail_loss(ff, head))(final_full)
            if use_slab:
                # eq. 5 masks = the ω̃ SECTION of the same slab draw the
                # phase-C backward applies (only that stream is re-drawn)
                n_i = sectioned_final_norm(g_final,
                                           packed_omega_key(base_key),
                                           chan_c, cluster_axes, omega_pk)
            else:
                n_i = _masked_final_norm(g_final, final_axes, base_key,
                                         chan_c, fl, cluster_axes,
                                         n_clients)
            f0 = jnp.where(state.step == 0, F_i, f0_i)
            ratio = F_i / jnp.maximum(f0, 1e-12)

            # Alg. 2, computed unconditionally so the psums stay uniform
            # across devices, then selected by the traced weighting gate —
            # equal-weight scenarios take the passthrough of the SAME
            # trace (the distributed analogue of fgn_update_gated).
            gbar = jax.lax.pmean(p_i * n_i, CLIENT_AXIS_NAME)
            rmean = jax.lax.pmean(ratio, CLIENT_AXIS_NAME)
            target = jnp.power(
                jnp.maximum(ratio / jnp.maximum(rmean, 1e-12), 1e-12),
                fl.gamma)
            resid = p_i * n_i - gbar * target
            gp = jnp.sign(resid) * n_i
            fgrad_fgn = jax.lax.psum(jnp.abs(resid), CLIENT_AXIS_NAME)
            # scalar Adam on p_i (state shared-stepped)
            t = (state.fgn_t + 1).astype(jnp.float32)
            b1, b2, eps = 0.9, 0.999, 1e-8
            mu_fgn = b1 * state.fgn_mu[0] + (1 - b1) * gp
            nu_fgn = b2 * state.fgn_nu[0] + (1 - b2) * gp * gp
            p_fgn = p_i - fl.alpha * (mu_fgn / (1 - b1 ** t)) / (
                jnp.sqrt(nu_fgn / (1 - b2 ** t)) + eps)
            p_fgn = jnp.maximum(p_fgn, fl.p_min + 1e-6)
            p_fgn = p_fgn * n_clients / jnp.maximum(
                jax.lax.psum(p_fgn, CLIENT_AXIS_NAME), 1e-12)

            # gate off: p/mu/nu/t ALL pass through untouched — identical
            # to fgn_update_gated's FGNState gating, so a scenario
            # schedule that flips the gate mid-run sees the same p
            # trajectory (and the same Adam bias-correction t) as the
            # sim path. p starts at 1, so for pure-equal runs the
            # passthrough is the old static p≡1 branch.
            fgn_on = chan_c.fgn_on > 0.5
            # under faults a dead cluster's (p, Adam moment) state also
            # freezes (its IS heard nothing this round); fgn_t stays
            # device-uniform — it is a single replicated scalar, unlike
            # the sim's per-cluster FGNState (DESIGN.md §3.14)
            fgn_upd = (fgn_on if partc is None
                       else jnp.logical_and(fgn_on, live_me > 0.5))
            p_new = jnp.where(fgn_upd, p_fgn, p_i)
            mu = jnp.where(fgn_upd, mu_fgn, state.fgn_mu[0])
            nu = jnp.where(fgn_upd, nu_fgn, state.fgn_nu[0])
            fgn_t_new = jnp.where(fgn_on, state.fgn_t + 1, state.fgn_t)
            fgrad_val = jnp.where(fgn_on, fgrad_fgn, jnp.zeros(()))

        # ---- phase C: full backward through the OTA aggregation ----
        # Channel keys fold only (step, layer, leaf): masks and AWGN are
        # identical across microbatches, so averaging the per-microbatch
        # estimates equals ONE MAC transmission of the round-averaged
        # x^(l) — exact Alg.-1 round semantics under grad accumulation.
        if use_slab:
            # one custom-vjp gather for the WHOLE model: its backward is
            # the slab-native aggregation (fused w·g·M kernel per leaf in
            # place + ONE psum set — repro.core.hota_slab). Under faults
            # the transmit weight folds participation and the FedBuff
            # staleness discount; live/n_eff generalize the eq.-10 guard.
            if partc is not None:
                # FedBuff discount from the CARRIED age (how long ago the
                # stale copy was refreshed), not the static τ — a copy
                # refreshed last round is barely discounted
                disc = jnp.where(stale_me > 0.5,
                                 jax.lax.rsqrt(1.0 + state.stale_age), 1.0)
                w_tx = jnp.asarray(p_new, jnp.float32) * part_me * disc
                ctx_live, ctx_n_eff = partc.live, partc.n_eff
            else:
                w_tx = jnp.asarray(p_new, jnp.float32)
                ctx_live = ctx_n_eff = None
            slab_ctx = OTACtx(
                p_weight=w_tx,
                key=packed_omega_key(base_key),
                # FULL (C,) σ² vector: the backward narrows to its own
                # cluster (ctx.sigma2[cidx]) in the default psum count
                # mode, and needs every cluster's σ² under
                # count_mode="local" (collective-free |M|)
                sigma2=jnp.asarray(chan.sigma2, jnp.float32),
                h_th=jnp.asarray(chan_c.h_threshold, jnp.float32),
                noise_std=jnp.asarray(chan_c.noise_std, jnp.float32),
                ota_on=jnp.asarray(chan_c.ota_on, jnp.float32),
                live=ctx_live, n_eff=ctx_n_eff)

            def mb_loss(omega, hd, tok_mb, lab_mb):
                full = omega_gather(omega, slab_ctx)
                if stale_full is not None:
                    # straight-through stale select (§3.15): a straggler
                    # evaluates the loss at the DELAYED params while the
                    # gradient still flows through the custom-vjp OTA
                    # gather. stop(sel) + fr - stop(fr) is exactly sel in
                    # value (fr - fr ≡ 0, no dtype promotion, no
                    # precision loss) and exactly d/dfr = 1 in gradient —
                    # the FedBuff delayed gradient, masked / weighted /
                    # discounted by the same kernel path as a fresh one.
                    def st_sel(fr, st):
                        sel = jnp.where(stale_me > 0.5, st, fr)
                        return (jax.lax.stop_gradient(sel) + fr
                                - jax.lax.stop_gradient(fr))
                    full = jax.tree.map(st_sel, full, stale_full)
                h, aux, _ = model.trunk_apply(full["trunk"], tok_mb,
                                              mode="train")
                feats = model.final_apply(full["final"], h)
                return loss_fn(hd, feats, lab_mb) + aux
        else:
            hook = make_param_hook(gather, registry, base_key, p_new,
                                   chan_c)

            def mb_loss(omega, hd, tok_mb, lab_mb):
                h, aux, _ = model.trunk_apply(omega["trunk"], tok_mb,
                                              mode="train", param_hook=hook)
                ff = hook(omega["final"], "final")
                feats = model.final_apply(ff, h)
                return loss_fn(hd, feats, lab_mb) + aux

        n_mb = max(fl.microbatches, 1)
        b_loc = tokens.shape[0]
        assert b_loc % n_mb == 0, (b_loc, n_mb)
        if n_mb == 1:
            loss_val, (g_omega, g_head) = jax.value_and_grad(
                mb_loss, argnums=(0, 1))(state.omega, head, tokens, labels)
        else:
            tok_mb = tokens.reshape((n_mb, b_loc // n_mb) + tokens.shape[1:])
            lab_mb = labels.reshape((n_mb, b_loc // n_mb) + labels.shape[1:])

            def mb_body(carry, xs):
                g_acc, h_acc, l_acc = carry
                t_i, l_i = xs
                l_val, (g_om, g_hd) = jax.value_and_grad(
                    mb_loss, argnums=(0, 1))(state.omega, head, t_i, l_i)
                g_acc = jax.tree.map(jnp.add, g_acc, g_om)
                h_acc = jax.tree.map(jnp.add, h_acc, g_hd)
                return (g_acc, h_acc, l_acc + l_val), None

            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                              state.omega)
            h0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), head)
            (g_omega, g_head, l_sum), _ = jax.lax.scan(
                mb_body, (g0, h0, jnp.zeros((), jnp.float32)),
                (tok_mb, lab_mb))
            g_omega = jax.tree.map(lambda x: x / n_mb, g_omega)
            g_head = jax.tree.map(lambda x: x / n_mb, g_head)
            loss_val = l_sum / n_mb

        if use_slab:
            # slab-view PS update: moments stay one flat slab, params
            # unpack exactly once (the model-apply boundary)
            omega, opt = slab_adam_update(
                g_omega, state.opt, state.omega, tcfg.lr, tcfg.betas[0],
                tcfg.betas[1], tcfg.eps, tcfg.weight_decay)
        else:
            omega, opt = adam_update(g_omega, state.opt, state.omega,
                                     tcfg.lr, tcfg.betas[0], tcfg.betas[1],
                                     tcfg.eps, tcfg.weight_decay)
        # Alg. 1 trains heads only in the τ_h phase (lines 10-11); with
        # τ_h = 0 there is no phase A, so heads train on the phase-C
        # gradient instead — statically, for EVERY scenario, so the trace
        # stays weighting-polymorphic.
        if fl.tau_h == 0:
            head, head_opt = adam_update(g_head, head_opt, head, tcfg.lr)

        if partc is not None:
            # non-participant clients keep last round's head + moments
            # (the shared head-Adam step counter stays device-uniform —
            # unlike the sim's per-slot counters; DESIGN.md §3.14)
            keep = part_me > 0.5
            head = jax.tree.map(lambda n, o: jnp.where(keep, n, o),
                                head, head0)
            head_opt = AdamState(
                step=head_opt.step,
                mu=jax.tree.map(lambda n, o: jnp.where(keep, n, o),
                                head_opt.mu, head_opt0.mu),
                nu=jax.tree.map(lambda n, o: jnp.where(keep, n, o),
                                head_opt.nu, head_opt0.nu))

        new_state = HotaState(
            omega=omega, opt=opt,
            heads=jax.tree.map(lambda a: a[None], head),
            head_opt=AdamState(step=head_opt.step,
                               mu=jax.tree.map(lambda a: a[None], head_opt.mu),
                               nu=jax.tree.map(lambda a: a[None], head_opt.nu)),
            p=p_new[None], fgn_mu=mu[None], fgn_nu=nu[None],
            fgn_t=fgn_t_new, f0=f0[None], step=state.step + 1)

        metrics = {
            "loss": jax.lax.pmean(loss_val, client_axes),
            "p_mean": jax.lax.pmean(p_new, client_axes),
            "p_min": -jax.lax.pmax(-p_new, client_axes),
            "p_max": jax.lax.pmax(p_new, client_axes),
            "fgrad": jax.lax.pmean(fgrad_val, client_axes),
            "gnorm_mean": jax.lax.pmean(n_i, client_axes),
        }

        if partc is not None:
            # round guard (DESIGN.md §3.14): gn2 is the EXACT squared
            # estimate norm, device-uniform by construction — FSDP leaves
            # psum their shard sums over the data axes, replicated leaves
            # are already identical everywhere. spike_norm=inf leaves only
            # the non-finite check; a tripped guard (or a zero-participant
            # round) freezes the whole state — bit-exact identity, step
            # counter aside — via the fgn_on-style jnp.where passthrough.
            leaves_g = jax.tree.leaves(g_omega)
            gn2_loc = sum((jnp.sum(l.astype(jnp.float32) ** 2)
                           for l, ax in zip(leaves_g, omega_fsdp)
                           if ax >= 0), jnp.zeros((), jnp.float32))
            gn2_rep = sum((jnp.sum(l.astype(jnp.float32) ** 2)
                           for l, ax in zip(leaves_g, omega_fsdp)
                           if ax < 0), jnp.zeros((), jnp.float32))
            gn2 = jax.lax.psum(gn2_loc, data_axes) + gn2_rep
            ok = jnp.logical_and(jnp.isfinite(gn2),
                                 gn2 <= fp.spike_norm * fp.spike_norm)
            skip = jnp.logical_or(partc.total < 0.5, ~ok)
            # stale-model bookkeeping (mirrors the sim): refresh the
            # delayed FSDP-sharded copy every fp.staleness rounds (age in
            # [0, τ)); the skip freeze below covers these fields too, so
            # a skipped round leaves copy + age untouched
            refresh = (state.stale_age + 1.0) >= fp.staleness
            new_state = new_state._replace(
                omega_stale=jax.tree.map(
                    lambda new, old: jnp.where(refresh, new, old),
                    omega, state.omega_stale),
                stale_age=jnp.where(refresh, 0.0, state.stale_age + 1.0))
            new_state = jax.tree.map(
                lambda new, old: jnp.where(skip, old, new),
                new_state, state)
            new_state = new_state._replace(step=state.step + 1)
            metrics = dict(metrics, skipped=skip.astype(jnp.float32),
                           n_participants=partc.total)
        return new_state, metrics

    chan_spec = ChannelParams(*([P()] * len(ChannelParams._fields)))
    faults_spec = FaultParams(*([P()] * len(FaultParams._fields)))
    return StepParts(
        init_fn=init_fn, step=_step, state_specs=state_specs,
        batch_spec=batch_spec, metric_spec=metric_spec, chan_spec=chan_spec,
        chan_all=chan_all, n_total_clusters=n_total_clusters,
        has_fast=(fl.weighting == "equal" and fl.tau_h == 0
                  and not fl.faults),
        faults_spec=faults_spec, faults_all=faults_all)


def make_hota_train_step(
    model: Model,
    mesh,
    fl: FLConfig,
    tcfg: TrainConfig,
    *,
    loss_kind: str = "lm",
    n_out: Optional[int] = None,
):
    """Returns (init_fn, sharded_step_fn, state_sharding, batch_sharding).

    ``sharded_step_fn(state, tokens, labels, key, chan=None, faults=None)``:
    ``chan`` is an optional traced ``ChannelParams`` (σ² of shape
    (n_total_clusters,)) overriding the factory config's knobs for this
    call — scenario sweeps pass a different ``chan`` per call into ONE
    compiled step. ``faults`` likewise overrides the traced fault knobs
    (consumed only when the static ``fl.faults`` gate is on)."""
    parts = make_hota_step_parts(model, mesh, fl, tcfg, loss_kind=loss_kind,
                                 n_out=n_out)
    manual_axes = set(_mesh_client_axes(mesh))
    state_specs, metric_spec = parts.state_specs, parts.metric_spec
    in_specs = (state_specs, parts.batch_spec[0], parts.batch_spec[1], P(),
                parts.chan_spec, parts.faults_spec)
    sharded_inner = _shard_map(
        parts.step, mesh=mesh, in_specs=in_specs,
        out_specs=(state_specs, metric_spec), axis_names=manual_axes)
    # statically-specialized naive baseline: with equal weighting and no
    # head phase baked into the config, the FGN inputs can never be
    # consumed, so default-chan calls dispatch to a trace with phases
    # 0/A/B removed (the pre-traced-knobs fast path). A supplied chan
    # always takes the scenario-polymorphic trace.
    fast_inner = (_shard_map(
        partial(parts.step, fast=True), mesh=mesh, in_specs=in_specs,
        out_specs=(state_specs, metric_spec), axis_names=manual_axes)
        if parts.has_fast else None)
    n_total_clusters = parts.n_total_clusters
    chan_all = parts.chan_all
    faults_all = parts.faults_all

    def sharded_step(state: HotaState, tokens, labels, key,
                     chan: Optional[ChannelParams] = None,
                     faults: Optional[FaultParams] = None):
        fp = faults_all if faults is None else faults
        if chan is None:
            inner = fast_inner if fast_inner is not None else sharded_inner
            return inner(state, tokens, labels, key, chan_all, fp)
        if chan.sigma2.shape != (n_total_clusters,):
            raise ValueError(
                f"chan.sigma2 shape {chan.sigma2.shape} != "
                f"(n_total_clusters,) = ({n_total_clusters},)")
        return sharded_inner(state, tokens, labels, key, chan, fp)

    return parts.init_fn, sharded_step, state_specs, parts.batch_spec


CLIENT_AXIS_NAME = "client"


def _plain_gather_tree(shards, axes_list, data_axes, compute_dtype):
    return plain_gather_full(shards, [_fsdp_axis(a) for a in axes_list],
                             data_axes, compute_dtype)


def _masked_final_norm(g_final, axes_list, base_key, chan_c: ChannelParams,
                       fl, cluster_axes, n_clients):
    """n_i = ‖M ∘ ∇_{ω̃}F_i‖ with the same masks the transmission uses
    (per-region draws in scatter mode — full_transmission_mask mirrors the
    gather backward's key scheme exactly)."""
    leaves = jax.tree.leaves(g_final)
    total = jnp.zeros((), jnp.float32)
    for i, (g, axes) in enumerate(zip(leaves, axes_list)):
        key = fold_tags(base_key, "final", (), i)
        mask = full_transmission_mask(
            key, g.shape, _fsdp_axis(axes), n_clients, chan_c.sigma2,
            chan_c.h_threshold, chan_c.ota_on, cluster_axes,
            scatter_mode=(fl.ota_mode == "scatter"))
        total = total + jnp.sum(
            jnp.where(mask, g.astype(jnp.float32), 0.0) ** 2)
    return jnp.sqrt(total)
