"""Paper-scale simulator of HOTA-FedGradNorm (Algorithm 1 + Algorithm 2).

Faithful execution of the paper's loop at its native scale (C=10 clusters,
N=3 clients, MLP) via ``vmap`` over (cluster, client) — no mesh required,
runs on one CPU device. This is the engine behind the reproduction
experiments (Figs. 2-4) and the oracle the distributed path is tested
against.

Per global iteration k (Alg. 1):
 1. PS broadcasts ω_k (implicit: clients read the shared tree).
 2. Each client: τ_h personalized-head steps (Adam), then τ_ω local shared
    steps (SGD, line 13), accumulating ḡ_k^(l,i) and F̄_k^(l,i).
 3. IS l runs FGN_Server (Alg. 2) on masked last-layer grad norms → p_k.
 4. IS l transmits x^(l) = Σ_i β∘g (channel-inverted, thresholded); the MAC
    superimposes clusters; PS estimates ĝ (eqs. 3, 8-10).
 5. PS updates ω (Adam by default, matching Sec. IV-B; SGD available).

With ``use_pallas_ota=True`` (the default) the channel is **slab-native**
(DESIGN.md §3.12): step 4 runs client-folded — Σ_l M_l ∘ (Σ_n p·g) is
computed leaf by leaf from the raw (C, N, ·) gradients against the
multi-section zero-copy stream layout, so neither the client-weighted
tree nor a (C, P) packed slab is ever materialized (HLO-pinned), and
step 5 is the slab-view Adam (moments as one flat slab). The per-leaf
jnp path (``use_pallas_ota=False``) stays the bit-exact oracle.

Heads are padded to the max class count across tasks so clients vmap
homogeneously; logits above a client's class count are masked to -inf.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import FLConfig, TrainConfig
from repro.common.flatpack import packer_for
from repro.core import ota
from repro.core.channel import (
    ChannelParams, FaultParams, channel_params, fault_params,
)
from repro.core.fedgradnorm import FGNState, fgn_init, fgn_update_gated
from repro.kernels.masked_gradnorm.ops import masked_gradnorm
from repro.models.model import Model
from repro.models.params import init_params
from repro.optim.adam import (
    AdamState, adam_init, adam_update, slab_adam_init, slab_adam_update,
)


class SimState(NamedTuple):
    omega: Any                  # {"final": ..., "trunk": ...} shared net
    heads: Any                  # stacked (C, N, ...)
    p: jax.Array                # (C, N) loss weights
    ps_opt: Any                 # PS optimizer state for ω
    head_opt: Any               # stacked (C, N, ...) Adam states
    fgn: FGNState               # stacked per cluster: leaves (C, N)
    f0: jax.Array               # (C, N) initial losses (for F̃)
    step: jax.Array
    # Fault-injection state (DESIGN.md §3.14) — present only when
    # fl.faults (None = empty pytree node, legacy states unchanged):
    omega_stale: Any = None     # delayed shared-model copy stragglers use
    stale_age: Any = None       # () rounds since omega_stale was refreshed


def masked_cls_loss(logits: jax.Array, labels: jax.Array,
                    n_valid: jax.Array) -> jax.Array:
    """CE with classes ≥ n_valid masked out (heads padded to max classes)."""
    c = logits.shape[-1]
    valid = jnp.arange(c) < n_valid
    logits = jnp.where(valid, logits, -1e30)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1)[..., 0])


class HotaSim:
    def __init__(self, model: Model, fl: FLConfig, tcfg: TrainConfig,
                 n_classes_per_client, max_classes: int = None):
        self.model = model
        self.fl = fl
        self.tcfg = tcfg
        self.n_classes = jnp.asarray(n_classes_per_client, jnp.int32)  # (N,)
        self.max_classes = int(max_classes or int(max(n_classes_per_client)))
        # runtime channel/weighting knobs live in a traced pytree so scenario
        # sweeps (repro.core.sweep) can batch them; this is the default row.
        self.chan = channel_params(fl)
        # fault knobs are the same pattern (traced, bankable); fl.faults is
        # the one static gate that decides whether they are consumed at all
        self.faults = fault_params(fl)
        # no-silent-inertness (the PR-7 pattern): a static gate that the
        # chosen engine cannot honor must refuse loudly at build time,
        # not silently run the un-gated path
        if fl.ota_sectioned and not fl.use_pallas_ota:
            raise ValueError(
                "fl.ota_sectioned requires the slab engine "
                "(use_pallas_ota=True): the per-leaf oracle has no "
                "Section partition to stream — the gate would be "
                "silently inert (DESIGN.md §3.16)")
        if fl.ota_sectioned and fl.ota_sections != "toplevel":
            raise ValueError(
                "fl.ota_sectioned requires a multi-section layout "
                f"(ota_sections='toplevel', got {fl.ota_sections!r}): "
                "section streaming over the legacy two-section layout "
                "holds most of the model in its head section — the "
                "memory bound would be silently vacuous (DESIGN.md §3.16)")
        if fl.max_section_rows and not fl.use_pallas_ota:
            raise ValueError(
                "fl.max_section_rows requires the slab engine "
                "(use_pallas_ota=True): the per-leaf oracle has no "
                "section layout to split — the cap would be silently "
                "inert (DESIGN.md §3.16)")

    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> SimState:
        fl = self.fl
        k1, k2 = jax.random.split(key)
        omega = {"trunk": init_params(self.model.trunk_specs(), k1),
                 "final": init_params(self.model.final_specs(),
                                      jax.random.fold_in(
                                          k1, ota.FINAL_INIT_FOLD))}
        # reorder so "final" flattens first (leaf offset 0 for channel keys)
        omega = {"final": omega["final"], "trunk": omega["trunk"]}
        head_specs = self.model.head_specs(self.max_classes)

        def one_head(kc):
            return init_params(head_specs, kc)
        keys = jax.random.split(k2, fl.n_clusters * fl.n_clients).reshape(
            fl.n_clusters, fl.n_clients, -1)
        heads = jax.vmap(jax.vmap(one_head))(keys)
        head_opt = jax.vmap(jax.vmap(adam_init))(heads)
        p = jnp.ones((fl.n_clusters, fl.n_clients), jnp.float32)
        fgn = jax.vmap(lambda _: fgn_init(fl.n_clients))(
            jnp.arange(fl.n_clusters))
        # slab-native path (DESIGN.md §3.12): PS Adam moments live as one
        # flat slab — n_leaves-independent update, params unpacked once
        ps_opt = (slab_adam_init(omega) if fl.use_pallas_ota
                  else adam_init(omega))
        return SimState(
            omega=omega, heads=heads, p=p, ps_opt=ps_opt,
            head_opt=head_opt, fgn=fgn,
            f0=jnp.ones((fl.n_clusters, fl.n_clients), jnp.float32),
            step=jnp.zeros((), jnp.int32),
            omega_stale=(jax.tree.map(jnp.array, omega) if fl.faults
                         else None),
            stale_age=(jnp.zeros((), jnp.float32) if fl.faults else None))

    # ------------------------------------------------------------------
    def _client_update(self, omega, head, head_opt, x, y, n_valid):
        """τ_h head steps then τ_ω local shared steps (Alg. 1 lines 10-15)."""
        model, tcfg, fl = self.model, self.tcfg, self.fl

        def features(om, xx):
            h, _, _ = model.trunk_apply(om["trunk"], xx, mode="train")
            return model.final_apply(om["final"], h)

        def head_loss(hd, om):
            return masked_cls_loss(model.head_apply(hd, features(om, x)),
                                   y, n_valid)

        def head_step(carry, _):
            hd, hopt = carry
            g = jax.grad(head_loss)(hd, omega)
            hd, hopt = adam_update(g, hopt, hd, tcfg.lr)
            return (hd, hopt), None

        (head, head_opt), _ = jax.lax.scan(
            head_step, (head, head_opt), None, length=fl.tau_h)

        def omega_step(carry, _):
            om, gacc, lacc = carry
            l, g = jax.value_and_grad(
                lambda om_: head_loss(head, om_))(om)
            om = jax.tree.map(lambda w, gg: w - tcfg.lr * gg, om, g)
            gacc = jax.tree.map(jnp.add, gacc, g)
            return (om, gacc, lacc + l), None

        gacc0 = jax.tree.map(jnp.zeros_like, omega)
        (_, gacc, lsum), _ = jax.lax.scan(
            omega_step, (omega, gacc0, jnp.zeros(())), None, length=fl.tau_w)
        g_avg = jax.tree.map(lambda a: a / fl.tau_w, gacc)
        f_avg = lsum / fl.tau_w
        return head, head_opt, g_avg, f_avg

    # ------------------------------------------------------------------
    def _masked_final_norms(self, g_final, final_masks) -> jax.Array:
        """(C, N) masked last-shared-layer grad norms n_i (eq. 6), routed
        through the ``masked_gradnorm`` kernel per cluster: clients are
        the task rows, the cluster's eq.-7 mask is the shared column
        mask. Off-TPU the kernel wrapper dispatches to its jnp reference
        (same values — see repro.kernels.masked_gradnorm.ops), replacing
        the old per-(cluster, client) double-vmap tree walk."""
        c, n = self.fl.n_clusters, self.fl.n_clients
        gm = jnp.concatenate(
            [l.reshape(c, n, -1).astype(jnp.float32)
             for l in jax.tree.leaves(g_final)], axis=-1)        # (C, N, P̃)
        mm = jnp.concatenate(
            [m.reshape(c, -1).astype(jnp.float32)
             for m in jax.tree.leaves(final_masks)], axis=-1)    # (C, P̃)
        return jax.vmap(masked_gradnorm)(gm, mm)

    # ------------------------------------------------------------------
    def step(self, state: SimState, xb, yb, key,
             chan: ChannelParams = None, faults: FaultParams = None):
        """One Alg.-1 round. xb: (C,N,B,d) float32; yb: (C,N,B) int32.

        ``chan`` overrides the channel/weighting knobs at trace time
        (defaults to this sim's ``FLConfig``); the sweep engine vmaps
        ``step_with_channel`` over a bank of them. ``faults`` likewise
        overrides the traced fault knobs (consumed only when the static
        ``fl.faults`` gate is on)."""
        return self._step(state, xb, yb, key,
                          self.chan if chan is None else chan,
                          self.faults if faults is None else faults)

    @partial(jax.jit, static_argnums=0)
    def _step(self, state, xb, yb, key, chan, faults):
        return self.step_with_channel(state, xb, yb, key, chan,
                                      faults=faults)

    def step_with_channel(self, state: SimState, xb, yb, key,
                          chan: ChannelParams, ota_bits_mode: str = "fused",
                          faults: FaultParams = None):
        """Un-jitted step body with explicit traced ChannelParams — the
        vmap target of ``repro.core.sweep.ScenarioBank`` and, per device,
        of ``ShardedScenarioBank``'s scenario-sharded shard_map (DESIGN.md
        §3.8). Both pass ``ota_bits_mode="supplied"`` so the packed
        channel draw — a function of the shared key only — hoists out of
        the scenario vmap and is never re-drawn per scenario or per
        shard; same stream, same results as the fused default.

        Fault injection (DESIGN.md §3.14, static ``fl.faults`` gate):
        participation is drawn from the round key's reserved PART_FOLD
        domain — disjoint from every channel stream, so resampling fault
        rates is CRN-safe. Stragglers compute against the delayed
        ``omega_stale`` copy and transmit with the FedBuff-style
        1/√(1+age) discount; non-participant head slots and dead-cluster
        FGN state freeze; blackouts mask the MAC and the traced N_eff
        replaces N in eq. 10; a zero-participant or guard-tripped round
        degrades to a bit-exact identity step (step counter aside)."""
        fl, tcfg = self.fl, self.tcfg
        partc = None
        if fl.faults:
            fp = self.faults if faults is None else faults
            partc = ota.draw_participation(key, fp, fl.n_clusters,
                                           fl.n_clients)

            def client_upd(om, om_stale, stale_flag, head, hopt, x, y, nv):
                om_eff = jax.tree.map(
                    lambda f, s: jnp.where(stale_flag > 0.5, s, f),
                    om, om_stale)
                return self._client_update(om_eff, head, hopt, x, y, nv)

            upd = jax.vmap(jax.vmap(client_upd,
                                    in_axes=(None, None, 0, 0, 0, 0, 0, 0)),
                           in_axes=(None, None, 0, 0, 0, 0, 0, None))
            heads, head_opt, g, F = upd(
                state.omega, state.omega_stale, partc.stale, state.heads,
                state.head_opt, xb, yb, self.n_classes)
            # non-participant slots keep last round's head + optimizer
            pm = partc.part

            def sel_slot(new, old):
                m = pm.reshape(pm.shape + (1,) * (new.ndim - 2))
                return jnp.where(m > 0.5, new, old)

            heads = jax.tree.map(sel_slot, heads, state.heads)
            head_opt = jax.tree.map(sel_slot, head_opt, state.head_opt)
        else:
            upd = jax.vmap(jax.vmap(self._client_update,
                                    in_axes=(None, 0, 0, 0, 0, 0)),
                           in_axes=(None, 0, 0, 0, 0, None))
            heads, head_opt, g, F = upd(state.omega, state.heads,
                                        state.head_opt, xb, yb,
                                        self.n_classes)
        # g leaves: (C, N, ...); F: (C, N)

        chan_key = ota.sim_channel_key(key)   # reserved fold (DESIGN.md §4)
        # slab-native OTA (DESIGN.md §3.12): the shared tree is laid out by
        # a multi-section zero-copy packer (per-layer-stack trunk sections,
        # ω̃ tail) and the channel consumes every RAW (C, N, ·) gradient
        # leaf in place — no client-weighted tree, no (C, P) pack copy.
        # fl.use_pallas_ota is static config — the per-leaf jnp path stays
        # available as the property-test oracle. The section layout
        # (fl.ota_sections / fl.min_section_rows — normally written by
        # repro.common.layout_tune.apply_layout) decides the stream
        # folds, so it is static and checkpoint-pinned (DESIGN.md §3.13).
        packer = (packer_for(state.omega, tail="final",
                             sections=fl.ota_sections,
                             min_section_rows=fl.min_section_rows,
                             max_section_rows=fl.max_section_rows)
                  if fl.use_pallas_ota else None)

        # --- Alg. 2: FGN_Server per cluster -------------------------------
        # f0 latches each slot's FIRST observed loss (the F̃ baseline).
        # Besides step 0, a NEGATIVE f0 marks a never-seen slot — the
        # sampling layer (DESIGN.md §3.15) initializes its population
        # bank to -1 so a client first drawn at round k latches F at k.
        # Legacy states never hold a negative f0 (CE losses are ≥ 0 and
        # init is ones), so the extra clause is trace-only for them.
        f0 = jnp.where(jnp.logical_or(state.step == 0, state.f0 < 0.0),
                       F, state.f0)
        ratios = F / jnp.maximum(f0, 1e-12)

        if packer is not None:   # tail section of the round's stream draw
            final_masks = ota.final_layer_masks_packed(chan_key, chan, packer)
        else:
            final_masks = ota.final_layer_masks(
                chan_key, state.omega["final"], chan)   # leaves (C, ...)

        norms = self._masked_final_norms(g["final"], final_masks)   # (C, N)

        # weighting gate is traced (chan.fgn_on): "equal" scenarios take the
        # same trace and just select the passthrough; under faults a dead
        # cluster's gate also drops, freezing its (p, FGN) state in place
        if partc is not None:
            p_new, fgn_state, fval = jax.vmap(
                lambda pc, nc, rc, st, on: fgn_update_gated(
                    pc, nc, rc, st, fl, on)
            )(state.p, norms, ratios, state.fgn, chan.fgn_on * partc.live)
        else:
            p_new, fgn_state, fval = jax.vmap(
                lambda pc, nc, rc, st: fgn_update_gated(
                    pc, nc, rc, st, fl, chan.fgn_on)
            )(state.p, norms, ratios, state.fgn)

        # --- eqs. (3), (8)-(10): weighted transmission + OTA --------------
        # under faults the transmit weights fold participation and the
        # FedBuff staleness discount into the (C, N) matrix the channel
        # already carries; live/n_eff generalize the eq.-10 guard
        if partc is not None:
            disc = jnp.where(partc.stale > 0.5,
                             jax.lax.rsqrt(1.0 + state.stale_age), 1.0)
            w_tx = p_new * partc.part * disc
            live, n_eff = partc.live, partc.n_eff
        else:
            w_tx, live, n_eff = p_new, None, None
        if packer is not None:
            # client-folded: Σ_n p[l,n]·g[l,n] folds into the masked MAC
            # sum leaf by leaf — the einsum'd weighted tree never exists.
            # fl.ota_streaming (static, DESIGN.md §3.15) swaps in the
            # scan-over-clusters fold: identical streams, one cluster's
            # contribution resident at a time instead of all C.
            # fl.ota_sectioned (static, DESIGN.md §3.16) walks the
            # Section partition one section at a time — bit-identical
            # per leaf, peak live streams one section — and composes
            # with the cluster scan (the scan runs inside each section).
            if fl.ota_sectioned:
                ghat = ota.ota_aggregate_sectioned(
                    chan_key, g, w_tx, chan, fl.n_clients, packer,
                    bits_mode=ota_bits_mode, live=live, n_eff=n_eff,
                    streaming=fl.ota_streaming)
            else:
                agg = (ota.ota_aggregate_streaming if fl.ota_streaming
                       else ota.ota_aggregate_client_folded)
                ghat = agg(
                    chan_key, g, w_tx, chan, fl.n_clients, packer,
                    bits_mode=ota_bits_mode, live=live, n_eff=n_eff)
            # slab-view PS update: moments stay one flat slab, params
            # unpack exactly once (the model-apply boundary)
            omega, ps_opt = slab_adam_update(ghat, state.ps_opt,
                                             state.omega, tcfg.lr)
        else:
            weighted = jax.tree.map(
                lambda gl: jnp.einsum("cn,cn...->c...", w_tx, gl), g)
            ghat = ota.ota_aggregate_tree(chan_key, weighted, chan,
                                          fl.n_clients, live=live,
                                          n_eff=n_eff)
            # --- PS update (line 20) ---------------------------------------
            omega, ps_opt = adam_update(ghat, state.ps_opt, state.omega,
                                        tcfg.lr)

        metrics = {"loss": F, "p": p_new, "fgrad": fval,
                   "grad_norms": norms}
        if partc is None:
            return SimState(omega=omega, heads=heads, p=p_new,
                            ps_opt=ps_opt, head_opt=head_opt, fgn=fgn_state,
                            f0=f0, step=state.step + 1), metrics

        # --- round guard + degradation (DESIGN.md §3.14) ------------------
        # gn2 is the exact squared estimate norm; spike_norm=inf leaves
        # only the non-finite check (inf² = inf makes the ≤ vacuous)
        gn2 = sum(jnp.sum(l.astype(jnp.float32) ** 2)
                  for l in jax.tree.leaves(ghat))
        ok = jnp.logical_and(jnp.isfinite(gn2),
                             gn2 <= fp.spike_norm * fp.spike_norm)
        skip = jnp.logical_or(partc.total < 0.5, ~ok)
        # stale-model bookkeeping: refresh the delayed copy every
        # fp.staleness rounds (age in [0, τ))
        refresh = (state.stale_age + 1.0) >= fp.staleness
        omega_stale = jax.tree.map(
            lambda new, old: jnp.where(refresh, new, old),
            omega, state.omega_stale)
        stale_age = jnp.where(refresh, 0.0, state.stale_age + 1.0)
        new_state = SimState(omega=omega, heads=heads, p=p_new,
                             ps_opt=ps_opt, head_opt=head_opt,
                             fgn=fgn_state, f0=f0, step=state.step,
                             omega_stale=omega_stale, stale_age=stale_age)
        # skipped round = bit-exact identity (params, Adam moments, FGN
        # state, stale copy all frozen — like the fgn_on passthrough);
        # only the step counter advances
        new_state = jax.tree.map(
            lambda new, old: jnp.where(skip, old, new), new_state, state)
        new_state = new_state._replace(step=state.step + 1)
        metrics = dict(metrics, skipped=skip.astype(jnp.float32),
                       n_participants=partc.total)
        return new_state, metrics
