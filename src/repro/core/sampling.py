"""Per-round client sampling from a population bank (DESIGN.md §3.15).

The paper's HFL premise only pays off at population scale: a real round
draws a few hundred participants from millions of enrolled clients, not
all C·N synchronously. This module makes that a TRACED knob on top of the
existing simulator:

* ``ClientBank`` holds the per-client persistent state — personalized
  heads, their Adam moments, and the FGN loss baseline f0 — for a
  population of M candidates per (cluster, slot) position, leaves
  (C, N, M, ...). A slot is a task (the data stream and class count are
  keyed by slot position), so slot n's subpopulation is the M clients of
  cluster l working task n. Population size is C·N·M ≫ C·N.
* ``SampledHotaSim`` wraps ``HotaSim``: each round draws one id per slot
  from the reserved SAMPLE_FOLD stream domain
  (``repro.core.ota.draw_client_sample``), GATHERS the sampled clients'
  state into the (C, N) slot view (the same traced-gather trick
  ``ScenarioBank`` uses for scenario knobs), runs the unmodified inner
  round, and SCATTERS the slot results back into the bank. Subpopulations
  are disjoint, so the scatter is conflict-free and deterministic.

Position determinism (the §4 rule): every channel and participation
stream keys off the SLOT position and a reserved fold — never off the
drawn ids — so resampling, or growing the population, perturbs no mask,
no AWGN draw and no fault draw: channel streams are byte-identical
across resamples (pinned in tests/test_sampling.py). Per-round cost is
O(C·N) gather/scatter rows regardless of M, so rounds/sec stays flat in
the population size (BENCH_sample.json).

``SampledHotaSim`` duck-types ``HotaSim``'s bank interface (``fl``,
``chan``, ``faults``, ``init``, ``step_with_channel``), so the sweep
engines (``repro.core.sweep.ScenarioBank`` and the sharded flavor)
compose with sampling unchanged — a scenario bank over a sampled sim is
one jit, CRN included, with the sample draw hoisted out of the scenario
vmap exactly like the channel streams (key-only draw).

FGN semantics under sampling: the FedGradNorm state and loss weights p
live at SLOT (task) level — FGN balances tasks, not individual clients —
while f0 is per CLIENT (each client's own F̃ baseline). A never-sampled
client's f0 is the -1 sentinel; its first sampled round latches F (see
``HotaSim.step_with_channel``).
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import FLConfig, TrainConfig
from repro.core import ota
from repro.core.channel import ChannelParams, FaultParams
from repro.core.sim import HotaSim, SimState
from repro.models.model import Model
from repro.models.params import init_params
from repro.optim.adam import adam_init


class ClientBank(NamedTuple):
    """Per-client persistent state for the whole population. Leaves carry
    a leading (C, N, M) prefix: cluster × slot(task) × subpopulation."""
    heads: Any          # (C, N, M, ...) personalized heads
    head_opt: Any       # (C, N, M, ...) their Adam states
    f0: jax.Array       # (C, N, M) first-seen loss baseline; -1 = unseen


class SampledSimState(NamedTuple):
    """Carried state of a sampled sim: the inner (C, N) slot-view
    ``SimState`` (shared model, optimizer, FGN/task state, plus the slot
    copies of last round's participants) and the population bank."""
    sim: SimState
    bank: ClientBank


def init_client_bank(model: Model, fl: FLConfig, population: int,
                     max_classes: int, key: jax.Array) -> ClientBank:
    """Fresh population: every client gets its own head init (per-member
    keys), zeroed Adam moments, and the -1 unseen-f0 sentinel."""
    head_specs = model.head_specs(max_classes)
    c, n, m = fl.n_clusters, fl.n_clients, population
    keys = jax.random.split(key, c * n * m).reshape(c, n, m, -1)
    heads = jax.vmap(jax.vmap(jax.vmap(
        lambda kc: init_params(head_specs, kc))))(keys)
    head_opt = jax.vmap(jax.vmap(jax.vmap(adam_init)))(heads)
    return ClientBank(heads=heads, head_opt=head_opt,
                      f0=-jnp.ones((c, n, m), jnp.float32))


def gather_clients(bank: ClientBank, ids: jax.Array):
    """(heads, head_opt, f0) slot views for the drawn ids: leaf
    (C, N, M, ...) → (C, N, ...) by a traced take along the population
    axis — O(C·N) rows moved however large M is."""

    def take(leaf):
        idx = ids.reshape(ids.shape + (1,) * (leaf.ndim - 2))
        return jnp.take_along_axis(leaf, idx, axis=2).squeeze(2)

    return (jax.tree.map(take, bank.heads),
            jax.tree.map(take, bank.head_opt),
            take(bank.f0))


def scatter_clients(bank: ClientBank, ids: jax.Array, heads, head_opt,
                    f0: jax.Array) -> ClientBank:
    """Write the slot results back at the drawn ids. Each (cluster,
    slot) owns a disjoint subpopulation and draws exactly one id, so no
    two slots ever address the same bank entry — the scatter is
    deterministic by construction (no duplicate-index tie-break)."""
    c, n = ids.shape
    cg = jnp.arange(c)[:, None]
    ng = jnp.arange(n)[None, :]

    def put(leaf, val):
        return leaf.at[cg, ng, ids].set(val)

    return ClientBank(heads=jax.tree.map(put, bank.heads, heads),
                      head_opt=jax.tree.map(put, bank.head_opt, head_opt),
                      f0=put(bank.f0, f0))


class SampledHotaSim:
    """A ``HotaSim`` whose per-round participants are sampled from a
    ``ClientBank`` population (DESIGN.md §3.15).

    Same constructor as ``HotaSim`` plus ``population`` (M, the
    subpopulation size per slot). The inner round body is the unmodified
    ``HotaSim.step_with_channel`` — faults, staleness, skip rounds, the
    streaming aggregator and the scenario banks all compose: sampling is
    a gather/scatter shell around the slot view."""

    def __init__(self, model: Model, fl: FLConfig, tcfg: TrainConfig,
                 n_classes_per_client, population: int,
                 max_classes: int = None):
        if population < 1:
            raise ValueError(f"population must be ≥ 1, got {population}")
        self.sim = HotaSim(model, fl, tcfg, n_classes_per_client,
                           max_classes=max_classes)
        self.population = int(population)
        self.model = model
        self.tcfg = tcfg

    # bank interface (duck-typed by the sweep engines)
    @property
    def fl(self) -> FLConfig:
        return self.sim.fl

    @property
    def chan(self) -> ChannelParams:
        return self.sim.chan

    @property
    def faults(self) -> FaultParams:
        return self.sim.faults

    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> SampledSimState:
        inner = self.sim.init(key)
        bank = init_client_bank(self.model, self.fl, self.population,
                                self.sim.max_classes,
                                jax.random.fold_in(key, ota.SAMPLE_INIT_FOLD))
        return SampledSimState(sim=inner, bank=bank)

    # ------------------------------------------------------------------
    def step(self, state: SampledSimState, xb, yb, key,
             chan: ChannelParams = None, faults: FaultParams = None):
        """One sampled round (jit'd). Same contract as ``HotaSim.step``;
        metrics gain ``sample_ids`` — the (C, N) draw, a pure function
        of the round key (hosts can recompute it without state)."""
        return self._step(state, xb, yb, key,
                          self.chan if chan is None else chan,
                          self.faults if faults is None else faults)

    @partial(jax.jit, static_argnums=0)
    def _step(self, state, xb, yb, key, chan, faults):
        return self.step_with_channel(state, xb, yb, key, chan,
                                      faults=faults)

    def step_with_channel(self, state: SampledSimState, xb, yb, key,
                          chan: ChannelParams,
                          ota_bits_mode: str = "fused",
                          faults: FaultParams = None):
        """Un-jitted sampled round — the vmap target of the sweep
        engines, like the inner sim's method of the same name.

        draw ids → gather slot view → inner round → scatter back. The
        inner round sees a (C, N) ``SimState`` whose heads/head_opt/f0
        are the sampled clients' own state; everything the round does to
        a non-participating or frozen slot (fault path) round-trips
        through the scatter unchanged, so skip rounds stay bit-exact
        identities on the bank too."""
        ids = ota.draw_client_sample(key, self.fl.n_clusters,
                                     self.fl.n_clients, self.population)
        heads, head_opt, f0 = gather_clients(state.bank, ids)
        slot_state = state.sim._replace(heads=heads, head_opt=head_opt,
                                        f0=f0)
        new_sim, metrics = self.sim.step_with_channel(
            slot_state, xb, yb, key, chan, ota_bits_mode=ota_bits_mode,
            faults=faults)
        bank = scatter_clients(state.bank, ids, new_sim.heads,
                               new_sim.head_opt, new_sim.f0)
        metrics = dict(metrics, sample_ids=ids)
        return SampledSimState(sim=new_sim, bank=bank), metrics
