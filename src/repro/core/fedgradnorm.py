"""FedGradNorm with channel-sparsified auxiliary loss (paper Alg. 2, eqs. 5-6).

The IS of cluster l holds per-client (task) quantities at iteration k:

* n_i = ‖ M_k^(l) ∘ ∇_{ω̃} F_k^(l,i) ‖   — masked last-shared-layer grad norm
* F̃_i = F_k^(l,i) / F_0^(l,i)             — loss ratio (training-rate proxy)

and minimizes (one optimizer step per round, lr α):

    F_grad(p) = Σ_i | p_i · n_i  −  Ḡ · r_i^γ |,
    Ḡ = mean_i(p_i n_i),  r_i = F̃_i / mean_j F̃_j,

treating Ḡ and r as constants (standard GradNorm stop-gradient), then
renormalizes Σ_i p_i = N (the constraint under eq. (1)).

The paper uses Adam for the F_grad optimization (Sec. IV-B, α = 0.008);
plain GD is also provided. All functions are scalar-vector math — the same
code serves the vmap simulator (vmapped over clusters) and the distributed
path (each device computing its own client's slice with psum'd means).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import FLConfig


class FGNState(NamedTuple):
    """Adam state for the loss-weight optimization (per client slot)."""
    step: jax.Array
    mu: jax.Array
    nu: jax.Array


def fgn_init(n: int) -> FGNState:
    z = jnp.zeros((n,), jnp.float32)
    return FGNState(step=jnp.zeros((), jnp.int32), mu=z, nu=z)


def fgrad_value(p: jax.Array, norms: jax.Array, gbar: jax.Array,
                targets: jax.Array) -> jax.Array:
    """F_grad (eq. 5) given per-task masked norms and targets Ḡ·r^γ."""
    return jnp.sum(jnp.abs(p * norms - gbar * targets))


def fgn_targets(loss_ratios: jax.Array, gamma: float) -> jax.Array:
    """r_i^γ with r_i = F̃_i / mean(F̃)."""
    r = loss_ratios / jnp.maximum(jnp.mean(loss_ratios), 1e-12)
    return jnp.power(jnp.maximum(r, 1e-12), gamma)


def fgn_grad_p(p: jax.Array, norms: jax.Array, loss_ratios: jax.Array,
               gamma: float) -> Tuple[jax.Array, jax.Array]:
    """∂F_grad/∂p_i = sign(p_i n_i − Ḡ r_i^γ) · n_i  (Ḡ, r stopped).

    Returns (grad, fgrad_value)."""
    gbar = jnp.mean(jax.lax.stop_gradient(p) * norms)
    targets = fgn_targets(loss_ratios, gamma)
    resid = p * norms - gbar * targets
    return jnp.sign(resid) * norms, jnp.sum(jnp.abs(resid))


def fgn_update(
    p: jax.Array,                # (N,) current loss weights of the cluster
    norms: jax.Array,            # (N,) masked last-layer grad norms
    loss_ratios: jax.Array,      # (N,) F̃ = F_k / F_0
    state: FGNState,
    fl: FLConfig,
) -> Tuple[jax.Array, FGNState, jax.Array]:
    """One Alg.-2 step: p ← renorm(AdamStep(p, ∇_p F_grad))."""
    g, fval = fgn_grad_p(p, norms, loss_ratios, fl.gamma)

    # Adam on the weight vector
    step = state.step + 1
    t = step.astype(jnp.float32)
    b1, b2, eps = 0.9, 0.999, 1e-8
    mu = b1 * state.mu + (1 - b1) * g
    nu = b2 * state.nu + (1 - b2) * g * g
    mhat = mu / (1 - jnp.power(b1, t))
    vhat = nu / (1 - jnp.power(b2, t))
    p_new = p - fl.alpha * mhat / (jnp.sqrt(vhat) + eps)

    # constraint: p_i > p_min, Σ_i p_i = N (Sec. II)
    p_new = jnp.maximum(p_new, fl.p_min + 1e-6)
    p_new = p_new * (p.shape[0] / jnp.maximum(jnp.sum(p_new), 1e-12))
    return p_new, FGNState(step=step, mu=mu, nu=nu), fval


def fgn_update_gated(
    p: jax.Array,
    norms: jax.Array,
    loss_ratios: jax.Array,
    state: FGNState,
    fl: FLConfig,
    fgn_on: jax.Array,           # () traced gate: 1.0 = Alg. 2, 0.0 = equal
) -> Tuple[jax.Array, FGNState, jax.Array]:
    """Alg.-2 step behind a traced weighting gate (ChannelParams.fgn_on).

    With the gate off, (p, state) pass through untouched and F_grad reads 0 —
    exactly the static ``weighting="equal"`` branch — so dynamic-vs-equal
    scenario pairs share one trace and differ only in this select.
    """
    p_fgn, st_fgn, fval = fgn_update(p, norms, loss_ratios, state, fl)
    on = fgn_on > 0.5
    p_new = jnp.where(on, p_fgn, p)
    st_new = FGNState(*(jnp.where(on, a, b) for a, b in zip(st_fgn, state)))
    return p_new, st_new, jnp.where(on, fval, 0.0)


def masked_tree_norm(grad_tree, mask_tree) -> jax.Array:
    """‖ M ∘ g ‖ over a pytree (the n_i of eq. 6)."""
    total = jnp.zeros((), jnp.float32)
    for g, m in zip(jax.tree.leaves(grad_tree), jax.tree.leaves(mask_tree)):
        total = total + jnp.sum(
            jnp.where(m, g.astype(jnp.float32), 0.0) ** 2)
    return jnp.sqrt(total)
