"""The paper-scale experimental setup, in one place.

Figs. 2-4, the sweep benchmark, the quickstart example, and the sweep
tests all run the same stack: synthetic RadComDynamic -> cluster/client
partition -> FederatedBatcher -> Table-I MLP -> ``HotaSim``. This factory
is the single source of truth for that sequence so a change to the task
list, partition seeding, or model config propagates everywhere at once.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.sim import HotaSim
from repro.data.federated import FederatedBatcher
from repro.data.radcom import (
    N_CLASSES, RadComConfig, TASKS, client_partition, make_radcom_dataset,
)
from repro.models.model import build_model


def paper_mlp_setup(
    fl: FLConfig,
    batch: int = 24,
    n_points: Optional[int] = None,
    seed: int = 0,
    lr: float = 3e-4,
) -> Tuple[HotaSim, FederatedBatcher]:
    """Build the paper's (sim, batcher) for a topology/channel config.

    ``n_points`` overrides the RadComDynamic dataset size (None = the
    full paper-scale default); ``seed`` seeds the partition and the
    batcher stream (seed + 1), matching the historical runners.
    """
    rc = RadComConfig(n_points=n_points) if n_points else RadComConfig()
    data = make_radcom_dataset(rc)
    parts = client_partition(data, fl.n_clusters, fl.n_clients, seed=seed)
    batcher = FederatedBatcher(parts, batch, seed=seed + 1)
    n_cls = [N_CLASSES[TASKS[i % 3]] for i in range(fl.n_clients)]
    model = build_model(ModelConfig(family="mlp"))
    sim = HotaSim(model, fl, TrainConfig(lr=lr), n_cls)
    return sim, batcher
