"""Flat-packing of parameter pytrees for the fused OTA aggregation engine.

The paper's channel model is per-entry: every parameter entry j gets its
own gain draw, threshold test and superposition (eqs. 7-10) — nothing in
the math cares about the pytree structure. The per-leaf implementation in
``repro.core.ota`` therefore pays pure overhead: dozens of small
memory-bound kernels (one gain/mask/noise draw per leaf per cluster) per
round, multiplied by the scenario count under ``ScenarioBank``.

``TreePacker`` ravels the shared-model pytree ONCE into a lane-aligned
``(P,)`` slab with static per-leaf offsets, so the whole round's channel
can be drawn, thresholded and aggregated in a single fused Pallas pass
(``repro.kernels.ota_channel.ota_aggregate``).

Layout contract (relied on by ``repro.core.ota.final_layer_masks_packed``):

* leaves are packed in flatten order, **except** the leaves of the
  ``tail`` subtree (the last-shared-layer params ω̃), which are packed
  last, forming one contiguous tail slice of the slab;
* the head and tail sections are each zero-padded up to a multiple of
  ``ROW_QUANTUM`` (= 8·128), so every section — and the whole slab —
  reshapes exactly to the kernels' (rows, 128) view and each section can
  be drawn from its own counter-based bit stream (section folds and the
  chunk-quantized draw are specified in DESIGN.md §4);
* FedGradNorm's sparsified F_grad (eqs. 5-7) needs exactly the masks of
  ω̃: with this layout they are the tail slice of the same flat channel
  draw the transmission uses — no second per-leaf mask loop.

Multi-section layouts (``sections="toplevel"`` — DESIGN.md §3.10): every
depth-≤2 path prefix of the template becomes its own ROW_QUANTUM-aligned
section (so a {"final", "trunk"} omega template splits into one section
per trunk layer stack — "trunk/embed", "trunk/layers", ... — each with
its own bit stream), with the ``tail`` key's section always last. Within
a section every leaf additionally starts
ROW_QUANTUM-aligned, so a leaf's slice of the section's bit stream is
computable from static offsets alone — the zero-copy contract: the
slab-native distributed step (``repro.core.hota_slab``) never
materializes the (P,) slab, it walks ``leaf_runs()`` and consumes each
leaf's storage in place against the stream positions this layout pins.
The zero-copy consumers also accept leaves carrying identical LEADING
batch axes over the template shapes — the simulator's client-folded
channel (DESIGN.md §3.12) reads raw (C, N, *shape) gradient leaves
against (*shape,) slots (``check_tree_matches_packer(batch_ndim=2)``);
the maps themselves are batch-free element ranges.

Chunk coalescing (``min_section_rows`` — DESIGN.md §3.13): the stream
spec draws bits in 1024-row chunks (§4), so a template with many tiny
top-level groups pays a full chunk draw per sub-chunk section — the
adversarial-layout loss the benchmarks pin. With a nonzero threshold,
adjacent trunk groups below ``min_section_rows`` rows merge into one
ROW_QUANTUM-aligned section. Leaf slab offsets are IDENTICAL at every
threshold (every leaf and every group start is already
ROW_QUANTUM-aligned, so merging only re-groups — it never moves data);
what changes is the Section partition and therefore the per-section
stream folds. ``min_section_rows=0`` is bit-identical to the uncoalesced
layout (stream-pinned in tests), and the ω̃ tail always stays its own
last section so eq.-5 consumers keep ``PACKED_TAIL_FOLD``.

Section splitting (``max_section_rows`` — DESIGN.md §4): the
section-streaming engine (§3.16) holds ONE section's streams live at a
time, so its peak memory is the largest section — useless if one giant
layer stack is most of the model. With a nonzero cap, any trunk section
longer than ``max_section_rows`` LANE-wide rows is split at leaf
boundaries into consecutive sections of at most the cap (a single leaf
larger than the cap stays one section — leaf runs never straddle
sections, so the reachable bound is
``max(max_section_rows, ceil(largest_leaf / LANE))`` rows,
``peak_section_rows()``). Exactly like coalescing, the split moves no
data: leaf offsets are identical at every cap (every leaf start is
already ROW_QUANTUM-aligned); only the Section partition — and so the
per-section stream folds — changes. The ω̃ tail is never split.

Packers are cached on (treedef, shapes, dtypes, tail, sections,
min_section_rows, max_section_rows), so tracing a step re-uses the
offsets computed at the first call.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro.kernels.slab import LANE, ROW_QUANTUM, round_up


class LeafSlot(NamedTuple):
    offset: int                # start index into the (P,) slab
    size: int                  # element count
    shape: Tuple[int, ...]
    dtype: Any


class Section(NamedTuple):
    """One ROW_QUANTUM-aligned region of the slab (DESIGN.md §4 streams)."""
    name: str                  # top-level template key ("" = head catch-all)
    index: int                 # section position — selects the stream fold
    start: int                 # slab offset (ROW_QUANTUM-aligned)
    length: int                # padded length (ROW_QUANTUM multiple)
    leaf_indices: Tuple[int, ...]   # flatten-order leaf ids, pack order


class LeafRun(NamedTuple):
    """Zero-copy map entry: where one leaf's data sits inside a section's
    bit stream. The slab-native executor reads the leaf array in place
    and draws stream elements [offset, offset+size) of the section."""
    leaf: int                  # flatten-order leaf index
    section: int               # section index
    offset: int                # start within the section (elements)
    size: int


def _path_key(step):
    """One path step's key: dict key / attr name / sequence index (list
    and tuple containers carry SequenceKey with .idx, not .key/.name)."""
    key = getattr(step, "key", getattr(step, "name", None))
    return getattr(step, "idx", None) if key is None else key


def _top_key(path):
    return _path_key(path[0]) if path else None


def _in_tail(path, tail: Optional[str]) -> bool:
    return tail is not None and _top_key(path) == tail


def _section_key(path, tail: Optional[str]) -> Optional[str]:
    """Section of a leaf in the multi-section layout: the tail key, or
    the depth-≤2 path prefix — one section PER LAYER STACK ("trunk/
    layers", "trunk/embed", ...), not per top-level container, so a
    {"final", "trunk"} omega template still splits its trunk stacks
    into separate stream sections."""
    if _in_tail(path, tail):
        return tail
    if not path:
        return None
    return "/".join(str(_path_key(s)) for s in path[:2])


class TreePacker:
    """Static pack/unpack between a pytree and a lane-aligned (P,) slab.

    ``tail`` names a top-level key of ``template`` (usually ``"final"``)
    whose leaves are laid out as the contiguous tail of the slab; pass
    ``None`` to pack everything as one head section.

    ``sections`` selects the layout:

    * ``"tail"`` (default, the PR-2 layout): two sections — head leaves
      butt-packed in flatten order, tail leaves butt-packed last, each
      section ROW_QUANTUM-padded. Streams and values are bit-identical
      to the original two-section packer.
    * ``"toplevel"``: one section per depth-≤2 path prefix of
      ``template`` (the per-layer-stack trunk sections — "trunk/embed",
      "trunk/layers", ...), tail key last, and EVERY leaf starts
      ROW_QUANTUM-aligned inside its section — the zero-copy layout:
      ``leaf_runs()`` / ``chunk_leaf_map()`` give static maps from leaf
      storage to stream positions, so the slab-native executor
      (repro.core.hota_slab) never materializes the slab, and a
      full-section stream draw is bounded by ONE layer stack.

    ``min_section_rows`` (``sections="toplevel"`` only) coalesces
    adjacent trunk groups shorter than that many LANE-wide rows into one
    section, closing each merged section once it reaches the threshold;
    a trailing under-threshold remainder folds into the previous trunk
    section, and the ``tail`` group is never merged — it stays its own
    last section. Leaf offsets are identical at every threshold; only
    the Section partition (and so the stream folds) changes. ``0``
    (the default) reproduces the uncoalesced layout bit-exactly.

    ``max_section_rows`` (``sections="toplevel"`` only) splits, AFTER
    coalescing, any trunk section longer than that many rows at leaf
    boundaries into consecutive sections of at most the cap — the
    memory-budget knob of the section-streaming engine (DESIGN.md
    §3.16): peak live streams are one section, so the cap bounds them.
    A leaf larger than the cap stays one oversized section (runs never
    straddle sections); the tail is never split. Like coalescing this
    never moves data — only the partition and stream folds change —
    and ``0`` (the default) performs no split.

    The template must carry ONE uniform leaf dtype: the slab is a single
    flat buffer and the zero-copy maps alias leaf storage in place, so a
    mixed-dtype tree has no representable layout — cast it first.
    """

    def __init__(self, template, tail: Optional[str] = "final",
                 sections: str = "tail", min_section_rows: int = 0,
                 max_section_rows: int = 0):
        if sections not in ("tail", "toplevel"):
            raise ValueError(
                f"sections must be 'tail' or 'toplevel', got {sections!r}")
        min_section_rows = int(min_section_rows)
        max_section_rows = int(max_section_rows)
        if min_section_rows < 0:
            raise ValueError(
                f"min_section_rows must be >= 0, got {min_section_rows}")
        if max_section_rows < 0:
            raise ValueError(
                f"max_section_rows must be >= 0, got {max_section_rows}")
        if sections == "tail" and min_section_rows:
            raise ValueError(
                "min_section_rows requires sections='toplevel': the legacy "
                "two-section layout has no trunk groups to coalesce "
                f"(got min_section_rows={min_section_rows})")
        if sections == "tail" and max_section_rows:
            raise ValueError(
                "max_section_rows requires sections='toplevel': the legacy "
                "two-section layout has no trunk sections to split "
                f"(got max_section_rows={max_section_rows})")
        if max_section_rows and max_section_rows < min_section_rows:
            raise ValueError(
                f"max_section_rows ({max_section_rows}) < min_section_rows "
                f"({min_section_rows}): the coalescer would merge sections "
                f"the splitter immediately re-cuts — contradictory layout")
        self.min_section_rows = min_section_rows
        self.max_section_rows = max_section_rows
        paths_leaves, treedef = jtu.tree_flatten_with_path(template)
        self.treedef = treedef
        self.tail_name = tail
        self.layout = sections

        dtypes = sorted({jnp.dtype(l.dtype).name for _, l in paths_leaves})
        if len(dtypes) > 1:
            detail = ", ".join(f"{jtu.keystr(p)}={jnp.dtype(l.dtype).name}"
                               for p, l in paths_leaves)
            raise ValueError(
                f"TreePacker requires one uniform leaf dtype (the slab is "
                f"one flat buffer and the zero-copy maps read leaf storage "
                f"in place) but the template mixes {dtypes}; cast the tree "
                f"to a single dtype first. Leaves: {detail}")

        head_idx = [i for i, (p, _) in enumerate(paths_leaves)
                    if not _in_tail(p, tail)]
        tail_idx = [i for i, (p, _) in enumerate(paths_leaves)
                    if _in_tail(p, tail)]
        # pack order: head leaves in flatten order, tail leaves last
        self.order: List[int] = head_idx + tail_idx
        self.tail_indices = tail_idx

        self.slots: Dict[int, LeafSlot] = {}
        self.sections: List[Section] = []

        def _slot(i, off):
            leaf = paths_leaves[i][1]
            self.slots[i] = LeafSlot(off, int(leaf.size), tuple(leaf.shape),
                                     jnp.dtype(leaf.dtype))
            return int(leaf.size)

        if sections == "tail":
            off = 0
            for i in head_idx:
                off += _slot(i, off)
            self.head_len = round_up(off, ROW_QUANTUM)  # section boundary
            off = self.head_len
            for i in tail_idx:
                off += _slot(i, off)
            self.tail_len = round_up(off - self.head_len, ROW_QUANTUM)
            if head_idx:
                self.sections.append(Section("", 0, 0, self.head_len,
                                             tuple(head_idx)))
            if tail_idx:
                self.sections.append(
                    Section(tail, len(self.sections), self.head_len,
                            self.tail_len, tuple(tail_idx)))
        else:
            names: List[Optional[str]] = []
            groups: Dict[Optional[str], List[int]] = {}
            for i in head_idx + tail_idx:
                name = _section_key(paths_leaves[i][0], tail)
                if name not in groups:
                    groups[name] = []
                    names.append(name)
                groups[name].append(i)
            if tail is not None and tail in names:   # tail always last
                names.remove(tail)
                names.append(tail)
            # Phase 1: lay out every top-level group exactly as the
            # uncoalesced layout does. Leaf offsets are therefore
            # invariant under min_section_rows — every leaf and every
            # group start is ROW_QUANTUM-aligned, so re-grouping below
            # never moves data.
            off = 0
            atoms = []   # (name, start, length, leaf_indices, is_tail)
            for name in names:
                start = off
                for i in groups[name]:
                    # every leaf ROW_QUANTUM-aligned: its stream slice is
                    # a static, lane-aligned range of the section stream
                    off = start + round_up(off - start, ROW_QUANTUM)
                    off += _slot(i, off)
                length = round_up(off - start, ROW_QUANTUM)
                off = start + length
                atoms.append(("" if name is None else name, start, length,
                              tuple(groups[name]),
                              tail is not None and name == tail))
            # Phase 2: greedily merge adjacent sub-threshold trunk
            # groups; a trailing remainder folds into the previous trunk
            # section; the tail group is never merged (eq.-5 consumers
            # rely on it keeping its own fold in every layout).
            threshold = min_section_rows * LANE
            merged: List[List[Any]] = []   # [names, start, length, leaves]
            open_grp: Optional[List[Any]] = None
            for name, start, length, leaf_idx, is_tail in atoms:
                if is_tail:
                    continue
                if open_grp is None:
                    open_grp = [[name], start, length, list(leaf_idx)]
                else:
                    open_grp[0].append(name)
                    open_grp[2] += length
                    open_grp[3].extend(leaf_idx)
                if open_grp[2] >= threshold:
                    merged.append(open_grp)
                    open_grp = None
            if open_grp is not None:
                if merged:
                    merged[-1][0].extend(open_grp[0])
                    merged[-1][2] += open_grp[2]
                    merged[-1][3].extend(open_grp[3])
                else:
                    merged.append(open_grp)
            # Phase 2b: split over-cap trunk sections at leaf boundaries
            # (every leaf start is ROW_QUANTUM-aligned, so every piece
            # is too — no data moves, only the partition/folds change).
            # A single leaf longer than the cap stays one section: leaf
            # runs never straddle sections.
            if max_section_rows:
                cap = max_section_rows * LANE
                split: List[List[Any]] = []
                for sec_names, start, length, leaf_list in merged:
                    if length <= cap:
                        split.append([sec_names, start, length, leaf_list])
                        continue
                    base = "+".join(sec_names)
                    end = start + length
                    pieces: List[Tuple[int, List[int]]] = []
                    p_start, p_leaves = start, []
                    for i in leaf_list:
                        slot = self.slots[i]
                        if p_leaves and round_up(
                                slot.offset + slot.size - p_start,
                                ROW_QUANTUM) > cap:
                            pieces.append((p_start, p_leaves))
                            p_start, p_leaves = slot.offset, []
                        p_leaves.append(i)
                    pieces.append((p_start, p_leaves))
                    for k, (ps, pl) in enumerate(pieces):
                        pe = pieces[k + 1][0] if k + 1 < len(pieces) else end
                        split.append([[f"{base}[{k}]"], ps, pe - ps, pl])
                merged = split
            merged.extend([[a[0]], a[1], a[2], list(a[3])]
                          for a in atoms if a[4])
            self.order = []
            for sec_names, start, length, leaf_list in merged:
                self.sections.append(
                    Section("+".join(sec_names), len(self.sections),
                            start, length, tuple(leaf_list)))
                self.order.extend(leaf_list)
            self.tail_len = (self.sections[-1].length
                             if tail is not None and tail in names else 0)
            self.head_len = off - self.tail_len

        self.size = self.head_len + self.tail_len       # P, lane-aligned
        if self.size == 0:
            raise ValueError("cannot pack an empty pytree")
        self.n_rows = self.size // LANE

    # ------------------------------------------------------------------
    def leaf_runs(self) -> List[LeafRun]:
        """Static zero-copy map: one entry per leaf in pack order, giving
        the (section, offset, size) stream slice its storage occupies."""
        runs = []
        for sec in self.sections:
            for i in sec.leaf_indices:
                slot = self.slots[i]
                runs.append(LeafRun(i, sec.index, slot.offset - sec.start,
                                    slot.size))
        return runs

    def peak_section_rows(self) -> int:
        """Largest section in LANE-wide rows — the peak live stream
        footprint of the section-streaming engine (DESIGN.md §3.16).
        With ``max_section_rows`` set this is at most
        ``max(max_section_rows, ceil(largest_leaf / LANE))``; computable
        from the template alone (no weights materialized)."""
        return max(sec.length for sec in self.sections) // LANE

    def chunk_leaf_map(
            self, chunk: int,
    ) -> Dict[int, List[Tuple[int, List[LeafRun]]]]:
        """section index -> {chunk j: leaf runs intersecting
        [j·chunk, (j+1)·chunk)} — the inverse view of ``leaf_runs`` a
        chunk-driven kernel would walk. Purely static."""
        out: Dict[int, Dict[int, List[LeafRun]]] = {}
        for run in self.leaf_runs():
            per = out.setdefault(run.section, {})
            j0 = run.offset // chunk
            # a zero-size run still belongs to the chunk at its offset;
            # (offset + size - 1) // chunk would underflow past j0 and
            # silently drop the leaf from the chunk-driven view
            j1 = (run.offset + run.size - 1) // chunk if run.size else j0
            for j in range(j0, j1 + 1):
                per.setdefault(j, []).append(run)
        return {s: sorted(d.items()) for s, d in out.items()}

    # ------------------------------------------------------------------
    def pack(self, tree) -> jax.Array:
        """Pytree -> (..., P) f32 slab (section padding stays zero).

        Leaves may carry identical leading batch dims (e.g. the (C,)
        cluster axis — compare against ``slots[i].shape``); the batch
        axes are preserved: output is (*batch, P).

        Implementation note: a chain of static dynamic_update_slices into
        a zeros slab, NOT one big concatenate — XLA updates the buffer in
        place, while a wide concatenate of odd-sized segments falls off
        the vectorized copy path (~10x slower at 16M params on CPU).
        """
        leaves = self.treedef.flatten_up_to(tree)
        i0 = self.order[0]
        nb = leaves[i0].ndim - len(self.slots[i0].shape)
        batch = tuple(leaves[i0].shape[:nb])
        slab = jnp.zeros(batch + (self.size,), jnp.float32)
        for i in self.order:
            piece = leaves[i].astype(jnp.float32).reshape(batch + (-1,))
            slab = jax.lax.dynamic_update_slice(
                slab, piece, (0,) * nb + (self.slots[i].offset,))
        return slab

    # ------------------------------------------------------------------
    def unpack(self, slab: jax.Array):
        """(..., P) slab -> pytree with leaves (..., *shape)."""
        batch = slab.shape[:-1]
        leaves = [None] * len(self.slots)
        for i, slot in self.slots.items():
            piece = jax.lax.slice_in_dim(slab, slot.offset,
                                         slot.offset + slot.size, axis=-1)
            leaves[i] = piece.reshape(batch + slot.shape).astype(slot.dtype)
        return self.treedef.unflatten(leaves)

    # ------------------------------------------------------------------
    def tail_slice(self, slab: jax.Array) -> jax.Array:
        """The contiguous last-shared-layer tail of a (..., P) slab."""
        return jax.lax.slice_in_dim(slab, self.head_len, self.size, axis=-1)

    def unpack_tail(self, tail_slab: jax.Array):
        """(..., tail_len) tail slice -> the ``tail`` subtree's pytree,
        leaves (..., *shape) — dtype is NOT cast (masks stay bool etc.)."""
        if self.tail_name is None:
            raise ValueError("this packer was built with tail=None — it has "
                             "no tail section to unpack")
        batch = tail_slab.shape[:-1]
        sub_leaves = []
        for i in self.tail_indices:
            slot = self.slots[i]
            off = slot.offset - self.head_len
            piece = jax.lax.slice_in_dim(tail_slab, off, off + slot.size,
                                         axis=-1)
            sub_leaves.append(piece.reshape(batch + slot.shape))
        full = self.treedef.unflatten(list(range(len(self.slots))))
        _, tail_def = jtu.tree_flatten(full[self.tail_name])
        return jtu.tree_unflatten(tail_def, sub_leaves)


# ---------------------------------------------------------------------------
# template validation — readable mismatch errors for the gather paths
# ---------------------------------------------------------------------------

def check_tree_matches_packer(packer: TreePacker, tree, what: str,
                              check_shapes: bool = True,
                              batch_ndim: int = 0) -> None:
    """Raise a readable error when ``tree`` does not match the packer
    template: names the first offending leaf path and the section it was
    expected in, instead of letting a zip mispair leaves and die in an
    opaque downstream shape error (used by the packed gathers in
    repro.core.hota / repro.core.hota_slab and the client-folded sim
    path in repro.core.ota).

    ``batch_ndim`` allows every leaf to carry that many IDENTICAL
    leading batch axes on top of its template shape — the zero-copy
    consumers read e.g. (C, N, *shape) gradient leaves against a
    template of (*shape,) slots (the (cluster, client) axes of the
    simulator)."""
    leaves, treedef = jax.tree.flatten(tree)

    def _leaf_ok(i, l):
        shape = tuple(l.shape)
        if len(shape) < batch_ndim:
            return False
        if batch_ndim and shape[:batch_ndim] != tuple(
                leaves[0].shape[:batch_ndim]):
            return False
        return shape[batch_ndim:] == packer.slots[i].shape

    if treedef == packer.treedef:
        if not check_shapes or all(
                _leaf_ok(i, l) for i, l in enumerate(leaves)):
            return
    by_leaf = {i: sec for sec in packer.sections for i in sec.leaf_indices}
    n = len(packer.slots)
    tpl = packer.treedef.unflatten(list(range(n)))
    exp_paths = [None] * n
    for p, i in jtu.tree_flatten_with_path(tpl)[0]:
        exp_paths[i] = jtu.keystr(p)
    got_paths = [jtu.keystr(p)
                 for p, _ in jtu.tree_flatten_with_path(tree)[0]]
    for i in range(max(n, len(got_paths))):
        exp = exp_paths[i] if i < n else "<nothing — extra leaf>"
        got = got_paths[i] if i < len(got_paths) else "<missing leaf>"
        shape_ok = (not check_shapes) or (
            i < n and i < len(leaves) and _leaf_ok(i, leaves[i]))
        if exp != got or not shape_ok:
            sec = by_leaf.get(i)
            where = (f"section {sec.index} ({sec.name or 'head'!r}, slab "
                     f"[{sec.start}:{sec.start + sec.length}))"
                     if sec is not None else "beyond the template")
            exp_shape = packer.slots[i].shape if i < n else "-"
            got_shape = tuple(leaves[i].shape) if i < len(leaves) else "-"
            raise ValueError(
                f"{what} does not match the packer template at leaf {i}: "
                f"expected {exp} with shape {exp_shape} in {where}, got "
                f"{got} with shape {got_shape}. The packer was built from "
                f"the model's parameter template — pass a pytree of that "
                f"exact structure (same treedef, same leaf shapes).")
    raise ValueError(
        f"{what} does not match the packer template: treedefs differ "
        f"({treedef} vs {packer.treedef}) though every leaf path agrees — "
        f"check container types (dict vs namedtuple) at the root.")


# ---------------------------------------------------------------------------
# packer cache — keyed on static structure, reused across traces
# ---------------------------------------------------------------------------

_PACKER_CACHE: Dict[Any, TreePacker] = {}


def packer_for(tree, tail: Optional[str] = "final",
               sections: str = "tail",
               min_section_rows: int = 0,
               max_section_rows: int = 0) -> TreePacker:
    """Cached TreePacker for ``tree``'s (treedef, shapes, dtypes, tail,
    sections, min_section_rows, max_section_rows).

    ``tree`` may hold arrays, tracers or ShapeDtypeStructs — only the
    static structure is read.
    """
    leaves, treedef = jax.tree.flatten(tree)
    key = (treedef, tuple((tuple(l.shape), jnp.dtype(l.dtype).name)
                          for l in leaves), tail, sections,
           int(min_section_rows), int(max_section_rows))
    packer = _PACKER_CACHE.get(key)
    if packer is None:
        packer = TreePacker(
            treedef.unflatten([jax.ShapeDtypeStruct(tuple(l.shape), l.dtype)
                               for l in leaves]), tail, sections=sections,
            min_section_rows=min_section_rows,
            max_section_rows=max_section_rows)
        _PACKER_CACHE[key] = packer
    return packer
