"""Flat-packing of parameter pytrees for the fused OTA aggregation engine.

The paper's channel model is per-entry: every parameter entry j gets its
own gain draw, threshold test and superposition (eqs. 7-10) — nothing in
the math cares about the pytree structure. The per-leaf implementation in
``repro.core.ota`` therefore pays pure overhead: dozens of small
memory-bound kernels (one gain/mask/noise draw per leaf per cluster) per
round, multiplied by the scenario count under ``ScenarioBank``.

``TreePacker`` ravels the shared-model pytree ONCE into a lane-aligned
``(P,)`` slab with static per-leaf offsets, so the whole round's channel
can be drawn, thresholded and aggregated in a single fused Pallas pass
(``repro.kernels.ota_channel.ota_aggregate``).

Layout contract (relied on by ``repro.core.ota.final_layer_masks_packed``):

* leaves are packed in flatten order, **except** the leaves of the
  ``tail`` subtree (the last-shared-layer params ω̃), which are packed
  last, forming one contiguous tail slice of the slab;
* the head and tail sections are each zero-padded up to a multiple of
  ``ROW_QUANTUM`` (= 8·128), so every section — and the whole slab —
  reshapes exactly to the kernels' (rows, 128) view and each section can
  be drawn from its own counter-based bit stream (section folds and the
  chunk-quantized draw are specified in DESIGN.md §4);
* FedGradNorm's sparsified F_grad (eqs. 5-7) needs exactly the masks of
  ω̃: with this layout they are the tail slice of the same flat channel
  draw the transmission uses — no second per-leaf mask loop.

Packers are cached on (treedef, shapes, dtypes, tail), so tracing a step
re-uses the offsets computed at the first call.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro.kernels.slab import LANE, ROW_QUANTUM, round_up


class LeafSlot(NamedTuple):
    offset: int                # start index into the (P,) slab
    size: int                  # element count
    shape: Tuple[int, ...]
    dtype: Any


def _in_tail(path, tail: Optional[str]) -> bool:
    if tail is None or not path:
        return False
    step = path[0]
    key = getattr(step, "key", getattr(step, "name", None))
    return key == tail


class TreePacker:
    """Static pack/unpack between a pytree and a lane-aligned (P,) slab.

    ``tail`` names a top-level key of ``template`` (usually ``"final"``)
    whose leaves are laid out as the contiguous tail of the slab; pass
    ``None`` to pack everything as one head section.
    """

    def __init__(self, template, tail: Optional[str] = "final"):
        paths_leaves, treedef = jtu.tree_flatten_with_path(template)
        self.treedef = treedef
        self.tail_name = tail

        head_idx = [i for i, (p, _) in enumerate(paths_leaves)
                    if not _in_tail(p, tail)]
        tail_idx = [i for i, (p, _) in enumerate(paths_leaves)
                    if _in_tail(p, tail)]
        # pack order: head leaves in flatten order, tail leaves last
        self.order: List[int] = head_idx + tail_idx
        self.tail_indices = tail_idx

        self.slots: Dict[int, LeafSlot] = {}
        off = 0
        for i in head_idx:
            leaf = paths_leaves[i][1]
            self.slots[i] = LeafSlot(off, int(leaf.size), tuple(leaf.shape),
                                     jnp.dtype(leaf.dtype))
            off += int(leaf.size)
        self.head_len = round_up(off, ROW_QUANTUM)      # section boundary
        off = self.head_len
        for i in tail_idx:
            leaf = paths_leaves[i][1]
            self.slots[i] = LeafSlot(off, int(leaf.size), tuple(leaf.shape),
                                     jnp.dtype(leaf.dtype))
            off += int(leaf.size)
        self.tail_len = round_up(off - self.head_len, ROW_QUANTUM)
        self.size = self.head_len + self.tail_len       # P, lane-aligned
        if self.size == 0:
            raise ValueError("cannot pack an empty pytree")
        self.n_rows = self.size // LANE

    # ------------------------------------------------------------------
    def pack(self, tree) -> jax.Array:
        """Pytree -> (..., P) f32 slab (section padding stays zero).

        Leaves may carry identical leading batch dims (e.g. the (C,)
        cluster axis — compare against ``slots[i].shape``); the batch
        axes are preserved: output is (*batch, P).

        Implementation note: a chain of static dynamic_update_slices into
        a zeros slab, NOT one big concatenate — XLA updates the buffer in
        place, while a wide concatenate of odd-sized segments falls off
        the vectorized copy path (~10x slower at 16M params on CPU).
        """
        leaves = self.treedef.flatten_up_to(tree)
        i0 = self.order[0]
        nb = leaves[i0].ndim - len(self.slots[i0].shape)
        batch = tuple(leaves[i0].shape[:nb])
        slab = jnp.zeros(batch + (self.size,), jnp.float32)
        for i in self.order:
            piece = leaves[i].astype(jnp.float32).reshape(batch + (-1,))
            slab = jax.lax.dynamic_update_slice(
                slab, piece, (0,) * nb + (self.slots[i].offset,))
        return slab

    # ------------------------------------------------------------------
    def unpack(self, slab: jax.Array):
        """(..., P) slab -> pytree with leaves (..., *shape)."""
        batch = slab.shape[:-1]
        leaves = [None] * len(self.slots)
        for i, slot in self.slots.items():
            piece = jax.lax.slice_in_dim(slab, slot.offset,
                                         slot.offset + slot.size, axis=-1)
            leaves[i] = piece.reshape(batch + slot.shape).astype(slot.dtype)
        return self.treedef.unflatten(leaves)

    # ------------------------------------------------------------------
    def tail_slice(self, slab: jax.Array) -> jax.Array:
        """The contiguous last-shared-layer tail of a (..., P) slab."""
        return jax.lax.slice_in_dim(slab, self.head_len, self.size, axis=-1)

    def unpack_tail(self, tail_slab: jax.Array):
        """(..., tail_len) tail slice -> the ``tail`` subtree's pytree,
        leaves (..., *shape) — dtype is NOT cast (masks stay bool etc.)."""
        if self.tail_name is None:
            raise ValueError("this packer was built with tail=None — it has "
                             "no tail section to unpack")
        batch = tail_slab.shape[:-1]
        sub_leaves = []
        for i in self.tail_indices:
            slot = self.slots[i]
            off = slot.offset - self.head_len
            piece = jax.lax.slice_in_dim(tail_slab, off, off + slot.size,
                                         axis=-1)
            sub_leaves.append(piece.reshape(batch + slot.shape))
        full = self.treedef.unflatten(list(range(len(self.slots))))
        _, tail_def = jtu.tree_flatten(full[self.tail_name])
        return jtu.tree_unflatten(tail_def, sub_leaves)


# ---------------------------------------------------------------------------
# packer cache — keyed on static structure, reused across traces
# ---------------------------------------------------------------------------

_PACKER_CACHE: Dict[Any, TreePacker] = {}


def packer_for(tree, tail: Optional[str] = "final") -> TreePacker:
    """Cached TreePacker for ``tree``'s (treedef, shapes, dtypes, tail).

    ``tree`` may hold arrays, tracers or ShapeDtypeStructs — only the
    static structure is read.
    """
    leaves, treedef = jax.tree.flatten(tree)
    key = (treedef, tuple((tuple(l.shape), jnp.dtype(l.dtype).name)
                          for l in leaves), tail)
    packer = _PACKER_CACHE.get(key)
    if packer is None:
        packer = TreePacker(
            treedef.unflatten([jax.ShapeDtypeStruct(tuple(l.shape), l.dtype)
                               for l in leaves]), tail)
        _PACKER_CACHE[key] = packer
    return packer
