from repro.common.config import (
    FLConfig,
    HybridConfig,
    INPUT_SHAPES,
    InputShape,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ServeConfig,
    TrainConfig,
    XLSTMConfig,
)
from repro.common.tree import (
    tree_cast,
    tree_global_norm,
    tree_size,
    tree_zeros_like,
)

__all__ = [
    "FLConfig", "HybridConfig", "INPUT_SHAPES", "InputShape", "MeshConfig",
    "ModelConfig", "MoEConfig", "SSMConfig", "ServeConfig", "TrainConfig",
    "XLSTMConfig", "tree_cast", "tree_global_norm", "tree_size",
    "tree_zeros_like",
]
