"""Small pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of elements in a pytree of arrays."""
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_cast(tree, dtype):
    """Cast floating-point leaves to ``dtype``; leave integer leaves alone."""
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(_cast, tree)


def tree_global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leafwise."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)
