"""Configuration dataclasses for the repro framework.

Every architecture in the zoo is described by a single ``ModelConfig``;
family-specific fields are optional and ignored by other families.
``FLConfig`` describes the HOTA-FedGradNorm topology/channel, and
``TrainConfig``/``ServeConfig`` the step-level knobs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD form) hyper-parameters."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2           # d_inner = expand * d_model
    head_dim: int = 64        # SSD head dim
    chunk_size: int = 256     # SSD chunk length
    n_groups: int = 1         # B/C groups (GVA-style)


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8          # every k-th block is an sLSTM block
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.333
    conv_kernel: int = 4


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: SSM backbone + shared attention block."""
    attn_every: int = 6           # shared attn applied every k SSM layers
    shared_attn_n_heads: int = 32
    shared_attn_n_kv: int = 32


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | xlstm
    modality: str = "text"         # text | audio | vision (audio/vision = stub frontends)
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None          # default d_model // n_heads
    max_seq_len: int = 4096
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None   # gemma3 global layers
    qkv_bias: bool = False                  # qwen2.5
    mlp_act: str = "silu"                   # silu (SwiGLU) | gelu (plain MLP)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # attention pattern
    sliding_window: Optional[int] = None    # SWA width (starcoder2/mixtral: 4096)
    local_global_ratio: Optional[int] = None  # gemma3: 5 local per 1 global
    local_window: int = 1024                # window of "local" layers (gemma3)
    # family-specific
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat_policy: str = "nothing_saveable"   # none | dots | nothing_saveable
    # attention implementation: blocked (scan online-softmax) | naive | pallas
    attn_impl: str = "blocked"
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    # citation for provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        """Whether the arch supports bounded-state long-context decode."""
        if self.family in ("ssm", "xlstm", "hybrid"):
            return True
        if self.sliding_window is not None:
            return True
        if self.local_global_ratio is not None:
            return True
        return False

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class FLConfig:
    """HOTA-FedGradNorm topology + channel model (paper Secs. II-III)."""
    n_clusters: int = 4               # C
    n_clients: int = 4                # N per cluster
    sigma2: Tuple[float, ...] = ()    # per-cluster channel variance; () -> all 1.0
    h_threshold: float = 3.2e-2       # H_th (paper Sec. IV-B)
    noise_std: float = 1.0            # AWGN z ~ N(0,1)
    gamma: float = 0.6                # FedGradNorm restoring-force strength
    alpha: float = 8e-3               # F_grad learning rate (Alg 2)
    tau_h: int = 1                    # local head steps per round
    tau_w: int = 1                    # local shared-net steps per round
    weighting: str = "fedgradnorm"    # fedgradnorm | equal (paper baseline)
    ota: bool = True                  # over-the-air aggregation on/off
    p_min: float = 0.0                # clamp for loss weights before renorm
    # Flat-packed OTA: ravel the shared tree into one lane-aligned slab and
    # run eqs. 7-10 in a single fused Pallas kernel (repro.common.flatpack +
    # repro.kernels.ota_channel.ota_aggregate). False keeps the per-leaf jnp
    # path — the property-test oracle (different PRNG stream, same math).
    use_pallas_ota: bool = True
    # gradient-transmission implementation (same math — DESIGN.md §3.1):
    #  * "naive":   paper-literal — per-layer full-size weighted psum over
    #    clients (LAN) + full-size masked psum over clusters (MAC).
    #  * "scatter": psum_scatter the LAN sum into per-client regions, mask
    #    and MAC-reduce regions, slice the FSDP piece — ~3x fewer
    #    collective bytes, no full-size intermediates.
    # Channel keys fold (step, layer, leaf) only, so microbatch-averaged
    # estimates equal one MAC transmission per round (exact Alg. 1).
    ota_mode: str = "scatter"         # "scatter" | "naive"
    # Packed-slab section layout (DESIGN.md §3.13) — static, like ota_mode:
    # the Section partition decides the stream folds, so it changes every
    # channel draw and is pinned in checkpoint manifests. "toplevel" =
    # one section per layer stack (tail last); "tail" = the legacy
    # two-section layout. min_section_rows coalesces adjacent sub-
    # threshold trunk sections (rows of 128 lanes) to kill the chunk-
    # quantization RNG waste on many-tiny-leaf templates; 0 = uncoalesced
    # (bit-identical to the pre-autotuner layout). Set both via
    # repro.common.layout_tune.apply_layout, not by hand.
    ota_sections: str = "toplevel"    # "toplevel" | "tail"
    min_section_rows: int = 0         # coalescing threshold (slab rows)
    max_section_rows: int = 0         # section split cap (slab rows); 0=off
    # Streaming aggregation (DESIGN.md §3.15) — static, sim engine only:
    # fold arriving cluster contributions into the slab running sum one
    # cluster at a time (lax.scan over repro.core.ota.ota_stream_fold)
    # instead of drawing every cluster's streams at once. Same streams,
    # same math (equal up to float associativity — the cross-cluster
    # reduction order changes); peak aggregation memory drops from
    # (C × section) to one cluster's contribution + the running sum.
    ota_streaming: bool = False
    # Section-streaming aggregation (DESIGN.md §3.16) — static: make the
    # multi-section layout the unit of scheduling. The round walks the
    # Section partition one section at a time, drawing only that
    # section's gain/noise streams (the same per-section folds — bit-
    # identical draws), folding only its leaf runs, then releasing the
    # buffers, so peak live streams are ONE section (bounded by
    # max_section_rows above), never the (P,) or (C,P) slab. Composes
    # with ota_streaming: the cluster scan then runs inside each
    # section. Requires a multi-section layout (ota_sections="toplevel").
    ota_sectioned: bool = False
    microbatches: int = 1             # gradient accumulation count
    # Fault injection (DESIGN.md §3.14). ``faults`` is the one static gate:
    # False keeps the legacy trace bit-exact (no participation draws, no
    # stale-model state in SimState); True threads the traced FaultParams
    # knobs below through the round. The rates themselves are traced
    # (FaultParams) so fault scenarios sweep without retracing.
    faults: bool = False              # static: enable fault plumbing
    dropout_rate: float = 0.0         # per-client drop probability
    blackout_rate: float = 0.0        # per-cluster blackout probability
    straggler_rate: float = 0.0       # per-client straggler probability
    staleness_rounds: int = 1         # straggler staleness depth τ (rounds)
    spike_norm: float = float("inf")  # guard: skip round if ‖ĝ‖ exceeds

    def cluster_sigma2(self, cluster: int) -> float:
        if not self.sigma2:
            return 1.0
        return self.sigma2[cluster % len(self.sigma2)]


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4                  # β in the paper
    betas: Tuple[float, float] = (0.9, 0.999)
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = None
    steps: int = 100
    seed: int = 0
    fl: FLConfig = field(default_factory=FLConfig)


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 8
    prefill_len: int = 128
    cache_len: int = 256
    param_dtype: str = "bfloat16"


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")
    multi_pod: bool = False


# --- input shapes assigned to this paper ------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str   # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
