"""Section-layout autotuner for the packed OTA engines (DESIGN.md §3.13).

The chunk-quantized stream spec (§4) makes section layout a performance
decision with correctness consequences: every sub-chunk section pays a
full 131072-entry chunk draw and truncates it, so a template with many
tiny top-level groups (the `1M_x32leaves` bench case, the paper MLP's
10 flat leaves) can spend ~4x the RNG of a coalesced layout — while the
Section partition also decides the stream folds, i.e. every channel
draw. This module makes the choice once, explicitly, and persistable:

* ``tune_layout(template, C, N)`` runs a one-shot calibration bench per
  model template — a coalescing-threshold sweep over
  ``sections="toplevel"`` packers for both the full-slab and the
  section-streaming engine (§3.16), the legacy two-section layout, and
  the per-leaf engine — and returns the fastest as a ``LayoutChoice``.
  ``memory_budget_bytes`` excludes candidates whose estimated peak
  aggregation working set (``estimate_peak_slab_bytes``) exceeds the
  budget and adds a sectioned candidate with ``max_section_rows`` sized
  to fit — the billion-parameter path where full-slab layouts cannot
  run at all. Results are cached per (template structure, C, N), so a
  sweep bank or a restarted trainer never re-times a template it has
  seen.
* ``apply_layout(fl, choice)`` writes the choice into ``FLConfig``'s
  static layout fields (``use_pallas_ota`` / ``ota_sectioned`` /
  ``ota_sections`` / ``min_section_rows`` / ``max_section_rows``),
  which `sim.step_with_channel`, the slab-native distributed step and
  the sweep banks all consume. It raises ``LayoutUnavailableError``
  when a (typically cached) choice names an engine the gates cannot
  run, so stale caches fail at config time with the layout named.
* ``LayoutChoice.to_metadata()`` is what the checkpoint layer persists:
  section folds — and therefore all channel streams — depend on the
  layout, so a restore under a different layout would silently change
  the channel. ``repro.checkpoint.store.restore_checkpoint`` raises
  with both layouts named on a mismatch.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import FLConfig
from repro.common.flatpack import packer_for
from repro.kernels.slab import LANE

# threshold sweep, in slab rows (x128 lanes): 0 = uncoalesced; 1024 rows
# = one full stream chunk (CHUNK_ROWS), the natural upper useful bound —
# any larger threshold cannot reduce the per-section chunk waste further
DEFAULT_THRESHOLDS: Tuple[int, ...] = (0, 64, 256, 1024)

# every engine a LayoutChoice may legally name; anything else is a
# stale/foreign cache entry and must fail loudly, not deep in tracing
ENGINES: Tuple[str, ...] = ("slab", "sectioned", "perleaf")


class LayoutUnavailableError(ValueError):
    """A LayoutChoice names an engine/section combination the current
    gates cannot run (DESIGN.md §3.16) — e.g. a stale disk-cache entry
    with ``engine="sectioned"`` on the legacy two-section layout, or an
    engine string this build does not know. Raised by ``apply_layout``
    (and ``LayoutChoice.from_metadata``) so the failure happens at
    config time with the layout named, not as a shape/trace error deep
    inside the step."""


class LayoutChoice(NamedTuple):
    """One tuned packed-layout decision — the unit the manifest pins."""
    engine: str             # "slab" | "sectioned" | "perleaf"
    sections: str           # "toplevel" | "tail" (legacy two-section)
    min_section_rows: int   # coalescing threshold (slab rows; 0 = off)
    max_section_rows: int = 0   # section split cap (slab rows; 0 = off)

    def to_metadata(self) -> Dict[str, Any]:
        md = {"engine": self.engine, "sections": self.sections,
              "min_section_rows": int(self.min_section_rows)}
        # emitted only when set: keeps the metadata dict — which the
        # checkpoint manifest compares verbatim — byte-identical to
        # pre-sectioned builds for every pre-sectioned layout
        if self.max_section_rows:
            md["max_section_rows"] = int(self.max_section_rows)
        return md

    @classmethod
    def from_metadata(cls, md: Dict[str, Any]) -> "LayoutChoice":
        choice = cls(str(md["engine"]), str(md["sections"]),
                     int(md["min_section_rows"]),
                     int(md.get("max_section_rows", 0)))
        _check_available(choice)
        return choice

    def describe(self) -> str:
        if self.engine == "perleaf":
            return "perleaf"
        desc = (f"{self.engine}/sections={self.sections}"
                f"/min_section_rows={self.min_section_rows}")
        if self.max_section_rows:
            desc += f"/max_section_rows={self.max_section_rows}"
        return desc


def _check_available(choice: LayoutChoice) -> None:
    """Raise LayoutUnavailableError unless ``choice`` names a runnable
    engine/layout combination under the FLConfig gates."""
    if choice.engine not in ENGINES:
        raise LayoutUnavailableError(
            f"layout names unknown engine {choice.engine!r} (known: "
            f"{', '.join(ENGINES)}) — likely a stale or foreign "
            "layout-tune cache / checkpoint entry; re-tune the layout")
    if choice.engine == "sectioned" and choice.sections != "toplevel":
        raise LayoutUnavailableError(
            f"layout {choice.describe()} is unavailable: the sectioned "
            "engine streams the multi-section layout and requires "
            "sections='toplevel' (DESIGN.md §3.16); the legacy "
            f"{choice.sections!r} layout has no section structure to "
            "stream. Re-tune or pick a slab/perleaf layout.")
    if choice.engine == "perleaf" and (choice.min_section_rows
                                       or choice.max_section_rows):
        raise LayoutUnavailableError(
            f"layout {choice.describe()} is unavailable: the per-leaf "
            "engine has no packed sections, so min/max_section_rows "
            "would be silently inert — a stale cache entry; re-tune.")
    if choice.max_section_rows < 0:
        raise LayoutUnavailableError(
            f"layout {choice.describe()} is unavailable: "
            "max_section_rows must be >= 0")
    if (0 < choice.max_section_rows < choice.min_section_rows):
        raise LayoutUnavailableError(
            f"layout {choice.describe()} is unavailable: "
            "max_section_rows < min_section_rows cannot be packed "
            "(split pieces would violate the coalescing floor)")


def layout_of(fl: FLConfig) -> LayoutChoice:
    """The LayoutChoice an FLConfig currently encodes."""
    if not fl.use_pallas_ota:
        return LayoutChoice("perleaf", fl.ota_sections,
                            fl.min_section_rows, fl.max_section_rows)
    return LayoutChoice("sectioned" if fl.ota_sectioned else "slab",
                        fl.ota_sections, fl.min_section_rows,
                        fl.max_section_rows)


def apply_layout(fl: FLConfig, choice: LayoutChoice) -> FLConfig:
    """FLConfig with the tuned layout written into its static fields.

    Raises :class:`LayoutUnavailableError` when the choice — typically
    a cached/persisted one — names an engine the current gates cannot
    run, so a stale cache fails here with the layout named instead of
    as a trace error inside the step."""
    import dataclasses
    _check_available(choice)
    return dataclasses.replace(
        fl, use_pallas_ota=(choice.engine != "perleaf"),
        ota_sectioned=(choice.engine == "sectioned"),
        ota_sections=choice.sections,
        min_section_rows=int(choice.min_section_rows),
        max_section_rows=int(choice.max_section_rows))


def packer_for_layout(template, choice: LayoutChoice, tail: str = "final"):
    """The (cached) TreePacker a slab/sectioned LayoutChoice denotes."""
    if choice.engine == "perleaf":
        raise ValueError(
            f"layout {choice.describe()} uses the per-leaf engine — it has "
            "no packer")
    return packer_for(template, tail=tail, sections=choice.sections,
                      min_section_rows=choice.min_section_rows,
                      max_section_rows=choice.max_section_rows)


# ---------------------------------------------------------------------------
# memory model: what a candidate's aggregation intermediates cost
# ---------------------------------------------------------------------------

def estimate_peak_slab_bytes(template, choice: LayoutChoice,
                             n_clusters: int, n_clients: int) -> int:
    """Estimated peak f32 bytes the aggregation intermediates of
    ``choice`` hold live at once (DESIGN.md §3.16).

    The model counts LANE-padded slab rows times the per-row working
    set: C*N packed gradient blocks + C gain streams + one noise stream
    + one running estimate, i.e. ``4 * LANE * rows * (C*(N+1) + 2)``.
    ``rows`` is the whole slab for the full-slab engines, the peak
    SECTION for the sectioned engine, and the largest single leaf for
    the per-leaf engine. Deliberately coarse — it ranks engines for the
    budget constraint and the benches; it is not an allocator."""
    C, N = int(n_clusters), int(n_clients)
    per_row = 4 * LANE * (C * (N + 1) + 2)
    if choice.engine == "perleaf":
        leaves = jax.tree.leaves(template)
        rows = max((-(-int(np_size(l)) // LANE) for l in leaves),
                   default=0)
    else:
        packer = packer_for_layout(template, choice)
        rows = (packer.peak_section_rows()
                if choice.engine == "sectioned" else packer.n_rows)
    return rows * per_row


def np_size(leaf) -> int:
    """Element count of an array or ShapeDtypeStruct leaf."""
    size = getattr(leaf, "size", None)
    if size is not None:
        return int(size)
    n = 1
    for d in leaf.shape:
        n *= int(d)
    return n


# ---------------------------------------------------------------------------
# the calibration bench
# ---------------------------------------------------------------------------

def _time(fn, *args, iters: int) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _grad_tree(template, n_clusters: int, n_clients: int, key):
    """Synthetic raw (C, N, *shape) f32 gradient tree on the template —
    exactly what the sim holds after the local phase."""
    leaves, treedef = jax.tree.flatten(template)
    out = [jax.random.normal(jax.random.fold_in(key, i),
                             (n_clusters, n_clients) + tuple(l.shape),
                             jnp.float32)
           for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


class LayoutBudgetError(ValueError):
    """``memory_budget_bytes`` excluded every candidate layout — even
    the tightest sectioned split exceeds the budget (its floor is the
    largest single leaf; DESIGN.md §4 split rule). Raised with the
    smallest candidate named so the caller can loosen the budget."""


def _budget_section_rows(n_clusters: int, n_clients: int,
                         memory_budget_bytes: int) -> int:
    """Largest max_section_rows whose estimated per-section working set
    (see ``estimate_peak_slab_bytes``) fits the budget."""
    per_row = 4 * LANE * (int(n_clusters) * (int(n_clients) + 1) + 2)
    return max(1, int(memory_budget_bytes) // per_row)


def calibrate_layout(template, n_clusters: int, n_clients: int,
                     thresholds: Tuple[int, ...] = DEFAULT_THRESHOLDS,
                     iters: int = 3,
                     include_perleaf: bool = True,
                     memory_budget_bytes: Optional[int] = None,
                     ) -> Tuple[LayoutChoice, List[Dict[str, Any]]]:
    """Time every candidate layout on this template and return
    (winner, per-candidate report).

    Candidates: ``sections="toplevel"`` at each coalescing threshold
    for BOTH the full-slab (client-folded) and the sectioned engine,
    the legacy two-section layout, and (optionally) the per-leaf jnp
    engine. All candidates run the SAME math from the same raw
    (C, N, ...) gradients — they differ only in stream layout and
    engine, which is the whole point: the choice is free to make.

    ``memory_budget_bytes`` is the §3.16 constraint: candidates whose
    ``estimate_peak_slab_bytes`` exceeds it are excluded from timing
    (reported with ``us=None``), and one extra sectioned candidate is
    added with ``max_section_rows`` sized to the budget. If nothing
    fits, raises :class:`LayoutBudgetError`.
    Report entries: {"layout", "us", "peak_bytes", "choice"}.
    """
    from repro.core import ota
    from repro.core.channel import channel_params

    template = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(tuple(l.shape), jnp.float32),
        template)
    # autotuner probes time synthetic traffic; never a training stream
    # repro-lint: allow(bare-prng-seed, fixed synthetic probe seed)
    key = jax.random.PRNGKey(0)
    g = _grad_tree(template, n_clusters, n_clients, key)
    p = jax.random.uniform(jax.random.fold_in(key, ota.TUNE_PROBE_FOLD),
                           (n_clusters, n_clients), jnp.float32, 0.5, 1.5)
    chan = channel_params(FLConfig(
        n_clusters=n_clusters, n_clients=n_clients,
        sigma2=tuple(0.25 + 0.25 * i for i in range(n_clusters))))

    candidates: List[LayoutChoice] = [
        LayoutChoice("slab", "toplevel", t) for t in dict.fromkeys(thresholds)
    ] + [LayoutChoice("slab", "tail", 0)] + [
        LayoutChoice("sectioned", "toplevel", t)
        for t in dict.fromkeys(thresholds)
    ]
    if memory_budget_bytes is not None:
        rows = _budget_section_rows(n_clusters, n_clients,
                                    memory_budget_bytes)
        candidates.append(LayoutChoice("sectioned", "toplevel", 0, rows))
    if include_perleaf:
        candidates.append(LayoutChoice("perleaf", "toplevel", 0))

    report: List[Dict[str, Any]] = []
    best: Optional[Tuple[float, LayoutChoice]] = None
    for choice in dict.fromkeys(candidates):
        peak = estimate_peak_slab_bytes(template, choice,
                                        n_clusters, n_clients)
        if memory_budget_bytes is not None and peak > memory_budget_bytes:
            report.append({"layout": choice.describe(), "us": None,
                           "peak_bytes": peak, "choice": choice})
            continue
        if choice.engine == "sectioned":
            packer = packer_for_layout(template, choice)
            fn = jax.jit(lambda k, gg, pp, ch, pk=packer:
                         ota.ota_aggregate_sectioned(
                             k, gg, pp, ch, n_clients, pk))
        elif choice.engine == "slab":
            packer = packer_for_layout(template, choice)
            fn = jax.jit(lambda k, gg, pp, ch, pk=packer:
                         ota.ota_aggregate_client_folded(
                             k, gg, pp, ch, n_clients, pk))
        else:
            fn = jax.jit(lambda k, gg, pp, ch: ota.ota_aggregate_tree(
                k, jax.tree.map(
                    lambda l: jnp.einsum("cn,cn...->c...", pp, l), gg),
                ch, n_clients))
        us = _time(fn, key, g, p, chan, iters=iters) * 1e6
        report.append({"layout": choice.describe(), "us": us,
                       "peak_bytes": peak, "choice": choice})
        if best is None or us < best[0]:
            best = (us, choice)
    if best is None:
        smallest = min(report, key=lambda r: r["peak_bytes"])
        raise LayoutBudgetError(
            f"memory_budget_bytes={memory_budget_bytes} excludes every "
            f"candidate layout; smallest is {smallest['layout']} at "
            f"{smallest['peak_bytes']} estimated peak bytes (floor: the "
            "largest single leaf — DESIGN.md §4 split rule). Loosen the "
            "budget.")
    return best[1], report


_TUNE_CACHE: Dict[Any, LayoutChoice] = {}

# default on-disk calibration cache (override with REPRO_LAYOUT_CACHE;
# "" disables persistence entirely)
DEFAULT_CACHE_PATH = os.path.join(
    os.path.expanduser("~"), ".cache", "repro", "layout_tune.json")


def template_hash(template, n_clusters: int, n_clients: int,
                  thresholds: Tuple[int, ...] = DEFAULT_THRESHOLDS,
                  include_perleaf: bool = True,
                  memory_budget_bytes: Optional[int] = None) -> str:
    """Stable digest of everything a calibration result depends on: the
    template's tree structure + leaf shapes/dtypes, the (C, N) topology
    and the candidate set. This is the persisted cache key — NOT the
    leaf values, which the synthetic calibration gradients ignore."""
    leaves, treedef = jax.tree.flatten(template)
    desc = repr((str(treedef),
                 tuple((tuple(l.shape), jnp.dtype(l.dtype).name)
                       for l in leaves),
                 int(n_clusters), int(n_clients), tuple(thresholds),
                 bool(include_perleaf)))
    # appended only when set, so unconstrained tunes keep their
    # pre-sectioned hashes and the existing disk caches stay warm
    if memory_budget_bytes is not None:
        desc += repr(int(memory_budget_bytes))
    return hashlib.sha256(desc.encode()).hexdigest()[:16]


def _load_disk_cache(path: str) -> Dict[str, Any]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _store_disk_cache(path: str, entries: Dict[str, Any]) -> None:
    """Atomic read-merge-write (tmp + rename), so concurrent tuners —
    parallel bench shards, a sweep next to a trainer — never tear the
    file; last writer wins per key, which is fine for measurements."""
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        merged = dict(_load_disk_cache(path), **entries)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   prefix=".layout_tune.")
        with os.fdopen(fd, "w") as f:
            json.dump(merged, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass                       # persistence is best-effort only


def tune_layout(template, n_clusters: int, n_clients: int,
                thresholds: Tuple[int, ...] = DEFAULT_THRESHOLDS,
                iters: int = 3,
                include_perleaf: bool = True,
                cache_path: Optional[str] = None,
                memory_budget_bytes: Optional[int] = None) -> LayoutChoice:
    """Cached one-shot calibration: the fastest LayoutChoice for this
    template at this (C, N) topology. The cache key is the template's
    static structure — a sweep bank or restarted trainer re-uses the
    measurement instead of re-timing.

    Results also persist on disk keyed by ``template_hash`` (JSON at
    ``cache_path``, default ``DEFAULT_CACHE_PATH`` / the
    ``REPRO_LAYOUT_CACHE`` env var; empty string disables), so the
    calibration survives process restarts — the default-on wiring in
    ``launch/train.py`` and the benchmark sweeps costs one bench per
    template per MACHINE, not per run."""
    leaves, treedef = jax.tree.flatten(template)
    key = (treedef,
           tuple((tuple(l.shape), jnp.dtype(l.dtype).name) for l in leaves),
           int(n_clusters), int(n_clients), tuple(thresholds),
           bool(include_perleaf), memory_budget_bytes)
    choice = _TUNE_CACHE.get(key)
    if choice is not None:
        return choice
    if cache_path is None:
        cache_path = os.environ.get("REPRO_LAYOUT_CACHE",
                                    DEFAULT_CACHE_PATH)
    h = template_hash(template, n_clusters, n_clients, thresholds,
                      include_perleaf, memory_budget_bytes)
    if cache_path:
        entry = _load_disk_cache(cache_path).get(h)
        if entry is not None:
            try:
                # from_metadata validates availability, so an entry
                # naming an engine the current gates cannot run
                # (LayoutUnavailableError) is re-measured here instead
                # of crashing later inside step tracing
                choice = LayoutChoice.from_metadata(entry)
            except (KeyError, TypeError, ValueError):
                choice = None      # stale/foreign entry: re-measure
        if choice is not None:
            _TUNE_CACHE[key] = choice
            return choice
    choice, _ = calibrate_layout(template, n_clusters, n_clients,
                                 thresholds=thresholds, iters=iters,
                                 include_perleaf=include_perleaf,
                                 memory_budget_bytes=memory_budget_bytes)
    _TUNE_CACHE[key] = choice
    if cache_path:
        _store_disk_cache(cache_path, {h: choice.to_metadata()})
    return choice


def tuned_fl(fl: FLConfig, template, iters: int = 3,
             include_perleaf: Optional[bool] = None,
             cache_path: Optional[str] = None,
             memory_budget_bytes: Optional[int] = None) -> FLConfig:
    """``fl`` with the tuned layout for ``template`` written into its
    static fields — the one-line default-on entry point the launchers
    use. Checkpoint manifests pin the resulting layout (layout_of), so
    a restore under a cache miss that tunes differently fails loudly
    instead of silently re-keying the streams.

    ``include_perleaf`` defaults to ``not fl.faults``: the fault path
    exists only in the slab engines (DESIGN.md §3.14), so a faulted
    config never tunes onto the per-leaf candidate."""
    if include_perleaf is None:
        include_perleaf = not fl.faults
    choice = tune_layout(template, fl.n_clusters, fl.n_clients,
                         iters=iters, include_perleaf=include_perleaf,
                         cache_path=cache_path,
                         memory_budget_bytes=memory_budget_bytes)
    return apply_layout(fl, choice)
