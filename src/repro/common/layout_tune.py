"""Section-layout autotuner for the packed OTA engines (DESIGN.md §3.13).

The chunk-quantized stream spec (§4) makes section layout a performance
decision with correctness consequences: every sub-chunk section pays a
full 131072-entry chunk draw and truncates it, so a template with many
tiny top-level groups (the `1M_x32leaves` bench case, the paper MLP's
10 flat leaves) can spend ~4x the RNG of a coalesced layout — while the
Section partition also decides the stream folds, i.e. every channel
draw. This module makes the choice once, explicitly, and persistable:

* ``tune_layout(template, C, N)`` runs a one-shot calibration bench per
  model template — a coalescing-threshold sweep over
  ``sections="toplevel"`` packers, the legacy two-section layout, and
  the per-leaf engine — and returns the fastest as a ``LayoutChoice``.
  Results are cached per (template structure, C, N), so a sweep bank or
  a restarted trainer never re-times a template it has seen.
* ``apply_layout(fl, choice)`` writes the choice into ``FLConfig``'s
  static layout fields (``use_pallas_ota`` / ``ota_sections`` /
  ``min_section_rows``), which `sim.step_with_channel`, the slab-native
  distributed step and the sweep banks all consume.
* ``LayoutChoice.to_metadata()`` is what the checkpoint layer persists:
  section folds — and therefore all channel streams — depend on the
  layout, so a restore under a different layout would silently change
  the channel. ``repro.checkpoint.store.restore_checkpoint`` raises
  with both layouts named on a mismatch.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import FLConfig
from repro.common.flatpack import packer_for

# threshold sweep, in slab rows (x128 lanes): 0 = uncoalesced; 1024 rows
# = one full stream chunk (CHUNK_ROWS), the natural upper useful bound —
# any larger threshold cannot reduce the per-section chunk waste further
DEFAULT_THRESHOLDS: Tuple[int, ...] = (0, 64, 256, 1024)


class LayoutChoice(NamedTuple):
    """One tuned packed-layout decision — the unit the manifest pins."""
    engine: str             # "slab" | "perleaf"
    sections: str           # "toplevel" | "tail" (legacy two-section)
    min_section_rows: int   # coalescing threshold (slab rows; 0 = off)

    def to_metadata(self) -> Dict[str, Any]:
        return {"engine": self.engine, "sections": self.sections,
                "min_section_rows": int(self.min_section_rows)}

    @classmethod
    def from_metadata(cls, md: Dict[str, Any]) -> "LayoutChoice":
        return cls(str(md["engine"]), str(md["sections"]),
                   int(md["min_section_rows"]))

    def describe(self) -> str:
        if self.engine == "perleaf":
            return "perleaf"
        return (f"slab/sections={self.sections}"
                f"/min_section_rows={self.min_section_rows}")


def layout_of(fl: FLConfig) -> LayoutChoice:
    """The LayoutChoice an FLConfig currently encodes."""
    return LayoutChoice("slab" if fl.use_pallas_ota else "perleaf",
                        fl.ota_sections, fl.min_section_rows)


def apply_layout(fl: FLConfig, choice: LayoutChoice) -> FLConfig:
    """FLConfig with the tuned layout written into its static fields."""
    import dataclasses
    return dataclasses.replace(
        fl, use_pallas_ota=(choice.engine == "slab"),
        ota_sections=choice.sections,
        min_section_rows=int(choice.min_section_rows))


def packer_for_layout(template, choice: LayoutChoice, tail: str = "final"):
    """The (cached) TreePacker a slab LayoutChoice denotes."""
    if choice.engine != "slab":
        raise ValueError(
            f"layout {choice.describe()} uses the per-leaf engine — it has "
            "no packer")
    return packer_for(template, tail=tail, sections=choice.sections,
                      min_section_rows=choice.min_section_rows)


# ---------------------------------------------------------------------------
# the calibration bench
# ---------------------------------------------------------------------------

def _time(fn, *args, iters: int) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _grad_tree(template, n_clusters: int, n_clients: int, key):
    """Synthetic raw (C, N, *shape) f32 gradient tree on the template —
    exactly what the sim holds after the local phase."""
    leaves, treedef = jax.tree.flatten(template)
    out = [jax.random.normal(jax.random.fold_in(key, i),
                             (n_clusters, n_clients) + tuple(l.shape),
                             jnp.float32)
           for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


def calibrate_layout(template, n_clusters: int, n_clients: int,
                     thresholds: Tuple[int, ...] = DEFAULT_THRESHOLDS,
                     iters: int = 3,
                     include_perleaf: bool = True,
                     ) -> Tuple[LayoutChoice, List[Dict[str, Any]]]:
    """Time every candidate layout on this template and return
    (winner, per-candidate report).

    Candidates: ``sections="toplevel"`` at each coalescing threshold,
    the legacy two-section layout, and (optionally) the per-leaf jnp
    engine. All candidates run the SAME math from the same raw
    (C, N, ...) gradients — they differ only in stream layout and
    engine, which is the whole point: the choice is free to make.
    Report entries: {"layout", "us", "choice"}.
    """
    from repro.core import ota
    from repro.core.channel import channel_params

    template = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(tuple(l.shape), jnp.float32),
        template)
    key = jax.random.PRNGKey(0)
    g = _grad_tree(template, n_clusters, n_clients, key)
    p = jax.random.uniform(jax.random.fold_in(key, 99),
                           (n_clusters, n_clients), jnp.float32, 0.5, 1.5)
    chan = channel_params(FLConfig(
        n_clusters=n_clusters, n_clients=n_clients,
        sigma2=tuple(0.25 + 0.25 * i for i in range(n_clusters))))

    candidates: List[LayoutChoice] = [
        LayoutChoice("slab", "toplevel", t) for t in dict.fromkeys(thresholds)
    ] + [LayoutChoice("slab", "tail", 0)]
    if include_perleaf:
        candidates.append(LayoutChoice("perleaf", "toplevel", 0))

    report: List[Dict[str, Any]] = []
    best: Optional[Tuple[float, LayoutChoice]] = None
    for choice in candidates:
        if choice.engine == "slab":
            packer = packer_for_layout(template, choice)
            fn = jax.jit(lambda k, gg, pp, ch, pk=packer:
                         ota.ota_aggregate_client_folded(
                             k, gg, pp, ch, n_clients, pk))
        else:
            fn = jax.jit(lambda k, gg, pp, ch: ota.ota_aggregate_tree(
                k, jax.tree.map(
                    lambda l: jnp.einsum("cn,cn...->c...", pp, l), gg),
                ch, n_clients))
        us = _time(fn, key, g, p, chan, iters=iters) * 1e6
        report.append({"layout": choice.describe(), "us": us,
                       "choice": choice})
        if best is None or us < best[0]:
            best = (us, choice)
    return best[1], report


_TUNE_CACHE: Dict[Any, LayoutChoice] = {}

# default on-disk calibration cache (override with REPRO_LAYOUT_CACHE;
# "" disables persistence entirely)
DEFAULT_CACHE_PATH = os.path.join(
    os.path.expanduser("~"), ".cache", "repro", "layout_tune.json")


def template_hash(template, n_clusters: int, n_clients: int,
                  thresholds: Tuple[int, ...] = DEFAULT_THRESHOLDS,
                  include_perleaf: bool = True) -> str:
    """Stable digest of everything a calibration result depends on: the
    template's tree structure + leaf shapes/dtypes, the (C, N) topology
    and the candidate set. This is the persisted cache key — NOT the
    leaf values, which the synthetic calibration gradients ignore."""
    leaves, treedef = jax.tree.flatten(template)
    desc = repr((str(treedef),
                 tuple((tuple(l.shape), jnp.dtype(l.dtype).name)
                       for l in leaves),
                 int(n_clusters), int(n_clients), tuple(thresholds),
                 bool(include_perleaf)))
    return hashlib.sha256(desc.encode()).hexdigest()[:16]


def _load_disk_cache(path: str) -> Dict[str, Any]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _store_disk_cache(path: str, entries: Dict[str, Any]) -> None:
    """Atomic read-merge-write (tmp + rename), so concurrent tuners —
    parallel bench shards, a sweep next to a trainer — never tear the
    file; last writer wins per key, which is fine for measurements."""
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        merged = dict(_load_disk_cache(path), **entries)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   prefix=".layout_tune.")
        with os.fdopen(fd, "w") as f:
            json.dump(merged, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass                       # persistence is best-effort only


def tune_layout(template, n_clusters: int, n_clients: int,
                thresholds: Tuple[int, ...] = DEFAULT_THRESHOLDS,
                iters: int = 3,
                include_perleaf: bool = True,
                cache_path: Optional[str] = None) -> LayoutChoice:
    """Cached one-shot calibration: the fastest LayoutChoice for this
    template at this (C, N) topology. The cache key is the template's
    static structure — a sweep bank or restarted trainer re-uses the
    measurement instead of re-timing.

    Results also persist on disk keyed by ``template_hash`` (JSON at
    ``cache_path``, default ``DEFAULT_CACHE_PATH`` / the
    ``REPRO_LAYOUT_CACHE`` env var; empty string disables), so the
    calibration survives process restarts — the default-on wiring in
    ``launch/train.py`` and the benchmark sweeps costs one bench per
    template per MACHINE, not per run."""
    leaves, treedef = jax.tree.flatten(template)
    key = (treedef,
           tuple((tuple(l.shape), jnp.dtype(l.dtype).name) for l in leaves),
           int(n_clusters), int(n_clients), tuple(thresholds),
           bool(include_perleaf))
    choice = _TUNE_CACHE.get(key)
    if choice is not None:
        return choice
    if cache_path is None:
        cache_path = os.environ.get("REPRO_LAYOUT_CACHE",
                                    DEFAULT_CACHE_PATH)
    h = template_hash(template, n_clusters, n_clients, thresholds,
                      include_perleaf)
    if cache_path:
        entry = _load_disk_cache(cache_path).get(h)
        if entry is not None:
            try:
                choice = LayoutChoice.from_metadata(entry)
            except (KeyError, TypeError, ValueError):
                choice = None      # stale/foreign entry: re-measure
        if choice is not None:
            _TUNE_CACHE[key] = choice
            return choice
    choice, _ = calibrate_layout(template, n_clusters, n_clients,
                                 thresholds=thresholds, iters=iters,
                                 include_perleaf=include_perleaf)
    _TUNE_CACHE[key] = choice
    if cache_path:
        _store_disk_cache(cache_path, {h: choice.to_metadata()})
    return choice


def tuned_fl(fl: FLConfig, template, iters: int = 3,
             include_perleaf: Optional[bool] = None,
             cache_path: Optional[str] = None) -> FLConfig:
    """``fl`` with the tuned layout for ``template`` written into its
    static fields — the one-line default-on entry point the launchers
    use. Checkpoint manifests pin the resulting layout (layout_of), so
    a restore under a cache miss that tunes differently fails loudly
    instead of silently re-keying the streams.

    ``include_perleaf`` defaults to ``not fl.faults``: the fault path
    exists only in the slab engines (DESIGN.md §3.14), so a faulted
    config never tunes onto the per-leaf candidate."""
    if include_perleaf is None:
        include_perleaf = not fl.faults
    choice = tune_layout(template, fl.n_clusters, fl.n_clients,
                         iters=iters, include_perleaf=include_perleaf,
                         cache_path=cache_path)
    return apply_layout(fl, choice)
