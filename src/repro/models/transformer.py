"""Dense decoder backbone: GQA + RoPE + SwiGLU, scan-stacked layers.

Covers: starcoder2 (SWA), stablelm, qwen2.5 (qkv bias), musicgen (audio
tokens), phi-3-vision (embeds input), and gemma3's 5:1 local:global pattern
(two-level scan over super-blocks).

Caches: dict of stacked arrays
    {"k": (L, B, C, KV, D), "v": ..., "pos": (B, C)} with pos[b, slot] =
    absolute position held by that slot (-1 = empty). Windowed layers use a
    ring buffer of capacity min(window, cache_len); full layers capacity
    cache_len. All layers in one stack share one pos table (same write
    pattern), windowed stacks carry their own.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamSpec


# --------------------------------------------------------------------------
# param specs
# --------------------------------------------------------------------------

def _stack(specs, n: int):
    """Prepend a ('layer',) stacking dim to every spec in a tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layer",) + s.axes, s.init, s.scale),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    specs = {
        "norm": ParamSpec((d,), ("embed",), "zeros"),
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), "zeros")
        specs["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros")
        specs["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros")
    return specs


def mlp_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    specs = {
        "norm": ParamSpec((d,), ("embed",), "zeros"),
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }
    if cfg.mlp_act == "silu":
        specs["w_gate"] = ParamSpec((d, f), ("embed", "mlp"))
    return specs


def dense_layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.moe is not None:
        from repro.models.moe import moe_specs
        return {"attn": attn_specs(cfg), "mlp": moe_specs(cfg)}
    return {"attn": attn_specs(cfg), "mlp": mlp_specs(cfg)}


def dense_trunk_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), "embed"),
    }
    if cfg.local_global_ratio:
        r = cfg.local_global_ratio
        n_super = cfg.n_layers // (r + 1)
        assert n_super * (r + 1) == cfg.n_layers, (cfg.n_layers, r)
        specs["local"] = _stack(_stack(dense_layer_specs(cfg), r), n_super)
        specs["global"] = _stack(dense_layer_specs(cfg), n_super)
    else:
        specs["layers"] = _stack(dense_layer_specs(cfg), cfg.n_layers)
    return specs


def final_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    """The 'last shared layer' ω̃ used by FedGradNorm (DESIGN.md §3.1)."""
    return {"norm": ParamSpec((cfg.d_model,), ("embed",), "zeros")}


# --------------------------------------------------------------------------
# attention block apply
# --------------------------------------------------------------------------

def _project_qkv(p, x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def attn_apply(
    p, x: jax.Array, cfg: ModelConfig, *,
    positions: jax.Array,           # (S,) for train/prefill; (B,) abs pos for decode
    window: Optional[int],
    theta: float,
    mode: str,                      # "train" | "prefill" | "decode"
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_len: Optional[int] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    h = rms_in = L.rms_norm(x, p["norm"], 1e-6)
    q, k, v = _project_qkv(p, h, cfg)

    if mode == "decode":
        # positions: (B,) absolute position of the incoming token
        q = L.apply_rope(q, positions[:, None], theta)
        k = L.apply_rope(k, positions[:, None], theta)
        cap = cache["k"].shape[1]
        slot = positions % cap if window is not None else positions
        slot = jnp.clip(slot, 0, cap - 1)
        bidx = jnp.arange(x.shape[0])
        k_cache = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
        pos_tab = cache["pos"].at[bidx, slot].set(positions)
        out = L.decode_attention(q, k_cache, v_cache,
                                 pos_q=positions, pos_kv=pos_tab, window=window)
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos_tab}
    else:
        q = L.apply_rope(q, positions[None, :], theta)
        k = L.apply_rope(k, positions[None, :], theta)
        out = L.attention(
            q, k, v, pos_q=positions, pos_kv=positions, impl=cfg.attn_impl,
            window=window, block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
        new_cache = None
        if mode == "prefill":
            s = k.shape[1]
            total = cache_len if cache_len is not None else s + 1
            if window is not None:
                cap = min(window, total)
                keep = min(cap, s)
                # ring layout by absolute position
                k_tail, v_tail = k[:, -keep:], v[:, -keep:]
                pos_tail = jnp.broadcast_to(positions[-keep:], (x.shape[0], keep))
                slot = positions[-keep:] % cap
                order = jnp.argsort(slot)
                k_tail = k_tail[:, order]
                v_tail = v_tail[:, order]
                pos_tail = pos_tail[:, order]
                pad = cap - keep
            else:
                cap = total
                keep = min(cap, s)
                k_tail, v_tail = k[:, -keep:], v[:, -keep:]
                pos_tail = jnp.broadcast_to(positions[-keep:], (x.shape[0], keep))
                pad = cap - keep
            if pad > 0:
                padc = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
                k_tail, v_tail = padc(k_tail), padc(v_tail)
                pos_tail = jnp.pad(pos_tail, ((0, 0), (0, pad)),
                                   constant_values=-1)
            new_cache = {"k": k_tail, "v": v_tail, "pos": pos_tail}

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return x + y, new_cache


def mlp_block_apply(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = L.rms_norm(x, p["norm"], 1e-6)
    h = L.mlp_apply({k: v.astype(x.dtype) for k, v in p.items() if k != "norm"},
                    h, cfg.mlp_act)
    return x + h


def dense_layer_apply(p, x, cfg: ModelConfig, *, positions, window, theta,
                      mode, cache=None, cache_len=None):
    """Returns (x, aux_loss, new_cache)."""
    x, new_cache = attn_apply(p["attn"], x, cfg, positions=positions,
                              window=window, theta=theta, mode=mode,
                              cache=cache, cache_len=cache_len)
    if cfg.moe is not None:
        from repro.models.moe import moe_apply
        x, aux = moe_apply(p["mlp"], x, cfg, train=(mode == "train"))
    else:
        x = mlp_block_apply(p["mlp"], x, cfg)
        aux = jnp.zeros((), jnp.float32)
    return x, aux, new_cache


# --------------------------------------------------------------------------
# trunk forward (scan over stacked layers)
# --------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _scan_stack(layer_fn, stack_params, x, cache, cfg: ModelConfig,
                mode: str = "train", param_hook=None, hook_klass="layers",
                hook_tags=()):
    """Scan ``layer_fn`` over a stacked param tree (+ optional stacked cache).

    ``layer_fn(lp, h, c) -> (h, aux, c)``. Returns (x, aux_sum, new_cache).
    Modes: train — no caches; prefill — no input cache, output caches
    stacked as scan ys; decode — stacked input caches, stacked outputs.
    """
    zero = jnp.zeros((), jnp.float32)
    n = jax.tree.leaves(stack_params)[0].shape[0]
    idxs = jnp.arange(n)

    # the hook (FSDP/OTA gather) sits INSIDE the remat boundary: backward
    # re-gathers each layer instead of saving gathered full params as scan
    # residuals (which would cost full-model memory per device).
    def hooked(lp, i, h, c):
        if param_hook is not None:
            lp = param_hook(lp, hook_klass, *hook_tags, i)
        return layer_fn(lp, h, c)

    fn = _remat(hooked, cfg)

    if mode == "train":
        def body(carry, xs):
            h, aux = carry
            lp, i = xs
            h2, a, _ = fn(lp, i, h, None)
            return (h2, aux + a), None
        (x, aux), _ = jax.lax.scan(body, (x, zero), (stack_params, idxs))
        return x, aux, None

    if mode == "prefill":
        def body(carry, xs):
            h, aux = carry
            lp, i = xs
            h2, a, c2 = fn(lp, i, h, None)
            return (h2, aux + a), c2
        (x, aux), new_cache = jax.lax.scan(body, (x, zero), (stack_params, idxs))
        return x, aux, new_cache

    def body(carry, xs):
        h, aux = carry
        lp, c, i = xs
        h2, a, c2 = fn(lp, i, h, c)
        return (h2, aux + a), c2
    (x, aux), new_cache = jax.lax.scan(
        body, (x, zero), (stack_params, cache, idxs))
    return x, aux, new_cache


def dense_trunk_apply(
    params, tokens_or_embeds, cfg: ModelConfig, *,
    positions, mode: str = "train", cache=None, cache_len=None,
    param_hook=None,
):
    """Returns (hidden_pre_final, aux_losses, new_cache)."""
    embed = params["embed"]
    if param_hook is not None:
        embed = param_hook(embed, "embed")
    if jnp.issubdtype(tokens_or_embeds.dtype, jnp.integer):
        x = embed.astype(_cdt(cfg))[tokens_or_embeds]
    else:
        x = tokens_or_embeds.astype(_cdt(cfg))

    if cfg.local_global_ratio:
        theta_g = cfg.rope_theta_global or cfg.rope_theta
        zero = jnp.zeros((), jnp.float32)
        r = cfg.local_global_ratio
        n_super = cfg.n_layers // (r + 1)
        sup_idx = jnp.arange(n_super)

        def local_fn(lp, h, c):
            return dense_layer_apply(lp, h, cfg, positions=positions,
                                     window=cfg.local_window,
                                     theta=cfg.rope_theta, mode=mode, cache=c,
                                     cache_len=cache_len)

        def global_fn(lp, h, c):
            return dense_layer_apply(lp, h, cfg, positions=positions,
                                     window=None, theta=theta_g,
                                     mode=mode, cache=c, cache_len=cache_len)

        def hooked_global(lp, si, h, c):
            if param_hook is not None:
                lp = param_hook(lp, "layers", si, r)
            return global_fn(lp, h, c)

        g_fn = _remat(hooked_global, cfg)

        if mode == "train":
            def body(carry, xs):
                h, aux = carry
                lp_l, lp_g, si = xs
                h, a1, _ = _scan_stack(local_fn, lp_l, h, None, cfg, mode,
                                       param_hook, "layers", (si,))
                h, a2, _ = g_fn(lp_g, si, h, None)
                return (h, aux + a1 + a2), None
            (x, aux), _ = jax.lax.scan(
                body, (x, zero), (params["local"], params["global"], sup_idx))
            new_cache = None
        elif mode == "prefill":
            def body(carry, xs):
                h, aux = carry
                lp_l, lp_g, si = xs
                h, a1, nc_l = _scan_stack(local_fn, lp_l, h, None, cfg, mode,
                                          param_hook, "layers", (si,))
                h, a2, nc_g = g_fn(lp_g, si, h, None)
                return (h, aux + a1 + a2), (nc_l, nc_g)
            (x, aux), (nc_l, nc_g) = jax.lax.scan(
                body, (x, zero), (params["local"], params["global"], sup_idx))
            new_cache = {"local": nc_l, "global": nc_g}
        else:
            def body(carry, xs):
                h, aux = carry
                lp_l, lp_g, c_l, c_g, si = xs
                h, a1, nc_l = _scan_stack(local_fn, lp_l, h, c_l, cfg, mode,
                                          param_hook, "layers", (si,))
                h, a2, nc_g = g_fn(lp_g, si, h, c_g)
                return (h, aux + a1 + a2), (nc_l, nc_g)
            (x, aux), (nc_l, nc_g) = jax.lax.scan(
                body, (x, zero),
                (params["local"], params["global"],
                 cache["local"], cache["global"], sup_idx))
            new_cache = {"local": nc_l, "global": nc_g}
    else:
        def layer_fn(lp, h, c):
            return dense_layer_apply(lp, h, cfg, positions=positions,
                                     window=cfg.sliding_window,
                                     theta=cfg.rope_theta, mode=mode, cache=c,
                                     cache_len=cache_len)
        x, aux, new_cache = _scan_stack(layer_fn, params["layers"], x, cache,
                                        cfg, mode, param_hook, "layers")

    return x, aux, new_cache


def final_apply(params, hidden, cfg: ModelConfig):
    return L.rms_norm(hidden, params["norm"], 1e-6)


def _cdt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


# --------------------------------------------------------------------------
# cache construction
# --------------------------------------------------------------------------

def _layer_cache_shape(cfg: ModelConfig, batch: int, cache_len: int,
                       window: Optional[int]):
    cap = min(window, cache_len) if window is not None else cache_len
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return cap, kv, hd


def init_dense_cache(cfg: ModelConfig, batch: int, cache_len: int,
                     dtype=jnp.bfloat16):
    """Empty stacked cache for decode-from-scratch or abstract dry-run."""
    def one(n_layers_stack, window, extra_lead=()):
        cap, kv, hd = _layer_cache_shape(cfg, batch, cache_len, window)
        lead = extra_lead + (n_layers_stack,)
        return {
            "k": jnp.zeros(lead + (batch, cap, kv, hd), dtype),
            "v": jnp.zeros(lead + (batch, cap, kv, hd), dtype),
            "pos": jnp.full(lead + (batch, cap), -1, jnp.int32),
        }

    if cfg.local_global_ratio:
        r = cfg.local_global_ratio
        n_super = cfg.n_layers // (r + 1)
        local = one(r, cfg.local_window, extra_lead=(n_super,))
        glob = one(n_super, None)
        # reorder lead dims: scan expects (n_super, r, ...) for local ✓ and
        # (n_super, ...) for global ✓ — `one` builds (n_super, r, ...) already
        return {"local": local, "global": glob}
    return one(cfg.n_layers, cfg.sliding_window)


def dense_cache_axes(cfg: ModelConfig, long_context: bool = False):
    """Logical axes for cache arrays (for sharding rules)."""
    def one(n_lead):
        lead = ("layer",) * n_lead
        return {
            "k": lead + ("batch", "cache_seq", "kv_heads", "head_dim"),
            "v": lead + ("batch", "cache_seq", "kv_heads", "head_dim"),
            "pos": lead + ("batch", "cache_seq"),
        }
    if cfg.local_global_ratio:
        return {"local": one(2), "global": one(1)}
    return one(1)
