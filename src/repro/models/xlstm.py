"""xLSTM blocks: mLSTM (matrix memory) + sLSTM (scalar memory).

mLSTM is implemented in *chunkwise* form (the TPU-native adaptation — the
same intra-chunk-quadratic / inter-chunk-state structure as SSD), with the
exp-input-gate stabilizer m carried across chunks (online-softmax-style
merge of intra- and inter-chunk contributions). Decode is the O(1)
recurrent update. sLSTM has true recurrent (hidden-to-gate) connections, so
it is sequential by construction — implemented as a lax.scan over time,
exactly as the paper describes it (no parallel form exists).

Block pattern (xlstm-1.3b): every ``slstm_every``-th block is sLSTM; the
stack is scanned as super-blocks of (slstm_every-1 mLSTM + 1 sLSTM).

Simplifications vs the reference implementation (DESIGN.md §3.5):
the short causal conv in front of q/k and per-block learnable skip scales
are omitted; gates use exp input gate + sigmoid forget gate (one of the two
variants the paper ablates).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamSpec

CHUNK = 256


def _dims(cfg: ModelConfig):
    d_in = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
    dh = d_in // cfg.n_heads
    return d_in, dh


# --------------------------------------------------------------------------
# specs
# --------------------------------------------------------------------------

def mlstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    h = cfg.n_heads
    d_in, dh = _dims(cfg)
    return {
        "norm": ParamSpec((d,), ("embed",), "zeros"),
        "w_up": ParamSpec((d, d_in), ("embed", "mlp")),
        "w_gate_out": ParamSpec((d, d_in), ("embed", "mlp")),
        "wq": ParamSpec((d_in, h, dh), ("mlp", "heads", "head_dim")),
        "wk": ParamSpec((d_in, h, dh), ("mlp", "heads", "head_dim")),
        "wv": ParamSpec((d_in, h, dh), ("mlp", "heads", "head_dim")),
        "w_if": ParamSpec((d_in, h, 2), ("mlp", "heads", None), scale=0.02),
        "b_if": ParamSpec((h, 2), ("heads", None), "zeros"),
        "out_norm": ParamSpec((d_in,), ("mlp",), "zeros"),
        "w_down": ParamSpec((d_in, d), ("mlp", "embed")),
    }


def slstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    f = int(cfg.xlstm.proj_factor_slstm * d)
    return {
        "norm": ParamSpec((d,), ("embed",), "zeros"),
        # 4 gates (z, i, f, o), input + recurrent (block-diag per head)
        "w_gates": ParamSpec((d, 4, h, dh), ("embed", None, "heads", "head_dim")),
        "r_gates": ParamSpec((4, h, dh, dh), (None, "heads", "head_dim", None),
                             scale=0.02),
        "b_gates": ParamSpec((4, h, dh), (None, "heads", "head_dim"), "zeros"),
        "out_norm": ParamSpec((d,), ("embed",), "zeros"),
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }


# --------------------------------------------------------------------------
# mLSTM chunkwise forward
# --------------------------------------------------------------------------

def _mlstm_chunked(q, k, v, log_i, log_f, state=None):
    """q,k,v: (B,S,H,D) (k pre-scaled by 1/sqrt(D)); log_i/log_f: (B,S,H).

    Returns y (B,S,H,D) and final state (C̃ (B,H,D,D), ñ (B,H,D), m (B,H)).
    """
    b, s, h, d = q.shape
    chunk = CHUNK if s % CHUNK == 0 else s
    nc = s // chunk

    qc = q.reshape(b, nc, chunk, h, d)
    kc = k.reshape(b, nc, chunk, h, d)
    vc = v.reshape(b, nc, chunk, h, d)
    li = log_i.reshape(b, nc, chunk, h).astype(jnp.float32)
    lf = log_f.reshape(b, nc, chunk, h).astype(jnp.float32)

    a = jnp.cumsum(lf, axis=2)                              # (b,nc,l,h) decay from chunk start
    a_end = a[:, :, -1, :]                                  # (b,nc,h)

    if state is None:
        C0 = jnp.zeros((b, h, d, d), jnp.float32)
        n0 = jnp.zeros((b, h, d), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    # intra-chunk log weights: w[t,s] = a[t] - a[s] + li[s]  (s <= t)
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]

    def chunk_step(carry, xs):
        C_in, n_in, m_in = carry
        q_i, k_i, v_i, a_i, li_i, aend_i = xs
        # shapes: q_i (b,l,h,d); a_i (b,l,h); aend_i (b,h)
        logw = (a_i[:, :, None, :] - a_i[:, None, :, :]
                + li_i[:, None, :, :])                      # (b,t,s,h)
        logw = jnp.where(causal[None, :, :, None], logw, -jnp.inf)
        m_intra = jnp.max(logw, axis=2)                     # (b,t,h)
        m_inter = a_i + m_in[:, None, :]                    # (b,t,h)
        m_tot = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)

        w = jnp.exp(logw - m_tot[:, :, None, :])            # (b,t,s,h)
        scores = jnp.einsum("bthd,bshd->btsh", q_i, k_i) * w
        num = jnp.einsum("btsh,bshd->bthd", scores, v_i)
        den = jnp.sum(scores, axis=2)                       # (b,t,h)

        inter_scale = jnp.exp(m_inter - m_tot)              # (b,t,h)
        num = num + jnp.einsum("bthd,bhde->bthe", q_i, C_in) * inter_scale[..., None]
        den = den + jnp.einsum("bthd,bhd->bth", q_i, n_in) * inter_scale

        y_i = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_tot))[..., None]

        # state update to chunk end
        m_out = jnp.maximum(m_in + aend_i,
                            jnp.max(aend_i[:, None, :] - a_i + li_i, axis=1))
        carry_scale = jnp.exp(m_in + aend_i - m_out)        # (b,h)
        kv_w = jnp.exp(aend_i[:, None, :] - a_i + li_i - m_out[:, None, :])
        C_out = (C_in * carry_scale[..., None, None]
                 + jnp.einsum("bsh,bshd,bshe->bhde", kv_w, k_i, v_i))
        n_out = (n_in * carry_scale[..., None]
                 + jnp.einsum("bsh,bshd->bhd", kv_w, k_i))
        return (C_out, n_out, m_out), y_i

    xs = (qc.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
          kc.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
          vc.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
          a.transpose(1, 0, 2, 3), li.transpose(1, 0, 2, 3),
          a_end.transpose(1, 0, 2))
    (C_f, n_f, m_f), ys = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)
    return y, (C_f, n_f, m_f)


def _mlstm_decode(q, k, v, log_i, log_f, state):
    """One-step recurrent mLSTM. q,k,v: (B,H,D); gates (B,H)."""
    C, n, m = state
    m_new = jnp.maximum(log_f + m, log_i)
    f_s = jnp.exp(log_f + m - m_new)
    i_s = jnp.exp(log_i - m_new)
    C = C * f_s[..., None, None] + i_s[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k, v)
    n = n * f_s[..., None] + i_s[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))
    y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return y, (C, n, m_new)


def mlstm_apply(p, x, cfg: ModelConfig, *, mode="train", cache=None):
    d_in, dh = _dims(cfg)
    h_heads = cfg.n_heads
    hid = L.rms_norm(x, p["norm"], 1e-6)
    up = hid @ p["w_up"].astype(x.dtype)
    gate = jax.nn.silu(hid @ p["w_gate_out"].astype(x.dtype))

    q = jnp.einsum("bsd,dhe->bshe", up, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", up, p["wk"].astype(x.dtype)) / math.sqrt(dh)
    v = jnp.einsum("bsd,dhe->bshe", up, p["wv"].astype(x.dtype))
    gates = jnp.einsum("bsd,dhg->bshg", up, p["w_if"].astype(x.dtype)) + p["b_if"].astype(x.dtype)
    log_i = gates[..., 0].astype(jnp.float32)               # exp input gate
    log_f = jax.nn.log_sigmoid(gates[..., 1].astype(jnp.float32))

    if mode == "decode":
        state = (cache["C"].astype(jnp.float32),
                 cache["n"].astype(jnp.float32),
                 cache["m"].astype(jnp.float32))
        y, (C, n_, m_) = _mlstm_decode(
            q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32), log_i[:, 0], log_f[:, 0], state)
        y = y[:, None]                                       # (B,1,H,D)
        new_cache = {"C": C.astype(cache["C"].dtype), "n": n_.astype(cache["n"].dtype),
                     "m": m_}
    else:
        state = None
        if cache is not None:
            state = (cache["C"].astype(jnp.float32),
                     cache["n"].astype(jnp.float32),
                     cache["m"].astype(jnp.float32))
        y, (C, n_, m_) = _mlstm_chunked(q.astype(jnp.float32),
                                        k.astype(jnp.float32),
                                        v.astype(jnp.float32), log_i, log_f,
                                        state)
        new_cache = None
        if mode == "prefill":
            new_cache = {"C": C.astype(jnp.bfloat16), "n": n_.astype(jnp.bfloat16),
                         "m": m_}

    y = y.reshape(x.shape[0], -1, d_in).astype(x.dtype)
    y = L.rms_norm(y, p["out_norm"], 1e-6) * gate
    return x + y @ p["w_down"].astype(x.dtype), new_cache


# --------------------------------------------------------------------------
# sLSTM (sequential scan; true recurrence)
# --------------------------------------------------------------------------

def slstm_apply(p, x, cfg: ModelConfig, *, mode="train", cache=None):
    b, s, d = x.shape
    h_heads = cfg.n_heads
    dh = d // h_heads
    hid = L.rms_norm(x, p["norm"], 1e-6)
    # input contributions for all 4 gates: (B,S,4,H,dh)
    gx = jnp.einsum("bsd,dghe->bsghe", hid, p["w_gates"].astype(x.dtype))
    gx = gx + p["b_gates"].astype(x.dtype)

    if cache is not None:
        c0 = cache["c"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        hh0 = cache["h"].astype(jnp.float32)
        m0 = cache["m"].astype(jnp.float32)
    else:
        c0 = jnp.zeros((b, h_heads, dh), jnp.float32)
        n0 = jnp.ones((b, h_heads, dh), jnp.float32)
        hh0 = jnp.zeros((b, h_heads, dh), jnp.float32)
        m0 = jnp.zeros((b, h_heads, dh), jnp.float32)

    r = p["r_gates"].astype(jnp.float32)                     # (4,H,dh,dh)

    def step(carry, gx_t):
        c, n, hh, m = carry
        gr = jnp.einsum("bhe,ghef->bghf", hh, r)             # (B,4,H,dh)
        g = gx_t.astype(jnp.float32) + gr
        z = jnp.tanh(g[:, 0])
        i_t = g[:, 1]
        f_t = jax.nn.log_sigmoid(g[:, 2])
        o = jax.nn.sigmoid(g[:, 3])
        m_new = jnp.maximum(f_t + m, i_t)
        i_s = jnp.exp(i_t - m_new)
        f_s = jnp.exp(f_t + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    (c_f, n_f, h_f, m_f), hs = jax.lax.scan(
        step, (c0, n0, hh0, m0), gx.transpose(1, 0, 2, 3, 4))
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = L.rms_norm(y, p["out_norm"], 1e-6)
    x = x + y
    # feed-forward
    hmlp = jax.nn.gelu(L.rms_norm(x, jnp.zeros_like(p["out_norm"]), 1e-6)
                       @ p["w_up"].astype(x.dtype))
    x = x + hmlp @ p["w_down"].astype(x.dtype)

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"c": c_f.astype(jnp.bfloat16), "n": n_f.astype(jnp.bfloat16),
                     "h": h_f.astype(jnp.bfloat16), "m": m_f}
    return x, new_cache


# --------------------------------------------------------------------------
# trunk: super-blocks of (slstm_every-1 mLSTM + 1 sLSTM)
# --------------------------------------------------------------------------

def _layout(cfg: ModelConfig) -> Tuple[int, int]:
    k = cfg.xlstm.slstm_every
    assert cfg.n_layers % k == 0, (cfg.n_layers, k)
    return cfg.n_layers // k, k - 1     # (n_super, mlstm_per_super)


def xlstm_trunk_specs(cfg: ModelConfig) -> Dict:
    from repro.models.transformer import _stack
    n_super, m_per = _layout(cfg)
    return {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           "embed"),
        "mlstm": _stack(_stack(mlstm_specs(cfg), m_per), n_super),
        "slstm": _stack(slstm_specs(cfg), n_super),
    }


def xlstm_trunk_apply(params, tokens, cfg: ModelConfig, *,
                      positions=None, mode: str = "train", cache=None,
                      cache_len=None, param_hook=None):
    from repro.models.transformer import _remat, _cdt
    n_super, m_per = _layout(cfg)
    embed = params["embed"]
    if param_hook is not None:
        embed = param_hook(embed, "embed")
    if jnp.issubdtype(tokens.dtype, jnp.integer):
        x = embed.astype(_cdt(cfg))[tokens]
    else:
        x = tokens.astype(_cdt(cfg))

    def _m(lp, si, i, h, c):
        if param_hook is not None:
            lp = param_hook(lp, "mlstm", si, i)
        return mlstm_apply(lp, h, cfg, mode=mode, cache=c)

    def _s(lp, si, h, c):
        if param_hook is not None:
            lp = param_hook(lp, "slstm", si)
        return slstm_apply(lp, h, cfg, mode=mode, cache=c)

    m_fn = _remat(_m, cfg)
    s_fn = _remat(_s, cfg)

    sup = jnp.arange(n_super)
    inner_idx = jnp.arange(m_per)

    if mode == "train":
        def body(h, xs):
            lp_m, lp_s, si = xs

            def inner(hh, ys):
                lp, i = ys
                h2, _ = m_fn(lp, si, i, hh, None)
                return h2, None
            h, _ = jax.lax.scan(inner, h, (lp_m, inner_idx))
            h, _ = s_fn(lp_s, si, h, None)
            return h, None
        x, _ = jax.lax.scan(body, x, (params["mlstm"], params["slstm"], sup))
        return x, jnp.zeros((), jnp.float32), None

    if mode == "prefill":
        def body(h, xs):
            lp_m, lp_s, si = xs

            def inner(hh, ys):
                lp, i = ys
                h2, c2 = m_fn(lp, si, i, hh, None)
                return h2, c2
            h, nc_m = jax.lax.scan(inner, h, (lp_m, inner_idx))
            h, nc_s = s_fn(lp_s, si, h, None)
            return h, (nc_m, nc_s)
        x, (nc_m, nc_s) = jax.lax.scan(
            body, x, (params["mlstm"], params["slstm"], sup))
        return x, jnp.zeros((), jnp.float32), {"mlstm": nc_m, "slstm": nc_s}

    def body(h, xs):
        lp_m, lp_s, c_m, c_s, si = xs

        def inner(hh, ys):
            lp, c, i = ys
            h2, c2 = m_fn(lp, si, i, hh, c)
            return h2, c2
        h, nc_m = jax.lax.scan(inner, h, (lp_m, c_m, inner_idx))
        h, nc_s = s_fn(lp_s, si, h, c_s)
        return h, (nc_m, nc_s)
    x, (nc_m, nc_s) = jax.lax.scan(
        body, x, (params["mlstm"], params["slstm"],
                  cache["mlstm"], cache["slstm"], sup))
    return x, jnp.zeros((), jnp.float32), {"mlstm": nc_m, "slstm": nc_s}


def init_xlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    n_super, m_per = _layout(cfg)

    def bcast(tree, lead):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[(None,) * len(lead)], lead + a.shape),
            tree)
    return {
        "mlstm": bcast(init_mlstm_cache(cfg, batch, dtype), (n_super, m_per)),
        "slstm": bcast(init_slstm_cache(cfg, batch, dtype), (n_super,)),
    }


def xlstm_cache_axes():
    m = {k: ("layer", "layer") + v for k, v in mlstm_cache_axes().items()}
    s = {k: ("layer",) + v for k, v in slstm_cache_axes().items()}
    return {"mlstm": m, "slstm": s}


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    _, dh = _dims(cfg)
    h = cfg.n_heads
    return {
        "C": jnp.zeros((batch, h, dh, dh), dtype),
        "n": jnp.zeros((batch, h, dh), dtype),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    h = cfg.n_heads
    dh = cfg.d_model // h
    return {
        "c": jnp.zeros((batch, h, dh), dtype),
        "n": jnp.ones((batch, h, dh), dtype),
        "h": jnp.zeros((batch, h, dh), dtype),
        "m": jnp.zeros((batch, h, dh), jnp.float32),
    }


def mlstm_cache_axes():
    return {"C": ("batch", "heads", "head_dim", "state"),
            "n": ("batch", "heads", "head_dim"),
            "m": ("batch", "heads")}


def slstm_cache_axes():
    return {k: ("batch", "heads", "head_dim") for k in ("c", "n", "h", "m")}
