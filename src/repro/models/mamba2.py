"""Mamba2 block in SSD (state-space duality) chunked form.

Follows the Mamba2 paper's SSD algorithm: split the sequence into chunks;
within a chunk the SSM output is a masked quadratic form (MXU-friendly);
states are passed between chunks with a (compact) sequential scan over
chunks. Decode is the classic O(1) recurrent update.

Layout: d_inner = expand * d_model, heads H = d_inner / head_dim P,
scalar A per head, B/C shared across heads in ``n_groups`` groups (we use
n_groups=1, Mamba2's default "multi-value attention" analogue).

State cache for decode:
    {"ssm": (B, H, P, N), "conv": (B, d_conv-1, d_in + 2*N_groups*N)}
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamSpec


def _dims(cfg: ModelConfig):
    scfg = cfg.ssm
    d_in = scfg.expand * cfg.d_model
    n_heads = d_in // scfg.head_dim
    conv_dim = d_in + 2 * scfg.n_groups * scfg.d_state
    return d_in, n_heads, conv_dim


def mamba2_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    scfg = cfg.ssm
    d = cfg.d_model
    d_in, n_heads, conv_dim = _dims(cfg)
    n, gr = scfg.d_state, scfg.n_groups
    return {
        "norm": ParamSpec((d,), ("embed",), "zeros"),
        # fused input projection: [z, x, B, C, dt]
        "w_in": ParamSpec((d, 2 * d_in + 2 * gr * n + n_heads), ("embed", "mlp")),
        "conv_w": ParamSpec((scfg.d_conv, conv_dim), (None, "mlp"), scale=0.1),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), "zeros"),
        "a_log": ParamSpec((n_heads,), ("heads",), "zeros"),
        "d_skip": ParamSpec((n_heads,), ("heads",), "ones"),
        "dt_bias": ParamSpec((n_heads,), ("heads",), "zeros"),
        "out_norm": ParamSpec((d_in,), ("mlp",), "zeros"),
        "w_out": ParamSpec((d_in, d), ("mlp", "embed")),
    }


def _split_proj(proj, cfg: ModelConfig):
    scfg = cfg.ssm
    d_in, n_heads, _ = _dims(cfg)
    gn = scfg.n_groups * scfg.d_state
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * gn], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv along seq. xbc: (B,S,C). conv_w: (K,C)."""
    k = conv_w.shape[0]
    if conv_state is not None:
        xbc_pad = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    else:
        xbc_pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    new_state = xbc_pad[:, -(k - 1):] if k > 1 else None
    out = jnp.zeros_like(xbc)
    for i in range(k):
        out = out + xbc_pad[:, i:i + xbc.shape[1]] * conv_w[i]
    return jax.nn.silu(out + conv_b.astype(xbc.dtype)), new_state


def _segsum(log_a):
    """Stable segment-sum: out[i,j] = sum_{j<m<=i} log_a[m], -inf for j>i.
    log_a: (..., L). Returns (..., L, L)."""
    L_ = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    idx = jnp.arange(L_)
    mask = idx[:, None] >= idx[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD forward.

    x: (B,S,H,P) values; dt: (B,S,H) positive step sizes; A: (H,) negative;
    B, C: (B,S,G,N) with G groups broadcast over heads.
    Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert h % g == 0
    rep = h // g
    if s % chunk != 0:
        chunk = s
    nc = s // chunk

    xr = x.reshape(b, nc, chunk, h, p)
    dtr = dt.reshape(b, nc, chunk, h)
    Br = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)   # (b,nc,l,h,n)
    Cr = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)

    dA = dtr * A[None, None, None, :]                     # (b,nc,l,h) negative
    dA_cum = jnp.cumsum(dA, axis=2)

    # --- intra-chunk (diagonal blocks): masked quadratic form
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))     # (b,nc,h,l,l)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cr, Br)     # (b,nc,h,l,l)
    y_diag = jnp.einsum("bchls,bcsh,bcshp->bclhp",
                        scores * Lmat, dtr, xr)

    # --- chunk states: state_c = sum_l exp(dA_cum_end - dA_cum_l) * dt*B*x
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (b,nc,l,h)
    states = jnp.einsum("bclhn,bclh,bclh,bclhp->bchpn",
                        Br, decay_to_end, dtr, xr)         # (b,nc,h,p,n)

    # --- inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])             # (b,nc,h)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                                  # emit state *before* this chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (b,nc,h,p,n)

    # --- contribution of the carried-in state to each position
    state_decay = jnp.exp(dA_cum)                          # (b,nc,l,h)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       Cr, prev_states.astype(Cr.dtype), state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def mamba2_apply(
    p, x: jax.Array, cfg: ModelConfig, *,
    mode: str = "train",
    cache: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B,S,d). Decode: S=1 with cache {"ssm","conv"}."""
    scfg = cfg.ssm
    d_in, n_heads, conv_dim = _dims(cfg)
    gr, n = scfg.n_groups, scfg.d_state
    ph = scfg.head_dim
    h = L.rms_norm(x, p["norm"], 1e-6)
    proj = h @ p["w_in"].astype(h.dtype)
    z, xbc, dt = _split_proj(proj, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))

    if mode == "decode":
        xbc_act, conv_tail = _causal_conv(xbc, p["conv_w"].astype(h.dtype),
                                          p["conv_b"], cache["conv"])
        xs, B_, C_ = jnp.split(xbc_act, [d_in, d_in + gr * n], axis=-1)
        xs = xs.reshape(-1, 1, n_heads, ph)[:, 0]          # (B,H,P)
        B_ = B_.reshape(-1, gr, n)
        C_ = C_.reshape(-1, gr, n)
        rep = n_heads // gr
        Bh = jnp.repeat(B_, rep, axis=1)                   # (B,H,N)
        Ch = jnp.repeat(C_, rep, axis=1)
        dt0 = dt[:, 0]                                     # (B,H)
        decay = jnp.exp(dt0 * A[None, :])                  # (B,H)
        ssm = cache["ssm"].astype(jnp.float32)
        ssm = ssm * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt0, xs.astype(jnp.float32), Bh.astype(jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), ssm)
        y = y + p["d_skip"][None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(-1, 1, d_in).astype(h.dtype)
        new_cache = {"ssm": ssm.astype(cache["ssm"].dtype), "conv": conv_tail}
    else:
        xbc_act, conv_tail = _causal_conv(xbc, p["conv_w"].astype(h.dtype),
                                          p["conv_b"])
        b, s, _ = xbc_act.shape
        xs, B_, C_ = jnp.split(xbc_act, [d_in, d_in + gr * n], axis=-1)
        xs = xs.reshape(b, s, n_heads, ph)
        B_ = B_.reshape(b, s, gr, n)
        C_ = C_.reshape(b, s, gr, n)
        y, final_state = ssd_chunked(xs.astype(jnp.float32), dt, A,
                                     B_.astype(jnp.float32),
                                     C_.astype(jnp.float32), scfg.chunk_size)
        y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(b, s, d_in).astype(h.dtype)
        new_cache = None
        if mode == "prefill":
            new_cache = {"ssm": final_state.astype(jnp.bfloat16),
                         "conv": conv_tail}

    y = y * jax.nn.silu(z)
    y = L.rms_norm(y, p["out_norm"], 1e-6)
    out = y @ p["w_out"].astype(h.dtype)
    return x + out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    scfg = cfg.ssm
    d_in, n_heads, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((batch, n_heads, scfg.head_dim, scfg.d_state), dtype),
        "conv": jnp.zeros((batch, scfg.d_conv - 1, conv_dim), dtype),
    }


def mamba_cache_axes():
    return {
        "ssm": ("batch", "heads", "head_dim", "state"),
        "conv": ("batch", "conv", "mlp"),
    }
