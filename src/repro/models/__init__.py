from repro.models.model import Model, build_model, lm_loss, cls_loss, PAPER_MLP_DIMS
from repro.models.params import (
    ParamSpec, init_params, logical_axes, abstract_params, param_count,
    spec_shapes,
)

__all__ = [
    "Model", "build_model", "lm_loss", "cls_loss", "PAPER_MLP_DIMS",
    "ParamSpec", "init_params", "logical_axes", "abstract_params",
    "param_count", "spec_shapes",
]
