"""Mixture-of-Experts FFN block (Mixtral / Phi-3.5-MoE style).

Top-k routing with capacity-based token dropping, implemented with the
standard dispatch/combine einsum formulation (MaxText/Switch style) so the
compute lowers to dense MXU-friendly einsums and the expert dimension is
shardable (expert parallelism when n_experts divides the mesh axis).

Tokens are grouped along the sequence dimension (group = ``group_size``
contiguous tokens) so dispatch tensors stay small ((B, nG, g, E, C)) and all
dispatch compute is local to the data shard. Capacity per group:
    C = ceil(top_k * g / E * capacity_factor)
Overflowing tokens are dropped (their combine weight is zero) — the
textbook trade-off; the aux load-balance loss keeps the router near-uniform.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamSpec

GROUP_SIZE = 512


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    e = cfg.moe.n_experts
    return {
        "norm": ParamSpec((d,), ("embed",), "zeros"),
        "router": ParamSpec((d, e), ("embed", None), scale=0.02),
        "w_gate": ParamSpec((e, d, f), ("expert", "embed", "mlp")),
        "w_up": ParamSpec((e, d, f), ("expert", "embed", "mlp")),
        "w_down": ParamSpec((e, f, d), ("expert", "mlp", "embed")),
    }


def _route(logits: jax.Array, top_k: int):
    """logits (..., E) -> (gates (..., E), mask (..., E)) with top-k support."""
    e = logits.shape[-1]
    weights = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_vals, top_idx = jax.lax.top_k(weights, top_k)
    mask = jnp.sum(jax.nn.one_hot(top_idx, e, dtype=jnp.float32), axis=-2)
    gates = weights * mask
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, mask, weights


def moe_apply(p, x: jax.Array, cfg: ModelConfig,
              train: bool = True) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (y, aux_loss).

    ``train=False`` (prefill/decode) disables capacity dropping: capacity
    is a train-time compute/quality trade-off, and inference must be
    length-invariant — a token's expert assignment cannot depend on how
    many tokens share its group (prefill+decode must equal a full pass).
    The dropless path runs every expert densely and weights by the top-k
    gates: identical math to capacity=g dispatch (the FLOPs of the padded
    einsums are the same) without materializing the (g, E, cap) one-hot
    dispatch/combine tensors sized for worst-case all-to-one routing. It
    still pays E/top_k times the strictly-needed expert FLOPs; a
    sort/gather token-grouping path that computes only the selected
    experts is the planned optimization (see ROADMAP).
    """
    mcfg = cfg.moe
    e, k = mcfg.n_experts, mcfg.top_k
    b, s, d = x.shape
    h = L.rms_norm(x, p["norm"], 1e-6)

    g = min(GROUP_SIZE, s)
    if s % g != 0:
        g = s   # smoke-test shapes: one group
    ng = s // g
    hg = h.reshape(b, ng, g, d)

    logits = jnp.einsum("bngd,de->bnge", hg, p["router"].astype(h.dtype))
    gates, mask, weights = _route(logits, k)                 # (B,nG,g,E)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    frac_tokens = jnp.mean(mask, axis=(0, 1, 2))             # (E,)
    frac_weight = jnp.mean(weights, axis=(0, 1, 2))
    aux = e * jnp.sum(frac_tokens * frac_weight) * mcfg.aux_loss_weight

    if not train:
        # dropless inference: every expert on every token, masked by gates
        gate_h = jnp.einsum("bngd,edf->bngef", hg, p["w_gate"].astype(h.dtype))
        up_h = jnp.einsum("bngd,edf->bngef", hg, p["w_up"].astype(h.dtype))
        act = jax.nn.silu(gate_h) * up_h
        ye = jnp.einsum("bngef,efd->bnged", act, p["w_down"].astype(h.dtype))
        y = jnp.einsum("bnge,bnged->bngd", gates.astype(h.dtype), ye)
        return x + y.reshape(b, s, d), aux

    cap = max(int(k * g / e * mcfg.capacity_factor + 0.999), 1)

    # position of each token within its expert queue (per group)
    pos_in_expert = jnp.cumsum(mask, axis=2) * mask - 1.0    # (B,nG,g,E)
    keep = (pos_in_expert >= 0) & (pos_in_expert < cap)
    pos_clip = jnp.clip(pos_in_expert, 0, cap - 1).astype(jnp.int32)
    onehot_cap = jax.nn.one_hot(pos_clip, cap, dtype=h.dtype)  # (B,nG,g,E,C)
    dispatch = onehot_cap * keep[..., None].astype(h.dtype)    # (B,nG,g,E,C)
    combine = dispatch * gates[..., None].astype(h.dtype)

    # dispatch -> (B,nG,E,C,d)
    xe = jnp.einsum("bngec,bngd->bnecd", dispatch, hg)
    # expert FFN (SwiGLU), expert dim stays leading for EP sharding
    gate_h = jnp.einsum("bnecd,edf->bnecf", xe, p["w_gate"].astype(h.dtype))
    up_h = jnp.einsum("bnecd,edf->bnecf", xe, p["w_up"].astype(h.dtype))
    act = jax.nn.silu(gate_h) * up_h
    ye = jnp.einsum("bnecf,efd->bnecd", act, p["w_down"].astype(h.dtype))
    # combine back to tokens
    y = jnp.einsum("bngec,bnecd->bngd", combine, ye)

    return x + y.reshape(b, s, d), aux
