"""Model facade: one interface over every backbone family.

The PFL split (paper eq. (2)) is structural: every model is

    trunk (shared, scan-stacked)  ->  final (the "last shared layer" ω̃,
    kept separate because FedGradNorm differentiates F w.r.t. exactly this
    piece)  ->  head (personalized, per client).

Families: "mlp" (the paper's Table-I network), "dense" (covers GQA/RoPE/
SWA/local:global and, via cfg.moe, the MoE archs; via cfg.modality, the
audio/VLM backbones), "hybrid" (Zamba2), "xlstm", "ssm" (pure Mamba2
stack).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import layers as L
from repro.models.params import ParamSpec

# paper Table I: shared network FC dims (input 256 -> ... -> 256 out)
PAPER_MLP_DIMS = (256, 512, 1024, 2048, 512, 256)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------- specs ----------------
    def trunk_specs(self):
        cfg = self.cfg
        if cfg.family == "mlp":
            dims = PAPER_MLP_DIMS
            return {
                f"fc{i}": {
                    "w": ParamSpec((dims[i], dims[i + 1]), ("embed", "mlp")),
                    "b": ParamSpec((dims[i + 1],), ("mlp",), "zeros"),
                }
                for i in range(len(dims) - 2)   # all but the last FC
            }
        if cfg.family in ("dense", "moe"):
            from repro.models.transformer import dense_trunk_specs
            return dense_trunk_specs(cfg)
        if cfg.family == "hybrid":
            from repro.models.hybrid import hybrid_trunk_specs
            return hybrid_trunk_specs(cfg)
        if cfg.family == "xlstm":
            from repro.models.xlstm import xlstm_trunk_specs
            return xlstm_trunk_specs(cfg)
        if cfg.family == "ssm":
            from repro.models.mamba2 import mamba2_specs
            from repro.models.transformer import _stack
            return {
                "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                                   ("vocab", "embed"), "embed"),
                "layers": _stack(mamba2_specs(cfg), cfg.n_layers),
            }
        raise ValueError(cfg.family)

    def final_specs(self):
        cfg = self.cfg
        if cfg.family == "mlp":
            dims = PAPER_MLP_DIMS
            return {
                "w": ParamSpec((dims[-2], dims[-1]), ("embed", "mlp")),
                "b": ParamSpec((dims[-1],), ("mlp",), "zeros"),
            }
        return {"norm": ParamSpec((cfg.d_model,), ("embed",), "zeros")}

    def head_specs(self, n_out: Optional[int] = None):
        cfg = self.cfg
        if cfg.family == "mlp":
            n_out = n_out or 8
            return {
                "w": ParamSpec((PAPER_MLP_DIMS[-1], n_out), ("embed", "vocab")),
                "b": ParamSpec((n_out,), ("vocab",), "zeros"),
            }
        n_out = n_out or cfg.vocab_size
        return {"w": ParamSpec((cfg.d_model, n_out), ("embed", "vocab"))}

    # ---------------- apply ----------------
    def trunk_apply(self, params, inputs, *, positions=None,
                    mode: str = "train", cache=None, cache_len=None,
                    param_hook=None):
        cfg = self.cfg
        if cfg.family == "mlp":
            if param_hook is not None:
                params = param_hook(params, "layers")
            h = inputs
            for i in range(len(PAPER_MLP_DIMS) - 2):
                p = params[f"fc{i}"]
                h = jax.nn.relu(h @ p["w"] + p["b"])
            return h, jnp.zeros((), jnp.float32), None
        if positions is None:
            seq = inputs.shape[1]
            positions = jnp.arange(seq)
        if cfg.family in ("dense", "moe"):
            from repro.models.transformer import dense_trunk_apply
            return dense_trunk_apply(params, inputs, cfg, positions=positions,
                                     mode=mode, cache=cache,
                                     cache_len=cache_len,
                                     param_hook=param_hook)
        if cfg.family == "hybrid":
            from repro.models.hybrid import hybrid_trunk_apply
            return hybrid_trunk_apply(params, inputs, cfg, positions=positions,
                                      mode=mode, cache=cache,
                                      cache_len=cache_len,
                                      param_hook=param_hook)
        if cfg.family == "xlstm":
            from repro.models.xlstm import xlstm_trunk_apply
            return xlstm_trunk_apply(params, inputs, cfg, positions=positions,
                                     mode=mode, cache=cache,
                                     param_hook=param_hook)
        if cfg.family == "ssm":
            from repro.models.mamba2 import mamba2_apply
            from repro.models.transformer import _scan_stack, _cdt
            embed = params["embed"]
            if param_hook is not None:
                embed = param_hook(embed, "embed")
            if jnp.issubdtype(inputs.dtype, jnp.integer):
                x = embed.astype(_cdt(cfg))[inputs]
            else:
                x = inputs.astype(_cdt(cfg))

            def fn(lp, h, c):
                h2, c2 = mamba2_apply(lp, h, cfg, mode=mode, cache=c)
                return h2, jnp.zeros((), jnp.float32), c2
            return _scan_stack(fn, params["layers"], x, cache, cfg, mode,
                               param_hook, "layers")
        raise ValueError(cfg.family)

    def final_apply(self, params, hidden):
        cfg = self.cfg
        if cfg.family == "mlp":
            return jax.nn.relu(hidden @ params["w"] + params["b"])
        return L.rms_norm(hidden, params["norm"], cfg.norm_eps)

    def head_apply(self, params, features):
        if self.cfg.family == "mlp":
            return features @ params["w"] + params["b"]
        return (features @ params["w"].astype(features.dtype)).astype(jnp.float32)

    # ---------------- caches ----------------
    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            from repro.models.transformer import init_dense_cache
            return init_dense_cache(cfg, batch, cache_len, dtype)
        if cfg.family == "hybrid":
            from repro.models.hybrid import init_hybrid_cache
            return init_hybrid_cache(cfg, batch, cache_len, dtype)
        if cfg.family == "xlstm":
            from repro.models.xlstm import init_xlstm_cache
            return init_xlstm_cache(cfg, batch, dtype)
        if cfg.family == "ssm":
            from repro.models.mamba2 import init_mamba_cache
            one = init_mamba_cache(cfg, batch, dtype)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
                one)
        raise ValueError(cfg.family)

    def cache_axes(self):
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            from repro.models.transformer import dense_cache_axes
            return dense_cache_axes(cfg)
        if cfg.family == "hybrid":
            from repro.models.hybrid import hybrid_cache_axes
            return hybrid_cache_axes(cfg)
        if cfg.family == "xlstm":
            from repro.models.xlstm import xlstm_cache_axes
            return xlstm_cache_axes()
        if cfg.family == "ssm":
            from repro.models.mamba2 import mamba_cache_axes
            return {k: ("layer",) + v for k, v in mamba_cache_axes().items()}
        raise ValueError(cfg.family)

    # ---------------- convenience ----------------
    def backbone_specs(self):
        return {"trunk": self.trunk_specs(), "final": self.final_specs()}

    def forward_logits(self, backbone_params, head_params, inputs, *,
                       positions=None, mode="train", cache=None,
                       cache_len=None):
        h, aux, new_cache = self.trunk_apply(
            backbone_params["trunk"], inputs, positions=positions, mode=mode,
            cache=cache, cache_len=cache_len)
        feats = self.final_apply(backbone_params["final"], h)
        logits = self.head_apply(head_params, feats)
        return logits, aux, new_cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def lm_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Cross-entropy over the vocab; logits (B,S,V) fp32, labels (B,S)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def cls_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
