"""Shared neural-net layers: RMSNorm, RoPE, embeddings, MLP, GQA attention.

Attention comes in three implementations (ModelConfig.attn_impl):

* ``naive``   — full (Sq, Skv) score matrix. Reference/oracle; fine for
                short sequences and smoke tests.
* ``blocked`` — double-scan online-softmax (flash-style) in pure JAX:
                outer scan over query blocks, inner scan over KV blocks.
                Autodiff-able (training path) and memory-bounded by
                (block_q x block_kv). For full-causal attention the inner
                scan covers the whole rectangle with masking (the masked
                upper triangle is wasted compute — see EXPERIMENTS.md §Perf;
                the Pallas kernel removes it on real TPUs). For
                sliding-window attention the inner loop reads only a
                dynamic-sliced KV *band* of static width, so SWA pays no
                rectangle waste.
* ``pallas``  — repro.kernels.flash_attention (serving hot path).

All attention functions are GQA-native: q heads are grouped over KV heads.
Shapes: q (B, Sq, H, D); k, v (B, Skv, KV, D).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------
# normalization / embeddings / mlp
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                      # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mlp_apply(params, x: jax.Array, act: str = "silu") -> jax.Array:
    """SwiGLU (silu) or plain GELU MLP."""
    if act == "silu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


# --------------------------------------------------------------------------
# masking helpers
# --------------------------------------------------------------------------

def _mask_block(pos_q: jax.Array, pos_kv: jax.Array, window: Optional[int]) -> jax.Array:
    """Causal (+ optional sliding window) mask, True = attend."""
    diff = pos_q[:, None] - pos_kv[None, :]
    mask = diff >= 0
    if window is not None:
        mask &= diff < window
    return mask


def _gqa_scores(q: jax.Array, k: jax.Array, scale: float) -> jax.Array:
    """q (B,Sq,KV,G,D), k (B,Skv,KV,D) -> scores (B,KV,G,Sq,Skv) in fp32."""
    return jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                      preferred_element_type=jnp.float32) * scale


def _gqa_pv(p: jax.Array, v: jax.Array) -> jax.Array:
    """p (B,KV,G,Sq,Skv) x v (B,Skv,KV,D) -> (B,Sq,KV,G,D)."""
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(p.dtype))


def _split_gqa(q: jax.Array, n_kv: int) -> jax.Array:
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _merge_gqa(x: jax.Array) -> jax.Array:
    b, s, kv, g, d = x.shape
    return x.reshape(b, s, kv * g, d)


# --------------------------------------------------------------------------
# naive attention (oracle)
# --------------------------------------------------------------------------

def naive_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, pos_q: jax.Array, pos_kv: jax.Array,
    window: Optional[int] = None,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    n_kv = k.shape[2]
    qg = _split_gqa(q, n_kv)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = _gqa_scores(qg, k, scale)                     # (B,KV,G,Sq,Skv)
    mask = _mask_block(pos_q, pos_kv, window)              # (Sq,Skv)
    if kv_valid is not None:
        mask = mask & kv_valid[None, :]
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _merge_gqa(_gqa_pv(p, v))


# --------------------------------------------------------------------------
# blocked online-softmax attention (training / prefill workhorse)
# --------------------------------------------------------------------------

def _online_block(carry, q_blk, k_blk, v_blk, mask_blk, scale):
    """One online-softmax update. carry = (m, l, acc) for this q block."""
    m, l, acc = carry
    s = _gqa_scores(q_blk, k_blk, scale)                   # (B,KV,G,bq,bkv) fp32
    s = jnp.where(mask_blk[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    # guard fully-masked rows: keep m finite
    m_new = jnp.maximum(m_new, -1e30)
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l * alpha + jnp.sum(p, axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "bkgqs,bskd->bkgqd", p, v_blk.astype(jnp.float32))
    return (m_new, l_new, acc_new)


def blocked_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, pos_q: jax.Array, pos_kv: jax.Array,
    window: Optional[int] = None,
    block_q: int = 512, block_kv: int = 1024,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Flash-style attention. If ``window`` is set, uses the banded path
    (static-width KV band per q block — no rectangle waste)."""
    b, sq, h, d = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    if sq % block_q != 0 or skv % block_kv != 0:
        # fall back to naive for ragged shapes (smoke tests etc.)
        return naive_attention(q, k, v, pos_q=pos_q, pos_kv=pos_kv,
                               window=window, kv_valid=kv_valid)
    scale = 1.0 / math.sqrt(d)
    g = h // n_kv
    nq = sq // block_q

    qg = _split_gqa(q, n_kv)                               # (B,Sq,KV,G,D)
    qg = qg.reshape(b, nq, block_q, n_kv, g, d)
    pos_qb = pos_q.reshape(nq, block_q)

    use_band = window is not None and window + block_q <= skv
    if use_band:
        band = block_kv * -(-(window + block_q) // block_kv)   # round up
        band = min(band, skv)

        def per_q_block(q_blk, pos_blk, blk_idx):
            # static-width band ending at this q block's last kv position
            q_start = blk_idx * block_q
            start = jnp.clip(q_start + block_q - band, 0, skv - band)
            k_band = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            v_band = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            pos_band = jax.lax.dynamic_slice_in_dim(pos_kv, start, band, axis=0)
            valid = (None if kv_valid is None else
                     jax.lax.dynamic_slice_in_dim(kv_valid, start, band, axis=0))
            return _scan_kv(q_blk, k_band, v_band, pos_blk, pos_band, valid,
                            window, block_kv, scale)

        out = jax.lax.map(
            lambda args: per_q_block(*args),
            (qg.transpose(1, 0, 2, 3, 4, 5), pos_qb, jnp.arange(nq)),
        )                                                   # (nq, B, bq, KV, G, D)
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, n_kv, g, d)
        return _merge_gqa(out).astype(q.dtype)

    def per_q_block(args):
        q_blk, pos_blk = args
        return _scan_kv(q_blk, k, v, pos_blk, pos_kv, kv_valid,
                        window, block_kv, scale)

    out = jax.lax.map(per_q_block, (qg.transpose(1, 0, 2, 3, 4, 5), pos_qb))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, n_kv, g, d)
    return _merge_gqa(out).astype(q.dtype)


def _scan_kv(q_blk, k_seq, v_seq, pos_blk, pos_kv_seq, kv_valid,
             window, block_kv, scale):
    """Inner online-softmax scan over KV blocks for one q block.

    q_blk: (B, bq, KV, G, D); k_seq/v_seq: (B, Skv', KV, D).
    Returns (B, bq, KV, G, D) float32 accumulator normalized by l.
    """
    b, bq, n_kv, g, d = q_blk.shape
    skv = k_seq.shape[1]
    nkv_blocks = skv // block_kv
    kb = k_seq.reshape(b, nkv_blocks, block_kv, n_kv, d)
    vb = v_seq.reshape(b, nkv_blocks, block_kv, n_kv, d)
    pos_b = pos_kv_seq.reshape(nkv_blocks, block_kv)
    valid_b = (kv_valid.reshape(nkv_blocks, block_kv)
               if kv_valid is not None else None)

    m0 = jnp.full((b, n_kv, g, bq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, bq), jnp.float32)
    a0 = jnp.zeros((b, n_kv, g, bq, d), jnp.float32)
    qx = q_blk.transpose(0, 2, 3, 1, 4)  # unused view; keep layout simple

    # checkpoint: the backward recomputes per-block scores/probabilities
    # instead of saving the (bq x bkv) prob tensors for every block pair —
    # that residual is what would otherwise reintroduce O(S²) memory.
    @jax.checkpoint
    def body(carry, xs):
        if valid_b is not None:
            k_i, v_i, pos_i, val_i = xs
        else:
            k_i, v_i, pos_i = xs
            val_i = None
        mask = _mask_block(pos_blk, pos_i, window)
        if val_i is not None:
            mask = mask & val_i[None, :]
        new = _online_block(carry, q_blk, k_i, v_i, mask, scale)
        return new, None

    xs = (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4), pos_b)
    if valid_b is not None:
        xs = xs + (valid_b,)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]            # (B,KV,G,bq,D)
    return out.transpose(0, 3, 1, 2, 4)                     # (B,bq,KV,G,D)


# --------------------------------------------------------------------------
# folded causal attention: exact-triangle compute with static trip counts
# --------------------------------------------------------------------------

def blocked_attention_folded(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, pos_q: jax.Array, pos_kv: jax.Array, block: int = 512,
) -> jax.Array:
    """Causal blocked attention WITHOUT the rectangle waste.

    The plain blocked path scans every (q-block, kv-block) pair and masks
    the upper triangle — half the MXU work is thrown away. Pairing q block
    ``p`` with q block ``nq-1-p`` makes each pair's causal KV need exactly
    ``(p+1) + (nq-p) = nq+1`` blocks — a *static* trip count. Each scan
    iteration computes ONE bq x bkv block for whichever member of the pair
    it belongs to, so total compute is the exact lower triangle
    (~2x fewer FLOPs and ~2x less score HBM traffic at long S; measured in
    EXPERIMENTS.md §Perf P1).

    Requires sq == skv, divisible by ``block``, and an even block count;
    the caller falls back to ``blocked_attention`` otherwise.
    """
    b, sq, h, d = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    assert sq == skv and sq % block == 0
    nq = sq // block
    assert nq % 2 == 0, nq
    g = h // n_kv
    scale = 1.0 / math.sqrt(d)

    qg = _split_gqa(q, n_kv).reshape(b, nq, block, n_kv, g, d)
    pos_qb = pos_q.reshape(nq, block)
    kb = k.reshape(b, nq, block, n_kv, d)
    vb = v.reshape(b, nq, block, n_kv, d)
    pos_kb = pos_kv.reshape(nq, block)

    n_pairs = nq // 2

    def per_pair(args):
        p_idx = args
        lo, hi = p_idx, nq - 1 - p_idx
        q_lo = jax.lax.dynamic_index_in_dim(qg, lo, 1, keepdims=False)
        q_hi = jax.lax.dynamic_index_in_dim(qg, hi, 1, keepdims=False)
        pos_lo = jax.lax.dynamic_index_in_dim(pos_qb, lo, 0, keepdims=False)
        pos_hi = jax.lax.dynamic_index_in_dim(pos_qb, hi, 0, keepdims=False)

        def init():
            m = jnp.full((b, n_kv, g, block), -jnp.inf, jnp.float32)
            l = jnp.zeros((b, n_kv, g, block), jnp.float32)
            a = jnp.zeros((b, n_kv, g, block, d), jnp.float32)
            return (m, l, a)

        @jax.checkpoint
        def body(carry, j):
            (c_lo, c_hi) = carry
            use_lo = j <= p_idx
            kv_idx = jnp.where(use_lo, j, j - p_idx - 1)
            k_j = jax.lax.dynamic_index_in_dim(kb, kv_idx, 1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vb, kv_idx, 1, keepdims=False)
            pos_j = jax.lax.dynamic_index_in_dim(pos_kb, kv_idx, 0,
                                                 keepdims=False)
            q_blk = jnp.where(use_lo, q_lo, q_hi)
            pos_blk = jnp.where(use_lo, pos_lo, pos_hi)
            mask = _mask_block(pos_blk, pos_j, None)
            cur = jax.tree.map(
                lambda a_, b_: jnp.where(use_lo, a_, b_), c_lo, c_hi)
            new = _online_block(cur, q_blk, k_j, v_j, mask, scale)
            c_lo = jax.tree.map(
                lambda n_, o_: jnp.where(use_lo, n_, o_), new, c_lo)
            c_hi = jax.tree.map(
                lambda n_, o_: jnp.where(use_lo, o_, n_), new, c_hi)
            return (c_lo, c_hi), None

        (c_lo, c_hi), _ = jax.lax.scan(body, (init(), init()),
                                       jnp.arange(nq + 1))

        def fin(c):
            m, l, acc = c
            return (acc / jnp.maximum(l, 1e-30)[..., None]).transpose(
                0, 3, 1, 2, 4)                       # (B,block,KV,G,D)
        return fin(c_lo), fin(c_hi)

    out_lo, out_hi = jax.lax.map(per_pair, jnp.arange(n_pairs))
    # out_lo: (n_pairs, B, block, KV, G, D) for q blocks 0..n_pairs-1
    # out_hi: same for q blocks nq-1 .. n_pairs (descending)
    out = jnp.concatenate([out_lo, out_hi[::-1]], axis=0)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, n_kv, g, d)
    return _merge_gqa(out).astype(q.dtype)


# --------------------------------------------------------------------------
# decode attention (single query position against a cache)
# --------------------------------------------------------------------------

def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
    *, pos_q: jax.Array, pos_kv: jax.Array,
    window: Optional[int] = None,
) -> jax.Array:
    """q: (B,1,H,D); caches (B,L,KV,D); pos_kv (B,L) with -1 = empty slot.

    Works with ring-buffer caches: masking is purely positional, so slot
    order is irrelevant.
    """
    n_kv = k_cache.shape[2]
    qg = _split_gqa(q, n_kv)                                # (B,1,KV,G,D)
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqkgd,blkd->bkgql", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale  # (B,KV,G,1,L)
    diff = pos_q[:, None] - pos_kv                          # (B, L)
    mask = (pos_kv >= 0) & (diff >= 0)
    if window is not None:
        mask &= diff < window
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgql,blkd->bqkgd", p, v_cache.astype(p.dtype))
    return _merge_gqa(out).astype(q.dtype)


# --------------------------------------------------------------------------
# attention entry point used by the blocks
# --------------------------------------------------------------------------

def attention(
    q, k, v, *, pos_q, pos_kv, impl: str = "blocked",
    window: Optional[int] = None, block_q: int = 512, block_kv: int = 1024,
    kv_valid=None,
):
    if impl == "naive":
        return naive_attention(q, k, v, pos_q=pos_q, pos_kv=pos_kv,
                               window=window, kv_valid=kv_valid)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as flash_ops
        return flash_ops.flash_attention(q, k, v, pos_q=pos_q, pos_kv=pos_kv,
                                         window=window)
    if impl == "folded":
        sq, skv = q.shape[1], k.shape[1]
        nq = sq // min(block_q, sq)
        if (window is None and kv_valid is None and sq == skv
                and sq % block_q == 0 and nq % 2 == 0):
            return blocked_attention_folded(q, k, v, pos_q=pos_q,
                                            pos_kv=pos_kv, block=block_q)
        # fall through to the plain blocked path for unsupported shapes
    return blocked_attention(q, k, v, pos_q=pos_q, pos_kv=pos_kv,
                             window=window, block_q=block_q,
                             block_kv=block_kv, kv_valid=kv_valid)
