"""Parameter specification system.

Each module describes its parameters as a nested dict of ``ParamSpec``
(shape + logical axes + initializer). From one spec tree we derive:

* ``init_params``     — materialized arrays (seeded, correct dtype),
* ``logical_axes``    — same-structure tree of logical-axis tuples,
* ``abstract_params`` — ShapeDtypeStruct stand-ins for dry-run lowering
                        (no host memory is ever allocated).

Keeping shapes/axes/init in one place removes the classic failure mode of
parallel "axes trees" drifting out of sync with the real params.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override; default fan-in scaled

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _fan_in(shape: Tuple[int, ...]) -> int:
    if len(shape) == 1:
        return shape[0]
    # last dim is fan-out by convention; everything else fan-in
    return int(np.prod(shape[:-1]))


def init_params(specs, key: jax.Array, dtype=jnp.float32):
    """Materialize a spec tree into arrays. One fold_in per leaf path."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    arrays = []
    for i, spec in enumerate(leaves):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dtype)
        else:
            if spec.scale is not None:
                std = spec.scale
            elif spec.init == "embed":
                std = 1.0
            else:
                std = 1.0 / np.sqrt(max(_fan_in(spec.shape), 1))
            arr = (jax.random.normal(keys[i], spec.shape, jnp.float32) * std).astype(dtype)
        arrays.append(arr)
    return jax.tree.unflatten(treedef, arrays)


def logical_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def abstract_params(specs, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs, is_leaf=_is_spec
    )


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs, is_leaf=_is_spec))


def spec_shapes(specs):
    return jax.tree.map(lambda s: s.shape, specs, is_leaf=_is_spec)
