"""Zamba2-style hybrid backbone: Mamba2 layers + one *shared* attention block.

The defining Zamba trick: a single transformer block (attention + MLP) whose
weights are reused at several depths, interleaved into a Mamba backbone.
We apply the shared block after every ``hybrid.attn_every`` Mamba layers.

Layer layout for n_layers=38, attn_every=6:
    [6 mamba] A [6 mamba] A [6 mamba] A [6 mamba] A [6 mamba] A [6 mamba] A [2 mamba]
(A = the shared attention block, same parameters each time, 6 applications.)

Implemented as a python loop over segments — each segment is a lax.scan
over a *static slice* of the stacked Mamba params, so the HLO stays compact
(7 scans + 6 shared-block calls).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import layers as L
from repro.models.mamba2 import (
    init_mamba_cache, mamba2_apply, mamba2_specs, mamba_cache_axes,
)
from repro.models.params import ParamSpec
from repro.models.transformer import (
    _remat, attn_specs, attn_apply, mlp_specs, mlp_block_apply, _stack, _cdt,
)


def segments(cfg: ModelConfig) -> List[int]:
    k = cfg.hybrid.attn_every
    n = cfg.n_layers
    segs = [k] * (n // k)
    if n % k:
        segs.append(n % k)
    return segs


def n_shared_applications(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.hybrid.attn_every


def hybrid_trunk_specs(cfg: ModelConfig) -> Dict[str, Any]:
    shared_cfg = _shared_attn_cfg(cfg)
    return {
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                           "embed"),
        "mamba": _stack(mamba2_specs(cfg), cfg.n_layers),
        "shared_attn": attn_specs(shared_cfg),
        "shared_mlp": mlp_specs(shared_cfg),
    }


def _shared_attn_cfg(cfg: ModelConfig) -> ModelConfig:
    h = cfg.hybrid
    return cfg.replace(n_heads=h.shared_attn_n_heads,
                       n_kv_heads=h.shared_attn_n_kv, moe=None)


def hybrid_trunk_apply(
    params, tokens, cfg: ModelConfig, *,
    positions, mode: str = "train", cache=None, cache_len=None,
    param_hook=None,
):
    """Returns (hidden, aux, new_cache). Cache layout:
    {"mamba": stacked over all n_layers, "attn": list of per-application KV}."""
    shared_cfg = _shared_attn_cfg(cfg)
    embed = params["embed"]
    if param_hook is not None:
        embed = param_hook(embed, "embed")
    if jnp.issubdtype(tokens.dtype, jnp.integer):
        x = embed.astype(_cdt(cfg))[tokens]
    else:
        x = tokens.astype(_cdt(cfg))

    def mamba_fn(lp, i, h, c):
        if param_hook is not None:
            lp = param_hook(lp, "mamba", i)
        h2, c2 = mamba2_apply(lp, h, cfg, mode=mode, cache=c)
        return h2, c2

    mamba_fn = _remat(mamba_fn, cfg)

    # The shared block's weights are ONE parameter set used at several
    # depths: gather them exactly once so the paper's per-entry channel is
    # drawn once per iteration and autodiff sums all use-site cotangents
    # BEFORE the OTA reduction (fidelity to eq. (8)).
    shared_attn_p, shared_mlp_p = params["shared_attn"], params["shared_mlp"]
    if param_hook is not None:
        shared_attn_p = param_hook(shared_attn_p, "shared_attn")
        shared_mlp_p = param_hook(shared_mlp_p, "shared_mlp")

    def shared_fn(h, c):
        h2, c2 = attn_apply(shared_attn_p, h, shared_cfg,
                            positions=positions, window=cfg.sliding_window,
                            theta=cfg.rope_theta, mode=mode, cache=c,
                            cache_len=cache_len)
        h2 = mlp_block_apply(shared_mlp_p, h2, shared_cfg)
        return h2, c2

    shared_fn = _remat(shared_fn, cfg)

    segs = segments(cfg)
    n_apps = n_shared_applications(cfg)
    new_mamba_caches = []
    new_attn_caches = []
    start = 0
    app = 0
    for si, seg in enumerate(segs):
        lp_seg = jax.tree.map(lambda a: a[start:start + seg], params["mamba"])

        seg_idx = jnp.arange(start, start + seg)
        if mode == "train":
            def body(h, xs):
                lp, i = xs
                h2, _ = mamba_fn(lp, i, h, None)
                return h2, None
            x, _ = jax.lax.scan(body, x, (lp_seg, seg_idx))
        elif mode == "prefill":
            def body(h, xs):
                lp, i = xs
                h2, c2 = mamba_fn(lp, i, h, None)
                return h2, c2
            x, nc = jax.lax.scan(body, x, (lp_seg, seg_idx))
            new_mamba_caches.append(nc)
        else:
            c_seg = jax.tree.map(lambda a: a[start:start + seg], cache["mamba"])

            def body(h, xs):
                lp, c, i = xs
                h2, c2 = mamba_fn(lp, i, h, c)
                return h2, c2
            x, nc = jax.lax.scan(body, x, (lp_seg, c_seg, seg_idx))
            new_mamba_caches.append(nc)

        start += seg
        if app < n_apps and start >= (app + 1) * cfg.hybrid.attn_every:
            c_attn = cache["attn"][app] if mode == "decode" else None
            x, nc_attn = shared_fn(x, c_attn)
            if mode in ("prefill", "decode"):
                new_attn_caches.append(nc_attn)
            app += 1

    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    if mode in ("prefill", "decode"):
        mamba_cache = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_mamba_caches)
        new_cache = {"mamba": mamba_cache, "attn": new_attn_caches}
    return x, aux, new_cache


def init_hybrid_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=jnp.bfloat16, window: Optional[int] = None):
    shared_cfg = _shared_attn_cfg(cfg)
    win = cfg.sliding_window
    cap = min(win, cache_len) if win is not None else cache_len
    kv, hd = shared_cfg.n_kv_heads, shared_cfg.resolved_head_dim
    one_mamba = init_mamba_cache(cfg, batch, dtype)
    mamba = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
        one_mamba)
    attn = [{
        "k": jnp.zeros((batch, cap, kv, hd), dtype),
        "v": jnp.zeros((batch, cap, kv, hd), dtype),
        "pos": jnp.full((batch, cap), -1, jnp.int32),
    } for _ in range(n_shared_applications(cfg))]
    return {"mamba": mamba, "attn": attn}


def hybrid_cache_axes(cfg: ModelConfig):
    m = {k: ("layer",) + v for k, v in mamba_cache_axes().items()}
    a = {"k": ("batch", "cache_seq", "kv_heads", "head_dim"),
         "v": ("batch", "cache_seq", "kv_heads", "head_dim"),
         "pos": ("batch", "cache_seq")}
    return {"mamba": m, "attn": [a for _ in range(n_shared_applications(cfg))]}
