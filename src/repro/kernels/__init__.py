"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §3.3).

Each kernel ships as <name>/{kernel.py, ops.py, ref.py}: the pallas_call
with explicit BlockSpec VMEM tiling, the jit'd public wrapper, and the
pure-jnp oracle the tests assert against (interpret mode on CPU).
"""
from repro.kernels.ota_channel.ops import (
    ota_aggregate, ota_aggregate_reference,
    ota_channel, ota_channel_reference,
)
from repro.kernels.masked_gradnorm.ops import (
    masked_gradnorm, masked_gradnorm_reference,
)
from repro.kernels.flash_attention.ops import (
    flash_attention, flash_attention_reference,
)

__all__ = [
    "ota_aggregate", "ota_aggregate_reference",
    "ota_channel", "ota_channel_reference",
    "masked_gradnorm", "masked_gradnorm_reference",
    "flash_attention", "flash_attention_reference",
]
