"""Pallas TPU kernel: flash attention forward (causal + sliding window, GQA).

Grid: (B, H, num_q_blocks, num_kv_blocks) — the KV dimension is innermost
(sequential on TPU), so the online-softmax state for one q block lives in
VMEM scratch across KV steps:

    m   (bq, 1)  running max
    l   (bq, 1)  running denominator
    acc (bq, D)  running numerator

Blocks whose (q, kv) range is fully masked (above the causal diagonal or
beyond the sliding window) skip their MXU work via ``pl.when`` — on real
TPUs the fetch still happens (BlockSpec-driven), but the dominant matmul
cost is skipped; the pure-JAX blocked path cannot skip at all, which is
exactly the gap this kernel closes (EXPERIMENTS.md §Perf).

MXU alignment: block_q x block_kv default 512 x 512; D padded to a lane
multiple by the wrapper. fp32 accumulation throughout.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, block_q, block_kv, n_kv_blocks, window, causal):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_kv

    # static-ish skip test (traced on grid ids; pl.when gates the compute)
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        needed = jnp.logical_and(
            needed, (q_start - (k_start + block_kv - 1)) < window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bkv, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bkv)

        pos_q = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        pos_k = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        diff = pos_q - pos_k
        mask = diff >= 0 if causal else jnp.ones_like(diff, jnp.bool_)
        if window is not None:
            mask = jnp.logical_and(mask, diff < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                           # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        m_new = jnp.maximum(m_new, NEG_INF)           # keep finite
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, window: Optional[int] = None, causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q, block_kv: int = DEFAULT_BLOCK_KV,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, KV, Skv, D) with H % KV == 0."""
    b, h, sq, d = q.shape
    n_kv, skv = k.shape[1], k.shape[2]
    g = h // n_kv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0
    grid = (b, h, sq // block_q, skv // block_kv)
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        n_kv_blocks=grid[3], window=window, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, i, j: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            # online-softmax state in VMEM, persistent across the KV grid dim
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
