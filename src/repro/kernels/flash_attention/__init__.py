from repro.kernels.flash_attention.ops import *  # noqa
