"""Jit'd public wrapper for the flash_attention kernel.

Accepts the framework's (B, S, H, D) layout, transposes to the kernel's
(B, H, S, D), pads D to a 128-lane multiple, and dispatches. Used by the
serving path when ModelConfig.attn_impl == "pallas"; training keeps the
autodiff-able blocked-scan path (layers.blocked_attention).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.slab import LANE, on_tpu, pad_axis


@partial(jax.jit, static_argnames=("window", "block_q", "block_kv",
                                   "interpret"))
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, pos_q=None, pos_kv=None, window: Optional[int] = None,
    block_q: int = 512, block_kv: int = 512,
    interpret: bool = None,
) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D). Causal self-attention.

    ``interpret=None`` resolves the platform at trace time (compiled on
    TPU, interpret elsewhere)."""
    if interpret is None:
        interpret = not on_tpu()
    b, sq, h, d = q.shape
    qt = pad_axis(jnp.transpose(q, (0, 2, 1, 3)), 3, LANE)
    kt = pad_axis(jnp.transpose(k, (0, 2, 1, 3)), 3, LANE)
    vt = pad_axis(jnp.transpose(v, (0, 2, 1, 3)), 3, LANE)
    out = flash_attention_pallas(qt, kt, vt, window=window, causal=True,
                                 block_q=block_q, block_kv=block_kv,
                                 scale=1.0 / (d ** 0.5),   # pre-padding D
                                 interpret=interpret)
    if d % LANE:
        out = out[..., :d]
    return jnp.transpose(out, (0, 2, 1, 3))


@partial(jax.jit, static_argnames=("window",))
def flash_attention_reference(q, k, v, *, window: Optional[int] = None):
    return flash_attention_ref(q, k, v, window=window)
