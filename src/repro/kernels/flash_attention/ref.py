"""Pure-jnp oracle for the flash_attention kernel: full-matrix GQA
attention with causal + optional sliding-window masking."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, window: Optional[int] = None,
) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D); Sq == Skv (self-attention)."""
    b, sq, h, d = q.shape
    n_kv = k.shape[2]
    g = h // n_kv
    qg = q.reshape(b, sq, n_kv, g, d)
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    pos = jnp.arange(sq)
    diff = pos[:, None] - pos[None, :]
    mask = diff >= 0
    if window is not None:
        mask &= diff < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)
