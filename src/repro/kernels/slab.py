"""Shared slab-layout helpers for the Pallas kernel wrappers.

Every kernel in this package views its operands as lane-aligned slabs:
the last dimension is the 128-wide VPU lane axis, the second-to-last is
padded to a multiple of 8 sublanes (f32 packing). Historically each
wrapper re-implemented the ravel/pad/reshape dance; this module is the
single home for that logic — used by ota_channel, masked_gradnorm,
flash_attention and the flat-pack OTA engine (repro.common.flatpack).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128            # VPU lane width — last dim of every slab
SUBLANE = 8           # f32 sublane packing — row-count multiple
ROW_QUANTUM = LANE * SUBLANE   # smallest lane-aligned flat section (1024)


def on_tpu() -> bool:
    """Whether the default backend is TPU, resolved NOW — not at import.

    Kernel wrappers must call this at trace time (inside the jit'd
    function or when resolving a ``None`` default), never bake it into a
    module-level constant: backend selection via ``jax.config`` /
    ``JAX_PLATFORMS`` after import would otherwise silently pin TPU runs
    to interpret-mode kernels (the 28x-slow class of bug —
    BENCH_kernels.json's masked_gradnorm interpret row).
    """
    return jax.default_backend() == "tpu"


def round_up(n: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``n`` (0 stays 0)."""
    return -(-n // m) * m


def slab_rows(n: int) -> int:
    """Rows of the (rows, LANE) slab holding ``n`` flat elements (>= 8)."""
    return max(SUBLANE, round_up(-(-n // LANE), SUBLANE))


def pad_to_lanes(x: jax.Array):
    """Ravel ``x`` into a zero-padded (rows, LANE) slab.

    Returns (slab, n) where ``n`` is the original element count —
    ``slab.reshape(-1)[:n].reshape(x.shape)`` round-trips exactly.
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = slab_rows(n)
    flat = jnp.pad(flat, (0, rows * LANE - n))
    return flat.reshape(rows, LANE), n


def flat_to_slab(flat: jax.Array) -> jax.Array:
    """View an already lane-aligned (..., P) flat array as (..., rows, LANE).

    ``P`` must be a multiple of ROW_QUANTUM (the flat-packer guarantees
    this); leading batch dims (cluster/scenario axes) pass through.
    """
    p = flat.shape[-1]
    assert p % ROW_QUANTUM == 0, (flat.shape, ROW_QUANTUM)
    return flat.reshape(flat.shape[:-1] + (p // LANE, LANE))


def slab_to_flat(slab: jax.Array) -> jax.Array:
    """Inverse of :func:`flat_to_slab`."""
    return slab.reshape(slab.shape[:-2] + (slab.shape[-2] * slab.shape[-1],))


def pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    """Zero-pad one axis of ``x`` up to a multiple of ``multiple``."""
    pad = -x.shape[axis] % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)
