"""Pallas TPU kernel: per-task masked L2 gradient norms (FedGradNorm, eq. 6).

A tiled masked reduction: grid (task_blocks, col_blocks); the (T_blk, 1)
output block is revisited across the column grid dimension (innermost,
sequential on TPU), accumulating partial sums of (M∘g)² in fp32 and taking
the square root on the last visit. Column tiles are (T_blk, 1024) —
8 sublanes x 128 lanes x 8 — sized so a g-tile + mask-tile fit comfortably
in VMEM at any task-block height.

The mask row is broadcast across the task block from a (1, col_blk) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COL_BLOCK = 1024
TASK_BLOCK = 8


def _gradnorm_kernel(g_ref, m_ref, out_ref, *, n_col_blocks):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)           # (1, colblk)
    part = jnp.sum((g * m) ** 2, axis=1, keepdims=True)
    out_ref[...] += part

    @pl.when(j == n_col_blocks - 1)
    def _finalize():
        out_ref[...] = jnp.sqrt(out_ref[...])


def masked_gradnorm_pallas(
    g: jax.Array,       # (T, P) — T multiple of TASK_BLOCK, P of COL_BLOCK
    mask: jax.Array,    # (1, P)
    *,
    task_block: int = TASK_BLOCK,
    col_block: int = COL_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    t, p = g.shape
    task_block = min(task_block, t)
    col_block = min(col_block, p)
    assert t % task_block == 0 and p % col_block == 0, (g.shape,)
    grid = (t // task_block, p // col_block)

    kernel = functools.partial(_gradnorm_kernel, n_col_blocks=grid[1])
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((task_block, col_block), lambda i, j: (i, j)),
            pl.BlockSpec((1, col_block), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((task_block, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, 1), jnp.float32),
        interpret=interpret,
    )(g, mask)
    return out[:, 0]
