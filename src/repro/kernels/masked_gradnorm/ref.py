"""Pure-jnp oracle for the masked_gradnorm kernel.

n_t = ‖ M ∘ g_t ‖₂  per task t (paper eq. 6) — the FedGradNorm input.
g: (T, P) stacked per-task last-shared-layer gradients; mask: (P,).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_gradnorm_ref(g: jax.Array, mask: jax.Array) -> jax.Array:
    g32 = g.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    return jnp.sqrt(jnp.sum((g32 * m[None, :]) ** 2, axis=1))
