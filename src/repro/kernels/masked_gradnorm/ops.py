"""Jit'd public wrapper for masked_gradnorm (pads ragged shapes)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.masked_gradnorm.kernel import (
    COL_BLOCK, TASK_BLOCK, masked_gradnorm_pallas,
)
from repro.kernels.masked_gradnorm.ref import masked_gradnorm_ref

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())


@partial(jax.jit, static_argnames=("interpret",))
def masked_gradnorm(g: jax.Array, mask: jax.Array,
                    interpret: bool = not _ON_TPU) -> jax.Array:
    """g: (T, P); mask: (P,) — returns (T,) masked L2 norms (fp32)."""
    t, p = g.shape
    tb = TASK_BLOCK if t >= TASK_BLOCK else t
    cb = COL_BLOCK if p >= COL_BLOCK else max(128, p)
    t_pad = -t % tb
    p_pad = -p % cb
    gp = jnp.pad(g, ((0, t_pad), (0, p_pad)))
    mp = jnp.pad(mask.astype(g.dtype), (0, p_pad))[None, :]
    out = masked_gradnorm_pallas(gp, mp, task_block=tb, col_block=cb,
                                 interpret=interpret)
    return out[:t]


@jax.jit
def masked_gradnorm_reference(g: jax.Array, mask: jax.Array) -> jax.Array:
    return masked_gradnorm_ref(g, mask)
