"""Jit'd public wrapper for masked_gradnorm (pads ragged shapes)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.masked_gradnorm.kernel import (
    COL_BLOCK, TASK_BLOCK, masked_gradnorm_pallas,
)
from repro.kernels.masked_gradnorm.ref import masked_gradnorm_ref
from repro.kernels.slab import LANE, on_tpu, pad_axis


@partial(jax.jit, static_argnames=("interpret", "impl"))
def masked_gradnorm(g: jax.Array, mask: jax.Array,
                    interpret: bool = None,
                    impl: str = None) -> jax.Array:
    """g: (T, P); mask: (P,) — returns (T,) masked L2 norms (fp32).

    ``impl``: "pallas" | "jnp". Default: "pallas" on TPU (the tiled VMEM
    kernel), "jnp" elsewhere — the interpret-mode pallas_call is ~28x
    slower than its own jnp oracle on this CPU (BENCH_kernels.json:
    28258 vs 1009 µs at 8x64k) while computing identical values, so
    off-TPU callers (the simulator's per-cluster eq.-6 norms) take the
    reference. Tests force ``impl="pallas"`` to validate the kernel.
    Platform resolves at trace time (``repro.kernels.slab.on_tpu``), not
    at import — late backend selection dispatches correctly."""
    if interpret is None:
        interpret = not on_tpu()
    if impl is None:
        impl = "pallas" if on_tpu() else "jnp"
    if impl == "jnp":
        return masked_gradnorm_ref(g, mask)
    t, p = g.shape
    tb = TASK_BLOCK if t >= TASK_BLOCK else t
    cb = COL_BLOCK if p >= COL_BLOCK else max(LANE, p)
    gp = pad_axis(pad_axis(g, 0, tb), 1, cb)
    mp = pad_axis(mask.astype(g.dtype), 0, cb)[None, :]
    out = masked_gradnorm_pallas(gp, mp, task_block=tb, col_block=cb,
                                 interpret=interpret)
    return out[:t]


@jax.jit
def masked_gradnorm_reference(g: jax.Array, mask: jax.Array) -> jax.Array:
    return masked_gradnorm_ref(g, mask)
