"""Jit'd public wrapper for masked_gradnorm (pads ragged shapes)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.masked_gradnorm.kernel import (
    COL_BLOCK, TASK_BLOCK, masked_gradnorm_pallas,
)
from repro.kernels.masked_gradnorm.ref import masked_gradnorm_ref
from repro.kernels.slab import LANE, pad_axis

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())


@partial(jax.jit, static_argnames=("interpret",))
def masked_gradnorm(g: jax.Array, mask: jax.Array,
                    interpret: bool = not _ON_TPU) -> jax.Array:
    """g: (T, P); mask: (P,) — returns (T,) masked L2 norms (fp32)."""
    t, p = g.shape
    tb = TASK_BLOCK if t >= TASK_BLOCK else t
    cb = COL_BLOCK if p >= COL_BLOCK else max(LANE, p)
    gp = pad_axis(pad_axis(g, 0, tb), 1, cb)
    mp = pad_axis(mask.astype(g.dtype), 0, cb)[None, :]
    out = masked_gradnorm_pallas(gp, mp, task_block=tb, col_block=cb,
                                 interpret=interpret)
    return out[:t]


@jax.jit
def masked_gradnorm_reference(g: jax.Array, mask: jax.Array) -> jax.Array:
    return masked_gradnorm_ref(g, mask)
