from repro.kernels.masked_gradnorm.ops import *  # noqa
