from repro.kernels.ota_channel.ops import *  # noqa
