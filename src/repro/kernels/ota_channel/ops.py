"""Jit'd public wrapper for the ota_channel kernel.

``ota_channel(x, key, sigma2, h_th)`` accepts an arbitrary-shape slab,
pads/reshapes it to the kernel's (rows, 128) layout, draws the uniform
bits with JAX's counter-based threefry (cheap, fused by XLA), and invokes
the Pallas kernel (interpret mode on CPU — this container has no TPU).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ota_channel.kernel import LANE, ota_channel_pallas
from repro.kernels.ota_channel.ref import ota_channel_ref

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())


def _pad_to_lanes(x: jax.Array):
    flat = x.reshape(-1)
    n = flat.shape[0]
    rows = -(-n // LANE)
    rows = max(8, -(-rows // 8) * 8)     # sublane multiple
    pad = rows * LANE - n
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, LANE), n


@partial(jax.jit, static_argnames=("h_th", "interpret"))
def ota_channel(x: jax.Array, key: jax.Array, sigma2, h_th: float,
                interpret: bool = not _ON_TPU):
    """Fused channel mask+apply. Returns (masked_x, mask) shaped like x."""
    slab, n = _pad_to_lanes(x)
    bits = jax.random.bits(key, slab.shape, jnp.uint32)
    out, mask = ota_channel_pallas(
        slab, bits, jnp.asarray(sigma2, jnp.float32), h_th,
        interpret=interpret)
    out = out.reshape(-1)[:n].reshape(x.shape)
    mask = mask.reshape(-1)[:n].reshape(x.shape)
    return out, mask


@partial(jax.jit, static_argnames=("h_th",))
def ota_channel_reference(x: jax.Array, key: jax.Array, sigma2, h_th: float):
    """Oracle path on the same bit stream (for tests/benchmarks)."""
    slab, n = _pad_to_lanes(x)
    bits = jax.random.bits(key, slab.shape, jnp.uint32)
    out, mask, _ = ota_channel_ref(slab, bits, sigma2, h_th)
    return (out.reshape(-1)[:n].reshape(x.shape),
            mask.reshape(-1)[:n].reshape(x.shape))
