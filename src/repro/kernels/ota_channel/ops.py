"""Jit'd public wrappers for the ota_channel kernel package.

``ota_channel(x, key, sigma2, h_th)`` accepts an arbitrary-shape slab,
pads/reshapes it to the kernels' (rows, 128) layout (shared helper in
``repro.kernels.slab``), draws the uniform bits with JAX's counter-based
threefry (cheap, fused by XLA), and invokes the Pallas kernel (interpret
mode on CPU — this container has no TPU).

``ota_aggregate(wg, bits, nbits, sigma2, ...)`` is the flat-packed whole-
model aggregation (eqs. 8-10): the caller supplies the lane-aligned
(C, P) weighted-grad slab and bit streams (see ``repro.core.ota``'s
packed path, which owns the key schedule), and one fused kernel returns
the (P,) PS estimate. All channel knobs are traced, so ``ScenarioBank``
vmaps over them freely.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ota_channel.kernel import (
    ota_aggregate_client_pallas, ota_aggregate_fused_pallas,
    ota_aggregate_pallas, ota_channel_pallas, ota_mask_count_pallas,
    ota_mask_weight_pallas,
)
from repro.kernels.ota_channel.ref import (
    bits_to_mask, ota_aggregate_client_ref, ota_aggregate_slab_ref,
    ota_channel_ref, ota_stream_fold_ref,
)
from repro.kernels.slab import (
    LANE, ROW_QUANTUM, flat_to_slab, on_tpu, pad_to_lanes,
)


def _ota_channel_impl(slab, bits, sigma2, h_th, ota_on, interpret: bool):
    """Un-jitted mask+apply on a (rows, 128) slab — the single home for
    the (1, 3) params-block layout (also used by the packed final gather
    in repro.core.hota, so the two call sites can never diverge)."""
    params = jnp.stack([jnp.asarray(sigma2, jnp.float32).reshape(()),
                        jnp.asarray(h_th, jnp.float32).reshape(()),
                        jnp.asarray(ota_on, jnp.float32).reshape(())])
    return ota_channel_pallas(slab, bits, params.reshape(1, 3),
                              interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def ota_channel(x: jax.Array, key: jax.Array, sigma2, h_th,
                ota_on=1.0, interpret: bool = None):
    """Fused channel mask+apply. Returns (masked_x, mask) shaped like x.

    All channel knobs (σ², H_th, the ota_on gate) are traced — one
    compiled kernel serves every scenario. ``interpret=None`` resolves
    the platform at trace time (compiled on TPU, interpret elsewhere) —
    never baked at import, so late backend selection dispatches right.
    """
    if interpret is None:
        interpret = not on_tpu()
    slab, n = pad_to_lanes(x)
    bits = jax.random.bits(key, slab.shape, jnp.uint32)
    out, mask = _ota_channel_impl(slab, bits, sigma2, h_th, ota_on,
                                  interpret)
    out = out.reshape(-1)[:n].reshape(x.shape)
    mask = mask.reshape(-1)[:n].reshape(x.shape)
    return out, mask


def ota_mask_weight_apply(x: jax.Array, bits: jax.Array, sigma2, h_th,
                          ota_on, weight,
                          interpret: bool = None,
                          impl: str = None):
    """Zero-copy fused mask + weighted apply for ONE leaf (DESIGN.md §3.10).

    ``x`` is consumed through a reshape of its own storage — no slab is
    packed: the LANE-aligned main body (a ROW_QUANTUM multiple) runs the
    ``ota_mask_weight_pallas`` kernel in place and the < ROW_QUANTUM
    ragged remainder takes the jnp reference on the SAME pre-sliced bit
    stream (``bits`` is the leaf's static slice of its section stream —
    see ``repro.common.flatpack.TreePacker.leaf_runs``). Returns
    (M ∘ (w·x), M) shaped like ``x``, both f32. This is the weighted-
    einsum fold: the FedGradNorm weight multiplies inside the kernel, so
    the caller's psum consumes the output directly.

    ``impl``: "pallas" | "jnp". Default: "pallas" on TPU (the compiled
    kernel), "jnp" elsewhere — per-device there is no cluster axis to
    fuse over, so on CPU the interpret-mode pallas_call is pure dispatch
    overhead while the jnp form computes the identical values
    (bit-equality pinned in tests/test_slab_native.py) AND fuses with
    the adjacent psums. Tests force ``impl="pallas"`` + interpret to
    validate the kernel itself.
    """
    if interpret is None:
        interpret = not on_tpu()
    if impl is None:
        impl = "pallas" if on_tpu() else "jnp"
    n = int(x.size)
    assert bits.shape == (n,), (bits.shape, n)
    flat = x.reshape(-1).astype(jnp.float32)
    w = jnp.asarray(weight, jnp.float32)
    if impl == "jnp":
        m = bits_to_mask(bits, sigma2, h_th, ota_on)
        out = jnp.where(m, w * flat, 0.0)
        return out.reshape(x.shape), m.astype(jnp.float32).reshape(x.shape)
    main = n - n % ROW_QUANTUM
    outs, masks = [], []
    if main:
        params = jnp.stack([
            jnp.asarray(sigma2, jnp.float32).reshape(()),
            jnp.asarray(h_th, jnp.float32).reshape(()),
            jnp.asarray(ota_on, jnp.float32).reshape(()),
            w.reshape(())]).reshape(1, 4)
        o, m = ota_mask_weight_pallas(
            jax.lax.slice(flat, (0,), (main,)).reshape(main // LANE, LANE),
            jax.lax.slice(bits, (0,), (main,)).reshape(main // LANE, LANE),
            params, interpret=interpret)
        outs.append(o.reshape(main))
        masks.append(m.reshape(main))
    if n - main:
        m = bits_to_mask(jax.lax.slice(bits, (main,), (n,)), sigma2, h_th,
                         ota_on)
        x_rem = jax.lax.slice(flat, (main,), (n,))
        outs.append(jnp.where(m, w * x_rem, 0.0))
        masks.append(m.astype(jnp.float32))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
    mask = masks[0] if len(masks) == 1 else jnp.concatenate(masks)
    return out.reshape(x.shape), mask.reshape(x.shape)


def ota_client_fold_apply(g: jax.Array, p: jax.Array, bits: jax.Array,
                          nbits: jax.Array, sigma2, h_th, noise_std, ota_on,
                          n_clients: int,
                          live=None, n_eff=None,
                          interpret: bool = None,
                          impl: str = None):
    """Zero-copy client-folded OTA aggregation for ONE leaf (DESIGN.md
    §3.12): ĝ = guard(Σ_l M_l ∘ (Σ_n p[l,n]·g[l,n]) + z), eqs. 3 + 8-10
    in one pass from the RAW (C, N, *shape) gradient leaf and the (C, N)
    loss-weight matrix — the client-weighted tree is never materialized.

    ``g`` is consumed through a reshape of its own storage: the
    LANE-aligned main body runs the ``ota_aggregate_client_pallas``
    kernel in place, the < ROW_QUANTUM ragged remainder takes the jnp
    reference on the SAME pre-sliced streams (``bits``/``nbits`` are the
    leaf's static slices of its section streams — see
    ``repro.common.flatpack.TreePacker.leaf_runs``). Returns the
    (*shape,) f32 PS estimate.

    ``impl``: "pallas" | "jnp". Default: "pallas" on TPU (the compiled
    kernel), "jnp" elsewhere — on CPU the interpret-mode pallas_call is
    pure dispatch overhead while the jnp form computes the identical
    values (pinned in tests/test_client_folded.py) AND lets XLA fuse the
    weight fold with the masked sum. Tests force ``impl="pallas"`` +
    interpret to validate the kernel itself.

    ``live`` (C,) / ``n_eff`` () inject partial participation
    (DESIGN.md §3.14): live ANDs into the cluster masks after the
    ``ota_on`` all-pass gate, n_eff replaces the static N denominator.
    None keeps the full-participation math bit-exact (the kernel is fed
    the identity values live=ones, n_eff=N).
    """
    if interpret is None:
        interpret = not on_tpu()
    if impl is None:
        impl = "pallas" if on_tpu() else "jnp"
    n_clusters, n_cl = g.shape[:2]
    assert n_cl == n_clients, (g.shape, n_clients)
    shape = g.shape[2:]
    n = int(g.size) // (n_clusters * n_clients)
    assert bits.shape == (n_clusters, n) and nbits.shape == (n,), \
        (bits.shape, nbits.shape, n)
    flat = g.reshape(n_clusters, n_clients, n)
    p32 = jnp.asarray(p, jnp.float32).reshape(n_clusters, n_clients)
    sig = jnp.asarray(sigma2, jnp.float32).reshape(n_clusters)
    if impl == "jnp":
        out = ota_aggregate_client_ref(flat, p32, bits, nbits, sig, h_th,
                                       noise_std, ota_on, n_clients,
                                       live=live, n_eff=n_eff)
        return out.reshape(shape)
    live_v = (jnp.ones((n_clusters,), jnp.float32) if live is None
              else jnp.asarray(live, jnp.float32).reshape(n_clusters))
    n_eff_v = (jnp.float32(n_clients) if n_eff is None
               else jnp.maximum(jnp.asarray(n_eff, jnp.float32), 1.0)
               .reshape(()))
    params = jnp.concatenate([
        sig,
        p32.reshape(n_clusters * n_clients),
        jnp.stack([jnp.asarray(h_th, jnp.float32).reshape(()),
                   jnp.asarray(noise_std, jnp.float32).reshape(()),
                   jnp.asarray(ota_on, jnp.float32).reshape(())]),
        live_v,
        n_eff_v.reshape(1),
    ]).reshape(1, n_clusters * (n_clients + 2) + 4)
    main = n - n % ROW_QUANTUM
    outs = []
    if main:
        rows = main // LANE
        o = ota_aggregate_client_pallas(
            jax.lax.slice(flat, (0, 0, 0), (n_clusters, n_clients, main))
            .astype(jnp.float32).reshape(n_clusters, n_clients, rows, LANE),
            jax.lax.slice(bits, (0, 0), (n_clusters, main))
            .reshape(n_clusters, rows, LANE),
            jax.lax.slice(nbits, (0,), (main,)).reshape(rows, LANE),
            params, n_clients=n_clients, interpret=interpret)
        outs.append(o.reshape(main))
    if n - main:
        outs.append(ota_aggregate_client_ref(
            jax.lax.slice(flat, (0, 0, main), (n_clusters, n_clients, n)),
            p32,
            jax.lax.slice(bits, (0, main), (n_clusters, n)),
            jax.lax.slice(nbits, (main,), (n,)),
            sig, h_th, noise_std, ota_on, n_clients,
            live=live, n_eff=n_eff))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
    return out.reshape(shape)


def ota_stream_fold_apply(g: jax.Array, p_c: jax.Array, bits: jax.Array,
                          sigma2_c, h_th, ota_on,
                          live_c=None,
                          interpret: bool = None,
                          impl: str = None):
    """Zero-copy streaming fold for ONE (leaf, cluster) pair (DESIGN.md
    §3.15): returns (M ∘ (Σ_n p[n]·g[n]), M) shaped like ``g[0]``, both
    f32 — the per-cluster term the streaming aggregator adds into its
    running sum. ``bits`` is this cluster's pre-sliced section stream
    (``stream_range_bits``), so the values are byte-identical to what
    the all-at-once client-folded path applies at the same positions.

    ``impl``: "pallas" | "jnp". Default: "pallas" on TPU, "jnp"
    elsewhere (same dispatch rationale as ``ota_client_fold_apply``).
    The pallas branch folds the (N,) weights with one einsum and runs
    the fused ``ota_mask_weight_pallas`` MAC kernel on the result — the
    same mask+apply loop the distributed per-leaf path uses — then
    scales both outputs by ``live_c`` (a {0,1} flag, so multiplying
    equals ANDing it into the mask)."""
    if interpret is None:
        interpret = not on_tpu()
    if impl is None:
        impl = "pallas" if on_tpu() else "jnp"
    n_cl = g.shape[0]
    shape = g.shape[1:]
    n = int(g.size) // n_cl
    assert bits.shape == (n,), (bits.shape, n)
    flat = g.reshape(n_cl, n).astype(jnp.float32)
    p32 = jnp.asarray(p_c, jnp.float32).reshape(n_cl)
    if impl == "jnp":
        y, cnt = ota_stream_fold_ref(flat, p32, bits, sigma2_c, h_th,
                                     ota_on, live_c=live_c)
        return y.reshape(shape), cnt.reshape(shape)
    wg = jnp.einsum("n,np->p", p32, flat)
    out, mask = ota_mask_weight_apply(wg, bits, sigma2_c, h_th, ota_on,
                                      1.0, interpret=interpret,
                                      impl="pallas")
    if live_c is not None:
        lv = jnp.asarray(live_c, jnp.float32).reshape(())
        lv = (lv > 0.5).astype(jnp.float32)
        out, mask = out * lv, mask * lv
    return out.reshape(shape), mask.reshape(shape)


def ota_mask_count_apply(x: jax.Array, bits_all: jax.Array, me, sigma2_all,
                         h_th, ota_on, weight,
                         live_all=None,
                         interpret: bool = None,
                         impl: str = None):
    """Slab-native local channel work for ONE leaf (DESIGN.md §3.10):
    returns (M_me ∘ (w·x), Σ_l M_l) shaped like ``x``, both f32.

    ``bits_all`` is the (C, n) stack of EVERY cluster's stream slice for
    this leaf — the masks are pure functions of the counter-based
    streams, so the |M| count is computed locally and the backward needs
    NO mask collective. ``me`` is this device's (traced) cluster index;
    the FedGradNorm weight folds into the apply (w·g·M in one pass).

    ``impl``: "pallas" | "jnp" — default "pallas" on TPU, "jnp"
    elsewhere (per-device elementwise work; in interpret mode the
    pallas_call is pure dispatch overhead while the jnp form computes
    identical values — pinned in tests/test_slab_native.py — and fuses
    with the adjacent psums).

    ``live_all`` (C,) injects cluster participation (DESIGN.md §3.14):
    dead clusters drop out of BOTH the |M| count and ``me``'s own mask,
    after the ``ota_on`` all-pass gate. None = all live (bit-exact).
    """
    if interpret is None:
        interpret = not on_tpu()
    if impl is None:
        impl = "pallas" if on_tpu() else "jnp"
    n = int(x.size)
    n_clusters = bits_all.shape[0]
    assert bits_all.shape == (n_clusters, n), (bits_all.shape, n)
    flat = x.reshape(-1).astype(jnp.float32)
    w = jnp.asarray(weight, jnp.float32)
    sig = jnp.asarray(sigma2_all, jnp.float32).reshape(n_clusters, 1)
    if impl == "jnp":
        masks = bits_to_mask(bits_all, sig, h_th, ota_on)   # (C, n)
        if live_all is not None:
            lv = jnp.asarray(live_all, jnp.float32).reshape(n_clusters, 1)
            masks = jnp.logical_and(masks, lv > 0.5)
        cnt = jnp.sum(masks.astype(jnp.float32), axis=0)
        mine = jnp.take(masks, me, axis=0)
        out = jnp.where(mine, w * flat, 0.0)
        return out.reshape(x.shape), cnt.reshape(x.shape)
    live_v = (jnp.ones((n_clusters,), jnp.float32) if live_all is None
              else jnp.asarray(live_all, jnp.float32).reshape(n_clusters))
    main = n - n % ROW_QUANTUM
    params = jnp.concatenate([
        sig.reshape(n_clusters),
        jnp.stack([jnp.asarray(h_th, jnp.float32).reshape(()),
                   jnp.asarray(ota_on, jnp.float32).reshape(()),
                   w.reshape(()),
                   jnp.asarray(me, jnp.float32).reshape(())]),
        live_v,
    ]).reshape(1, 2 * n_clusters + 4)
    outs, cnts = [], []
    if main:
        o, c = ota_mask_count_pallas(
            jax.lax.slice(flat, (0,), (main,)).reshape(main // LANE, LANE),
            jax.lax.slice(bits_all, (0, 0), (n_clusters, main)).reshape(
                n_clusters, main // LANE, LANE),
            params, interpret=interpret)
        outs.append(o.reshape(main))
        cnts.append(c.reshape(main))
    if n - main:
        b_rem = jax.lax.slice(bits_all, (0, main), (n_clusters, n))
        masks = bits_to_mask(b_rem, sig, h_th, ota_on)
        if live_all is not None:
            lv = jnp.asarray(live_all, jnp.float32).reshape(n_clusters, 1)
            masks = jnp.logical_and(masks, lv > 0.5)
        cnts.append(jnp.sum(masks.astype(jnp.float32), axis=0))
        mine = jnp.take(masks, me, axis=0)
        outs.append(jnp.where(
            mine, w * jax.lax.slice(flat, (main,), (n,)), 0.0))
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
    cnt = cnts[0] if len(cnts) == 1 else jnp.concatenate(cnts)
    return out.reshape(x.shape), cnt.reshape(x.shape)


@jax.jit
def ota_channel_reference(x: jax.Array, key: jax.Array, sigma2, h_th,
                          ota_on=1.0):
    """Oracle path on the same bit stream (for tests/benchmarks)."""
    slab, n = pad_to_lanes(x)
    bits = jax.random.bits(key, slab.shape, jnp.uint32)
    out, mask, _ = ota_channel_ref(slab, bits, sigma2, h_th, ota_on)
    return (out.reshape(-1)[:n].reshape(x.shape),
            mask.reshape(-1)[:n].reshape(x.shape))


def _channel_params_block(sigma2, h_th, noise_std, ota_on, c: int):
    return jnp.concatenate([
        jnp.asarray(sigma2, jnp.float32).reshape(c),
        jnp.asarray(h_th, jnp.float32).reshape(1),
        jnp.asarray(noise_std, jnp.float32).reshape(1),
        jnp.asarray(ota_on, jnp.float32).reshape(1),
    ]).reshape(1, c + 3)


def _ota_aggregate_fused_impl(wg, section_keys, section_lens, sigma2, h_th,
                              noise_std, ota_on, n_clients: int,
                              interpret: bool, bits=None,
                              nbits=None) -> jax.Array:
    """In-kernel-RNG whole-model aggregation (the packed slab path).

    ``section_keys``: (S, 2, 2) uint32 threefry keys — [section][gain|awgn]
    for each of the packer's sections in layout order (the caller derives
    the folds from ``ota.packed_section_folds``); ``section_lens``: the
    matching static lengths. Each section runs its own kernel call
    (disjoint row ranges of the slab, disjoint chunk-quantized streams),
    so the FGN phase can re-draw just the ω̃ tail. The interpret-mode
    stream is reproducible outside the kernel (see
    repro.core.ota._section_bits); pass the pre-drawn ``bits``/``nbits``
    slabs (the identical stream) to hoist the RNG out of a scenario vmap
    (ScenarioBank's supplied mode).
    """
    c, p = wg.shape
    params = _channel_params_block(sigma2, h_th, noise_std, ota_on, c)
    keys = jnp.asarray(section_keys, jnp.uint32)
    wg32 = wg.astype(jnp.float32)
    outs, off = [], 0
    for s, length in enumerate(section_lens):
        if not length:
            continue
        sec = jax.lax.slice_in_dim(wg32, off, off + length, axis=1)
        kw = {}
        if bits is not None:
            kw = dict(
                bits=flat_to_slab(
                    jax.lax.slice_in_dim(bits, off, off + length, axis=1)),
                nbits=flat_to_slab(
                    jax.lax.slice_in_dim(nbits, off, off + length, axis=0)))
        out = ota_aggregate_fused_pallas(
            flat_to_slab(sec), keys[s], params,
            n_clients=n_clients, interpret=interpret, **kw)
        outs.append(out.reshape(length))
        off += length
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs)


def _ota_aggregate_impl(wg, bits, nbits, sigma2, h_th, noise_std, ota_on,
                        n_clients: int, interpret: bool) -> jax.Array:
    """Un-jitted body of ``ota_aggregate`` — callers inside a jit use this
    directly so slab prep fuses with the kernel."""
    c, p = wg.shape
    params = _channel_params_block(sigma2, h_th, noise_std, ota_on, c)
    out = ota_aggregate_pallas(
        flat_to_slab(wg.astype(jnp.float32)),
        flat_to_slab(bits),
        flat_to_slab(nbits),
        params,
        n_clients=n_clients,
        interpret=interpret,
    )
    return out.reshape(p)


@partial(jax.jit, static_argnames=("n_clients", "interpret"))
def ota_aggregate(
    wg: jax.Array,           # (C, P) f32 slab, P lane-aligned (packer layout)
    bits: jax.Array,         # (C, P) uint32 gain bits
    nbits: jax.Array,        # (P,) uint32 AWGN bits
    sigma2: jax.Array,       # (C,) traced per-cluster variance
    h_th, noise_std, ota_on,
    n_clients: int,
    interpret: bool = None,
) -> jax.Array:
    """Whole-model OTA aggregation (eqs. 8-10) in one fused kernel pass.

    Returns the (P,) PS estimate ĝ. Bit streams are the caller's (the
    packed key schedule lives in ``repro.core.ota``), so the jnp oracle
    ``ota_aggregate_reference`` consumes the identical stream.
    ``interpret=None`` resolves the platform at trace time.
    """
    if interpret is None:
        interpret = not on_tpu()
    return _ota_aggregate_impl(wg, bits, nbits, sigma2, h_th, noise_std,
                               ota_on, n_clients, interpret)


@partial(jax.jit, static_argnames=("n_clients",))
def ota_aggregate_reference(wg, bits, nbits, sigma2, h_th, noise_std, ota_on,
                            n_clients: int) -> jax.Array:
    """Oracle for ``ota_aggregate`` on the same bit stream."""
    return ota_aggregate_slab_ref(wg, bits, nbits, sigma2, h_th, noise_std,
                                  ota_on, n_clients)
