"""Jit'd public wrappers for the ota_channel kernel package.

``ota_channel(x, key, sigma2, h_th)`` accepts an arbitrary-shape slab,
pads/reshapes it to the kernels' (rows, 128) layout (shared helper in
``repro.kernels.slab``), draws the uniform bits with JAX's counter-based
threefry (cheap, fused by XLA), and invokes the Pallas kernel (interpret
mode on CPU — this container has no TPU).

``ota_aggregate(wg, bits, nbits, sigma2, ...)`` is the flat-packed whole-
model aggregation (eqs. 8-10): the caller supplies the lane-aligned
(C, P) weighted-grad slab and bit streams (see ``repro.core.ota``'s
packed path, which owns the key schedule), and one fused kernel returns
the (P,) PS estimate. All channel knobs are traced, so ``ScenarioBank``
vmaps over them freely.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ota_channel.kernel import (
    ota_aggregate_fused_pallas, ota_aggregate_pallas, ota_channel_pallas,
)
from repro.kernels.ota_channel.ref import (
    ota_aggregate_slab_ref, ota_channel_ref,
)
from repro.kernels.slab import flat_to_slab, pad_to_lanes

_ON_TPU = any(d.platform == "tpu" for d in jax.devices())


def _ota_channel_impl(slab, bits, sigma2, h_th, ota_on, interpret: bool):
    """Un-jitted mask+apply on a (rows, 128) slab — the single home for
    the (1, 3) params-block layout (also used by the packed final gather
    in repro.core.hota, so the two call sites can never diverge)."""
    params = jnp.stack([jnp.asarray(sigma2, jnp.float32).reshape(()),
                        jnp.asarray(h_th, jnp.float32).reshape(()),
                        jnp.asarray(ota_on, jnp.float32).reshape(())])
    return ota_channel_pallas(slab, bits, params.reshape(1, 3),
                              interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def ota_channel(x: jax.Array, key: jax.Array, sigma2, h_th,
                ota_on=1.0, interpret: bool = not _ON_TPU):
    """Fused channel mask+apply. Returns (masked_x, mask) shaped like x.

    All channel knobs (σ², H_th, the ota_on gate) are traced — one
    compiled kernel serves every scenario.
    """
    slab, n = pad_to_lanes(x)
    bits = jax.random.bits(key, slab.shape, jnp.uint32)
    out, mask = _ota_channel_impl(slab, bits, sigma2, h_th, ota_on,
                                  interpret)
    out = out.reshape(-1)[:n].reshape(x.shape)
    mask = mask.reshape(-1)[:n].reshape(x.shape)
    return out, mask


@jax.jit
def ota_channel_reference(x: jax.Array, key: jax.Array, sigma2, h_th,
                          ota_on=1.0):
    """Oracle path on the same bit stream (for tests/benchmarks)."""
    slab, n = pad_to_lanes(x)
    bits = jax.random.bits(key, slab.shape, jnp.uint32)
    out, mask, _ = ota_channel_ref(slab, bits, sigma2, h_th, ota_on)
    return (out.reshape(-1)[:n].reshape(x.shape),
            mask.reshape(-1)[:n].reshape(x.shape))


def _channel_params_block(sigma2, h_th, noise_std, ota_on, c: int):
    return jnp.concatenate([
        jnp.asarray(sigma2, jnp.float32).reshape(c),
        jnp.asarray(h_th, jnp.float32).reshape(1),
        jnp.asarray(noise_std, jnp.float32).reshape(1),
        jnp.asarray(ota_on, jnp.float32).reshape(1),
    ]).reshape(1, c + 3)


def _ota_aggregate_fused_impl(wg, section_keys, section_lens, sigma2, h_th,
                              noise_std, ota_on, n_clients: int,
                              interpret: bool, bits=None,
                              nbits=None) -> jax.Array:
    """In-kernel-RNG whole-model aggregation (the sim hot path).

    ``section_keys``: (2, 2, 2) uint32 threefry keys — [section][gain|awgn]
    for the packer's head and tail sections; ``section_lens``: static
    (head_len, tail_len). Each section runs its own kernel call (disjoint
    row ranges of the slab, disjoint chunk-quantized streams), so the FGN
    phase can re-draw just the tail. The interpret-mode stream is
    reproducible outside the kernel (see repro.core.ota._section_bits);
    pass the pre-drawn ``bits``/``nbits`` slabs (the identical stream) to
    hoist the RNG out of a scenario vmap (ScenarioBank's supplied mode).
    """
    c, p = wg.shape
    params = _channel_params_block(sigma2, h_th, noise_std, ota_on, c)
    keys = jnp.asarray(section_keys, jnp.uint32)
    wg32 = wg.astype(jnp.float32)
    outs, off = [], 0
    for s, length in enumerate(section_lens):
        if not length:
            continue
        sec = jax.lax.slice_in_dim(wg32, off, off + length, axis=1)
        kw = {}
        if bits is not None:
            kw = dict(
                bits=flat_to_slab(
                    jax.lax.slice_in_dim(bits, off, off + length, axis=1)),
                nbits=flat_to_slab(
                    jax.lax.slice_in_dim(nbits, off, off + length, axis=0)))
        out = ota_aggregate_fused_pallas(
            flat_to_slab(sec), keys[s], params,
            n_clients=n_clients, interpret=interpret, **kw)
        outs.append(out.reshape(length))
        off += length
    return outs[0] if len(outs) == 1 else jnp.concatenate(outs)


def _ota_aggregate_impl(wg, bits, nbits, sigma2, h_th, noise_std, ota_on,
                        n_clients: int, interpret: bool) -> jax.Array:
    """Un-jitted body of ``ota_aggregate`` — callers inside a jit use this
    directly so slab prep fuses with the kernel."""
    c, p = wg.shape
    params = _channel_params_block(sigma2, h_th, noise_std, ota_on, c)
    out = ota_aggregate_pallas(
        flat_to_slab(wg.astype(jnp.float32)),
        flat_to_slab(bits),
        flat_to_slab(nbits),
        params,
        n_clients=n_clients,
        interpret=interpret,
    )
    return out.reshape(p)


@partial(jax.jit, static_argnames=("n_clients", "interpret"))
def ota_aggregate(
    wg: jax.Array,           # (C, P) f32 slab, P lane-aligned (packer layout)
    bits: jax.Array,         # (C, P) uint32 gain bits
    nbits: jax.Array,        # (P,) uint32 AWGN bits
    sigma2: jax.Array,       # (C,) traced per-cluster variance
    h_th, noise_std, ota_on,
    n_clients: int,
    interpret: bool = not _ON_TPU,
) -> jax.Array:
    """Whole-model OTA aggregation (eqs. 8-10) in one fused kernel pass.

    Returns the (P,) PS estimate ĝ. Bit streams are the caller's (the
    packed key schedule lives in ``repro.core.ota``), so the jnp oracle
    ``ota_aggregate_reference`` consumes the identical stream.
    """
    return _ota_aggregate_impl(wg, bits, nbits, sigma2, h_th, noise_std,
                               ota_on, n_clients, interpret)


@partial(jax.jit, static_argnames=("n_clients",))
def ota_aggregate_reference(wg, bits, nbits, sigma2, h_th, noise_std, ota_on,
                            n_clients: int) -> jax.Array:
    """Oracle for ``ota_aggregate`` on the same bit stream."""
    return ota_aggregate_slab_ref(wg, bits, nbits, sigma2, h_th, noise_std,
                                  ota_on, n_clients)
