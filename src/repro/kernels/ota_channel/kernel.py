"""Pallas TPU kernel: fused channel-draw + threshold + mask-apply.

The per-entry channel model is the memory-bound hot loop of HOTA-
FedGradNorm at scale: for every parameter entry, every cluster, every
iteration, draw H ~ N(0, σ²), threshold, and sparsify the weighted
gradient (paper eqs. 3 & 7). Done naively (jax.random.normal + where),
H round-trips through HBM; this kernel fuses bits→gaussian→mask→apply in
one VMEM pass and never materializes H.

Tiling: the slab is viewed as (rows, 128) — lane-aligned for the VPU —
with (block_rows, 128) VMEM blocks (block_rows a multiple of 8 for f32
sublane packing). Grid is 1-D over row blocks. All compute is elementwise
VPU work; the MXU is untouched.

Validated in interpret mode against ref.ota_channel_ref (same bits stream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TWO_PI = 6.283185307179586
LANE = 128
DEFAULT_BLOCK_ROWS = 256


def _ota_kernel(x_ref, bits_ref, sigma2_ref, out_ref, mask_ref, *, h_th):
    bits = bits_ref[...]
    hi = (bits >> 16).astype(jnp.float32)
    lo = (bits & jnp.uint32(0xFFFF)).astype(jnp.float32)
    u1 = (hi + 1.0) * (1.0 / 65536.0)
    u2 = lo * (1.0 / 65536.0)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    h = r * jnp.cos(TWO_PI * u2) * jnp.sqrt(sigma2_ref[0, 0])
    mask = (h * h) >= h_th
    x = x_ref[...]
    out_ref[...] = jnp.where(mask, x, jnp.zeros_like(x))
    mask_ref[...] = mask.astype(mask_ref.dtype)


def ota_channel_pallas(
    x: jax.Array,            # (rows, 128) slab
    bits: jax.Array,         # (rows, 128) uint32
    sigma2: jax.Array,       # scalar (passed as (1,1) in SMEM-like block)
    h_th: float,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
):
    rows, lane = x.shape
    assert lane == LANE, x.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)

    kernel = functools.partial(_ota_kernel, h_th=h_th)
    out, mask = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), x.dtype),
            jax.ShapeDtypeStruct((rows, LANE), x.dtype),
        ],
        interpret=interpret,
    )(x, bits, sigma2.reshape(1, 1).astype(jnp.float32))
    return out, mask
