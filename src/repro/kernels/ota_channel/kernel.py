"""Pallas TPU kernels for the OTA fading-MAC channel (paper Sec. III).

* ``ota_channel_pallas`` — per-cluster mask + apply for ONE slab via the
  Box-Muller core (bits -> N(0, σ²) gains, eq. 7's threshold — H is
  never materialized in HBM). Used by the distributed path (the MAC psum
  runs across the mesh, so masking is the only local per-entry work).

* ``ota_aggregate_pallas`` / ``ota_aggregate_fused_pallas`` — the full
  PS estimator (eqs. 8-10) for the simulator hot path: input a
  (C, rows, 128) weighted-grad slab (already Σ_i p_i g_i per cluster)
  and the traced channel knobs; an in-kernel loop over the cluster axis
  fuses mask draw→Σ_l mask·wg accumulation→AWGN→guarded |M|·N estimate.

* ``ota_aggregate_client_pallas`` — the client-folded variant (DESIGN.md
  §3.12): input the RAW (C, N, rows, 128) per-client gradient slab and
  the (C, N) loss-weight matrix (riding the params block); the MAC loop
  computes Σ_l mask_l · (Σ_n p[l,n]·g[l,n]) in block — eqs. 3 + 8-10 in
  one pass, so the caller never materializes the client-weighted tree.
  Masks are drawn by inverse-CDF thresholding (``u < erfc(√(H_th/2σ²))``
  — exactly the law of 1{|H|² ≥ H_th}; the estimator never consumes H
  because channel inversion cancels it on passing entries), so the
  per-entry cost is one compare, not a transcendental chain. Per-cluster
  masks and the noise tree never touch HBM — one output slab per round
  instead of ~4·C·L small leaf kernels. The ``_fused`` variant generates
  its bits in-kernel from per-section threefry keys on a chunk-quantized
  stream (no (C, P) bits slab in HBM, and blocking can never shift the
  draw); the bits-supplied variant is the oracle bridge for tests.

Channel knobs (σ_l², H_th, noise std, the ota_on gate) arrive as one
traced (1, C+3) params block, so scenario sweeps (``ScenarioBank``) vmap
over them without re-tracing; ``ota_on < 0.5`` forces every mask all-pass
and zeroes the AWGN (the error-free baseline) inside the same kernel.

Tiling: slabs are (rows, 128) — lane-aligned for the VPU — processed in
(CHUNK_ROWS, 128) chunks (sublane-aligned for f32 packing) with the
cluster loop unrolled in-kernel (C is static). All compute is
elementwise VPU work. The chunk-quantized key schedule — which streams
exist, what CHUNK_ROWS pins, and why blocking can never shift a draw —
is specified normatively in DESIGN.md §4 (the RNG stream spec).

Validated in interpret mode against ref.ota_channel_ref /
ref.ota_aggregate_slab_ref on the same bits stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.slab import LANE, SUBLANE

TWO_PI = 6.283185307179586
DEFAULT_BLOCK_ROWS = 256
VMEM_BUDGET_BYTES = 6 * 1024 * 1024
# per-grid-step wg budget of the in-kernel-RNG TPU path; C beyond
# 8MB / (CHUNK_ROWS·128·4) = 16 clusters loops the cluster axis in blocks
TPU_WG_BLOCK_BUDGET = 8 * 1024 * 1024


def _box_muller(bits, sigma2):
    """One N(0, σ²) draw per uint32 word (two u16 halves -> Box-Muller)."""
    hi = (bits >> 16).astype(jnp.float32)
    lo = (bits & jnp.uint32(0xFFFF)).astype(jnp.float32)
    u1 = (hi + 1.0) * (1.0 / 65536.0)     # (0, 1]: log-safe
    u2 = lo * (1.0 / 65536.0)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(TWO_PI * u2) * jnp.sqrt(sigma2)


def _pass_probability(sigma2, h_th):
    """P(|H|² ≥ H_th), H ~ N(0, σ²): erfc(√(H_th/2σ²)) — a per-cluster
    SCALAR, so the per-entry mask is one uniform-vs-threshold compare."""
    sig2 = jnp.maximum(sigma2, 1e-30)
    return jax.lax.erfc(jnp.sqrt(h_th / (2.0 * sig2)))


def _bits_mask(bits, p_pass, off):
    """Inverse-CDF mask draw (eq. 7): the estimator never consumes H
    itself (channel inversion cancels it on passing entries), and
    1{|H|² ≥ H_th} is exactly Bernoulli(p_pass) — sampled here as
    u < p_pass on the raw uniform word. Matches ref.bits_to_mask."""
    u = bits.astype(jnp.float32) * jnp.float32(2.0 ** -32)
    return jnp.logical_or(u < p_pass, off)


def _pick_block_rows(rows: int, n_slabs: int,
                     block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool = False) -> int:
    """Largest row-block <= block_rows dividing ``rows`` that keeps
    ``n_slabs`` concurrent (block, 128) f32 buffers under the VMEM budget.

    Interpret mode has no VMEM: one whole-slab grid step avoids the
    interpreter's per-block copy overhead (~10x on the 1M-param slab).
    """
    if interpret:
        return rows
    cap = max(SUBLANE, VMEM_BUDGET_BYTES // (n_slabs * LANE * 4))
    br = min(block_rows, rows, cap - cap % SUBLANE)
    br = max(SUBLANE, br - br % SUBLANE)
    while rows % br:
        br -= SUBLANE
    return br


# ---------------------------------------------------------------------------
# per-cluster mask + apply (distributed path)
# ---------------------------------------------------------------------------

def _ota_channel_kernel(x_ref, bits_ref, params_ref, out_ref, mask_ref):
    sigma2 = params_ref[0, 0]
    h_th = params_ref[0, 1]
    ota_on = params_ref[0, 2]
    h = _box_muller(bits_ref[...], sigma2)
    mask = jnp.logical_or((h * h) >= h_th, ota_on < 0.5)
    x = x_ref[...]
    out_ref[...] = jnp.where(mask, x, jnp.zeros_like(x))
    mask_ref[...] = mask.astype(mask_ref.dtype)


def _ota_mask_weight_kernel(x_ref, bits_ref, params_ref, out_ref, mask_ref):
    """Weighted-einsum fold (DESIGN.md §3.10): out = M ∘ (w·x) in ONE pass.

    This is the slab-native distributed trunk's local kernel — the
    FedGradNorm weight w multiplies inside the masked apply, so the
    LAN/MAC psum consumes the kernel output directly (no separate p·g
    materialization). Masks use the same inverse-CDF law as the fused
    aggregate kernel (one compare per entry, matches ref.bits_to_mask on
    the identical bit stream)."""
    sigma2 = params_ref[0, 0]
    h_th = params_ref[0, 1]
    ota_on = params_ref[0, 2]
    w = params_ref[0, 3]
    mask = _bits_mask(bits_ref[...], _pass_probability(sigma2, h_th),
                      ota_on < 0.5)
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.where(mask, w * x, 0.0)
    mask_ref[...] = mask.astype(mask_ref.dtype)


def ota_mask_weight_pallas(
    x: jax.Array,            # (rows, 128) slab
    bits: jax.Array,         # (rows, 128) uint32
    params: jax.Array,       # (1, 4) f32: [sigma2, h_th, ota_on, w] (traced)
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
):
    """Fused mask + weighted apply. Returns (M∘(w·x), M) as f32 slabs."""
    rows, lane = x.shape
    assert lane == LANE, x.shape
    br = _pick_block_rows(rows, 4, block_rows, interpret)
    grid = (rows // br,)

    out, mask = pl.pallas_call(
        _ota_mask_weight_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(x, bits, params.astype(jnp.float32))
    return out, mask


def _ota_mask_count_kernel(x_ref, bits_ref, params_ref, out_ref, cnt_ref,
                           *, n_clusters):
    """Slab-native local channel work (DESIGN.md §3.10): from the
    counter-based per-cluster bit streams, compute in ONE pass
    out = M_me ∘ (w·x) (this device's masked weighted gradient) and
    cnt = Σ_l M_l (the |M| count — every cluster's mask is a pure
    function of the streams, so the count needs NO collective). The
    per-cluster ``live`` flags (DESIGN.md §3.14) AND into the masks
    after the ``ota_on`` all-pass gate; all-ones = bit-exact legacy."""
    c = n_clusters
    h_th = params_ref[0, c]
    ota_on = params_ref[0, c + 1]
    w = params_ref[0, c + 2]
    me = params_ref[0, c + 3]
    off = ota_on < 0.5
    x = x_ref[...].astype(jnp.float32)
    out = jnp.zeros_like(x)
    cnt = jnp.zeros_like(x)
    for l in range(n_clusters):              # static unrolled cluster loop
        live_l = params_ref[0, c + 4 + l]
        mask = jnp.logical_and(
            _bits_mask(bits_ref[l],
                       _pass_probability(params_ref[0, l], h_th), off),
            live_l >= 0.5)
        cnt = cnt + mask.astype(jnp.float32)
        mine = jnp.logical_and(mask, me == jnp.float32(l))
        out = out + jnp.where(mine, w * x, 0.0)
    out_ref[...] = out
    cnt_ref[...] = cnt


def ota_mask_count_pallas(
    x: jax.Array,            # (rows, 128) slab
    bits: jax.Array,         # (C, rows, 128) uint32 — per-cluster streams
    params: jax.Array,       # (1, 2C+4): [σ²_·, H_th, ota_on, w, me, live_·]
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
):
    """Fused M_me∘(w·x) + Σ_l M_l. Returns (out, cnt) as f32 slabs."""
    n_clusters, rows, lane = bits.shape
    assert lane == LANE and x.shape == (rows, LANE), (bits.shape, x.shape)
    assert params.shape == (1, 2 * n_clusters + 4), params.shape
    br = _pick_block_rows(rows, n_clusters + 3, block_rows, interpret)
    grid = (rows // br,)

    kernel = functools.partial(_ota_mask_count_kernel,
                               n_clusters=n_clusters)
    out, cnt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((n_clusters, br, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((1, 2 * n_clusters + 4), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
            jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(x, bits, params.astype(jnp.float32))
    return out, cnt


def _ota_aggregate_client_kernel(x_ref, bits_ref, nbits_ref, params_ref,
                                 out_ref, *, n_clusters, n_clients):
    """Client-folded PS estimator (DESIGN.md §3.12): the MAC loop computes
    Σ_l M_l ∘ (Σ_n p[l,n]·x[l,n]) IN BLOCK from the raw (C, N, ·) gradient
    slab and the (C, N) loss-weight matrix — eqs. 3 + 8-10 in one pass;
    neither the client-weighted tree nor a (C, P) pack copy exists. The
    weight matrix rides the params block after the per-cluster σ²; the
    per-cluster ``live`` flags and the traced N_eff denominator
    (DESIGN.md §3.14) ride after the scalars — live ANDs into the masks
    AFTER the ``ota_on`` all-pass gate, and live=ones/n_eff=N is the
    bit-exact full-participation identity."""
    c, n = n_clusters, n_clients
    base = c + c * n
    h_th = params_ref[0, base]
    noise_std = params_ref[0, base + 1]
    ota_on = params_ref[0, base + 2]
    n_eff = params_ref[0, base + 3 + c]
    off = ota_on < 0.5                       # traced error-free gate

    acc = jnp.zeros_like(out_ref[...], jnp.float32)
    cnt = jnp.zeros_like(acc)
    for l in range(n_clusters):              # static unrolled cluster loop
        wg = jnp.zeros_like(acc)
        for i in range(n_clients):           # eq. 3: Σ_n p[l,n]·g[l,n]
            wg = wg + params_ref[0, c + l * n + i] * (
                x_ref[l, i].astype(jnp.float32))
        live_l = params_ref[0, base + 3 + l]
        mask = jnp.logical_and(
            _bits_mask(bits_ref[l],
                       _pass_probability(params_ref[0, l], h_th), off),
            live_l >= 0.5)
        acc = acc + jnp.where(mask, wg, 0.0)
        cnt = cnt + mask.astype(jnp.float32)

    z = _box_muller(nbits_ref[...], 1.0) * noise_std * ota_on
    y = acc + z
    out_ref[...] = jnp.where(
        cnt > 0, y / (jnp.maximum(cnt, 1.0) * jnp.maximum(n_eff, 1.0)), 0.0)


def _ota_aggregate_client_cblk_kernel(x_ref, bits_ref, nbits_ref, params_ref,
                                      out_ref, acc_ref, cnt_ref, *,
                                      cb, n_clients):
    """C-axis-blocked client-folded estimator (ROADMAP: large cluster
    counts). Grid is (row_blocks, cluster_blocks) with the cluster axis
    minor: each step accumulates ``cb`` clusters' masked contributions
    into VMEM scratch SEQUENTIALLY — the same accumulation ORDER as the
    unblocked kernel, so results agree to fusion level (XLA may contract
    mul+add into FMA differently around the scratch round-trip; ~1 ulp,
    pinned in tests/test_sectioned.py). The last cluster block adds AWGN
    and finishes the guarded estimate. The per-block params row carries
    that block's σ²/p/live slices (padded tail clusters arrive live=0,
    so they contribute nothing)."""
    c, n = cb, n_clients
    base = c + c * n
    h_th = params_ref[0, base]
    noise_std = params_ref[0, base + 1]
    ota_on = params_ref[0, base + 2]
    n_eff = params_ref[0, base + 3 + c]
    off = ota_on < 0.5

    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    acc = acc_ref[...]
    cnt = cnt_ref[...]
    for l in range(cb):                      # static unrolled cluster loop
        wg = jnp.zeros_like(acc)
        for i in range(n_clients):           # eq. 3: Σ_n p[l,n]·g[l,n]
            wg = wg + params_ref[0, c + l * n + i] * (
                x_ref[l, i].astype(jnp.float32))
        live_l = params_ref[0, base + 3 + l]
        mask = jnp.logical_and(
            _bits_mask(bits_ref[l],
                       _pass_probability(params_ref[0, l], h_th), off),
            live_l >= 0.5)
        acc = acc + jnp.where(mask, wg, 0.0)
        cnt = cnt + mask.astype(jnp.float32)
    acc_ref[...] = acc
    cnt_ref[...] = cnt

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        z = _box_muller(nbits_ref[...], 1.0) * noise_std * ota_on
        y = acc_ref[...] + z
        out_ref[...] = jnp.where(
            cnt_ref[...] > 0,
            y / (jnp.maximum(cnt_ref[...], 1.0) * jnp.maximum(n_eff, 1.0)),
            0.0)


def _client_cluster_block(n_clusters: int, n_clients: int,
                          interpret: bool) -> int:
    """Largest cluster block whose (cb·(N+1)+2) concurrent SUBLANE-row
    buffers fit the VMEM budget — n_clusters (one block, the fast
    unblocked kernel) whenever it fits."""
    if interpret:
        return n_clusters
    unit = SUBLANE * LANE * 4
    cb = max(1, (VMEM_BUDGET_BYTES // unit - 2) // (n_clients + 1))
    return min(n_clusters, cb)


def _client_params_blocked(params, n_clusters, n_clients, cb, n_cb):
    """Re-tile the (1, C(N+2)+4) client params row into (n_cb, cb(N+2)+4)
    per-cluster-block rows of the SAME layout (σ², p, scalars, live,
    N_eff), padding the tail block's clusters with live=0."""
    c, n = n_clusters, n_clients
    pad = n_cb * cb - c
    sig = jnp.pad(params[0, :c], (0, pad))
    p = jnp.pad(params[0, c:c + c * n].reshape(c, n), ((0, pad), (0, 0)))
    live = jnp.pad(params[0, c + c * n + 3:c + c * n + 3 + c], (0, pad))
    scal = jnp.broadcast_to(params[0, c + c * n:c + c * n + 3].reshape(1, 3),
                            (n_cb, 3))
    n_eff = jnp.broadcast_to(params[0, -1].reshape(1, 1), (n_cb, 1))
    return jnp.concatenate([
        sig.reshape(n_cb, cb), p.reshape(n_cb, cb * n), scal,
        live.reshape(n_cb, cb), n_eff], axis=1)


def ota_aggregate_client_pallas(
    x: jax.Array,            # (C, N, rows, 128) f32 — RAW per-client grads
    bits: jax.Array,         # (C, rows, 128) uint32 — gain bits per cluster
    nbits: jax.Array,        # (rows, 128) uint32 — AWGN bits
    params: jax.Array,       # (1, C·(N+2)+4):
                             #   [σ²_·, p_··, H_th, z_std, ota_on, live_·, N_eff]
    *,
    n_clients: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
    cluster_block: int = 0,  # 0 = auto; tests force small blocks
) -> jax.Array:
    """Fused client-folded OTA aggregation for one leaf/section slab.

    Returns the (rows, 128) PS estimate ĝ. The caller supplies the bit
    streams (the chunk-quantized key schedule lives in ``repro.core.ota``
    — under a scenario vmap the draw depends only on the shared key and
    hoists out of the scenario axis). At large cluster counts the C·N
    concurrent VMEM blocks outgrow the budget faster than row blocking
    can shrink them, so the call auto-switches to the C-axis-blocked
    kernel (scratch accumulation over cluster blocks in the same float
    order — equal to fusion level, validated in interpret mode)."""
    n_clusters, n_cl, rows, lane = x.shape
    assert lane == LANE and n_cl == n_clients, (x.shape, n_clients)
    assert bits.shape == (n_clusters, rows, LANE), (bits.shape, x.shape)
    assert nbits.shape == (rows, LANE), nbits.shape
    assert params.shape == (1, n_clusters * (n_clients + 2) + 4), params.shape
    cb = (cluster_block if cluster_block
          else _client_cluster_block(n_clusters, n_clients, interpret))
    if cb < n_clusters:
        n_cb = pl.cdiv(n_clusters, cb)
        # cb·N grad blocks + cb bits blocks + noise + out + 2 scratch
        br = _pick_block_rows(rows, cb * (n_clients + 1) + 4,
                              block_rows, interpret)
        kernel = functools.partial(_ota_aggregate_client_cblk_kernel,
                                   cb=cb, n_clients=n_clients)
        from jax.experimental.pallas import tpu as pltpu
        return pl.pallas_call(
            kernel,
            grid=(rows // br, n_cb),
            in_specs=[
                pl.BlockSpec((cb, n_clients, br, LANE),
                             lambda i, j: (j, 0, i, 0)),
                pl.BlockSpec((cb, br, LANE), lambda i, j: (j, i, 0)),
                pl.BlockSpec((br, LANE), lambda i, j: (i, 0)),
                pl.BlockSpec((1, cb * (n_clients + 2) + 4),
                             lambda i, j: (j, 0)),
            ],
            out_specs=pl.BlockSpec((br, LANE), lambda i, j: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
            scratch_shapes=[pltpu.VMEM((br, LANE), jnp.float32),
                            pltpu.VMEM((br, LANE), jnp.float32)],
            interpret=interpret,
        )(x, bits, nbits,
          _client_params_blocked(params.astype(jnp.float32), n_clusters,
                                 n_clients, cb, n_cb))

    # C·N grad blocks + C bits blocks + noise + out resident at once
    br = _pick_block_rows(rows, n_clusters * (n_clients + 1) + 2,
                          block_rows, interpret)
    grid = (rows // br,)

    kernel = functools.partial(_ota_aggregate_client_kernel,
                               n_clusters=n_clusters, n_clients=n_clients)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_clusters, n_clients, br, LANE),
                         lambda i: (0, 0, i, 0)),
            pl.BlockSpec((n_clusters, br, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, n_clusters * (n_clients + 2) + 4),
                         lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(x, bits, nbits, params.astype(jnp.float32))


def ota_channel_pallas(
    x: jax.Array,            # (rows, 128) slab
    bits: jax.Array,         # (rows, 128) uint32
    params: jax.Array,       # (1, 3) f32: [sigma2, h_th, ota_on] (traced)
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
):
    rows, lane = x.shape
    assert lane == LANE, x.shape
    br = _pick_block_rows(rows, 4, block_rows, interpret)
    grid = (rows // br,)

    out, mask = pl.pallas_call(
        _ota_channel_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANE), x.dtype),
            jax.ShapeDtypeStruct((rows, LANE), x.dtype),
        ],
        interpret=interpret,
    )(x, bits, params.astype(jnp.float32))
    return out, mask


# ---------------------------------------------------------------------------
# full OTA aggregation (simulator hot path, eqs. 8-10)
# ---------------------------------------------------------------------------

def _ota_aggregate_kernel(wg_ref, bits_ref, nbits_ref, params_ref, out_ref,
                          *, n_clusters, n_clients):
    c = n_clusters
    h_th = params_ref[0, c]
    noise_std = params_ref[0, c + 1]
    ota_on = params_ref[0, c + 2]
    off = ota_on < 0.5                       # traced error-free gate

    acc = jnp.zeros_like(out_ref[...], jnp.float32)
    cnt = jnp.zeros_like(acc)
    for l in range(n_clusters):              # static unrolled cluster loop
        mask = _bits_mask(bits_ref[l],
                          _pass_probability(params_ref[0, l], h_th), off)
        acc = acc + jnp.where(mask, wg_ref[l].astype(jnp.float32), 0.0)
        cnt = cnt + mask.astype(jnp.float32)

    z = _box_muller(nbits_ref[...], 1.0) * noise_std * ota_on
    y = acc + z
    # |M_k(j)| = 0 -> nothing received but noise; estimator guarded to 0
    out_ref[...] = jnp.where(cnt > 0,
                             y / (jnp.maximum(cnt, 1.0) * n_clients), 0.0)


# The stream quantum of the in-kernel RNG: bits are always drawn in
# (CHUNK_ROWS, 128) pieces keyed by fold_in(fold_in(section_key, cluster),
# chunk) — so the stream NEVER depends on how the loop is blocked, and a
# chunk (512 KB of f32) is also the VMEM/cache-sized work unit per step.
# Changing CHUNK_ROWS changes the draw — it is part of the stream spec
# (DESIGN.md §4).
CHUNK_ROWS = 1024
# chunk loops up to this long are unrolled (faster in interpret mode);
# longer slabs use fori_loop so compile time stays independent of P
UNROLL_CHUNKS = 16


def _interp_chunk_bits(key2, cluster, chunk):
    """One (CHUNK_ROWS, 128) uint32 draw of the chunk-quantized threefry
    stream (chunk j of ``fold_in(section_key, cluster)``'s stream).
    ``cluster`` is None for the per-entry AWGN stream (no cluster axis).
    """
    k = key2
    if cluster is not None:
        k = jax.random.fold_in(k, cluster)
    k = jax.random.fold_in(k, chunk)
    return jax.random.bits(k, (CHUNK_ROWS, LANE), jnp.uint32)


def _fused_body(wg, bits_fn, nbits_fn, params_ref, n_clusters, n_clients,
                r0, br):
    """Accumulate one row-chunk [r0, r0+br) over the cluster axis and
    finish it with AWGN + the guarded |M|·N estimate (eqs. 8-10)."""
    c = n_clusters
    h_th = params_ref[0, c]
    noise_std = params_ref[0, c + 1]
    ota_on = params_ref[0, c + 2]
    off = ota_on < 0.5

    acc = jnp.zeros((br, LANE), jnp.float32)
    cnt = jnp.zeros_like(acc)
    for l in range(n_clusters):              # static unrolled cluster loop
        bits = bits_fn(l)[:br]
        mask = _bits_mask(bits, _pass_probability(params_ref[0, l], h_th),
                          off)
        acc = acc + jnp.where(mask, wg(l, r0, br).astype(jnp.float32), 0.0)
        cnt = cnt + mask.astype(jnp.float32)
    z = _box_muller(nbits_fn()[:br], 1.0) * noise_std * ota_on
    y = acc + z
    return jnp.where(cnt > 0, y / (jnp.maximum(cnt, 1.0) * n_clients), 0.0)


def _chunk_sweep(out_ref, chunk):
    """Drive ``chunk(j, rows_ds, br)`` over the slab's row-chunks and
    write its results: unrolled for small slabs (faster in interpret
    mode), a PURE lax.map for big ones (compile size independent of P;
    the ref is written once after — a ref store inside the loop would
    batch as a full-slab update per chunk under ScenarioBank's vmap)."""
    rows = out_ref.shape[0]
    n_full = rows // CHUNK_ROWS
    if 0 < n_full <= UNROLL_CHUNKS:
        for j in range(n_full):
            r0 = j * CHUNK_ROWS
            out_ref[r0:r0 + CHUNK_ROWS, :] = chunk(
                j, pl.ds(r0, CHUNK_ROWS), CHUNK_ROWS)
    elif n_full:
        ys = jax.lax.map(
            lambda j: chunk(j, pl.ds(j * CHUNK_ROWS, CHUNK_ROWS),
                            CHUNK_ROWS),
            jnp.arange(n_full))
        out_ref[:n_full * CHUNK_ROWS, :] = ys.reshape(-1, LANE)
    rem = rows - n_full * CHUNK_ROWS
    if rem:                                  # static partial last chunk
        r0 = n_full * CHUNK_ROWS
        out_ref[r0:, :] = chunk(n_full, pl.ds(r0, rem), rem)


def _ota_aggregate_interp_kernel(wg_ref, keys_ref, params_ref, out_ref, *,
                                 n_clusters, n_clients):
    """Interpret-mode body, in-kernel RNG: every temp is one cache-sized
    chunk and the chunk-quantized threefry stream matches the oracle's
    draw (repro.core.ota._section_bits) bit for bit."""
    def chunk(j, r0, br):
        return _fused_body(
            lambda l, r, b: wg_ref[l, r, :],
            lambda l: _interp_chunk_bits(keys_ref[0], l, j),
            lambda: _interp_chunk_bits(keys_ref[1], None, j),
            params_ref, n_clusters, n_clients, r0, br)

    _chunk_sweep(out_ref, chunk)


def _ota_aggregate_supplied_kernel(wg_ref, bits_ref, nbits_ref, params_ref,
                                   out_ref, *, n_clusters, n_clients):
    """Interpret-mode body, caller-supplied bits: same chunk sweep, but
    the gain/AWGN streams are read from (C, rows, 128)/(rows, 128) slabs.
    Under ScenarioBank's vmap the bit draw does not depend on the banked
    knobs, so it hoists out of the scenario axis — the RNG cost is paid
    once per round, not once per scenario."""
    def chunk(j, r0, br):
        return _fused_body(
            lambda l, r, b: wg_ref[l, r, :],
            lambda l, r=r0: bits_ref[l, r, :],
            lambda r=r0: nbits_ref[r, :],
            params_ref, n_clusters, n_clients, r0, br)

    _chunk_sweep(out_ref, chunk)


def tpu_hw_seed(key2, l, i):
    """The compiled TPU branch's hardware-PRNG seed for (cluster ``l``,
    row-chunk ``i``) of the stream keyed by the (2,) uint32 threefry key
    ``key2`` (``l=None`` = the AWGN stream). ONE home for the seed
    arithmetic — the kernels below and the validation pass
    (tests/test_sectioned.py) both call it, so the schedule the tests
    check for (cluster, chunk) collisions and C-blocking invariance is
    the schedule the hardware actually seeds. All arithmetic wraps mod
    2³²; ``l``/``i`` may be traced."""
    s = key2[0] ^ key2[1]
    if l is not None:
        s = s + jnp.asarray(l, jnp.uint32) * jnp.uint32(0x10001)
    return s + jnp.asarray(i, jnp.uint32)


def _hw_chunk_bits(key_row, l, i):
    """One hardware-PRNG (CHUNK_ROWS, 128) uint32 chunk draw. The
    int32->uint32 astype is a bit-preserving cast (mod 2³²):
    ``prng_random_bits`` yields int32, and consuming it signed would
    sign-extend in ``_bits_mask``'s uniform compare and ``_box_muller``'s
    ``>> 16`` — the mask law would be biased (the bug the hardware-PRNG
    validation pass exists to catch)."""
    from jax.experimental.pallas import tpu as pltpu
    pltpu.prng_seed(tpu_hw_seed(key_row, l, i))
    return pltpu.prng_random_bits((CHUNK_ROWS, LANE)).astype(jnp.uint32)


def _ota_aggregate_tpu_kernel(wg_ref, keys_ref, params_ref, out_ref,
                              acc_ref, cnt_ref, *, cb, n_clusters,
                              n_clients):
    """Compiled TPU body: grid (row-chunks, cluster-blocks) with the
    cluster axis minor, hardware PRNG (pltpu.prng_random_bits — an
    i.i.d. stream distinct from the interpret/oracle threefry stream;
    statistical tests only). Each step folds ``cb`` clusters' masked
    contributions into VMEM scratch SEQUENTIALLY (the same float order —
    and, via ``tpu_hw_seed`` on GLOBAL cluster indices, the same seeds —
    as the old single-block kernel), so VMEM holds cb·CHUNK_ROWS wg rows
    however large C grows; the last cluster block adds AWGN and writes
    the guarded estimate."""
    c = n_clusters
    h_th = params_ref[0, c]
    noise_std = params_ref[0, c + 1]
    ota_on = params_ref[0, c + 2]
    off = ota_on < 0.5
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    acc = acc_ref[...]
    cnt = cnt_ref[...]
    for l_loc in range(cb):                  # static unrolled local loop
        l = j * cb + l_loc                   # traced GLOBAL cluster index
        bits = _hw_chunk_bits(keys_ref[0], l, i)
        valid = l < n_clusters               # padded tail cluster block
        sig_l = jnp.sum(jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, (c, 1), 0)
            == jnp.minimum(l, c - 1),
            params_ref[0, :c].reshape(c, 1), 0.0))
        mask = jnp.logical_and(
            _bits_mask(bits, _pass_probability(sig_l, h_th), off), valid)
        acc = acc + jnp.where(mask, wg_ref[l_loc].astype(jnp.float32), 0.0)
        cnt = cnt + mask.astype(jnp.float32)
    acc_ref[...] = acc
    cnt_ref[...] = cnt

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        nbits = _hw_chunk_bits(keys_ref[1], None, i)
        z = _box_muller(nbits, 1.0) * noise_std * ota_on
        y = acc_ref[...] + z
        out_ref[...] = jnp.where(
            cnt_ref[...] > 0,
            y / (jnp.maximum(cnt_ref[...], 1.0) * n_clients), 0.0)


def ota_aggregate_fused_pallas(
    wg: jax.Array,           # (C, rows, 128) f32 — ONE section's slab
    keys: jax.Array,         # (2, 2) uint32 threefry keys [gains, AWGN]
    params: jax.Array,       # (1, C+3) f32: [σ²_0..σ²_{C-1}, H_th, z_std, ota_on]
    *,
    n_clients: int,
    interpret: bool = False,
    bits: jax.Array = None,     # optional (C, rows, 128) uint32 pre-drawn
    nbits: jax.Array = None,    # optional (rows, 128) uint32 pre-drawn
) -> jax.Array:
    """OTA aggregation for one packed section (the sim hot path). The
    bit stream is quantized to CHUNK_ROWS blocks keyed by (section,
    cluster, chunk), so kernel blocking never shifts the draw; a partial
    last chunk just truncates its stream (the oracle does the same).
    Pass pre-drawn ``bits``/``nbits`` (the identical stream) to hoist
    the RNG out of a scenario vmap."""
    n_clusters, rows, lane = wg.shape
    assert lane == LANE, wg.shape

    if interpret and bits is not None:
        kernel = functools.partial(_ota_aggregate_supplied_kernel,
                                   n_clusters=n_clusters,
                                   n_clients=n_clients)
        return pl.pallas_call(
            kernel,
            grid=(1,),
            in_specs=[
                pl.BlockSpec((n_clusters, rows, LANE), lambda i: (0, 0, 0)),
                pl.BlockSpec((n_clusters, rows, LANE), lambda i: (0, 0, 0)),
                pl.BlockSpec((rows, LANE), lambda i: (0, 0)),
                pl.BlockSpec((1, n_clusters + 3), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((rows, LANE), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
            interpret=True,
        )(wg, bits, nbits, params.astype(jnp.float32))

    if bits is not None:         # compiled: block-gridded supplied-bits
        return ota_aggregate_pallas(wg, bits, nbits, params,
                                    n_clients=n_clients, interpret=False)

    if interpret:
        kernel = functools.partial(_ota_aggregate_interp_kernel,
                                   n_clusters=n_clusters,
                                   n_clients=n_clients)
        return pl.pallas_call(
            kernel,
            grid=(1,),
            in_specs=[
                pl.BlockSpec((n_clusters, rows, LANE), lambda i: (0, 0, 0)),
                pl.BlockSpec((2, 2), lambda i: (0, 0)),
                pl.BlockSpec((1, n_clusters + 3), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((rows, LANE), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
            interpret=True,
        )(wg, keys, params.astype(jnp.float32))

    # the wg block is (cb, CHUNK_ROWS, 128) f32 — CHUNK_ROWS is part of
    # the stream spec and cannot shrink per call, so at large C the
    # CLUSTER axis is blocked (scratch accumulation over a minor grid
    # dim); seeds key on global cluster indices, so blocking never
    # shifts the hardware draw (tpu_hw_seed — validated in
    # tests/test_sectioned.py).
    from jax.experimental.pallas import tpu as pltpu
    cb_cap = max(1, TPU_WG_BLOCK_BUDGET // (CHUNK_ROWS * LANE * 4))
    cb = min(n_clusters, cb_cap)
    n_cb = pl.cdiv(n_clusters, cb)
    kernel = functools.partial(_ota_aggregate_tpu_kernel, cb=cb,
                               n_clusters=n_clusters, n_clients=n_clients)
    return pl.pallas_call(
        kernel,
        grid=(pl.cdiv(rows, CHUNK_ROWS), n_cb),
        in_specs=[
            pl.BlockSpec((cb, CHUNK_ROWS, LANE),
                         lambda i, j: (j, i, 0)),
            pl.BlockSpec((2, 2), lambda i, j: (0, 0)),
            pl.BlockSpec((1, n_clusters + 3), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((CHUNK_ROWS, LANE), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        scratch_shapes=[pltpu.VMEM((CHUNK_ROWS, LANE), jnp.float32),
                        pltpu.VMEM((CHUNK_ROWS, LANE), jnp.float32)],
        interpret=False,
    )(wg, keys, params.astype(jnp.float32))


def ota_aggregate_pallas(
    wg: jax.Array,           # (C, rows, 128) f32 — Σ_i p_i g_i per cluster
    bits: jax.Array,         # (C, rows, 128) uint32 — gain bits per cluster
    nbits: jax.Array,        # (rows, 128) uint32 — AWGN bits
    params: jax.Array,       # (1, C+3) f32: [σ²_0..σ²_{C-1}, H_th, z_std, ota_on]
    *,
    n_clients: int,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    n_clusters, rows, lane = wg.shape
    assert lane == LANE, wg.shape
    assert bits.shape == wg.shape, (bits.shape, wg.shape)
    assert nbits.shape == (rows, LANE), nbits.shape
    # 2C cluster blocks + noise + out resident at once
    br = _pick_block_rows(rows, 2 * n_clusters + 2, block_rows, interpret)
    grid = (rows // br,)

    kernel = functools.partial(_ota_aggregate_kernel,
                               n_clusters=n_clusters, n_clients=n_clients)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_clusters, br, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((n_clusters, br, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, n_clusters + 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(wg, bits, nbits, params.astype(jnp.float32))
