"""Pure-jnp oracle for the ota_channel kernel.

Math (paper eqs. 3, 7): from counter-based uniform bits, draw per-entry
channel gains H ~ N(0, σ²) via Box-Muller, threshold |H|² ≥ H_th into the
sparsification mask M, and apply it to the weighted-gradient slab x:

    out  = M ∘ x
    mask = M (as x.dtype, for the |M_k(j)| count psum / CSI bookkeeping)
    gain = H (faithful mode needs the gains themselves for β = p/H)

Bits are supplied by the caller (jax.random.bits), so kernel and oracle
consume the identical stream — outputs match bit-for-bit up to float
associativity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

TWO_PI = 6.283185307179586


def bits_to_gaussian(bits: jax.Array, sigma2) -> jax.Array:
    """Box-Muller on the two u16 halves of each u32 word -> one N(0, σ²)."""
    hi = (bits >> 16).astype(jnp.float32)
    lo = (bits & jnp.uint32(0xFFFF)).astype(jnp.float32)
    # map to (0,1]: (k + 1) / 65536 keeps u1 away from 0 (log-safe)
    u1 = (hi + 1.0) * (1.0 / 65536.0)
    u2 = lo * (1.0 / 65536.0)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    h = r * jnp.cos(TWO_PI * u2)
    return h * jnp.sqrt(jnp.asarray(sigma2, jnp.float32))


def ota_channel_ref(x: jax.Array, bits: jax.Array, sigma2, h_th):
    """x: any-shape slab; bits: same-shape uint32. Returns (masked_x, mask, gain)."""
    h = bits_to_gaussian(bits, sigma2)
    mask = (h * h) >= h_th
    out = jnp.where(mask, x, jnp.zeros_like(x))
    return out, mask.astype(x.dtype), h
