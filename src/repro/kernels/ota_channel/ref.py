"""Pure-jnp oracles for the ota_channel kernel package.

Math (paper eqs. 3, 7-10): from counter-based uniform bits, draw per-entry
channel gains H ~ N(0, σ²) via Box-Muller, threshold |H|² ≥ H_th into the
sparsification mask M, and either apply it to one weighted-gradient slab
(``ota_channel_ref``) or run the whole PS estimator across the cluster
axis (``ota_aggregate_slab_ref``):

    y(j)  = Σ_{l∈M(j)} wg_l(j) + z(j)          (eq. 8, channel inverted)
    ĝ(j)  = y(j) / (|M_k(j)| · N), 0 if |M|=0  (eq. 10, guarded)

Bits are supplied by the caller (jax.random.bits), so kernel and oracle
consume the identical stream — outputs match bit-for-bit up to float
associativity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

TWO_PI = 6.283185307179586


def bits_to_gaussian(bits: jax.Array, sigma2) -> jax.Array:
    """Box-Muller on the two u16 halves of each u32 word -> one N(0, σ²)."""
    hi = (bits >> 16).astype(jnp.float32)
    lo = (bits & jnp.uint32(0xFFFF)).astype(jnp.float32)
    # map to (0,1]: (k + 1) / 65536 keeps u1 away from 0 (log-safe)
    u1 = (hi + 1.0) * (1.0 / 65536.0)
    u2 = lo * (1.0 / 65536.0)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    h = r * jnp.cos(TWO_PI * u2)
    return h * jnp.sqrt(jnp.asarray(sigma2, jnp.float32))


def pass_probability(sigma2, h_th) -> jax.Array:
    """P(|H|² ≥ H_th) for H ~ N(0, σ²): erfc(√(H_th / 2σ²)) (eq. 7)."""
    sig2 = jnp.maximum(jnp.asarray(sigma2, jnp.float32), 1e-30)
    return jax.lax.erfc(jnp.sqrt(jnp.asarray(h_th, jnp.float32)
                                 / (2.0 * sig2)))


def bits_to_mask(bits: jax.Array, sigma2, h_th, ota_on=1.0) -> jax.Array:
    """eq. (7) from a bit stream by inverse-CDF thresholding: the
    estimator only ever consumes the MASK (channel inversion cancels H on
    passing entries), and 1{|H|² ≥ H_th} for H ~ N(0, σ²) is exactly
    Bernoulli(erfc(√(H_th/2σ²))) — so ``u < p_pass`` on the raw uniform
    draw is the identical distribution at one compare per entry instead
    of a Box-Muller log/sqrt/cos chain. ``ota_on < 0.5`` forces all-pass.
    """
    u = bits.astype(jnp.float32) * jnp.float32(2.0 ** -32)
    p = pass_probability(sigma2, h_th)
    return jnp.logical_or(u < p, jnp.asarray(ota_on, jnp.float32) < 0.5)


def ota_channel_ref(x: jax.Array, bits: jax.Array, sigma2, h_th, ota_on=1.0):
    """x: any-shape slab; bits: same-shape uint32. Returns (masked_x, mask, gain)."""
    h = bits_to_gaussian(bits, sigma2)
    mask = jnp.logical_or((h * h) >= h_th,
                          jnp.asarray(ota_on, jnp.float32) < 0.5)
    out = jnp.where(mask, x, jnp.zeros_like(x))
    return out, mask.astype(x.dtype), h


def ota_aggregate_client_ref(
    g: jax.Array,            # (C, N, ...) RAW per-client gradients
    p: jax.Array,            # (C, N) loss weights
    bits: jax.Array,         # (C, ...) uint32 gain bits per cluster
    nbits: jax.Array,        # (...) uint32 AWGN bits
    sigma2: jax.Array,       # (C,)
    h_th, noise_std, ota_on,
    n_clients: int,
    live=None,               # (C,) cluster participation (DESIGN.md §3.14)
    n_eff=None,              # () traced effective N
) -> jax.Array:
    """Client-folded oracle (eqs. 3 + 8-10): fold the per-client weights
    into the MAC sum — Σ_l M_l ∘ (Σ_n p[l,n]·g[l,n]) — then AWGN and the
    guarded |M|·N estimate. Same bits/mask/noise laws as
    ``ota_aggregate_slab_ref``; the weighted tree is never an input."""
    wg = jnp.einsum("cn,cn...->c...", p.astype(jnp.float32),
                    g.astype(jnp.float32))
    return ota_aggregate_slab_ref(wg, bits, nbits, sigma2, h_th, noise_std,
                                  ota_on, n_clients, live=live, n_eff=n_eff)


def ota_stream_fold_ref(
    g: jax.Array,            # (N, ...) ONE cluster's raw client gradients
    p_c: jax.Array,          # (N,) this cluster's loss weights
    bits: jax.Array,         # (...) uint32 gain bits, this cluster's stream
    sigma2_c, h_th, ota_on,
    live_c=None,             # () cluster participation flag (§3.14)
):
    """One cluster's streaming-fold contribution (DESIGN.md §3.15):
    (M_l ∘ Σ_n p[n]·g[n], M_l) — the per-cluster term of the eq.-8 MAC
    sum plus its |M| count, BEFORE any cross-cluster reduction. The
    streaming aggregator accumulates these one arriving cluster at a
    time; folding all C and adding the AWGN + eq.-10 guard reproduces
    ``ota_aggregate_client_ref`` exactly (same weight fold, same mask
    law, same term order). ``live_c`` ANDs into the mask after the
    ``ota_on`` all-pass gate, like ``live`` does in the slab oracle."""
    wg = jnp.einsum("n,n...->...", p_c.astype(jnp.float32),
                    g.astype(jnp.float32))
    m = bits_to_mask(bits.reshape(wg.shape), sigma2_c, h_th, ota_on)
    if live_c is not None:
        m = jnp.logical_and(m, jnp.asarray(live_c, jnp.float32) > 0.5)
    return jnp.where(m, wg, 0.0), m.astype(jnp.float32)


def ota_aggregate_slab_ref(
    wg: jax.Array,           # (C, ...) weighted grads, already Σ_i p_i g_i
    bits: jax.Array,         # (C, ...) uint32 gain bits per cluster
    nbits: jax.Array,        # (...) uint32 AWGN bits
    sigma2: jax.Array,       # (C,)
    h_th, noise_std, ota_on,
    n_clients: int,
    live=None,               # (C,) cluster participation (DESIGN.md §3.14)
    n_eff=None,              # () traced effective N
) -> jax.Array:
    """eqs. (8)-(10) on flat slabs, per-cluster where+sum in plain jnp.

    The packed kernel's oracle: same bits, same inverse-CDF mask rule
    (``bits_to_mask``), same Box-Muller AWGN, same |M|·N guard — but
    per-cluster masks materialize as full (C, ...) arrays. A non-None
    ``live`` ANDs cluster participation into the masks AFTER the
    ``ota_on`` all-pass gate (blackout removes a cluster even in the
    error-free baseline); ``n_eff`` replaces the static N denominator.
    """
    c = wg.shape[0]
    sig = jnp.asarray(sigma2, jnp.float32).reshape((c,) + (1,) * (wg.ndim - 1))
    masks = bits_to_mask(bits, sig, h_th, ota_on)
    if live is not None:
        lv = jnp.asarray(live, jnp.float32).reshape(
            (c,) + (1,) * (wg.ndim - 1))
        masks = jnp.logical_and(masks, lv > 0.5)
    y = jnp.sum(jnp.where(masks, wg.astype(jnp.float32), 0.0), axis=0)
    z = bits_to_gaussian(nbits, 1.0) * noise_std * jnp.asarray(
        ota_on, jnp.float32)
    y = y + z
    cnt = jnp.sum(masks.astype(jnp.float32), axis=0)
    denom = (jnp.float32(n_clients) if n_eff is None
             else jnp.maximum(jnp.asarray(n_eff, jnp.float32), 1.0))
    return jnp.where(cnt > 0, y / (jnp.maximum(cnt, 1.0) * denom), 0.0)
