"""Spec ↔ code cross-check of the reserved fold/salt registry (DESIGN.md §4).

The RNG stream spec lives twice: as named constants in the registry
modules (``core/ota.py``, ``core/hota.py``, ``core/hota_slab.py``) and as
the normative table in DESIGN.md §4. Either copy drifting silently is
exactly the failure mode the spec exists to prevent — a renamed or
renumbered fold re-keys every stream drawn under it. This module parses
BOTH sides without importing jax (the code side via ``ast``, the doc side
via the markdown table) and reports every disagreement:

* names present on one side only;
* value mismatches;
* ``channel``-class folds below the ``0x7FFF0000`` reserved floor or
  colliding pairwise (they share the per-round channel key domain);
* ``aux``-class salts colliding pairwise (conservative: today every
  registered salt is distinct, so a new collision is a red flag even
  across parent-key domains);
* dict registries (``KLASS_SALT``) with colliding values.

Run via ``python scripts/repro_lint.py`` (rule name: ``stream-registry``)
— see DESIGN.md §3.17.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

RULE = "stream-registry"

# the registry homes: every reserved fold/salt constant lives in one of
# these (tests/test_stream_spec.py scans the same set at runtime)
REGISTRY_MODULES = (
    os.path.join("src", "repro", "core", "ota.py"),
    os.path.join("src", "repro", "core", "hota.py"),
    os.path.join("src", "repro", "core", "hota_slab.py"),
)

CHANNEL_FLOOR = 0x7FFF0000

_CONST_NAME = re.compile(r"^[A-Z][A-Z0-9_]*$")
_SALTY = re.compile(r"(?:^|_)(?:FOLD|SALT)(?:_|$)")

# | `NAME` | `0x7FFF0001` | channel | purpose... |
_TABLE_ROW = re.compile(
    r"^\|\s*`([A-Z][A-Z0-9_]*)`\s*\|\s*`(0x[0-9A-Fa-f]+|\d+)`\s*\|"
    r"\s*([a-z]+)\s*\|")


@dataclass
class CodeRegistry:
    """Named salt constants AST-parsed out of the registry modules."""
    scalars: Dict[str, int] = field(default_factory=dict)   # NAME -> value
    dicts: Dict[str, Dict[str, int]] = field(default_factory=dict)
    homes: Dict[str, str] = field(default_factory=dict)     # NAME -> relpath

    @property
    def names(self):
        """Every registry name a lint-checked salt may reference."""
        return set(self.scalars) | set(self.dicts)


def is_salt_name(name: str) -> bool:
    """Whether an identifier claims membership in the salt registry."""
    return bool(_CONST_NAME.match(name)) and bool(_SALTY.search(name))


def code_registry(repo_root: str) -> CodeRegistry:
    """AST-parse the registry modules for ``NAME = <int>`` (and str->int
    dict) assignments whose name contains FOLD or SALT."""
    reg = CodeRegistry()
    for rel in REGISTRY_MODULES:
        path = os.path.join(repo_root, rel)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            tree = ast.parse(f.read(), filename=rel)
        for node in tree.body:
            if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                continue
            tgt = node.targets[0]
            if not isinstance(tgt, ast.Name) or not is_salt_name(tgt.id):
                continue
            val = node.value
            if isinstance(val, ast.Constant) and isinstance(val.value, int):
                reg.scalars[tgt.id] = val.value
                reg.homes[tgt.id] = rel
            elif isinstance(val, ast.Dict):
                entries = {}
                for k, v in zip(val.keys, val.values):
                    if (isinstance(k, ast.Constant) and isinstance(k.value, str)
                            and isinstance(v, ast.Constant)
                            and isinstance(v.value, int)):
                        entries[k.value] = v.value
                if entries:
                    reg.dicts[tgt.id] = entries
                    reg.homes[tgt.id] = rel
    return reg


def design_table(design_text: str) -> Dict[str, Tuple[int, str]]:
    """Parse the §4 registry table: NAME -> (value, class)."""
    out: Dict[str, Tuple[int, str]] = {}
    for line in design_text.splitlines():
        m = _TABLE_ROW.match(line.strip())
        if m:
            out[m.group(1)] = (int(m.group(2), 0), m.group(3))
    return out


def cross_check(code: CodeRegistry,
                table: Dict[str, Tuple[int, str]]) -> List[str]:
    """Every way the two registries can disagree, as messages (empty =
    in sync). Pure so tests can perturb either side."""
    problems: List[str] = []
    if not table:
        return ["DESIGN.md §4 has no parseable fold/salt registry table "
                "(rows like `| `NAME` | `0x...` | channel | ... |`)"]
    if not code.scalars:
        return ["no fold/salt constants found in the registry modules "
                f"({', '.join(REGISTRY_MODULES)})"]

    for name in sorted(set(code.scalars) - set(table)):
        problems.append(
            f"{code.homes[name]}: constant {name} = "
            f"0x{code.scalars[name]:X} has no DESIGN.md §4 table row — "
            f"register it (value + class) or rename it without FOLD/SALT")
    for name in sorted(set(table) - set(code.scalars)):
        problems.append(
            f"DESIGN.md §4 table row {name} matches no constant in the "
            f"registry modules — stale doc or renamed code constant")
    for name in sorted(set(table) & set(code.scalars)):
        want, _ = table[name]
        got = code.scalars[name]
        if got != want:
            problems.append(
                f"{code.homes[name]}: {name} = 0x{got:X} but DESIGN.md §4 "
                f"spec's 0x{want:X} — renumbering re-keys every stream "
                f"drawn under it")

    by_class: Dict[str, List[Tuple[str, int]]] = {}
    for name, (value, klass) in table.items():
        by_class.setdefault(klass, []).append((name, value))
    for name, value in by_class.get("channel", ()):
        if value < CHANNEL_FLOOR:
            problems.append(
                f"DESIGN.md §4: channel fold {name} = 0x{value:X} is below "
                f"the 0x{CHANNEL_FLOOR:X} reserved floor — it can collide "
                f"with a cluster/leaf/section index")
    for klass, entries in sorted(by_class.items()):
        entries = sorted(entries)
        for i, (a, va) in enumerate(entries):
            for b, vb in entries[i + 1:]:
                if va == vb:
                    problems.append(
                        f"DESIGN.md §4: {klass} salts {a} and {b} collide "
                        f"at 0x{va:X} — their streams are identical")

    for dname, entries in sorted(code.dicts.items()):
        seen: Dict[int, str] = {}
        for k, v in entries.items():
            if v in seen:
                problems.append(
                    f"{code.homes[dname]}: {dname}[{k!r}] collides with "
                    f"{dname}[{seen[v]!r}] at {v}")
            seen[v] = k
    return problems


def check_registry(repo_root: str) -> List[str]:
    """Cross-check the live tree: parse code + the §4 table and diff."""
    # name assembled so the design-ref pass has no bare citation to flag
    design_path = os.path.join(repo_root, "DESIGN" + ".md")
    if not os.path.exists(design_path):
        return ["DESIGN.md does not exist — the §4 registry table is the "
                "normative half of the stream spec"]
    with open(design_path) as f:
        table = design_table(f.read())
    return cross_check(code_registry(repo_root), table)
