"""Declarative HLO buffer/opcode audits (DESIGN.md §3.17).

The slab and sectioned engines' memory claims (no materialized
``f32[C,P]`` slab, no ``(P,)`` flat vector, streams drawn one
``u32[C,CHUNK]`` window at a time — DESIGN.md §3.10/§3.15/§3.16) were
asserted by ad-hoc ``as_text()`` substring checks copy-pasted across
five test modules. This library makes them declarative pin specs:

    pins = [
        forbid_buffer((C, P), note="full slab"),
        require_buffer((C, CHUNK), dtypes=("u32",), note="chunk window"),
        forbid_opcode("dynamic-update-slice"),
    ]
    assert_hlo_pins(lowered.as_text(), pins, context="sectioned fwd")

Buffer matching tokenizes every ``dtype[d0,d1,...]`` shape in the HLO
text with the same parser the roofline cost model uses
(``launch/hlo_cost.py``) — exact dtype + exact dims, layout annotations
ignored. Opcode matching walks the parsed computations (fusion bodies
included). Failures name the pin's note so a tripped memory claim reads
as a claim, not a regex.

New engines get the canned pin sets (``no_slab_pins``,
``no_cluster_stream_pins``, ``cluster_chunk_stream_pin``) for free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Sequence, Set, Tuple

from repro.launch.hlo_cost import (_SHAPE_TOKEN, DTYPE_BYTES, analyze,
                                   parse_hlo)

Shape = Tuple[int, ...]

# forbidding both the f32 payload and a u32 twin catches bit-cast
# reappearances of the same buffer (the sectioned tests always banned
# both)
DEFAULT_DTYPES = ("f32", "u32")


@dataclass(frozen=True)
class BufferPin:
    """A buffer that must (``require``) or must not (``forbid``) appear
    anywhere in the lowered HLO."""
    kind: str                     # "forbid" | "require"
    dtypes: Tuple[str, ...]
    shape: Shape
    note: str = ""

    def __post_init__(self):
        assert self.kind in ("forbid", "require"), self.kind
        for d in self.dtypes:
            assert d in DTYPE_BYTES, f"unknown HLO dtype {d!r}"

    def describe(self) -> str:
        shapes = ", ".join(f"{d}[{','.join(map(str, self.shape))}]"
                           for d in self.dtypes)
        return f"{shapes}" + (f" ({self.note})" if self.note else "")


@dataclass(frozen=True)
class OpcodePin:
    """An HLO opcode that must not appear (e.g. ``dynamic-update-slice``
    — scatter-into-slab)."""
    kind: str
    opcode: str
    note: str = ""

    def __post_init__(self):
        assert self.kind in ("forbid", "require"), self.kind

    def describe(self) -> str:
        return self.opcode + (f" ({self.note})" if self.note else "")


Pin = object  # BufferPin | OpcodePin


def forbid_buffer(shape: Sequence[int],
                  dtypes: Sequence[str] = DEFAULT_DTYPES,
                  note: str = "") -> BufferPin:
    return BufferPin("forbid", tuple(dtypes), tuple(shape), note)


def require_buffer(shape: Sequence[int],
                   dtypes: Sequence[str] = DEFAULT_DTYPES,
                   note: str = "") -> BufferPin:
    return BufferPin("require", tuple(dtypes), tuple(shape), note)


def forbid_opcode(opcode: str, note: str = "") -> OpcodePin:
    return OpcodePin("forbid", opcode, note)


def require_opcode(opcode: str, note: str = "") -> OpcodePin:
    return OpcodePin("require", opcode, note)


def buffer_shapes(hlo: str) -> Set[Tuple[str, Shape]]:
    """Every ``(dtype, dims)`` shape token in the HLO text — same
    tokenizer as the roofline cost model, so one parser serves both."""
    out: Set[Tuple[str, Shape]] = set()
    for dtype, dims in _SHAPE_TOKEN.findall(hlo):
        if dtype not in DTYPE_BYTES:
            continue
        out.add((dtype,
                 tuple(int(d) for d in dims.split(",") if d.strip())))
    return out


def opcodes(hlo: str) -> Set[str]:
    """Opcodes across all computations, fusion bodies included."""
    comps, _ = parse_hlo(hlo)
    return {op.opcode for comp in comps.values() for op in comp.ops}


def audit_hlo(hlo: str, pins: Iterable[Pin]) -> List[str]:
    """Evaluate pins against lowered HLO text; return failure messages
    (empty list = all pins hold)."""
    shapes = buffer_shapes(hlo)
    ops = None
    failures: List[str] = []
    for pin in pins:
        if isinstance(pin, BufferPin):
            hits = [d for d in pin.dtypes if (d, pin.shape) in shapes]
            if pin.kind == "forbid" and hits:
                failures.append(
                    f"forbidden buffer materialized: {pin.describe()} — "
                    f"present as {', '.join(hits)}"
                    f"[{','.join(map(str, pin.shape))}]")
            elif pin.kind == "require" and not hits:
                failures.append(
                    f"required buffer absent: {pin.describe()} — the "
                    f"positive control no longer compiles the expected "
                    f"shape (pin may be vacuous)")
        elif isinstance(pin, OpcodePin):
            if ops is None:
                ops = opcodes(hlo)
            present = pin.opcode in ops
            if pin.kind == "forbid" and present:
                failures.append(
                    f"forbidden opcode present: {pin.describe()}")
            elif pin.kind == "require" and not present:
                failures.append(
                    f"required opcode absent: {pin.describe()}")
        else:
            raise TypeError(f"not a pin: {pin!r}")
    return failures


def assert_hlo_pins(hlo: str, pins: Iterable[Pin], context: str = ""):
    """Raise AssertionError listing every failed pin."""
    failures = audit_hlo(hlo, pins)
    if failures:
        where = f" [{context}]" if context else ""
        raise AssertionError(
            "HLO audit failed" + where + ":\n  " + "\n  ".join(failures))


# ----------------------------------------------------------- canned sets

def no_slab_pins(n_clusters: int, slab_size: int,
                 note: str = "") -> List[Pin]:
    """The §3.10 claim: neither the full (C, P) slab nor a flat (P,)
    vector may materialize."""
    tag = note or "slab"
    return [
        forbid_buffer((n_clusters, slab_size),
                      note=f"full (C, P) {tag}"),
        forbid_buffer((slab_size,), note=f"flat (P,) {tag} vector"),
    ]


def no_cluster_stream_pins(n_clusters: int,
                           lengths: Iterable[int]) -> List[Pin]:
    """The §3.16 claim: no (C, L) per-section cross-cluster buffer for
    any section length L."""
    return [forbid_buffer((n_clusters, int(L)),
                          note=f"(C, {L}) cross-cluster section buffer")
            for L in sorted(set(int(L) for L in lengths))]


def cluster_chunk_stream_pin(n_clusters: int, chunk: int) -> List[Pin]:
    """Positive control for the streaming engines: the per-chunk
    ``u32[C, CHUNK]`` random window IS expected (proves the pins are
    inspecting the real program, not a trivially-empty one)."""
    return [require_buffer((n_clusters, chunk), dtypes=("u32",),
                           note="per-chunk stream window")]
