"""DESIGN.md § citation checker (rule ``design-ref``).

Source files cite design sections as ``DESIGN.md §3.1`` (optionally with
filler in between, e.g. "documented in DESIGN.md §3.5", possibly wrapped
across a docstring line break). This pass greps every citation under the
checked roots, collects the section anchors actually present in
DESIGN.md (headings containing ``§x.y``), and reports the dangling ones.
Bare ``DESIGN.md`` mentions without a § are rejected too — every
citation must be anchorable, or it rots exactly the way the pre-PR-3
tree did.

Historically ``scripts/check_design_refs.py``; now one rule inside
``scripts/repro_lint.py`` (the script remains as a thin wrapper). Tests
and benchmarks are walked by default — §-refs in test docstrings used to
dangle unchecked.
"""
from __future__ import annotations

import os
import re
from typing import List, Sequence

from repro.analysis.lint import Violation

RULE = "design-ref"

DEFAULT_ROOTS = ("src", "tests", "benchmarks")

# assembled so this module's own source carries no bare citation for the
# checker to flag when it walks itself
DESIGN_MD = "DESIGN" + ".md"

# a citation may wrap across a docstring line break between "DESIGN.md"
# and its "§x.y" — tolerate up to ~40 chars of any filler incl. newlines
SECTION = re.compile(
    r"DESIGN\.md((?:(?!DESIGN\.md)[^§]){0,40}?)§([0-9]+(?:\.[0-9]+)*)", re.S)
BARE = re.compile(r"DESIGN\.md(?!(?:(?!DESIGN\.md)[^§]){0,40}§)", re.S)
ANCHOR = re.compile(r"^#+.*§([0-9]+(?:\.[0-9]+)*)", re.M)


def design_anchors(design_text: str) -> set:
    """§x.y anchors present as design-doc headings."""
    return set(ANCHOR.findall(design_text))


def check_file_text(rel: str, text: str, anchors: set) -> List[Violation]:
    """All dangling/bare design-doc citations in one file's text."""
    out: List[Violation] = []
    cited_spans = []
    for m in SECTION.finditer(text):
        cited_spans.append(m.start())
        if m.group(2) not in anchors:
            out.append(Violation(
                rel, text.count("\n", 0, m.start()) + 1, RULE,
                f"cites {DESIGN_MD} §{m.group(2)} but no such heading "
                f"exists"))
    for m in BARE.finditer(text):
        if m.start() not in cited_spans:
            out.append(Violation(
                rel, text.count("\n", 0, m.start()) + 1, RULE,
                f"cites {DESIGN_MD} without a § anchor — point it at a "
                f"section"))
    return out


def check_design_refs(repo_root: str,
                      roots: Sequence[str] = DEFAULT_ROOTS
                      ) -> List[Violation]:
    """Walk the roots and report every unanchorable citation."""
    design_path = os.path.join(repo_root, DESIGN_MD)
    if not os.path.exists(design_path):
        return [Violation(DESIGN_MD, 0, RULE,
                          f"{DESIGN_MD} does not exist")]
    with open(design_path) as f:
        anchors = design_anchors(f.read())

    out: List[Violation] = []
    for root in roots:
        top = os.path.join(repo_root, root)
        if not os.path.isdir(top):
            continue
        for dirpath, dirnames, files in os.walk(top):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            for name in sorted(files):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, repo_root)
                with open(path) as f:
                    text = f.read()
                out.extend(check_file_text(rel, text, anchors))
    return out
