"""Static analysis for the reproduction (DESIGN.md §3.17).

Three layers, all stdlib-only (importable without jax, so the CI lint
job needs no accelerator install):

* :mod:`repro.analysis.lint` — AST passes over the source tree
  (bare fold salts, hard-coded PRNG seeds, Python branches on traced
  ChannelParams/FaultParams fields, import-time platform pins, host
  nondeterminism in ``core/``) with inline
  ``# repro-lint: allow(<rule>, <reason>)`` suppressions.
* :mod:`repro.analysis.stream_registry` — spec↔code cross-check of the
  DESIGN.md §4 reserved fold/salt table against the registry constants
  in ``core/ota.py`` / ``core/hota*.py``.
* :mod:`repro.analysis.hlo_audit` — declarative ``forbid_buffer`` /
  ``require_buffer`` / ``forbid_opcode`` pins over lowered HLO, shared
  by the engine memory-claim tests.

This namespace also re-exports the single HLO text parser
(``parse_hlo`` / ``analyze`` / ``parse_shape_tokens`` from
``launch/hlo_cost.py``) so the audit library and the roofline extractor
stay on one regex dialect.

CLI: ``python scripts/repro_lint.py`` (wired as the CI ``lint`` job).
"""
from repro.analysis.design_refs import DEFAULT_ROOTS, check_design_refs
from repro.analysis.hlo_audit import (BufferPin, OpcodePin, assert_hlo_pins,
                                      audit_hlo, buffer_shapes,
                                      cluster_chunk_stream_pin,
                                      forbid_buffer, forbid_opcode,
                                      no_cluster_stream_pins, no_slab_pins,
                                      opcodes, require_buffer,
                                      require_opcode)
from repro.analysis.lint import (Violation, lint_paths, lint_source,
                                 rules_for_path)
from repro.analysis.stream_registry import (check_registry, code_registry,
                                            cross_check, design_table,
                                            is_salt_name)
from repro.launch.hlo_cost import analyze, parse_hlo
from repro.launch.hlo_cost import parse_shape_tokens  # noqa: F401

__all__ = [
    "DEFAULT_ROOTS", "check_design_refs",
    "BufferPin", "OpcodePin", "assert_hlo_pins", "audit_hlo",
    "buffer_shapes", "cluster_chunk_stream_pin", "forbid_buffer",
    "forbid_opcode", "no_cluster_stream_pins", "no_slab_pins", "opcodes",
    "require_buffer", "require_opcode",
    "Violation", "lint_paths", "lint_source", "rules_for_path",
    "check_registry", "code_registry", "cross_check", "design_table",
    "is_salt_name",
    "analyze", "parse_hlo", "parse_shape_tokens",
]
