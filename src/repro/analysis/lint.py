"""AST lint passes over the source tree (DESIGN.md §3.17).

The invariants this reproduction's correctness rests on — the §4
reserved-fold registry, the traced-vs-static knob discipline that lets
one compiled step serve every scenario, trace-time platform dispatch —
were enforced only by convention and scattered tests. These passes make
them machine-checked:

* ``bare-fold-salt`` — every ``jax.random.fold_in(key, <salt>)`` whose
  salt is a literal (or an UPPERCASE constant not in the §4 registry)
  is flagged. Bare salts *did* collide once (the pre-PR-2 ``fold_in(key,
  999)`` noise stream vs cluster 999); named registry constants are the
  only sanctioned spelling. Runtime indices (lowercase names: cluster,
  leaf_idx, chunk, ...) pass.
* ``bare-prng-seed`` — ``jax.random.PRNGKey(<int literal>)`` outside a
  ``jax.eval_shape`` argument: a hard-coded root seed in library code.
  Shape-only keys under ``eval_shape`` never produce bits and pass.
* ``traced-branch`` — Python ``if``/``while``/ternary/``assert`` on a
  ChannelParams/FaultParams field: traced values must branch through
  ``jnp.where``/``lax.switch``, or the knob silently stops being
  sweepable and one compiled step no longer serves every scenario.
  ``.shape``/``.dtype`` accesses and static-config receivers
  (``fl.…``, ``cfg.…``, ``*Config`` class bodies) are static and pass.
* ``import-time-platform-pin`` — module-scope ``jax.devices()`` /
  ``jax.default_backend()`` / ``on_tpu()``: backend selection after
  import silently pins kernels to the wrong dispatch (the ``_ON_TPU``
  regression PR 6 fixed). Resolve platform at trace time instead.
* ``host-nondeterminism`` — ``time.time`` / ``np.random`` / stdlib
  ``random`` / ``os.urandom`` etc. inside ``core/``: round math must be
  a pure function of (state, batch, key).

Suppression: every exception is documented in place with

    # repro-lint: allow(<rule>, <reason>)

on the offending line or the line above. A suppression without a reason
is itself a violation (``bad-suppression``).
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.stream_registry import is_salt_name

RULE_BARE_FOLD = "bare-fold-salt"
RULE_BARE_SEED = "bare-prng-seed"
RULE_TRACED_BRANCH = "traced-branch"
RULE_PLATFORM_PIN = "import-time-platform-pin"
RULE_HOST_NONDET = "host-nondeterminism"
RULE_SUPPRESSION = "bad-suppression"
RULE_PARSE = "parse-error"

AST_RULES = (RULE_BARE_FOLD, RULE_BARE_SEED, RULE_TRACED_BRANCH,
             RULE_PLATFORM_PIN, RULE_HOST_NONDET)

_SUPPRESS = re.compile(
    r"#\s*repro-lint:\s*allow\(\s*([A-Za-z0-9_-]+)\s*(?:,\s*([^)]*?)\s*)?\)")

# ChannelParams / FaultParams fields (core/channel.py). Branching on one
# of these in Python means the knob is being read statically.
TRACED_FIELDS = frozenset({
    "sigma2", "h_threshold", "noise_std", "ota_on", "fgn_on",
    "dropout", "blackout", "straggler", "staleness", "spike_norm",
    "faults_on",
})
# metadata reads are static even on traced arrays
_STATIC_META = frozenset({"shape", "dtype", "ndim", "size", "weak_type",
                          "sharding", "aval"})
# receivers that hold the STATIC config mirror of these field names
# (FLConfig.sigma2/noise_std/... are frozen Python values, branch freely)
_STATIC_RECEIVERS = frozenset({"fl", "cfg", "config", "tcfg", "mcfg",
                               "flconfig", "base_fl"})

_PLATFORM_CALLS = frozenset({
    "jax.devices", "jax.local_devices", "jax.default_backend",
    "jax.device_count", "jax.local_device_count", "jax.process_index",
})

# host-nondeterminism (exact canonical dotted names after alias
# resolution; "numpy.random." / "random." are prefix bans)
_NONDET_EXACT = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "os.urandom",
    "os.getpid", "uuid.uuid1", "uuid.uuid4", "secrets.token_bytes",
    "secrets.token_hex", "secrets.randbits", "datetime.datetime.now",
    "datetime.datetime.utcnow",
})
_NONDET_PREFIXES = ("numpy.random.", "random.")


@dataclass(frozen=True)
class Violation:
    path: str
    line: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _module_aliases(tree: ast.Module) -> Dict[str, str]:
    """local name -> canonical dotted module/object for top-level-ish
    imports (``import numpy as np`` => np -> numpy)."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _canonical(dotted: str, aliases: Dict[str, str]) -> str:
    head, _, rest = dotted.partition(".")
    head = aliases.get(head, head)
    return f"{head}.{rest}" if rest else head


def _salt_identifiers(expr: ast.AST) -> Tuple[bool, Set[str]]:
    """(has_any_identifier, uppercase identifiers referenced) in a salt
    expression."""
    has_ident = False
    uppers: Set[str] = set()
    for n in ast.walk(expr):
        if isinstance(n, ast.Name):
            has_ident = True
            if is_salt_name(n.id) or n.id.isupper():
                uppers.add(n.id)
        elif isinstance(n, ast.Attribute):
            has_ident = True
            if is_salt_name(n.attr) or n.attr.isupper():
                uppers.add(n.attr)
    return has_ident, uppers


class _FileLinter(ast.NodeVisitor):
    def __init__(self, path: str, tree: ast.Module, registry: Set[str],
                 rules: Set[str]):
        self.path = path
        self.rules = rules
        self.registry = registry
        self.aliases = _module_aliases(tree)
        self.violations: List[Violation] = []
        self._func_depth = 0
        self._class_stack: List[str] = []
        self._call_stack: List[str] = []

    # ---------------------------------------------------------- helpers
    def _flag(self, node: ast.AST, rule: str, message: str):
        if rule in self.rules:
            self.violations.append(
                Violation(self.path, getattr(node, "lineno", 0), rule,
                          message))

    # ------------------------------------------------------------ scope
    def visit_FunctionDef(self, node):
        self._func_depth += 1
        self.generic_visit(node)
        self._func_depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        self._func_depth += 1
        self.generic_visit(node)
        self._func_depth -= 1

    def visit_ClassDef(self, node):
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    # ------------------------------------------------------------ calls
    def visit_Call(self, node: ast.Call):
        dotted = _dotted(node.func)
        if dotted is not None:
            self._check_fold(node, dotted)
            self._check_prngkey(node, dotted)
            self._check_platform(node, dotted)
            self._check_nondet(node, dotted)
        self._call_stack.append(dotted or "")
        self.generic_visit(node)
        self._call_stack.pop()

    def _check_fold(self, node: ast.Call, dotted: str):
        if not (dotted == "fold_in" or dotted.endswith(".fold_in")):
            return
        salt = None
        if len(node.args) >= 2:
            salt = node.args[1]
        else:
            for kw in node.keywords:
                if kw.arg == "data":
                    salt = kw.value
        if salt is None:
            return
        if isinstance(salt, ast.Constant) and isinstance(salt.value, int):
            self._flag(node, RULE_BARE_FOLD,
                       f"bare fold_in salt {salt.value} — register it as a "
                       f"named constant in the DESIGN.md §4 registry "
                       f"(core/ota.py) and fold the NAME, not the number")
            return
        has_ident, uppers = _salt_identifiers(salt)
        if not has_ident:
            self._flag(node, RULE_BARE_FOLD,
                       "fold_in salt computed from literals only — use a "
                       "registered §4 constant")
            return
        for name in sorted(uppers - self.registry):
            self._flag(node, RULE_BARE_FOLD,
                       f"fold_in salt references constant {name} that is "
                       f"not in the DESIGN.md §4 registry — register it "
                       f"in core/ota.py (or core/hota*.py) with a table "
                       f"row")

    def _check_prngkey(self, node: ast.Call, dotted: str):
        if not (dotted.endswith(".PRNGKey") or dotted == "PRNGKey"
                or dotted.endswith("random.key")):
            return
        if not node.args:
            return
        seed = node.args[0]
        if not (isinstance(seed, ast.Constant) and isinstance(seed.value, int)):
            return
        if any(c.endswith("eval_shape") for c in self._call_stack):
            return     # shape-only key: never produces bits
        self._flag(node, RULE_BARE_SEED,
                   f"hard-coded PRNGKey({seed.value}) in library code — "
                   f"thread the caller's key (or wrap in jax.eval_shape "
                   f"if shape-only)")

    def _check_platform(self, node: ast.Call, dotted: str):
        if self._func_depth > 0:
            return
        canon = _canonical(dotted, self.aliases)
        if canon in _PLATFORM_CALLS or dotted.endswith("on_tpu") \
                or canon.endswith(".on_tpu"):
            self._flag(node, RULE_PLATFORM_PIN,
                       f"import-time platform pin {dotted}() at module "
                       f"scope — resolve the backend at trace time "
                       f"(kernels/slab.py on_tpu()); baking it in at "
                       f"import silently pins dispatch (the _ON_TPU "
                       f"regression)")

    def _check_nondet(self, node: ast.Call, dotted: str):
        if RULE_HOST_NONDET not in self.rules:
            return
        canon = _canonical(dotted, self.aliases)
        if canon in _NONDET_EXACT or any(
                canon.startswith(p) for p in _NONDET_PREFIXES):
            self._flag(node, RULE_HOST_NONDET,
                       f"host nondeterminism {dotted}() in core/ — round "
                       f"math must be a pure function of (state, batch, "
                       f"key)")

    # ----------------------------------------------------- traced knobs
    def _check_test_expr(self, node: ast.AST, test: ast.AST, kind: str):
        if any("Config" in c for c in self._class_stack):
            return     # static-config class bodies read their own fields
        parents: Dict[ast.AST, ast.AST] = {}
        for p in ast.walk(test):
            for c in ast.iter_child_nodes(p):
                parents[c] = p
        for n in ast.walk(test):
            if not (isinstance(n, ast.Attribute) and n.attr in TRACED_FIELDS):
                continue
            par = parents.get(n)
            if isinstance(par, ast.Attribute) and par.attr in _STATIC_META:
                continue
            chain: Set[str] = set()
            v = n.value
            while isinstance(v, ast.Attribute):
                chain.add(v.attr)
                v = v.value
            if isinstance(v, ast.Name):
                chain.add(v.id)
            if chain & _STATIC_RECEIVERS:
                continue
            self._flag(n, RULE_TRACED_BRANCH,
                       f"Python {kind} on traced field .{n.attr} — "
                       f"ChannelParams/FaultParams values must branch "
                       f"through jnp.where/lax.switch so one compiled "
                       f"step serves every scenario (DESIGN.md §3.8)")

    def visit_If(self, node):
        self._check_test_expr(node, node.test, "if")
        self.generic_visit(node)

    def visit_While(self, node):
        self._check_test_expr(node, node.test, "while")
        self.generic_visit(node)

    def visit_IfExp(self, node):
        self._check_test_expr(node, node.test, "conditional expression")
        self.generic_visit(node)

    def visit_Assert(self, node):
        self._check_test_expr(node, node.test, "assert")
        self.generic_visit(node)


def _suppressions(source: str, path: str):
    """(line -> {rule}) allowed suppressions + violations for malformed
    ones. A suppression covers its own line and the line below."""
    allowed: Dict[int, Set[str]] = {}
    bad: List[Violation] = []
    for i, line in enumerate(source.splitlines(), start=1):
        for m in _SUPPRESS.finditer(line):
            rule, reason = m.group(1), (m.group(2) or "").strip()
            if not reason:
                bad.append(Violation(
                    path, i, RULE_SUPPRESSION,
                    f"allow({rule}) without a reason — every suppression "
                    f"documents WHY in place: "
                    f"# repro-lint: allow({rule}, <reason>)"))
                continue
            allowed.setdefault(i, set()).add(rule)
            allowed.setdefault(i + 1, set()).add(rule)
    return allowed, bad


def rules_for_path(relpath: str) -> Set[str]:
    """Which AST rules apply to a file. ``host-nondeterminism`` is the
    round-math rule: it binds only inside ``core/``."""
    rules = set(AST_RULES)
    parts = relpath.replace(os.sep, "/").split("/")
    if "core" not in parts:
        rules.discard(RULE_HOST_NONDET)
    return rules


def lint_source(path: str, source: str, registry: Set[str],
                rules: Optional[Set[str]] = None) -> List[Violation]:
    """Run the AST rules over one file's source."""
    if rules is None:
        rules = rules_for_path(path)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Violation(path, e.lineno or 0, RULE_PARSE, str(e.msg))]
    linter = _FileLinter(path, tree, registry, rules)
    linter.visit(tree)
    allowed, bad = _suppressions(source, path)
    kept = [v for v in linter.violations
            if v.rule not in allowed.get(v.line, ())]
    return sorted(kept + bad, key=lambda v: (v.line, v.rule))


def lint_paths(paths: Sequence[str], registry: Set[str],
               repo_root: Optional[str] = None) -> List[Violation]:
    """Lint every ``.py`` file under the given files/directories."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, names in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                files.extend(os.path.join(dirpath, n)
                             for n in sorted(names) if n.endswith(".py"))
        elif p.endswith(".py"):
            files.append(p)
    out: List[Violation] = []
    for path in files:
        rel = (os.path.relpath(path, repo_root)
               if repo_root and os.path.abspath(path).startswith(
                   os.path.abspath(repo_root)) else path)
        with open(path) as f:
            source = f.read()
        out.extend(lint_source(rel, source, registry,
                               rules_for_path(rel)))
    return out
