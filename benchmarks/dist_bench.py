"""Distributed-step benchmark: slab-native vs per-leaf (DESIGN.md §3.10).

Times the FULL Algorithm-1 round of ``make_hota_train_step`` on a forced
multi-device CPU mesh (2 clusters × 2 clients — run.py --dist sets the
host device count before jax imports), per engine:

* ``slab``  — ``use_pallas_ota=True``: whole-model multi-section packed
  gather, fused w·g·M kernel per leaf IN PLACE (zero-copy — no (P,) pack
  copy exists in the backward; pinned by the HLO assertion in
  tests/dist_programs/dist_slab_step.py), ONE psum set, slab-view Adam.
* ``perleaf`` — ``use_pallas_ota=False``: the oracle — per-leaf hooks,
  per-leaf gain draws, 3 psums per leaf, pytree Adam.

Wall times are interpret-mode CPU times, NOT TPU times; the comparison
shows the relative cost of the two formulations at equal math. A third
row drives ``DistScenarioBank`` (S scenarios × the same FL mesh) and
reports per-scenario round time.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _block(x):
    jax.block_until_ready(jax.tree.leaves(x)[0])


def _time_steps(jstep, state, batches, keys, chan=None):
    t0 = time.perf_counter()
    state, _ = jstep(state, *batches[0], keys[0], *(
        () if chan is None else (chan,)))
    _block(state)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for t in range(1, len(batches)):
        state, _ = jstep(state, *batches[t], keys[t], *(
            () if chan is None else (chan,)))
    _block(state)
    steady = (time.perf_counter() - t0) / (len(batches) - 1)
    return compile_s, steady


def dist_rows(smoke: bool = False):
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.common.config import FLConfig, ModelConfig, TrainConfig
    from repro.core.hota_step import make_hota_train_step
    from repro.core.sweep import DistScenarioBank
    from repro.launch.mesh import make_dist_scenario_mesh
    from repro.models.model import build_model
    from repro.models.params import param_count

    C, N, B, D, MAXC = 2, 2, 8, 256, 8
    steps = 2 if smoke else 4
    tcfg = TrainConfig(lr=1e-3)
    rows = []

    mlp = build_model(ModelConfig(family="mlp", compute_dtype="float32"))
    # ~1.3M-param scan-stacked transformer: the structurally
    # representative case — the per-leaf engine pays its per-layer
    # collectives SERIALLY inside the scan backward, the slab engine
    # aggregates the stacked leaves once. The paper MLP (10 large flat
    # leaves) is the per-leaf path's best case and is kept as the
    # adversarial row.
    dense = build_model(ModelConfig(
        family="dense", n_layers=12, d_model=80, n_heads=4, n_kv_heads=4,
        d_ff=320, vocab_size=1024, attn_block_q=16, attn_block_kv=16,
        remat_policy="nothing_saveable", compute_dtype="float32"))
    cases = [("dense1M", dense, "lm"), ("paperMLP", mlp, "cls")]

    mesh = Mesh(np.array(jax.devices())[:C * N].reshape(C, N),
                ("cluster", "client"))
    key = jax.random.PRNGKey(0)
    keys = [jax.random.PRNGKey(100 + t) for t in range(steps + 1)]

    for label, model, loss_kind in cases:
        n_params = (param_count(model.trunk_specs())
                    + param_count(model.final_specs()))
        if loss_kind == "cls":
            xs = [jax.random.normal(jax.random.fold_in(key, 10 + t),
                                    (C * N * B, D)) for t in range(steps + 1)]
            ys = [jax.random.randint(jax.random.fold_in(key, 50 + t),
                                     (C * N * B,), 0, MAXC)
                  for t in range(steps + 1)]
        else:
            xs = [jax.random.randint(jax.random.fold_in(key, 10 + t),
                                     (C * N, 32), 0, 1024)
                  for t in range(steps + 1)]
            ys = [jax.random.randint(jax.random.fold_in(key, 50 + t),
                                     (C * N, 32), 0, 1024)
                  for t in range(steps + 1)]

        results = {}
        for engine, use_slab in (("slab", True), ("perleaf", False)):
            fl = FLConfig(n_clusters=C, n_clients=N, noise_std=0.1,
                          tau_h=1, use_pallas_ota=use_slab)
            init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
                model, mesh, fl, tcfg, loss_kind=loss_kind,
                n_out=MAXC if loss_kind == "cls" else None)
            state = init_fn(jax.random.PRNGKey(123))
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                state, state_specs, is_leaf=lambda z: isinstance(z, P))
            batches = [
                (jax.device_put(x, NamedSharding(mesh, batch_spec[0])),
                 jax.device_put(y, NamedSharding(mesh, batch_spec[1])))
                for x, y in zip(xs, ys)]
            compile_s, steady = _time_steps(jax.jit(step_fn), state,
                                            batches, keys)
            results[engine] = steady
            rows.append((
                f"dist_{engine}_{label}_{n_params // 1000}k",
                steady * 1e6,
                f"compile={compile_s:.1f}s;{C}x{N}mesh" + (
                    ";zero-copy,1 psum set,slab Adam" if use_slab
                    else ";per-leaf oracle")))
        rows.append((
            f"dist_slab_speedup_{label}", 0.0,
            f"steady={results['perleaf'] / results['slab']:.2f}x_vs_perleaf;"
            f"pack_copy=eliminated(zero-copy)"))

        # --- autotuned layout (DESIGN.md §3.13) ---------------------------
        # The proxy calibration sweeps the slab candidates (sections x
        # coalescing threshold) cheaply on the sim's client-folded path;
        # the ENGINE pick then falls to the dist-level measurements
        # themselves (perleaf / slab@0 / slab@tuned are all in hand), so
        # the tuned row is the fastest measured engine — >= 1.0x vs
        # per-leaf by construction, > 1.0x where a coalesced slab layout
        # genuinely wins the round.
        from repro.common.layout_tune import (
            LayoutChoice, apply_layout, layout_of, tune_layout,
        )
        from repro.models.params import abstract_params
        omega_template = {"final": abstract_params(model.final_specs()),
                          "trunk": abstract_params(model.trunk_specs())}
        slab_choice = tune_layout(omega_template, C, N, iters=1,
                                  include_perleaf=False)
        base_fl = FLConfig(n_clusters=C, n_clients=N, noise_std=0.1,
                           tau_h=1)
        candidates = {
            LayoutChoice("perleaf", "toplevel", 0): results["perleaf"],
            layout_of(base_fl): results["slab"],
        }
        if slab_choice not in candidates:
            fl_t = apply_layout(base_fl, slab_choice)
            init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
                model, mesh, fl_t, tcfg, loss_kind=loss_kind,
                n_out=MAXC if loss_kind == "cls" else None)
            state = init_fn(jax.random.PRNGKey(123))
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                state, state_specs, is_leaf=lambda z: isinstance(z, P))
            batches = [
                (jax.device_put(x, NamedSharding(mesh, batch_spec[0])),
                 jax.device_put(y, NamedSharding(mesh, batch_spec[1])))
                for x, y in zip(xs, ys)]
            _, steady_t = _time_steps(jax.jit(step_fn), state, batches,
                                      keys)
            candidates[slab_choice] = steady_t
        tuned_choice = min(candidates, key=candidates.get)
        tuned = candidates[tuned_choice]
        rows.append((
            f"dist_tuned_{label}_{n_params // 1000}k", tuned * 1e6,
            f"layout={tuned_choice.describe()};"
            f"tuned_speedup={results['perleaf'] / tuned:.2f}x_vs_perleaf"))

    # --- 2-D (scenario × client) bank: S scenarios in one compiled step ---
    n_dev = len(jax.devices())
    if n_dev >= 4:
        fl = FLConfig(n_clusters=1, n_clients=2, noise_std=0.1, tau_h=1)
        bank_mesh = make_dist_scenario_mesh(1, 2, n_scenario_devices=2)
        scenarios = [dict(sigma2=(0.5,)), dict(sigma2=(2.0,)),
                     dict(weighting="equal"), dict(ota=False)]
        S = len(scenarios)
        bank = DistScenarioBank(mlp, fl, tcfg, scenarios, bank_mesh,
                                loss_kind="cls", n_out=MAXC)
        xs = [jax.random.normal(jax.random.fold_in(key, 10 + t), (2 * B, D))
              for t in range(steps + 1)]
        ys = [jax.random.randint(jax.random.fold_in(key, 50 + t), (2 * B,),
                                 0, MAXC) for t in range(steps + 1)]
        states = bank.init(jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        states, _ = bank.step(states, xs[0], ys[0], keys[0])
        _block(states)
        compile_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        for t in range(1, steps + 1):
            states, _ = bank.step(states, xs[t], ys[t], keys[t])
        _block(states)
        steady = (time.perf_counter() - t0) / steps
        rows.append((
            f"dist_bank_S{S}_paperMLP_step", steady * 1e6,
            f"compile={compile_s:.1f}s;{steady / S * 1e6:.0f}us/scenario;"
            f"2 scenario rows x (1x2) FL mesh"))
    return rows


if __name__ == "__main__":
    import os
    if "xla_force_host_platform_device_count" not in os.environ.get(
            "XLA_FLAGS", ""):
        raise SystemExit("run via benchmarks/run.py --dist (forces devices)")
    for name, us, note in dist_rows():
        print(f"{name},{us:.0f},{note}")
