"""Shared runner for the paper-reproduction experiments (Figs. 2-4).

Faithful setting (paper Sec. IV): C clusters x N=3 clients, tasks
(modulation-6, signal-8, anomaly-2), synthetic RadComDynamic (DESIGN.md §2),
Table-I MLP, γ=0.6, α=0.008, β=3e-4, Adam everywhere, H_th=3.2e-2,
z ~ N(0,1). "Epoch" on the x-axis = EPOCH_STEPS global iterations.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.sim import HotaSim
from repro.data.federated import FederatedBatcher
from repro.data.radcom import (
    N_CLASSES, RadComConfig, TASKS, client_partition, make_radcom_dataset,
)
from repro.models.model import build_model

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "repro")
EPOCH_STEPS = 10


def run_experiment(
    name: str,
    weighting: str = "fedgradnorm",
    sigma2: Sequence[float] = (),
    steps: int = 800,
    n_clusters: int = 10,
    n_clients: int = 3,
    batch: int = 24,
    seed: int = 0,
    noise_std: float = 1.0,
    ota: bool = True,
    force: bool = False,
    log_every: int = 50,
) -> Dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, name + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    data = make_radcom_dataset(RadComConfig())
    parts = client_partition(data, n_clusters, n_clients, seed=seed)
    batcher = FederatedBatcher(parts, batch, seed=seed + 1)
    n_cls = [N_CLASSES[TASKS[i % 3]] for i in range(n_clients)]

    model = build_model(ModelConfig(family="mlp"))
    fl = FLConfig(n_clusters=n_clusters, n_clients=n_clients,
                  weighting=weighting, sigma2=tuple(sigma2),
                  noise_std=noise_std, ota=ota)
    sim = HotaSim(model, fl, TrainConfig(lr=3e-4), n_cls)
    state = sim.init(jax.random.PRNGKey(seed))

    losses, ps = [], []
    t0 = time.time()
    for step in range(steps):
        x, y = batcher.next_stacked()
        state, m = sim.step(state, jnp.asarray(x), jnp.asarray(y),
                            jax.random.PRNGKey(seed * 7919 + step))
        losses.append(np.asarray(m["loss"]))
        ps.append(np.asarray(m["p"]))
        if step % log_every == 0:
            print(f"  [{name}] step {step}/{steps} "
                  f"loss {losses[-1].mean():.4f} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True)

    losses = np.stack(losses)   # (steps, C, N)
    ps = np.stack(ps)
    result = {
        "name": name, "weighting": weighting, "sigma2": list(sigma2),
        "steps": steps, "epoch_steps": EPOCH_STEPS,
        "tasks": TASKS[:n_clients],
        "loss_cluster0": losses[:, 0, :].tolist(),
        "loss_mean_tasks": losses.mean(axis=1).tolist(),
        "p_cluster0": ps[:, 0, :].tolist(),
        "p_mean": ps.mean(axis=1).tolist(),
        "final_loss_per_task": losses[-EPOCH_STEPS:].mean(axis=(0, 1)).tolist(),
        "auc_loss_per_task": losses.mean(axis=(0, 1)).tolist(),
        "wall_s": time.time() - t0,
    }
    with open(out_path, "w") as f:
        json.dump(result, f)
    return result


def summarize(results: Dict[str, Dict], label: str) -> str:
    lines = [f"== {label} =="]
    for name, r in results.items():
        fl = r["final_loss_per_task"]
        auc = r["auc_loss_per_task"]
        lines.append(
            f"{name:34s} final per task: "
            + " ".join(f"{x:.4f}" for x in fl)
            + "  | auc: " + " ".join(f"{x:.4f}" for x in auc))
    return "\n".join(lines)
