"""Shared runner for the paper-reproduction experiments (Figs. 2-4).

Faithful setting (paper Sec. IV): C clusters x N=3 clients, tasks
(modulation-6, signal-8, anomaly-2), synthetic RadComDynamic (DESIGN.md §2),
Table-I MLP, γ=0.6, α=0.008, β=3e-4, Adam everywhere, H_th=3.2e-2,
z ~ N(0,1). "Epoch" on the x-axis = EPOCH_STEPS global iterations.

Each figure runs as ONE compiled ``ScenarioBank`` sweep (``run_sweep``):
all of its scenarios share a single jit, a single data stream, and common
random numbers — no Python loop over re-jitted sims. ``run_experiment``
remains as the single-scenario convenience wrapper.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FLConfig, ModelConfig
from repro.core.paper_setup import paper_mlp_setup
from repro.core.sweep import ScenarioBank, ShardedScenarioBank
from repro.data.radcom import TASKS

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "repro")
EPOCH_STEPS = 10


def make_bank(sim, specs, sharded=None):
    """Pick the bank flavor for a scenario list: sharded when more than
    one device is visible and the device count divides S evenly (the
    scenario axis goes on the mesh — DESIGN.md §3.8), plain vmap
    otherwise. ``sharded=True/False`` forces the choice."""
    n_dev = len(jax.devices())
    if sharded is None:
        sharded = n_dev > 1 and len(specs) % n_dev == 0
    if sharded:
        return ShardedScenarioBank(sim, specs)
    return ScenarioBank(sim, specs)


def _scenario_result(name: str, spec: Dict, losses: np.ndarray,
                     ps: np.ndarray, steps: int, n_clients: int,
                     wall_s: float, sweep_size: int) -> Dict:
    """Per-scenario JSON payload from (steps, C, N) loss/p trajectories.
    ``wall_s`` is the measured wall time of the WHOLE sweep this scenario
    ran in (shared across its ``sweep_size`` scenarios — divide to
    estimate a per-scenario share)."""
    return {
        "name": name,
        "weighting": spec.get("weighting", "fedgradnorm"),
        "sigma2": list(spec.get("sigma2", ())),
        "steps": steps, "epoch_steps": EPOCH_STEPS,
        "tasks": TASKS[:n_clients],
        "loss_cluster0": losses[:, 0, :].tolist(),
        "loss_mean_tasks": losses.mean(axis=1).tolist(),
        "p_cluster0": ps[:, 0, :].tolist(),
        "p_mean": ps.mean(axis=1).tolist(),
        "final_loss_per_task": losses[-EPOCH_STEPS:].mean(axis=(0, 1)).tolist(),
        "auc_loss_per_task": losses.mean(axis=(0, 1)).tolist(),
        "wall_s": wall_s,
        "sweep_size": sweep_size,
    }


def run_sweep(
    experiments: Dict[str, Dict],
    steps: int = 800,
    n_clusters: int = 10,
    n_clients: int = 3,
    batch: int = 24,
    seed: int = 0,
    force: bool = False,
    log_every: int = 50,
    sharded: Optional[bool] = None,
    tune: bool = True,
    ota_streaming: bool = False,
    ota_sectioned: bool = False,
    max_section_rows: int = 0,
) -> Dict[str, Dict]:
    """Run ALL experiments as one compiled ScenarioBank sweep.

    ``experiments`` maps result-name -> FLConfig channel overrides
    (``weighting``, ``sigma2``, ``noise_std``, ``ota``). Every scenario sees
    the same data stream and per-step keys (common random numbers), which is
    exactly what the old sequential runner did one scenario at a time.
    Results are cached per scenario under RESULTS_DIR. ``sharded`` picks
    the bank flavor (None = auto by device count and S — see make_bank).
    ``tune`` runs the section-layout autotuner (DESIGN.md §3.13) on the
    paper MLP template before the sweep compiles; its calibration is
    persisted (keyed by template hash), so only the first sweep on a
    machine pays for it.

    ``ota_streaming`` / ``ota_sectioned`` / ``max_section_rows`` select
    the §3.15/§3.16 engines for the whole bank (engines are static, so
    they cannot vary per scenario — the bank rejects scenarios that
    try). Never silently inert: ``HotaSim`` raises by name when a flag's
    prerequisites are off. Setting any of them skips the autotuner,
    which would otherwise clobber the explicit engine choice.
    """
    os.makedirs(RESULTS_DIR, exist_ok=True)
    paths = {n: os.path.join(RESULTS_DIR, n + ".json") for n in experiments}
    if not force and all(os.path.exists(p) for p in paths.values()):
        out = {}
        for n, p in paths.items():
            with open(p) as f:
                out[n] = json.load(f)
        return out

    base_fl = FLConfig(n_clusters=n_clusters, n_clients=n_clients,
                       ota_streaming=ota_streaming,
                       ota_sectioned=ota_sectioned,
                       max_section_rows=max_section_rows)
    explicit_engine = ota_streaming or ota_sectioned or bool(max_section_rows)
    if tune and explicit_engine:
        print("  layout: explicit engine flags — autotuner skipped",
              flush=True)
        tune = False
    if tune:
        from repro.common.layout_tune import layout_of, tuned_fl
        from repro.models.model import build_model
        from repro.models.params import abstract_params

        mlp = build_model(ModelConfig(family="mlp"))
        template = {"final": abstract_params(mlp.final_specs()),
                    "trunk": abstract_params(mlp.trunk_specs())}
        base_fl = tuned_fl(base_fl, template)
        print(f"  layout: {layout_of(base_fl).describe()}", flush=True)
    sim, batcher = paper_mlp_setup(base_fl, batch=batch, seed=seed)
    names = list(experiments)
    specs = [dict(experiments[n]) for n in names]
    for sp in specs:
        if "sigma2" in sp:
            sp["sigma2"] = tuple(sp["sigma2"])
    bank = make_bank(sim, specs, sharded=sharded)
    states = bank.init(jax.random.PRNGKey(seed))

    losses, ps = [], []
    t0 = time.time()
    for step in range(steps):
        x, y = batcher.next_stacked()
        states, m = bank.step(states, jnp.asarray(x), jnp.asarray(y),
                              jax.random.PRNGKey(seed * 7919 + step))
        losses.append(np.asarray(m["loss"]))    # (S, C, N)
        ps.append(np.asarray(m["p"]))
        if step % log_every == 0:
            print(f"  [sweep x{bank.n_scenarios}] step {step}/{steps} "
                  f"loss {losses[-1].mean():.4f} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True)
    wall_s = time.time() - t0

    losses = np.stack(losses)   # (steps, S, C, N)
    ps = np.stack(ps)
    out = {}
    for s, name in enumerate(names):
        out[name] = _scenario_result(
            name, specs[s], losses[:, s], ps[:, s], steps, n_clients,
            wall_s, bank.n_scenarios)
        with open(paths[name], "w") as f:
            json.dump(out[name], f)
    return out


def run_experiment(
    name: str,
    weighting: str = "fedgradnorm",
    sigma2: Sequence[float] = (),
    steps: int = 800,
    n_clusters: int = 10,
    n_clients: int = 3,
    batch: int = 24,
    seed: int = 0,
    noise_std: float = 1.0,
    ota: bool = True,
    force: bool = False,
    log_every: int = 50,
) -> Dict:
    """Single-scenario convenience wrapper (a bank of one)."""
    return run_sweep(
        {name: dict(weighting=weighting, sigma2=tuple(sigma2),
                    noise_std=noise_std, ota=ota)},
        steps=steps, n_clusters=n_clusters, n_clients=n_clients,
        batch=batch, seed=seed, force=force, log_every=log_every)[name]


def summarize(results: Dict[str, Dict], label: str) -> str:
    lines = [f"== {label} =="]
    for name, r in results.items():
        fl = r["final_loss_per_task"]
        auc = r["auc_loss_per_task"]
        lines.append(
            f"{name:34s} final per task: "
            + " ".join(f"{x:.4f}" for x in fl)
            + "  | auc: " + " ".join(f"{x:.4f}" for x in auc))
    return "\n".join(lines)
