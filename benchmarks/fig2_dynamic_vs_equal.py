"""Paper Fig. 2: HOTA-FedGradNorm vs naive equal weighting, σ_l² = 1 ∀l.

Claim validated: the dynamic weighting trains FASTER (lower loss at equal
epoch) on most tasks, and the hardest task's weight p rises before its
loss drops (Fig. 2d dynamics).

Both scenarios run as ONE compiled ScenarioBank sweep with common random
numbers — the dynamic-vs-equal contrast is paired by construction.
"""
from __future__ import annotations

import sys

from benchmarks.paper_common import run_sweep, summarize


def run(steps: int = 800, force: bool = False,
        ota_streaming: bool = False, ota_sectioned: bool = False,
        max_section_rows: int = 0):
    # engine kwargs ride through run_sweep to the bank's base FLConfig —
    # they are static, bank-wide, and validated there (never inert)
    results = run_sweep({
        "fig2_hota_fgn": dict(weighting="fedgradnorm"),
        "fig2_equal": dict(weighting="equal"),
    }, steps=steps, force=force, ota_streaming=ota_streaming,
        ota_sectioned=ota_sectioned, max_section_rows=max_section_rows)
    print(summarize(results, "Fig. 2 — dynamic vs equal (sigma²=1)"))
    return results


if __name__ == "__main__":
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 800
    run(steps=steps)
