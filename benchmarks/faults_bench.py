"""Fault-injection benchmark (DESIGN.md §3.14): round throughput vs
dropout rate on the slab-native sim engine.

Two claims measured:

* the fault path's overhead at zero rates — the participation draw, the
  |M∩P| estimator generalization and the guard/freeze select ride the
  same fused round, so enabling the gate should cost a few percent, not
  a re-formulation;
* throughput is FLAT in the dropout rate: rates are traced values
  compared against shared uniforms inside one compiled round, so a
  faultier channel costs the same wall time (the work is masked, not
  skipped at the host).

Rows time ``HotaSim.step`` per round (CPU wall; relative numbers are the
point) for the legacy engine and the faulted engine across dropout
rates, plus one full-blackout row where every round degrades to the
identity step.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp


def _block(x):
    jax.block_until_ready(jax.tree.leaves(x)[0])


def _time_rounds(sim, state, x, y, faults, rounds):
    state, m = sim.step(state, x, y, jax.random.PRNGKey(1), faults=faults)
    _block(state)                       # compile + first round
    t0 = time.perf_counter()
    for r in range(rounds):
        state, m = sim.step(state, x, y, jax.random.PRNGKey(2 + r),
                            faults=faults)
    _block(state)
    per_round = (time.perf_counter() - t0) / rounds
    return per_round, m


def fault_rows(smoke: bool = False):
    from repro.common.config import FLConfig, ModelConfig, TrainConfig
    from repro.core.channel import fault_params
    from repro.core.sim import HotaSim
    from repro.models.model import build_model

    C, N, B = (2, 2, 4) if smoke else (4, 4, 8)
    rounds = 3 if smoke else 10
    model = build_model(ModelConfig(family="mlp"))
    tcfg = TrainConfig(lr=3e-4)
    x = jax.random.normal(jax.random.PRNGKey(1), (C, N, B, 256))
    y = jax.random.randint(jax.random.PRNGKey(2), (C, N, B), 0, 4)

    rows = []

    fl0 = FLConfig(n_clusters=C, n_clients=N, noise_std=0.1)
    sim0 = HotaSim(model, fl0, tcfg, [4] * C)
    per, _ = _time_rounds(sim0, sim0.init(jax.random.PRNGKey(0)), x, y,
                          None, rounds)
    rows.append(("faults_off_baseline", per * 1e6,
                 f"rounds_per_s={1.0 / per:.1f}"))

    fl = dataclasses.replace(fl0, faults=True)
    sim = HotaSim(model, fl, tcfg, [4] * C)
    st0 = sim.init(jax.random.PRNGKey(0))
    for rate in (0.0, 0.25, 0.5):
        fp = fault_params(dataclasses.replace(fl, dropout_rate=rate))
        per, m = _time_rounds(sim, st0, x, y, fp, rounds)
        rows.append((f"faults_dropout_{rate:g}", per * 1e6,
                     f"rounds_per_s={1.0 / per:.1f},"
                     f"participants={float(m['n_participants']):g},"
                     f"skipped={float(m['skipped']):g}"))
    fp = fault_params(dataclasses.replace(fl, blackout_rate=1.0))
    per, m = _time_rounds(sim, st0, x, y, fp, rounds)
    rows.append(("faults_blackout_identity", per * 1e6,
                 f"rounds_per_s={1.0 / per:.1f},"
                 f"skipped={float(m['skipped']):g}"))
    return rows
