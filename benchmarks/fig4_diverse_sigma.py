"""Paper Fig. 4: diverse channel qualities — σ₁² ∈ {2, 0.25}, σ₂² = 0.75,
σ_l² = 1 for l ≥ 3.

Claim validated: HOTA-FedGradNorm is both more robust and faster to train
under heterogeneous channel conditions.

All four (σ₁², weighting) combinations run as ONE compiled ScenarioBank
sweep — a single jit serves the whole figure.
"""
from __future__ import annotations

import sys

from benchmarks.paper_common import run_sweep, summarize


def run(steps: int = 800, force: bool = False,
        ota_streaming: bool = False, ota_sectioned: bool = False,
        max_section_rows: int = 0):
    experiments = {}
    for s1, tag in [(2.0, "s1_2.0"), (0.25, "s1_0.25")]:
        sigma2 = (s1, 0.75) + (1.0,) * 8
        for w in ("fedgradnorm", "equal"):
            experiments[f"fig4_{tag}_{w}"] = dict(weighting=w, sigma2=sigma2)
    results = run_sweep(experiments, steps=steps, force=force,
                        ota_streaming=ota_streaming,
                        ota_sectioned=ota_sectioned,
                        max_section_rows=max_section_rows)
    print(summarize(results, "Fig. 4 — diverse sigma"))
    return results


if __name__ == "__main__":
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 800
    run(steps=steps)
