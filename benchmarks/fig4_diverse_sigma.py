"""Paper Fig. 4: diverse channel qualities — σ₁² ∈ {2, 0.25}, σ₂² = 0.75,
σ_l² = 1 for l ≥ 3.

Claim validated: HOTA-FedGradNorm is both more robust and faster to train
under heterogeneous channel conditions.
"""
from __future__ import annotations

import sys

from benchmarks.paper_common import run_experiment, summarize


def run(steps: int = 800, force: bool = False):
    results = {}
    for s1, tag in [(2.0, "s1_2.0"), (0.25, "s1_0.25")]:
        sigma2 = (s1, 0.75) + (1.0,) * 8
        for w in ("fedgradnorm", "equal"):
            name = f"fig4_{tag}_{w}"
            results[name] = run_experiment(
                name, weighting=w, sigma2=sigma2, steps=steps, force=force)
    print(summarize(results, "Fig. 4 — diverse sigma"))
    return results


if __name__ == "__main__":
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 800
    run(steps=steps)
