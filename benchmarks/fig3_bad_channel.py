"""Paper Fig. 3: one bad channel — σ₁² = 0.5, σ_l² = 1 for l ≥ 2.

Claim validated: a single degraded cluster hurts equal weighting much more
than HOTA-FedGradNorm, which compensates via the channel-masked F_grad.
"""
from __future__ import annotations

import sys

from benchmarks.paper_common import run_experiment, summarize


def run(steps: int = 800, force: bool = False):
    sigma2 = (0.5,) + (1.0,) * 9
    results = {
        "fig3_hota_fgn": run_experiment(
            "fig3_hota_fgn", weighting="fedgradnorm", sigma2=sigma2,
            steps=steps, force=force),
        "fig3_equal": run_experiment(
            "fig3_equal", weighting="equal", sigma2=sigma2, steps=steps,
            force=force),
    }
    print(summarize(results, "Fig. 3 — bad channel sigma1²=0.5"))
    return results


if __name__ == "__main__":
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 800
    run(steps=steps)


def run_harsh(steps: int = 150, force: bool = False):
    """Supplementary: harsher regime where the bad cluster matters —
    C=3 clusters (1/3 of data behind the bad channel), σ₁² = 0.05
    (pass rate ~0.43 at H_th=3.2e-2)."""
    sigma2 = (0.05, 1.0, 1.0)
    results = {
        "fig3b_harsh_hota_fgn": run_experiment(
            "fig3b_harsh_hota_fgn", weighting="fedgradnorm", sigma2=sigma2,
            steps=steps, n_clusters=3, force=force),
        "fig3b_harsh_equal": run_experiment(
            "fig3b_harsh_equal", weighting="equal", sigma2=sigma2,
            steps=steps, n_clusters=3, force=force),
    }
    print(summarize(results, "Fig. 3b — harsh channel sigma1²=0.05, C=3"))
    return results
