"""Paper Fig. 3: one bad channel — σ₁² = 0.5, σ_l² = 1 for l ≥ 2.

Claim validated: a single degraded cluster hurts equal weighting much more
than HOTA-FedGradNorm, which compensates via the channel-masked F_grad.

Both weightings run as ONE compiled ScenarioBank sweep (shared data,
shared channel draws — paired comparison).
"""
from __future__ import annotations

import sys

from benchmarks.paper_common import run_sweep, summarize


def run(steps: int = 800, force: bool = False,
        ota_streaming: bool = False, ota_sectioned: bool = False,
        max_section_rows: int = 0):
    sigma2 = (0.5,) + (1.0,) * 9
    results = run_sweep({
        "fig3_hota_fgn": dict(weighting="fedgradnorm", sigma2=sigma2),
        "fig3_equal": dict(weighting="equal", sigma2=sigma2),
    }, steps=steps, force=force, ota_streaming=ota_streaming,
        ota_sectioned=ota_sectioned, max_section_rows=max_section_rows)
    print(summarize(results, "Fig. 3 — bad channel sigma1²=0.5"))
    return results


if __name__ == "__main__":
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 800
    run(steps=steps)


def run_harsh(steps: int = 150, force: bool = False):
    """Supplementary: harsher regime where the bad cluster matters —
    C=3 clusters (1/3 of data behind the bad channel), σ₁² = 0.05
    (pass rate ~0.43 at H_th=3.2e-2). Separate bank: C differs (static)."""
    sigma2 = (0.05, 1.0, 1.0)
    results = run_sweep({
        "fig3b_harsh_hota_fgn": dict(weighting="fedgradnorm", sigma2=sigma2),
        "fig3b_harsh_equal": dict(weighting="equal", sigma2=sigma2),
    }, steps=steps, n_clusters=3, force=force)
    print(summarize(results, "Fig. 3b — harsh channel sigma1²=0.05, C=3"))
    return results
