"""Section-streaming aggregation bench (DESIGN.md §3.16).

Compares the full-slab client-folded engine against the sectioned
engine — same math, same streams — at three scales:

* the paper MLP (Table I, ~3.9M params, C=10 x N=3): the sectioned
  engine must stay within ~1.3x of client-folded rounds/sec here, i.e.
  section streaming is close to free where the slab already fits;
* 16M params x 64 leaves (the adversarial many-section layout);
* a ~107M-param scan-stacked transformer template at C=2 x N=1: the
  scale where the full-slab working set exceeds the bench's memory
  budget and only the sectioned engine runs a round at all.

Every row reports the engine's ESTIMATED peak aggregation working set
(``repro.common.layout_tune.estimate_peak_slab_bytes`` — C*N packed
gradient blocks + C gain streams + noise + estimate, in LANE-padded
rows). Engines over the budget are reported but not timed — that is the
bench's claim, not a failure: at billion-parameter scale the full-slab
engines cannot run, the sectioned engine is the round path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

# per-case working-set budgets live in the case tables below: the small
# cases get a budget everything fits under (so the sectioned-vs-slab
# rounds/sec comparison exists), the ~100M case gets one only the
# sectioned engine can meet — the bench's claim, demonstrated both ways


def _time(fn, *args, iters=2):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6     # us


def scan_transformer_template(n_layers: int, d_model: int, d_ff: int,
                              vocab: int):
    """Abstract template of a scan-stacked decoder block: per-layer
    params carry a leading (n_layers,) axis — ONE leaf per parameter
    kind, the layout ``jax.lax.scan``-over-layers models produce. The
    top-level trunk groups below are the natural packed sections."""
    L, D, F = n_layers, d_model, d_ff
    sds = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    return {
        "final": {"head": sds(D, vocab)},
        "trunk": {
            "embed": {"w": sds(vocab, D)},
            "attn": {"qkv": sds(L, D, 3 * D), "proj": sds(L, D, D)},
            "mlp": {"up": sds(L, D, F), "down": sds(L, F, D)},
            "norm": {"ln1": sds(L, D), "ln2": sds(L, D), "lnf": sds(D)},
        },
    }


def _grad_tree(template, C: int, N: int, key):
    """Raw (C, N, ...) gradients on the template — the sim's post-local
    state, drawn leaf-by-leaf so no (C, N, P) slab ever materializes."""
    leaves, treedef = jax.tree.flatten(template)
    out = [jax.random.normal(jax.random.fold_in(key, i),
                             (C, N) + tuple(l.shape), jnp.float32)
           for i, l in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


def _paper_mlp_template():
    from repro.common.config import ModelConfig
    from repro.models.model import build_model
    from repro.models.params import ParamSpec

    model = build_model(ModelConfig(family="mlp"))
    specs = {"final": model.final_specs(), "trunk": model.trunk_specs()}
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(tuple(s.shape), jnp.float32),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def _sixteen_m_template(n_leaves: int = 64, n_params: int = 1 << 24):
    final_n = max(128, n_params // 20)
    trunk_n = max(128, (n_params - final_n) // n_leaves)
    sds = lambda n: jax.ShapeDtypeStruct((n,), jnp.float32)
    return {"final": {"w": sds(final_n)},
            "trunk": {f"l{i}": {"w": sds(trunk_n)} for i in range(n_leaves)}}


def section_rows(smoke: bool = False, iters: int = 2):
    """(name, us, derived) rows for the §3.16 engine comparison."""
    from repro.common.layout_tune import (
        LayoutChoice, _budget_section_rows, estimate_peak_slab_bytes,
        packer_for_layout)
    from repro.common.config import FLConfig
    from repro.core import ota
    from repro.core.channel import channel_params

    GiB = 1 << 30
    if smoke:
        iters = 1
        cases = [
            # budget everything fits: the comparison rows must exist
            ("paperMLP_3.9M", _paper_mlp_template(), 10, 3, GiB),
            # structure of the 107M case at CI scale: scan-stacked
            # trunk groups, full slab over the smoke budget
            ("transformer_4M_scan4",
             scan_transformer_template(4, 256, 1024, 2048), 2, 1, 96 << 20),
        ]
    else:
        cases = [
            ("paperMLP_3.9M", _paper_mlp_template(), 10, 3, GiB),
            ("16M_x64leaves", _sixteen_m_template(), 10, 3, 4 * GiB),
            # ~107M params; C=2 x N=1 keeps the INPUT gradients (which
            # every engine shares) under a GiB — the engines differ in
            # the aggregation working set on top of them. The 1 GiB
            # budget is the claim: the full slab cannot meet it.
            ("transformer_107M_scan24",
             scan_transformer_template(24, 512, 2048, 32768), 2, 1, GiB),
        ]

    rows = []
    key = jax.random.PRNGKey(0)
    for label, template, C, N, budget_bytes in cases:
        g = _grad_tree(template, C, N, key)
        p = jax.random.uniform(jax.random.fold_in(key, 99), (C, N),
                               jnp.float32, 0.5, 1.5)
        chan = channel_params(FLConfig(
            n_clusters=C, n_clients=N,
            sigma2=tuple(0.25 + 0.25 * (i % 8) for i in range(C))))

        choices = [
            ("clientfold", LayoutChoice("slab", "toplevel", 0)),
            ("sectioned", LayoutChoice("sectioned", "toplevel", 0)),
        ]
        budget_choice = LayoutChoice("sectioned", "toplevel", 0,
                                     _budget_section_rows(C, N,
                                                          budget_bytes))
        # the budget-split candidate only earns a row when the split
        # actually changes the layout (otherwise it IS the natural
        # sectioned row — no point compiling it twice)
        if (packer_for_layout(template, budget_choice).peak_section_rows()
                < packer_for_layout(template, choices[1][1])
                .peak_section_rows()):
            choices.append(("sectioned_budget", budget_choice))
        timed = {}
        for tag, choice in choices:
            peak = estimate_peak_slab_bytes(template, choice, C, N)
            peak_mb = peak / (1 << 20)
            if peak > budget_bytes:
                rows.append((
                    f"ota_sections_{tag}_{label}", 0.0,
                    f"SKIPPED:peak_slab_mb={peak_mb:.1f} over budget "
                    f"{budget_bytes / (1 << 20):.0f}MB"))
                continue
            packer = packer_for_layout(template, choice)
            if choice.engine == "sectioned":
                fn = jax.jit(lambda k, gg, pp, ch, pk=packer:
                             ota.ota_aggregate_sectioned(
                                 k, gg, pp, ch, N, pk))
            else:
                fn = jax.jit(lambda k, gg, pp, ch, pk=packer:
                             ota.ota_aggregate_client_folded(
                                 k, gg, pp, ch, N, pk))
            us = _time(fn, key, g, p, chan, iters=iters)
            timed[tag] = us
            derived = (f"peak_slab_mb={peak_mb:.1f};"
                       f"rounds_per_s={1e6 / us:.2f}")
            if tag != "clientfold" and "clientfold" in timed:
                derived += (f";vs_clientfold="
                            f"{us / timed['clientfold']:.2f}x")
            rows.append((f"ota_sections_{tag}_{label}", us, derived))
    return rows
