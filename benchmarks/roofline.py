"""Roofline table from the dry-run JSONs (assignment §ROOFLINE, one row per
architecture x input-shape x mesh): the three terms in seconds, the
dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_all():
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(rows=None, mesh_filter=None):
    rows = rows if rows is not None else load_all()
    out = []
    hdr = (f"{'arch':<18} {'shape':<12} {'mesh':<11} {'stat':<8} "
           f"{'compute_s':>10} {'memory_s':>10} {'coll_s':>9} "
           f"{'dominant':>10} {'useful':>7} {'mem_GiB':>8}")
    out.append(hdr)
    out.append("-" * len(hdr))
    for r in rows:
        if mesh_filter and r.get("mesh") != mesh_filter:
            continue
        if r["status"] == "skipped":
            out.append(f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<11} "
                       f"{'skipped':<8} {'—':>10} {'—':>10} {'—':>9} "
                       f"{'—':>10} {'—':>7} {'—':>8}")
            continue
        if r["status"] != "ok":
            out.append(f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<11} "
                       f"{'ERROR':<8} {r.get('error','')[:60]}")
            continue
        rl = r["roofline"]
        mem = r["memory"]["total_bytes"] / 2**30
        out.append(
            f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<11} {'ok':<8} "
            f"{rl['compute_s']:>10.3f} {rl['memory_s']:>10.3f} "
            f"{rl['collective_s']:>9.3f} {rl['dominant']:>10} "
            f"{r['useful_flops_ratio']:>7.3f} {mem:>8.2f}")
    return "\n".join(out)


if __name__ == "__main__":
    print(table())
