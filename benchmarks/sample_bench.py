"""Client-sampling benchmark (DESIGN.md §3.15): round throughput vs
population size at fixed C·N.

Two claims measured:

* rounds/sec is FLAT in the population size: a round's compute is the
  C·N slot view regardless of how many clients the ``ClientBank`` holds
  — the only population-dependent work is the gather/scatter, which is
  O(bank bytes) memory traffic, tiny next to the round itself;
* the streaming aggregator trades the all-C channel materialization for
  a scan at small-C-comparable wall time — the win is peak memory (the
  HLO pin in tests/test_sampling.py), not speed, so the row documents
  the cost of turning it on.

Rows time jitted rounds (CPU wall; relative numbers are the point) for
the plain sim baseline, ``SampledHotaSim`` across populations, and the
``ota_streaming=True`` sim engine.
"""
from __future__ import annotations

import dataclasses
import time

import jax


def _block(x):
    jax.block_until_ready(jax.tree.leaves(x)[0])


def _time_rounds(step, state, x, y, rounds):
    state, m = step(state, x, y, jax.random.PRNGKey(1))
    _block(state)                       # compile + first round
    t0 = time.perf_counter()
    for r in range(rounds):
        state, m = step(state, x, y, jax.random.PRNGKey(2 + r))
    _block(state)
    per_round = (time.perf_counter() - t0) / rounds
    return per_round, m


def sample_rows(smoke: bool = False):
    from repro.common.config import FLConfig, ModelConfig, TrainConfig
    from repro.core.sampling import SampledHotaSim
    from repro.core.sim import HotaSim
    from repro.models.model import build_model

    C, N, B = (2, 2, 4) if smoke else (4, 3, 8)
    rounds = 3 if smoke else 10
    populations = (1, 8, 64) if smoke else (1, 16, 256)
    model = build_model(ModelConfig(family="mlp"))
    tcfg = TrainConfig(lr=3e-4)
    fl = FLConfig(n_clusters=C, n_clients=N, noise_std=0.1)
    x = jax.random.normal(jax.random.PRNGKey(1), (C, N, B, 256))
    y = jax.random.randint(jax.random.PRNGKey(2), (C, N, B), 0, 4)

    rows = []

    sim0 = HotaSim(model, fl, tcfg, [4] * N)
    per0, _ = _time_rounds(sim0.step, sim0.init(jax.random.PRNGKey(0)),
                           x, y, rounds)
    rows.append(("sample_off_baseline", per0 * 1e6,
                 f"rounds_per_s={1.0 / per0:.1f}"))

    for m_pop in populations:
        samp = SampledHotaSim(model, fl, tcfg, [4] * N, population=m_pop)
        per, _ = _time_rounds(samp.step, samp.init(jax.random.PRNGKey(0)),
                              x, y, rounds)
        rows.append((f"sample_population_{m_pop * C * N}", per * 1e6,
                     f"rounds_per_s={1.0 / per:.1f},"
                     f"vs_baseline={per / per0:.2f}x"))

    fl_s = dataclasses.replace(fl, ota_streaming=True)
    sim_s = HotaSim(model, fl_s, tcfg, [4] * N)
    per, _ = _time_rounds(sim_s.step, sim_s.init(jax.random.PRNGKey(0)),
                          x, y, rounds)
    rows.append(("sample_streaming_agg", per * 1e6,
                 f"rounds_per_s={1.0 / per:.1f},"
                 f"vs_baseline={per / per0:.2f}x"))
    return rows
