"""§Perf hillclimb harness: lower+compile a (arch, shape) pair under a
named variant (config/FL overrides), extract roofline terms, cache JSON.

Each variant is one hypothesis -> change -> measure cycle; the comparison
tables in EXPERIMENTS.md §Perf are assembled from results/perf/*.json.

Run inside the dry-run environment (512 host devices):
    PYTHONPATH=src:. python benchmarks/perf_variants.py P0
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import json
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import FLConfig, INPUT_SHAPES, TrainConfig
from repro.configs import ALIASES, get_config
from repro.core.hota_step import make_hota_train_step
from repro.launch import hlo_cost
from repro.launch.dryrun import (
    RESULTS_DIR, TRAIN_ARCH_OVERRIDES, _pick_microbatches,
    hota_state_shardings, lower_serve,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import input_specs
from repro.models.model import build_model
from repro.sharding.mesh_utils import fl_view

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9
PERF_DIR = os.path.join(os.path.dirname(RESULTS_DIR), "perf")


def lower_train_variant(arch: str, shape_name: str, *, cfg_over=None,
                        fl_over=None, n_clients: int = 4):
    cfg = get_config(ALIASES.get(arch, arch)).replace(**TRAIN_ARCH_OVERRIDES)
    if cfg_over:
        cfg = cfg.replace(**cfg_over)
    shape = INPUT_SHAPES[shape_name]
    model = build_model(cfg)
    mesh = fl_view(make_production_mesh(), n_clients)
    n_total = int(np.prod([s for s, a in zip(mesh.devices.shape,
                                             mesh.axis_names)
                           if a in ("pod", "cluster", "client")]))
    fl_kw = dict(n_clients=n_clients, ota_mode="scatter",
                 microbatches=_pick_microbatches(cfg, shape, n_total))
    if fl_over:
        fl_kw.update(fl_over)
    fl = FLConfig(**fl_kw)
    tcfg = TrainConfig(lr=3e-4)
    init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
        model, mesh, fl, tcfg, loss_kind="lm")
    state_abs = jax.eval_shape(init_fn, jax.ShapeDtypeStruct((2,), jnp.uint32))
    state_sh = hota_state_shardings(model, mesh, state_abs)
    ins = input_specs(cfg, shape)
    client_axes = tuple(a for a in mesh.axis_names
                        if a in ("pod", "cluster", "client"))
    tok_sh = NamedSharding(mesh, P(client_axes))
    jf = jax.jit(step_fn, in_shardings=(state_sh, tok_sh, tok_sh,
                                        NamedSharding(mesh, P())))
    return jf.lower(state_abs, ins["tokens"], ins["labels"],
                    jax.ShapeDtypeStruct((2,), jnp.uint32)), fl


def measure(tag: str, lowered, extra: Optional[dict] = None,
            force: bool = False) -> dict:
    os.makedirs(PERF_DIR, exist_ok=True)
    path = os.path.join(PERF_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    compiled = lowered.compile()
    totals = hlo_cost.analyze(compiled.as_text())
    ma = compiled.memory_analysis()
    out = {
        "tag": tag,
        "compile_s": round(time.time() - t0, 1),
        "temp_gib": ma.temp_size_in_bytes / 2**30,
        "arg_gib": ma.argument_size_in_bytes / 2**30,
        "flops": totals.flops,
        "bytes_major": totals.bytes_major,
        "bytes_upper": totals.bytes,
        "collective_bytes": {k: float(v) for k, v in totals.coll_bytes.items()},
        "compute_s": totals.flops / PEAK_FLOPS,
        "memory_s": totals.bytes_major / HBM_BW,
        "collective_s": sum(totals.coll_bytes.values()) / ICI_BW,
        "collective_sites": sorted(
            [{"comp": c, "op": o, "bytes_once": b, "mult": m,
              "total": b * m} for c, o, b, m in totals.coll_detail],
            key=lambda d: -d["total"])[:20],
        **(extra or {}),
    }
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return out


def show(rows):
    print(f"{'tag':<40} {'cmp_s':>8} {'mem_s':>8} {'coll_s':>8} "
          f"{'tempGiB':>8} {'compile':>8}")
    for r in rows:
        print(f"{r['tag']:<40} {r['compute_s']:>8.3f} {r['memory_s']:>8.3f} "
              f"{r['collective_s']:>8.3f} {r['temp_gib']:>8.2f} "
              f"{r['compile_s']:>8.1f}")


def P0():
    """Paper-naive vs scatter OTA transmission (stablelm-3b train_4k)."""
    rows = []
    for mode in ("naive", "scatter"):
        lowered, fl = lower_train_variant(
            "stablelm_3b", "train_4k", fl_over={"ota_mode": mode})
        rows.append(measure(f"P0_stablelm_train4k_{mode}", lowered,
                            {"ota_mode": mode, "microbatches": fl.microbatches}))
    # mb=1: OTA volume is proportionally dominant (no gather amplification)
    for mode in ("naive", "scatter"):
        lowered, fl = lower_train_variant(
            "stablelm_3b", "train_4k",
            fl_over={"ota_mode": mode, "microbatches": 1})
        rows.append(measure(f"P0_stablelm_train4k_{mode}_mb1", lowered,
                            {"ota_mode": mode, "microbatches": 1}))
    show(rows)


def P1():
    """Worst useful-flops pair (musicgen train_4k, ratio 0.044): the causal
    rectangle dominates a small-d model. Variants: folded-causal attention
    (exact triangle), block-size sweep."""
    rows = []
    for tag, cfg_over in [
        ("base_blocked", {}),
        ("folded", {"attn_impl": "folded", "attn_block_q": 512}),
        ("folded_bq256", {"attn_impl": "folded", "attn_block_q": 256}),
        ("blocked_bq1024_bkv4096",
         {"attn_block_q": 1024, "attn_block_kv": 4096}),
    ]:
        lowered, _ = lower_train_variant("musicgen_medium", "train_4k",
                                         cfg_over=cfg_over)
        rows.append(measure(f"P1_musicgen_train4k_{tag}", lowered))
    show(rows)


def P2():
    """Most collective-bound pair: mixtral train_4k (658s — FSDP gathers x
    16 microbatches of a 141B model). Lever: fewer microbatches (memory
    trade) + folded attention to shrink the activation footprint that
    forces mb=16."""
    rows = []
    for tag, cfg_over, fl_over in [
        ("base_mb16", {}, {}),
        ("mb8", {}, {"microbatches": 8}),
        ("mb8_folded", {"attn_impl": "folded"}, {"microbatches": 8}),
        ("mb4_folded", {"attn_impl": "folded"}, {"microbatches": 4}),
    ]:
        lowered, fl = lower_train_variant("mixtral_8x22b", "train_4k",
                                          cfg_over=cfg_over, fl_over=fl_over)
        rows.append(measure(f"P2_mixtral_train4k_{tag}", lowered,
                            {"microbatches": fl.microbatches}))
    show(rows)


def P3():
    """Paper-representative pair (stablelm train_4k = canonical HOTA round):
    cost of the technique itself — full FGN round vs equal/no-FGN ablation
    vs error-free channel; plus the FGN overhead levers."""
    rows = []
    for tag, fl_over in [
        ("hota_full", {}),
        ("equal_tau0_ablation", {"weighting": "equal", "tau_h": 0}),
        ("no_ota_errorfree", {"ota": False}),
        ("tau_h3", {"tau_h": 3}),
    ]:
        lowered, fl = lower_train_variant("stablelm_3b", "train_4k",
                                          fl_over=fl_over)
        rows.append(measure(f"P3_stablelm_train4k_{tag}", lowered,
                            {"fl": {k: str(v) for k, v in fl_over.items()}}))
    show(rows)


if __name__ == "__main__":
    for name in (sys.argv[1:] or ["P0"]):
        globals()[name]()
