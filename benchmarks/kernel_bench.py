"""Kernel microbenchmarks: us/call of each Pallas kernel (interpret mode on
this CPU container — wall times are NOT TPU times; the oracle comparison
shows relative cost of the fused formulation) and of the pure-jnp oracle.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import (
    flash_attention, flash_attention_reference,
    masked_gradnorm, masked_gradnorm_reference,
    ota_channel, ota_channel_reference,
)


def _time(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    x = jax.random.normal(key, (1 << 20,))
    rows.append(("ota_channel_pallas_1M", _time(ota_channel, x, key, 1.0, 0.032),
                 "fused bits->gauss->mask->apply"))
    rows.append(("ota_channel_ref_1M",
                 _time(ota_channel_reference, x, key, 1.0, 0.032),
                 "jnp oracle"))

    g = jax.random.normal(key, (8, 1 << 16))
    m = jax.random.uniform(jax.random.fold_in(key, 1), (1 << 16,)) > 0.3
    rows.append(("masked_gradnorm_pallas_8x64k", _time(masked_gradnorm, g, m),
                 "tiled masked L2"))
    rows.append(("masked_gradnorm_ref_8x64k",
                 _time(masked_gradnorm_reference, g, m), "jnp oracle"))

    q = jax.random.normal(key, (1, 1024, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1024, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, 1024, 2, 64))
    rows.append(("flash_attn_pallas_1k", _time(
        flash_attention, q, k, v, iters=2, block_q=256, block_kv=256),
        "interpret mode"))
    rows.append(("flash_attn_ref_1k", _time(
        flash_attention_reference, q, k, v, iters=2), "jnp oracle"))
    return rows


def sweep_rows(n_scenarios: int = 8, steps: int = 3, n_clusters: int = 10,
               n_clients: int = 3, batch: int = 24):
    """ScenarioBank (one jit, vmap over S scenarios) vs the old sequential
    Python loop (S re-jitted HotaSims) on the paper-scale MLP config.
    Reports steady-state per-round wall time for the WHOLE scenario set and
    total wall including compilation."""
    import dataclasses
    import time as _time_mod

    from repro.common.config import FLConfig, TrainConfig
    from repro.core.paper_setup import paper_mlp_setup
    from repro.core.sim import HotaSim
    from repro.core.sweep import ScenarioBank

    base_fl = FLConfig(n_clusters=n_clusters, n_clients=n_clients)
    sim, batcher = paper_mlp_setup(base_fl, batch=batch, n_points=6000)

    sigmas = [0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.75, 2.0]
    scenarios = [
        dict(sigma2=(sigmas[s % len(sigmas)],),
             weighting="fedgradnorm" if s % 2 == 0 else "equal")
        for s in range(n_scenarios)
    ]
    batches = [[jnp.asarray(a) for a in batcher.next_stacked()]
               for _ in range(steps + 1)]
    keys = [jax.random.PRNGKey(s) for s in range(steps + 1)]

    def _block(x):
        jax.block_until_ready(jax.tree.leaves(x)[0])

    # --- banked: one jit over all scenarios -------------------------------
    bank = ScenarioBank(sim, scenarios)
    t0 = _time_mod.perf_counter()
    states = bank.init(jax.random.PRNGKey(0))
    states, _ = bank.step(states, *batches[0], keys[0])   # compile
    _block(states)
    t_compile_bank = _time_mod.perf_counter() - t0
    t0 = _time_mod.perf_counter()
    for t in range(1, steps + 1):
        states, _ = bank.step(states, *batches[t], keys[t])
    _block(states)
    bank_step = (_time_mod.perf_counter() - t0) / steps
    bank_total = t_compile_bank + bank_step * steps

    # --- sequential: one re-jitted HotaSim per scenario -------------------
    t0 = _time_mod.perf_counter()
    seq_steady = 0.0
    n_cls = [int(c) for c in sim.n_classes]
    for spec in scenarios:
        fl_s = dataclasses.replace(base_fl, **spec)
        sim_s = HotaSim(sim.model, fl_s, TrainConfig(lr=3e-4), n_cls)
        st = sim_s.init(jax.random.PRNGKey(0))
        st, _ = sim_s.step(st, *batches[0], keys[0])      # compile
        _block(st)
        t1 = _time_mod.perf_counter()
        for t in range(1, steps + 1):
            st, _ = sim_s.step(st, *batches[t], keys[t])
        _block(st)
        seq_steady += _time_mod.perf_counter() - t1
    seq_total = _time_mod.perf_counter() - t0
    seq_step = seq_steady / steps

    return [
        (f"sweep_bank_S{n_scenarios}_step", bank_step * 1e6,
         f"total={bank_total:.2f}s(incl compile)"),
        (f"sweep_seq_S{n_scenarios}_step", seq_step * 1e6,
         f"total={seq_total:.2f}s(incl {n_scenarios}x compile)"),
        (f"sweep_speedup_S{n_scenarios}", 0.0,
         f"steady={seq_step/bank_step:.2f}x;"
         f"end_to_end={seq_total/bank_total:.2f}x"),
    ]


if __name__ == "__main__":
    for name, us, note in run() + sweep_rows():
        print(f"{name},{us:.0f},{note}")
