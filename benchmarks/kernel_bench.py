"""Kernel microbenchmarks: us/call of each Pallas kernel (interpret mode on
this CPU container — wall times are NOT TPU times; the oracle comparison
shows relative cost of the fused formulation) and of the pure-jnp oracle.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import (
    flash_attention, flash_attention_reference,
    masked_gradnorm, masked_gradnorm_reference,
    ota_channel, ota_channel_reference,
)


def _time(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    x = jax.random.normal(key, (1 << 20,))
    rows.append(("ota_channel_pallas_1M", _time(ota_channel, x, key, 1.0, 0.032),
                 "fused bits->gauss->mask->apply"))
    rows.append(("ota_channel_ref_1M",
                 _time(ota_channel_reference, x, key, 1.0, 0.032),
                 "jnp oracle"))

    g = jax.random.normal(key, (8, 1 << 16))
    m = jax.random.uniform(jax.random.fold_in(key, 1), (1 << 16,)) > 0.3
    rows.append(("masked_gradnorm_pallas_8x64k", _time(masked_gradnorm, g, m),
                 "tiled masked L2"))
    rows.append(("masked_gradnorm_ref_8x64k",
                 _time(masked_gradnorm_reference, g, m), "jnp oracle"))

    q = jax.random.normal(key, (1, 1024, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1024, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, 1024, 2, 64))
    rows.append(("flash_attn_pallas_1k", _time(
        flash_attention, q, k, v, iters=2, block_q=256, block_kv=256),
        "interpret mode"))
    rows.append(("flash_attn_ref_1k", _time(
        flash_attention_reference, q, k, v, iters=2), "jnp oracle"))
    return rows


if __name__ == "__main__":
    for name, us, note in run():
        print(f"{name},{us:.0f},{note}")
