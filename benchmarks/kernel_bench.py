"""Kernel microbenchmarks: us/call of each Pallas kernel (interpret mode on
this CPU container — wall times are NOT TPU times; the oracle comparison
shows relative cost of the fused formulation) and of the pure-jnp oracle.

``packed_rows`` is the tentpole comparison: the flat-packed whole-model
``ota_aggregate`` (one fused pass) vs the per-leaf jnp path
(``ota.ota_aggregate_tree``, one gain/mask/noise draw per leaf per
cluster), at paper-MLP scale and at 1M/16M params, banked (S scenarios
vmapped over a ChannelParams bank) and unbanked.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import (
    flash_attention, flash_attention_reference,
    masked_gradnorm, masked_gradnorm_reference,
    ota_channel, ota_channel_reference,
)


def _time(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    x = jax.random.normal(key, (1 << 20,))
    rows.append(("ota_channel_pallas_1M", _time(ota_channel, x, key, 1.0, 0.032),
                 "fused bits->gauss->mask->apply"))
    rows.append(("ota_channel_ref_1M",
                 _time(ota_channel_reference, x, key, 1.0, 0.032),
                 "jnp oracle"))

    g = jax.random.normal(key, (8, 1 << 16))
    m = jax.random.uniform(jax.random.fold_in(key, 1), (1 << 16,)) > 0.3
    rows.append(("masked_gradnorm_pallas_8x64k",
                 _time(masked_gradnorm, g, m, impl="pallas"),
                 "tiled masked L2 (impl forced)"))
    rows.append(("masked_gradnorm_dispatch_8x64k",
                 _time(masked_gradnorm, g, m),
                 "default dispatch (jnp off-TPU)"))
    rows.append(("masked_gradnorm_ref_8x64k",
                 _time(masked_gradnorm_reference, g, m), "jnp oracle"))

    q = jax.random.normal(key, (1, 1024, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1024, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, 1024, 2, 64))
    rows.append(("flash_attn_pallas_1k", _time(
        flash_attention, q, k, v, iters=2, block_q=256, block_kv=256),
        "interpret mode"))
    rows.append(("flash_attn_ref_1k", _time(
        flash_attention_reference, q, k, v, iters=2), "jnp oracle"))
    return rows


def _ota_tree(n_params: int, n_leaves: int, C: int, key) -> dict:
    """Synthetic per-cluster weighted-grad pytree: ``n_leaves`` trunk
    leaves + a final leaf of ~5% of the params (the ω̃ tail)."""
    final_n = max(128, n_params // 20)
    trunk_n = max(128, (n_params - final_n) // n_leaves)
    tree = {"final": {"w": jax.random.normal(key, (C, final_n))},
            "trunk": {}}
    for i in range(n_leaves):
        tree["trunk"][f"l{i}"] = jax.random.normal(
            jax.random.fold_in(key, i + 1), (C, trunk_n))
    return tree


def _paper_mlp_tree(C: int, key) -> dict:
    """The real paper-MLP omega shapes (Table I), per-cluster batched."""
    from repro.common.config import ModelConfig
    from repro.models.model import build_model
    from repro.models.params import ParamSpec

    model = build_model(ModelConfig(family="mlp"))
    specs = {"final": model.final_specs(), "trunk": model.trunk_specs()}
    i = [0]

    def draw(spec):
        i[0] += 1
        return jax.random.normal(jax.random.fold_in(key, i[0]),
                                 (C,) + spec.shape)
    return jax.tree.map(draw, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def packed_rows(n_scenarios: int = 8, iters: int = 3, quick: bool = False):
    """Flat-packed kernel vs per-leaf jnp OTA aggregation."""
    from repro.common.config import FLConfig
    from repro.common.flatpack import packer_for
    from repro.core import ota
    from repro.core.channel import channel_params, stack_channel_params

    rows = []
    key = jax.random.PRNGKey(0)
    cases = [
        ("paperMLP_3.9M", None, 10),            # real Table-I shapes
        ("1M_x32leaves", (1 << 20, 32), 10),
        ("16M_x64leaves", (1 << 24, 64), 10),   # (C, P) slab = 640 MB
    ]
    if quick:                                   # CI smoke: small case only
        cases, n_scenarios, iters = cases[:1], min(n_scenarios, 4), 1
    for label, spec, C in cases:
        if spec is None:
            wg = _paper_mlp_tree(C, key)
        else:
            wg = _ota_tree(spec[0], spec[1], C, key)
        n_leaves = len(jax.tree.leaves(wg))
        fl = FLConfig(n_clusters=C, n_clients=3,
                      sigma2=tuple(0.25 + 0.25 * i for i in range(C)))
        chan = channel_params(fl)
        template = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), wg)
        packer = packer_for(template, tail="final")

        f_pack = jax.jit(lambda k, w, ch: ota.ota_aggregate_packed(
            k, w, ch, 3, packer))
        f_leaf = jax.jit(lambda k, w, ch: ota.ota_aggregate_tree(k, w, ch, 3))
        t_pack = _time(f_pack, key, wg, chan, iters=iters)
        t_leaf = _time(f_leaf, key, wg, chan, iters=iters)
        rows.append((f"ota_agg_packed_{label}", t_pack,
                     f"{n_leaves} leaves,C={C};1 fused kernel"))
        rows.append((f"ota_agg_perleaf_{label}", t_leaf,
                     f"jnp per-leaf;packed_speedup={t_leaf / t_pack:.2f}x"))

        # banked: vmap over an (S,)-batched ChannelParams bank (CRN: shared
        # key and weighted grads — the ScenarioBank composition)
        bank = stack_channel_params(
            [channel_params(FLConfig(
                n_clusters=C, n_clients=3,
                sigma2=(0.25 + 0.25 * (s % 8),),
                ota=(s % 4 != 3))) for s in range(n_scenarios)])
        # supplied bits mode = ScenarioBank's composition: the bit draw
        # hoists out of the scenario vmap (it depends only on the shared key)
        fb_pack = jax.jit(jax.vmap(
            lambda ch, k, w: ota.ota_aggregate_packed(
                k, w, ch, 3, packer, bits_mode="supplied"),
            in_axes=(0, None, None)))
        fb_leaf = jax.jit(jax.vmap(
            lambda ch, k, w: ota.ota_aggregate_tree(k, w, ch, 3),
            in_axes=(0, None, None)))
        tb_pack = _time(fb_pack, bank, key, wg, iters=iters)
        tb_leaf = _time(fb_leaf, bank, key, wg, iters=iters)
        rows.append((f"ota_agg_packed_S{n_scenarios}_{label}", tb_pack,
                     "banked vmap"))
        rows.append((f"ota_agg_perleaf_S{n_scenarios}_{label}", tb_leaf,
                     f"packed_speedup={tb_leaf / tb_pack:.2f}x"))
    return rows


def _client_grad_tree(n_params: int, n_leaves: int, C: int, N: int, key):
    """Synthetic RAW per-client gradient pytree — leaves (C, N, ...)."""
    final_n = max(128, n_params // 20)
    trunk_n = max(128, (n_params - final_n) // n_leaves)
    tree = {"final": {"w": jax.random.normal(key, (C, N, final_n))},
            "trunk": {}}
    for i in range(n_leaves):
        tree["trunk"][f"l{i}"] = jax.random.normal(
            jax.random.fold_in(key, i + 1), (C, N, trunk_n))
    return tree


def _paper_mlp_client_tree(C: int, N: int, key) -> dict:
    """The real paper-MLP omega shapes, (cluster, client)-batched."""
    from repro.common.config import ModelConfig
    from repro.models.model import build_model
    from repro.models.params import ParamSpec

    model = build_model(ModelConfig(family="mlp"))
    specs = {"final": model.final_specs(), "trunk": model.trunk_specs()}
    i = [0]

    def draw(spec):
        i[0] += 1
        return jax.random.normal(jax.random.fold_in(key, i[0]),
                                 (C, N) + spec.shape)
    return jax.tree.map(draw, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def client_folded_rows(n_scenarios: int = 8, iters: int = 3,
                       quick: bool = False):
    """Client-folded zero-copy OTA (DESIGN.md §3.12) vs the sim's old
    formulation (einsum the client weights, then per-leaf jnp channel) —
    BOTH paths start from the RAW (C, N, ...) gradient tree + the (C, N)
    weight matrix, i.e. exactly what ``HotaSim.step_with_channel`` holds
    after the local phase. This is the sim-hot-path comparison the old
    ``packed_rows`` (pre-weighted wg input, pack-copy path) could not
    express; those rows stay for the trajectory."""
    from repro.common.config import FLConfig
    from repro.common.flatpack import packer_for
    from repro.core import ota
    from repro.core.channel import channel_params, stack_channel_params

    rows = []
    key = jax.random.PRNGKey(0)
    N = 3
    cases = [
        ("paperMLP_3.9M", None, 10),            # real Table-I shapes
        ("1M_x32leaves", (1 << 20, 32), 10),
        ("16M_x64leaves", (1 << 24, 64), 10),   # raw grads = 1.9 GB
    ]
    if quick:                                   # CI smoke: small case only
        cases, n_scenarios, iters = cases[:1], min(n_scenarios, 4), 1
    for label, spec, C in cases:
        if spec is None:
            g = _paper_mlp_client_tree(C, N, key)
        else:
            g = _client_grad_tree(spec[0], spec[1], C, N, key)
        p = jax.random.uniform(jax.random.fold_in(key, 99), (C, N),
                               jnp.float32, 0.5, 1.5)
        n_leaves = len(jax.tree.leaves(g))
        fl = FLConfig(n_clusters=C, n_clients=N,
                      sigma2=tuple(0.25 + 0.25 * i for i in range(C)))
        chan = channel_params(fl)
        template = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[2:], l.dtype), g)
        packer = packer_for(template, tail="final", sections="toplevel")

        f_fold = jax.jit(lambda k, gg, pp, ch: ota.ota_aggregate_client_folded(
            k, gg, pp, ch, N, packer))
        f_leaf = jax.jit(lambda k, gg, pp, ch: ota.ota_aggregate_tree(
            k, jax.tree.map(
                lambda l: jnp.einsum("cn,cn...->c...", pp, l), gg), ch, N))
        t_fold = _time(f_fold, key, g, p, chan, iters=iters)
        t_leaf = _time(f_leaf, key, g, p, chan, iters=iters)
        rows.append((f"ota_agg_clientfold_{label}", t_fold,
                     f"{n_leaves} leaves,C={C},N={N};zero-copy client fold"))
        rows.append((f"ota_agg_perleaf_raw_{label}", t_leaf,
                     f"einsum+jnp per-leaf;"
                     f"clientfold_speedup={t_leaf / t_fold:.2f}x"))

        # autotuned layout (DESIGN.md §3.13): the calibration sweep picks
        # engine x sections x coalescing threshold; when it picks the
        # per-leaf engine the tuned path IS f_leaf (reuse its time), so
        # the tuned row is >= 1.0x vs per-leaf by construction and > 1.0x
        # exactly where a coalesced slab layout genuinely wins
        from repro.common.layout_tune import packer_for_layout, tune_layout
        choice = tune_layout(template, C, N, iters=max(1, iters - 1))
        if choice.engine == "slab":
            tuned_pk = packer_for_layout(template, choice)
            f_tuned = jax.jit(
                lambda k, gg, pp, ch: ota.ota_aggregate_client_folded(
                    k, gg, pp, ch, N, tuned_pk))
            t_tuned = _time(f_tuned, key, g, p, chan, iters=iters)
        elif choice.engine == "sectioned":
            tuned_pk = packer_for_layout(template, choice)
            f_tuned = jax.jit(
                lambda k, gg, pp, ch: ota.ota_aggregate_sectioned(
                    k, gg, pp, ch, N, tuned_pk))
            t_tuned = _time(f_tuned, key, g, p, chan, iters=iters)
        else:
            t_tuned = t_leaf
        rows.append((f"ota_agg_clientfold_tuned_{label}", t_tuned,
                     f"layout={choice.describe()};"
                     f"tuned_speedup={t_leaf / t_tuned:.2f}x_vs_perleaf"))

        # banked: vmap over an (S,)-batched ChannelParams bank — shared
        # key/grads/weights (CRN); the key-only stream draw hoists out of
        # the scenario vmap by construction
        bank = stack_channel_params(
            [channel_params(FLConfig(
                n_clusters=C, n_clients=N,
                sigma2=(0.25 + 0.25 * (s % 8),),
                ota=(s % 4 != 3))) for s in range(n_scenarios)])
        fb_fold = jax.jit(jax.vmap(
            lambda ch, k, gg, pp: ota.ota_aggregate_client_folded(
                k, gg, pp, ch, N, packer),
            in_axes=(0, None, None, None)))
        fb_leaf = jax.jit(jax.vmap(
            lambda ch, k, gg, pp: ota.ota_aggregate_tree(
                k, jax.tree.map(
                    lambda l: jnp.einsum("cn,cn...->c...", pp, l), gg),
                ch, N),
            in_axes=(0, None, None, None)))
        tb_fold = _time(fb_fold, bank, key, g, p, iters=iters)
        tb_leaf = _time(fb_leaf, bank, key, g, p, iters=iters)
        rows.append((f"ota_agg_clientfold_S{n_scenarios}_{label}", tb_fold,
                     "banked vmap"))
        rows.append((f"ota_agg_perleaf_raw_S{n_scenarios}_{label}", tb_leaf,
                     f"clientfold_speedup={tb_leaf / tb_fold:.2f}x"))
        del g
    return rows


def layout_tune_rows(quick: bool = False, iters: int = 2):
    """The section-layout autotuner's calibration sweep (DESIGN.md
    §3.13), reported as bench rows: one row per candidate layout
    (engine x sections x coalescing threshold) per template, plus the
    chosen LayoutChoice. This is what ``run.py --tune`` emits — the CI
    smoke runs it quick to pin that the sweep executes end to end."""
    from repro.common.layout_tune import calibrate_layout

    rows = []
    key = jax.random.PRNGKey(0)
    N = 3
    cases = [
        ("paperMLP_3.9M", None, 10),
        ("1M_x32leaves", (1 << 20, 32), 10),   # the adversarial layout
    ]
    if quick:
        cases, iters = cases[:1], 1
    for label, spec, C in cases:
        if spec is None:
            g = _paper_mlp_client_tree(C, N, key)
        else:
            g = _client_grad_tree(spec[0], spec[1], C, N, key)
        template = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[2:], l.dtype), g)
        del g
        choice, report = calibrate_layout(template, C, N, iters=iters)
        for entry in report:
            rows.append((f"layout_tune_{label}[{entry['layout']}]",
                         entry["us"], "calibration candidate"))
        rows.append((f"layout_tune_{label}_chosen", 0.0,
                     f"layout={choice.describe()}"))
    return rows


def _time_bank(bank, batches, keys, steps, block):
    """(compile_s, steady_step_s) of one bank flavor over the shared
    batch/key schedule."""
    import time as _time_mod
    t0 = _time_mod.perf_counter()
    states = bank.init(jax.random.PRNGKey(0))
    states, _ = bank.step(states, *batches[0], keys[0])   # compile
    block(states)
    compile_s = _time_mod.perf_counter() - t0
    t0 = _time_mod.perf_counter()
    for t in range(1, steps + 1):
        states, _ = bank.step(states, *batches[t], keys[t])
    block(states)
    return compile_s, (_time_mod.perf_counter() - t0) / steps


def sweep_rows(n_scenarios: int = 8, steps: int = 3, n_clusters: int = 10,
               n_clients: int = 3, batch: int = 24,
               include_sequential: bool = True):
    """ScenarioBank (one jit, vmap over S scenarios) vs ShardedScenarioBank
    (scenario axis on the device mesh) vs the old sequential Python loop
    (S re-jitted HotaSims) on the paper-scale MLP config. Reports
    steady-state per-round wall time for the WHOLE scenario set and total
    wall including compilation. Sharded rows appear only when more than
    one device is visible and the device count divides S (force host
    devices with XLA_FLAGS=--xla_force_host_platform_device_count)."""
    import dataclasses
    import time as _time_mod

    from repro.common.config import FLConfig, TrainConfig
    from repro.core.paper_setup import paper_mlp_setup
    from repro.core.sim import HotaSim
    from repro.core.sweep import ScenarioBank, ShardedScenarioBank

    base_fl = FLConfig(n_clusters=n_clusters, n_clients=n_clients)
    sim, batcher = paper_mlp_setup(base_fl, batch=batch, n_points=6000)

    sigmas = [0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 1.75, 2.0]
    scenarios = [
        dict(sigma2=(sigmas[s % len(sigmas)],),
             weighting="fedgradnorm" if s % 2 == 0 else "equal")
        for s in range(n_scenarios)
    ]
    batches = [[jnp.asarray(a) for a in batcher.next_stacked()]
               for _ in range(steps + 1)]
    keys = [jax.random.PRNGKey(s) for s in range(steps + 1)]

    def _block(x):
        jax.block_until_ready(jax.tree.leaves(x)[0])

    # --- banked: one jit over all scenarios -------------------------------
    t_compile_bank, bank_step = _time_bank(
        ScenarioBank(sim, scenarios), batches, keys, steps, _block)
    bank_total = t_compile_bank + bank_step * steps
    rows = [(f"sweep_bank_S{n_scenarios}_step", bank_step * 1e6,
             f"total={bank_total:.2f}s(incl compile)")]

    # --- sharded: the same jit, scenario axis split across devices --------
    n_dev = len(jax.devices())
    if n_dev > 1 and n_scenarios % n_dev == 0:
        t_compile_sh, sh_step = _time_bank(
            ShardedScenarioBank(sim, scenarios), batches, keys, steps,
            _block)
        sh_total = t_compile_sh + sh_step * steps
        rows += [
            (f"sweep_sharded_S{n_scenarios}_step", sh_step * 1e6,
             f"total={sh_total:.2f}s(incl compile);{n_dev} devices"),
            (f"sweep_sharded_speedup_S{n_scenarios}", 0.0,
             f"steady={bank_step/sh_step:.2f}x_vs_vmap;"
             f"end_to_end={bank_total/sh_total:.2f}x"),
        ]

    # --- sequential: one re-jitted HotaSim per scenario -------------------
    if include_sequential:
        t0 = _time_mod.perf_counter()
        seq_steady = 0.0
        n_cls = [int(c) for c in sim.n_classes]
        for spec in scenarios:
            fl_s = dataclasses.replace(base_fl, **spec)
            sim_s = HotaSim(sim.model, fl_s, TrainConfig(lr=3e-4), n_cls)
            st = sim_s.init(jax.random.PRNGKey(0))
            st, _ = sim_s.step(st, *batches[0], keys[0])      # compile
            _block(st)
            t1 = _time_mod.perf_counter()
            for t in range(1, steps + 1):
                st, _ = sim_s.step(st, *batches[t], keys[t])
            _block(st)
            seq_steady += _time_mod.perf_counter() - t1
        seq_total = _time_mod.perf_counter() - t0
        seq_step = seq_steady / steps
        rows += [
            (f"sweep_seq_S{n_scenarios}_step", seq_step * 1e6,
             f"total={seq_total:.2f}s(incl {n_scenarios}x compile)"),
            (f"sweep_speedup_S{n_scenarios}", 0.0,
             f"steady={seq_step/bank_step:.2f}x;"
             f"end_to_end={seq_total/bank_total:.2f}x"),
        ]
    return rows


if __name__ == "__main__":
    for name, us, note in (run() + packed_rows() + client_folded_rows()
                           + layout_tune_rows() + sweep_rows()):
        print(f"{name},{us:.0f},{note}")
