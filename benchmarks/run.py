"""Benchmark entry point: one function per paper table/figure + the
framework's own harnesses. Prints ``name,us_per_call,derived`` CSV.

Default mode is quick (reads cached results where the full experiments are
long-running; see scripts/run_paper_experiments.sh and
scripts/run_dryrun_sweep.sh for the full passes). ``--full`` recomputes the
paper figures at full length.

One parser, one mode: the row-set selectors (``--kernels``/``--sweep``/
``--tune``/``--faults``/``--sample``/``--dist``/``--sections``) are
mutually exclusive and unknown flags are an ERROR — the old
``parse_known_args`` silently ignored typos like ``--smoke=1`` or a
misspelled mode and ran the wrong (often much longer) benchmark.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _figure_rows(results: dict):
    """Derive the paper's claim metrics from cached loss curves."""
    rows = []
    for name, r in results.items():
        # wall_s covers the whole sweep; divide by sweep size for this
        # scenario's share
        wall_us = (r.get("wall_s", 0.0) / max(r.get("sweep_size", 1), 1)
                   / max(r["steps"], 1) * 1e6)
        auc = sum(r["auc_loss_per_task"]) / len(r["auc_loss_per_task"])
        rows.append((name, wall_us, f"mean_auc_loss={auc:.4f}"))
    return rows


def _print_rows(rows) -> None:
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def _write_rows_json(rows, path: str, merge: bool = False) -> None:
    """Write rows as the perf-trajectory JSON artifact. With ``merge``,
    update an existing artifact by row name — a partial (smoke/tune)
    pass refreshes only the rows it ran and the committed full-size
    rows survive."""
    new = {n: {"name": n, "us_per_call": round(us, 1), "derived": d}
           for n, us, d in rows}
    merged = []
    if merge and os.path.exists(path):
        with open(path) as f:
            merged = [new.pop(row["name"], row)
                      for row in json.load(f).get("rows", [])]
    merged += list(new.values())
    with open(path, "w") as f:
        json.dump({"rows": merged}, f, indent=1)


def _mode_json_path(args, default: str) -> str | None:
    """The JSON artifact path for a non-kernel mode: honor an explicit
    --json PATH; the bare flag's const names the kernel artifact, so
    each mode defaults to its own file instead."""
    if not args.json:
        return None
    return default if args.json == "BENCH_kernels.json" else args.json


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--kernels", action="store_true",
                      help="kernel/packed/sweep rows only (skip paper "
                           "figures and roofline)")
    mode.add_argument("--sweep", action="store_true",
                      help="sweep-engine rows only (sharded vs vmap vs "
                           "sequential banks) on a forced multi-device CPU "
                           "mesh; with --json writes BENCH_sweep.json")
    mode.add_argument("--tune", action="store_true",
                      help="section-layout autotuner rows only (the "
                           "calibration sweep of DESIGN.md §3.13 per bench "
                           "template); with --json merges into "
                           "BENCH_kernels.json by row name")
    mode.add_argument("--faults", action="store_true",
                      help="fault-injection rows only (round throughput vs "
                           "dropout rate on the slab sim engine, DESIGN.md "
                           "§3.14); with --json writes BENCH_faults.json")
    mode.add_argument("--sample", action="store_true",
                      help="client-sampling rows only (round throughput vs "
                           "population size at fixed C*N, plus the "
                           "streaming aggregator, DESIGN.md §3.15); with "
                           "--json writes BENCH_sample.json")
    mode.add_argument("--dist", action="store_true",
                      help="distributed-step rows only (slab-native vs "
                           "per-leaf engines + the 2-D scenario × client "
                           "bank) on a forced 4-device CPU mesh; with "
                           "--json writes BENCH_dist.json")
    mode.add_argument("--sections", action="store_true",
                      help="section-streaming rows only (sectioned vs "
                           "full-slab engines with estimated peak working "
                           "set, DESIGN.md §3.16); with --json writes "
                           "BENCH_sections.json")
    ap.add_argument("--full", action="store_true",
                    help="recompute paper figures at full length")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small fast variant of every row set (CI)")
    ap.add_argument("--sweep-devices", type=int, default=2,
                    help="forced host device count for --sweep (default 2)")
    ap.add_argument("--json", nargs="?", const="BENCH_kernels.json",
                    default=None, metavar="PATH",
                    help="also write the rows to PATH as JSON (default "
                         "BENCH_kernels.json) — the perf trajectory "
                         "artifact; each mode defaults to its own "
                         "BENCH_<mode>.json")
    return ap


def main() -> None:
    args = build_parser().parse_args()

    if args.sweep or args.dist:
        # must land before ANY jax import in this process
        n_dev = 4 if args.dist else args.sweep_devices
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count="
                f"{n_dev}").strip()

    if args.tune:
        # --- section-layout autotuner calibration (DESIGN.md §3.13) ------
        from benchmarks.kernel_bench import layout_tune_rows
        trows = layout_tune_rows(quick=args.smoke,
                                 iters=1 if args.smoke else 2)
        if args.json:
            # merge into the kernel artifact by row name: a tune pass
            # refreshes only its own rows and leaves the committed
            # kernel rows intact
            _write_rows_json(trows, args.json, merge=True)
        _print_rows(trows)
        return

    if args.faults:
        # --- fault injection: rounds/sec vs dropout rate (§3.14) ---------
        from benchmarks.faults_bench import fault_rows
        frows = fault_rows(smoke=args.smoke)
        if args.json:
            _write_rows_json(frows, _mode_json_path(args, "BENCH_faults.json"))
        _print_rows(frows)
        return

    if args.sample:
        # --- client sampling: rounds/sec vs population size (§3.15) ------
        from benchmarks.sample_bench import sample_rows
        srows = sample_rows(smoke=args.smoke)
        if args.json:
            _write_rows_json(srows, _mode_json_path(args, "BENCH_sample.json"))
        _print_rows(srows)
        return

    if args.dist:
        # --- distributed step: slab-native vs per-leaf + 2-D bank --------
        from benchmarks.dist_bench import dist_rows
        drows = dist_rows(smoke=args.smoke)
        if args.json:
            _write_rows_json(drows, _mode_json_path(args, "BENCH_dist.json"))
        _print_rows(drows)
        return

    if args.sections:
        # --- section streaming: sectioned vs full-slab engines (§3.16) ---
        from benchmarks.sections_bench import section_rows
        xrows = section_rows(smoke=args.smoke)
        if args.json:
            _write_rows_json(xrows,
                             _mode_json_path(args, "BENCH_sections.json"),
                             merge=True)
        _print_rows(xrows)
        return

    if args.sweep:
        # --- sweep-engine comparison: sharded vs vmap vs sequential -------
        from benchmarks.kernel_bench import sweep_rows
        s, steps = (8, 2) if args.smoke else (16, 3)
        # smoke (CI) skips the S sequential re-compiles; the full pass
        # keeps all three flavors for BENCH_sweep.json
        srows = sweep_rows(n_scenarios=s, steps=steps,
                           include_sequential=not args.smoke)
        if args.json:
            _write_rows_json(srows, _mode_json_path(args, "BENCH_sweep.json"))
        _print_rows(srows)
        return

    rows = []
    if not args.kernels:
        # --- paper figures (Figs. 2-4) -----------------------------------
        steps = args.steps or (500 if args.full else 40)
        from benchmarks.fig2_dynamic_vs_equal import run as fig2
        from benchmarks.fig3_bad_channel import run as fig3
        from benchmarks.fig4_diverse_sigma import run as fig4
        rows += _figure_rows(fig2(steps=steps))
        rows += _figure_rows(fig3(steps=steps))
        rows += _figure_rows(fig4(steps=steps))

        # claim check: dynamic beats equal on loss-AUC (Fig. 2 headline)
        try:
            from benchmarks.paper_common import RESULTS_DIR
            for fig in ("fig2", "fig3"):
                with open(os.path.join(RESULTS_DIR,
                                       f"{fig}_hota_fgn.json")) as f:
                    dyn = json.load(f)
                with open(os.path.join(RESULTS_DIR, f"{fig}_equal.json")) as f:
                    eq = json.load(f)
                adv = (sum(eq["auc_loss_per_task"])
                       - sum(dyn["auc_loss_per_task"]))
                rows.append((f"{fig}_claim_dynamic_faster", 0.0,
                             f"auc_advantage={adv:+.4f} "
                             f"({'PASS' if adv > 0 else 'CHECK'})"))
        except FileNotFoundError:
            pass

    # --- kernel microbenchmarks ------------------------------------------
    from benchmarks.kernel_bench import client_folded_rows, packed_rows, \
        run as kbench, sweep_rows
    kernel_rows = kbench()

    # --- flat-packed OTA engine vs per-leaf jnp path ----------------------
    kernel_rows += packed_rows(quick=args.smoke)

    # --- client-folded zero-copy sim channel vs einsum+per-leaf ----------
    kernel_rows += client_folded_rows(quick=args.smoke)

    # --- scenario-sweep engine: banked vs sequential ----------------------
    if not args.smoke:
        kernel_rows += sweep_rows()
    rows += kernel_rows

    if args.json:
        # merge by row name into an existing artifact: a --smoke pass
        # refreshes only the rows it actually ran, so the committed
        # full-size rows (1M/16M, banked S=8) survive a local CI-smoke
        # invocation instead of being clobbered by the smaller row set
        _write_rows_json(kernel_rows, args.json, merge=True)

    if not args.kernels:
        # --- roofline table (from cached dry-run JSONs) -------------------
        from benchmarks.roofline import load_all
        dr = load_all()
        ok = [r for r in dr if r["status"] == "ok"]
        skipped = [r for r in dr if r["status"] == "skipped"]
        err = [r for r in dr if r["status"] == "error"]
        rows.append(("dryrun_pairs", 0.0,
                     f"ok={len(ok)} skipped={len(skipped)} error={len(err)} "
                     f"total={len(dr)}"))
        for r in ok:
            rl = r["roofline"]
            rows.append((
                f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
                f"dom={rl['dominant']};c={rl['compute_s']:.3f}s;"
                f"m={rl['memory_s']:.3f}s;coll={rl['collective_s']:.3f}s"))

    _print_rows(rows)


if __name__ == "__main__":
    main()
