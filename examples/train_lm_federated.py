"""End-to-end driver: federated HOTA-FedGradNorm training of a ~100M-param
dense LM for a few hundred rounds on the distributed (shard_map) path.

Topology: 2 clusters x 2 clients x 2-way tensor parallel = 8 host devices.
Each client owns a differently-skewed synthetic token stream (statistical
heterogeneity), personalized output heads, dynamic FedGradNorm weighting,
and the fading-MAC OTA aggregation between cluster ISs and the PS.

    PYTHONPATH=src python examples/train_lm_federated.py --steps 200

(~100M params; on this CPU container a step takes a few seconds — trim
--steps for a quick look. Checkpoints land in results/example_lm/.)
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import save_checkpoint
from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.hota_step import make_hota_train_step
from repro.data.lm import synthetic_lm_batches
from repro.models.model import build_model
from repro.models.params import param_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-per-client", type=int, default=4)
    ap.add_argument("--weighting", default="fedgradnorm")
    ap.add_argument("--out", default="results/example_lm")
    args = ap.parse_args()

    # ~100M-parameter dense GQA transformer
    cfg = ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=640, n_heads=8,
        n_kv_heads=4, d_ff=2560, vocab_size=32_000, compute_dtype="float32",
        remat_policy="none", attn_block_q=64, attn_block_kv=64)
    model = build_model(cfg)
    n_params = param_count({"t": model.trunk_specs()})
    print(f"model: {n_params/1e6:.1f}M shared params")

    devs = np.array(jax.devices())[:8].reshape(2, 2, 2)
    mesh = Mesh(devs, ("cluster", "client", "model"))
    fl = FLConfig(n_clusters=2, n_clients=2, weighting=args.weighting,
                  noise_std=0.5, ota_mode="scatter")
    tcfg = TrainConfig(lr=3e-4)
    init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
        model, mesh, fl, tcfg, loss_kind="lm")

    state = init_fn(jax.random.PRNGKey(0))
    state = jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        state, state_specs, is_leaf=lambda x: isinstance(x, P))

    # per-client skewed streams: different zipf exponents = heterogeneity
    streams = [synthetic_lm_batches(cfg.vocab_size, args.batch_per_client,
                                    args.seq_len, seed=i, zipf_s=1.05 + 0.15 * i)
               for i in range(4)]
    jstep = jax.jit(step_fn)

    t0 = time.time()
    for step in range(args.steps):
        toks, labs = zip(*(next(s) for s in streams))
        toks = jnp.concatenate([jnp.asarray(t) for t in toks])
        labs = jnp.concatenate([jnp.asarray(l) for l in labs])
        toks = jax.device_put(toks, NamedSharding(mesh, batch_spec[0]))
        labs = jax.device_put(labs, NamedSharding(mesh, batch_spec[1]))
        state, m = jstep(state, toks, labs, jax.random.PRNGKey(1))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"round {step:4d} | loss {float(m['loss']):.4f} | "
                  f"p∈[{float(m['p_min']):.3f},{float(m['p_max']):.3f}] | "
                  f"fgrad {float(m['fgrad']):.3f} | "
                  f"{(time.time()-t0)/(step+1):.2f}s/round", flush=True)

    os.makedirs(args.out, exist_ok=True)
    path = save_checkpoint(args.out, args.steps,
                           jax.tree.map(np.asarray, state.omega),
                           {"params_m": n_params / 1e6})
    print("saved shared-network checkpoint:", path)


if __name__ == "__main__":
    main()
