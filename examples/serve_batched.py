"""Batched serving example: prefill a batch of requests, then greedy-decode
continuations with a KV cache — for any assigned architecture's reduced
config (--arch accepts the assignment ids).

    PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x22b
    PYTHONPATH=src python examples/serve_batched.py --arch zamba2-1.2b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, get_smoke_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.model import build_model
from repro.models.params import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prefill-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(ALIASES.get(args.arch, args.arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    backbone = {"trunk": init_params(model.trunk_specs(), key),
                "final": init_params(model.final_specs(),
                                     jax.random.fold_in(key, 7))}
    head = init_params(model.head_specs(), jax.random.fold_in(key, 9))

    prefill = jax.jit(make_prefill_step(
        model, cache_len=args.prefill_len + args.new_tokens + 1))
    decode = jax.jit(make_decode_step(model))

    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (args.batch, args.prefill_len), 0,
                                 cfg.vocab_size)
    print(f"== {cfg.name} ({cfg.family}) | batch={args.batch} "
          f"prefill={args.prefill_len} ==")
    t0 = time.time()
    logits, cache = prefill(backbone, head, prompts)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    print(f"prefill: {time.time()-t0:.2f}s")

    pos = jnp.full((args.batch,), args.prefill_len, jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        tok, _, cache = decode(backbone, head, cache, tok[:, None], pos)
        out.append(tok)
        pos = pos + 1
    dt = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out], 1)
    print(f"decode: {args.new_tokens-1} tokens x {args.batch} reqs in "
          f"{dt:.2f}s ({dt/(args.new_tokens-1)*1000:.0f} ms/step)")
    for b in range(min(args.batch, 3)):
        print(f"  req{b}: {gen[b, :12]} ...")


if __name__ == "__main__":
    main()
