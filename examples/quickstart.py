"""Quickstart: train the paper's exact setting for a few rounds.

HOTA-FedGradNorm (Alg. 1 + 2) on synthetic RadComDynamic with the Table-I
MLP, C=4 clusters x N=3 clients, fading MAC with AWGN, dynamic loss
weights. Runs on CPU in ~a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.sim import HotaSim
from repro.data.federated import FederatedBatcher
from repro.data.radcom import (
    N_CLASSES, RadComConfig, TASKS, client_partition, make_radcom_dataset,
)
from repro.models.model import build_model


def main(steps: int = 60):
    print("== HOTA-FedGradNorm quickstart ==")
    data = make_radcom_dataset(RadComConfig(n_points=20_000))
    parts = client_partition(data, n_clusters=4, n_clients=3)
    batcher = FederatedBatcher(parts, batch=32)
    n_cls = [N_CLASSES[TASKS[i % 3]] for i in range(3)]

    model = build_model(ModelConfig(family="mlp"))
    fl = FLConfig(n_clusters=4, n_clients=3, weighting="fedgradnorm",
                  h_threshold=3.2e-2, noise_std=1.0, gamma=0.6, alpha=8e-3)
    sim = HotaSim(model, fl, TrainConfig(lr=3e-4), n_cls)
    state = sim.init(jax.random.PRNGKey(0))

    for step in range(steps):
        x, y = batcher.next_stacked()
        state, m = sim.step(state, jnp.asarray(x), jnp.asarray(y),
                            jax.random.PRNGKey(step))
        if step % 10 == 0 or step == steps - 1:
            loss = np.asarray(m["loss"]).mean(axis=0)   # per-task mean
            p = np.asarray(m["p"]).mean(axis=0)
            print(f"round {step:3d} | loss per task "
                  f"mod={loss[0]:.3f} sig={loss[1]:.3f} anom={loss[2]:.3f} "
                  f"| p = [{p[0]:.3f} {p[1]:.3f} {p[2]:.3f}]")
    print("done — task weights adapted to task difficulty & channel state.")


def sweep(steps: int = 20):
    """Multi-scenario sweep: 3 channel scenarios, ONE compiled step.

    ScenarioBank batches the traced channel knobs (σ², noise, threshold,
    OTA on/off, weighting) over a leading scenario axis and vmaps the
    simulator across it. Data batches and PRNG keys are shared between
    scenarios (common random numbers), so the comparison is paired.
    """
    print("== 3-scenario ScenarioBank sweep ==")
    from repro.core.paper_setup import paper_mlp_setup
    from repro.core.sweep import ScenarioBank

    base_fl = FLConfig(n_clusters=4, n_clients=3)
    sim, batcher = paper_mlp_setup(base_fl, batch=32, n_points=20_000)
    bank = ScenarioBank(sim, [
        dict(),                                  # fading MAC + FedGradNorm
        dict(weighting="equal"),                 # naive baseline
        dict(sigma2=(0.05, 1.0, 1.0, 1.0)),      # one bad channel
    ])
    labels = ["hota_fgn", "equal", "bad_channel"]

    states = bank.init(jax.random.PRNGKey(0))
    states, history = bank.run(
        states,
        (batcher.next_stacked() for _ in range(steps)),
        [jax.random.PRNGKey(step) for step in range(steps)])
    loss = np.asarray(history["loss"][-1]).mean(axis=(1, 2))   # (S,)
    for lbl, l in zip(labels, loss):
        print(f"  scenario {lbl:12s} mean loss after {steps} rounds: {l:.3f}")
    print("one jit served all scenarios — same data, same channel draws.")


if __name__ == "__main__":
    main()
    sweep()
