"""Regenerate the data-driven sections of EXPERIMENTS.md from results/.

Usage: PYTHONPATH=src:. python scripts/update_experiments.py
Reads results/dryrun/*.json and results/repro/*.json; rewrites the blocks
between the AUTOGEN markers in EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")


def load(pattern):
    out = []
    for p in sorted(glob.glob(os.path.join(ROOT, "results", pattern))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_section() -> str:
    rows = load("dryrun/*.json")
    lines = [
        "| arch | shape | mesh | status | fits ≤16GiB | arg+temp GiB | "
        "compile s | collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skipped | — | — | — | {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"**ERROR** | — | — | — | {r.get('error','')[:60]} |")
            continue
        mem = r["memory"]
        tot = (mem["argument_bytes"] + mem["temp_bytes"]) / 2**30
        fits = "✅" if tot <= 16 else "⚠️"
        coll = ", ".join(f"{k.split('-')[-1][:3]}:{v/2**30:.1f}G"
                         for k, v in sorted(r["collective_bytes"].items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {fits} | "
            f"{tot:.2f} | {r['compile_s']} | {coll} |")
    n_ok = sum(1 for r in rows if r["status"] == "ok")
    n_skip = sum(1 for r in rows if r["status"] == "skipped")
    n_err = len(rows) - n_ok - n_skip
    lines.append("")
    lines.append(f"**{n_ok} ok / {n_skip} skipped (documented) / "
                 f"{n_err} errors, of {len(rows)} recorded runs.**")
    return "\n".join(lines)


def roofline_section() -> str:
    rows = [r for r in load("dryrun/*.json") if r["status"] == "ok"
            and r["mesh"] == "pod16x16"]
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | one-line lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    LEVERS = {
        ("compute",): "raise MXU utilization (bigger per-chip batch, fused attn)",
        ("memory",): "cut HBM traffic: flash-attn keeps S² scores in VMEM; "
                     "fuse channel-mask (ota_channel kernel)",
        ("collective",): "shard-level OTA (defer), 2D-sharded gathers, "
                         "overlap gather with compute",
    }
    for r in rows:
        rl = r["roofline"]
        lever = LEVERS[(rl["dominant"],)]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"**{rl['dominant']}** | {r['useful_flops_ratio']:.3f} | "
            f"{lever} |")
    return "\n".join(lines)


def repro_section() -> str:
    names = ["fig2_hota_fgn", "fig2_equal", "fig3_hota_fgn", "fig3_equal",
             "fig4_s1_2.0_fedgradnorm", "fig4_s1_2.0_equal",
             "fig4_s1_0.25_fedgradnorm", "fig4_s1_0.25_equal"]
    rows = {}
    for n in names:
        p = os.path.join(ROOT, "results", "repro", n + ".json")
        if os.path.exists(p):
            with open(p) as f:
                rows[n] = json.load(f)
    if not rows:
        return "_(experiments still running)_"
    lines = [
        "| run | weighting | σ² pattern | final loss (mod/sig/anom) | "
        "AUC loss (mod/sig/anom) |",
        "|---|---|---|---|---|",
    ]
    for n, r in rows.items():
        fl = "/".join(f"{x:.3f}" for x in r["final_loss_per_task"])
        auc = "/".join(f"{x:.3f}" for x in r["auc_loss_per_task"])
        sig = ",".join(str(s) for s in r["sigma2"][:2]) + ",…" if r["sigma2"] else "all 1"
        lines.append(f"| {n} | {r['weighting']} | {sig} | {fl} | {auc} |")

    # claim verdicts
    lines.append("")
    for fig in ("fig2", "fig3"):
        a, b = rows.get(f"{fig}_hota_fgn"), rows.get(f"{fig}_equal")
        if a and b:
            adv = sum(b["auc_loss_per_task"]) - sum(a["auc_loss_per_task"])
            verdict = "✅ dynamic faster" if adv > 0 else "❌ check"
            lines.append(f"* **{fig} claim**: AUC-loss advantage of dynamic "
                         f"over equal = {adv:+.4f} → {verdict}")
    for tag in ("s1_2.0", "s1_0.25"):
        a = rows.get(f"fig4_{tag}_fedgradnorm")
        b = rows.get(f"fig4_{tag}_equal")
        if a and b:
            adv = sum(b["auc_loss_per_task"]) - sum(a["auc_loss_per_task"])
            verdict = "✅" if adv > 0 else "❌"
            lines.append(f"* **fig4 {tag}**: advantage {adv:+.4f} {verdict}")
    return "\n".join(lines)


def replace_block(text: str, tag: str, content: str) -> str:
    start, end = f"<!-- AUTOGEN:{tag} -->", f"<!-- /AUTOGEN:{tag} -->"
    pattern = re.compile(re.escape(start) + ".*?" + re.escape(end), re.S)
    return pattern.sub(start + "\n" + content + "\n" + end, text)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    text = replace_block(text, "dryrun", dryrun_section())
    text = replace_block(text, "roofline", roofline_section())
    text = replace_block(text, "repro", repro_section())
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
