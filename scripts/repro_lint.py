#!/usr/bin/env python
"""repro-lint: machine-check the tree's invariants (DESIGN.md §3.17).

Runs, in order:

1. the AST lint passes over the given paths (default ``src``):
   bare-fold-salt, bare-prng-seed, traced-branch,
   import-time-platform-pin, host-nondeterminism;
2. the ``stream-registry`` cross-check (DESIGN.md §4 table ↔
   ``core/ota.py``/``core/hota*.py`` constants);
3. the ``design-ref`` citation check over ``src``/``tests``/
   ``benchmarks``.

Every violation prints as ``path:line: rule: message``; exit status is
non-zero iff any violation survived its suppressions. Stdlib-only — no
jax import, safe as a bare CI job.

Usage: python scripts/repro_lint.py [path ...]   (default: src)
"""
from __future__ import annotations

import os
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis.design_refs import check_design_refs          # noqa: E402
from repro.analysis.lint import Violation, lint_paths             # noqa: E402
from repro.analysis.stream_registry import (RULE as REGISTRY_RULE,  # noqa: E402
                                            check_registry,
                                            code_registry)


def main(argv) -> int:
    paths = argv or ["src"]
    paths = [p if os.path.isabs(p) else os.path.join(REPO, p)
             for p in paths]

    registry = code_registry(REPO)
    violations = list(lint_paths(paths, registry.names, repo_root=REPO))
    violations += [Violation("DESIGN.md", 0, REGISTRY_RULE, msg)
                   for msg in check_registry(REPO)]
    violations += check_design_refs(REPO)

    for v in sorted(violations, key=lambda v: (v.path, v.line, v.rule)):
        print(v.format(), file=sys.stderr)
    if violations:
        print(f"repro-lint: {len(violations)} violation(s)",
              file=sys.stderr)
        return 1
    print(f"repro-lint: clean ({len(registry.names)} registered salts, "
          f"all DESIGN.md citations resolve)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
