#!/usr/bin/env python
"""Fail if any ``DESIGN.md §x.y`` citation lacks a matching anchor.

Thin wrapper over :mod:`repro.analysis.design_refs` (the ``design-ref``
rule of ``scripts/repro_lint.py``, which runs this plus the AST lint and
the §4 stream-registry cross-check). Kept as a standalone entry point
for focused runs; walks ``src``, ``tests``, and ``benchmarks`` by
default so §-refs in test docstrings can no longer dangle.

Usage: python scripts/check_design_refs.py [root ...]
"""
from __future__ import annotations

import os
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis.design_refs import (DEFAULT_ROOTS,      # noqa: E402
                                        check_design_refs)


def main(roots) -> int:
    violations = check_design_refs(REPO, roots or DEFAULT_ROOTS)
    for v in violations:
        print(v.format(), file=sys.stderr)
    if violations:
        return 1
    print(f"check_design_refs: all DESIGN.md citations under "
          f"{list(roots or DEFAULT_ROOTS)} resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
