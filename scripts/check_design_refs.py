#!/usr/bin/env python
"""Fail if any ``DESIGN.md §x.y`` citation lacks a matching anchor.

Source files cite design sections as ``DESIGN.md §3.1`` (optionally with
more text in between, e.g. "documented in DESIGN.md §3.5"). This script
greps every citation under the checked roots, collects the section
anchors actually present in DESIGN.md (headings containing ``§x.y``),
and exits non-zero listing the dangling ones. Bare ``DESIGN.md``
mentions without a § are rejected too — every citation must be
anchorable, or it rots exactly the way the pre-PR-3 tree did.

Usage: python scripts/check_design_refs.py [root ...]   (default: src)
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
# a citation may wrap across a docstring line break between "DESIGN.md"
# and its "§x.y" — tolerate up to ~40 chars of any filler incl. newlines
SECTION = re.compile(
    r"DESIGN\.md((?:(?!DESIGN\.md)[^§]){0,40}?)§([0-9]+(?:\.[0-9]+)*)", re.S)
BARE = re.compile(r"DESIGN\.md(?!(?:(?!DESIGN\.md)[^§]){0,40}§)", re.S)
ANCHOR = re.compile(r"^#+.*§([0-9]+(?:\.[0-9]+)*)", re.M)


def main(roots) -> int:
    design_path = os.path.join(REPO, "DESIGN.md")
    if not os.path.exists(design_path):
        print("check_design_refs: DESIGN.md does not exist", file=sys.stderr)
        return 1
    with open(design_path) as f:
        anchors = set(ANCHOR.findall(f.read()))

    dangling, bare = [], []
    for root in roots:
        for dirpath, _, files in os.walk(os.path.join(REPO, root)):
            for name in sorted(files):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, REPO)
                with open(path) as f:
                    text = f.read()
                # scan whole-file text (citations may wrap across lines);
                # recover line numbers from match offsets
                cited_spans = []
                for m in SECTION.finditer(text):
                    cited_spans.append(m.start())
                    if m.group(2) not in anchors:
                        dangling.append(
                            (rel, text.count("\n", 0, m.start()) + 1,
                             m.group(2)))
                for m in BARE.finditer(text):
                    if m.start() not in cited_spans:
                        bare.append(
                            (rel, text.count("\n", 0, m.start()) + 1))

    ok = True
    for rel, lineno, sec in dangling:
        print(f"{rel}:{lineno}: cites DESIGN.md §{sec} but DESIGN.md has "
              f"no such heading", file=sys.stderr)
        ok = False
    for rel, lineno in bare:
        print(f"{rel}:{lineno}: cites DESIGN.md without a § anchor — "
              f"point it at a section", file=sys.stderr)
        ok = False
    if ok:
        print(f"check_design_refs: all DESIGN.md citations under "
              f"{list(roots)} resolve ({len(anchors)} anchors)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or ["src"]))
