"""Million-client rounds (DESIGN.md §3.15): traced client sampling from
a population bank + streaming cluster aggregation.

Covers the SAMPLE_FOLD reserved domain's position-determinism rule
(channel streams are byte-identical across resamples and across
population sizes — the single-round bit-exactness pin), the
gather/scatter bank shell (population-1 ≡ the plain sim, skipped rounds
are bank identities, the f0 first-seen latch), the streaming aggregator's
equivalence to the all-at-once client-folded path (stream bits EXACT,
values equal up to float associativity) and its peak-memory HLO pin (no
(C, section)-sized stream/mask buffer compiles), the |M∩P|/n_eff
estimator properties under composed sampling+faults (monotone coupling
in every rate, full participation bit-equal to the legacy /N path,
zero-participant identity), and the sweep-engine composition
(ScenarioBank over a SampledHotaSim).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import hlo_audit
from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.common.flatpack import packer_for
from repro.core import ota
from repro.core.channel import channel_params, fault_params
from repro.core.sampling import (ClientBank, SampledHotaSim,
                                 gather_clients, init_client_bank,
                                 scatter_clients)

C, N = 2, 2


def _grad_tree(key, c, n, scale=1.0):
    ks = [jax.random.fold_in(key, i) for i in range(6)]
    return {
        "final": {"w": jax.random.normal(ks[0], (c, n, 40, 8)) * scale,
                  "b": jax.random.normal(ks[1], (c, n, 8)) * scale},
        "trunk": {"fc0": {"w": jax.random.normal(ks[2], (c, n, 30, 50)) * scale,
                          "b": jax.random.normal(ks[3], (c, n, 50)) * scale},
                  "fc1": {"w": jax.random.normal(ks[4], (c, n, 50, 40)) * scale,
                          "b": jax.random.normal(ks[5], (c, n, 40)) * scale}},
    }


def _template(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[2:], l.dtype),
                        tree)


def _packer(tree):
    return packer_for(_template(tree), tail="final", sections="toplevel")


def _setup(c=C, n=N, key=11):
    fl = FLConfig(n_clusters=c, n_clients=n,
                  sigma2=tuple(0.5 + 0.5 * i for i in range(c)),
                  noise_std=0.7)
    chan = channel_params(fl)
    k = jax.random.PRNGKey(key)
    g = _grad_tree(jax.random.fold_in(k, 1), c, n)
    p = jax.random.uniform(jax.random.fold_in(k, 2), (c, n), jnp.float32,
                           0.5, 1.5)
    return fl, chan, k, g, p, _packer(g)


@functools.lru_cache(maxsize=None)
def _jitted(c=C, n=N):
    """One compile per (C, N) topology, shared across tests — the eager
    aggregation re-dispatches every interpret-mode kernel per call and
    dominates the suite's runtime otherwise."""
    fl, chan, key, g, p, packer = _setup(c, n)

    def wrap(agg, faulted):
        if faulted:
            return jax.jit(lambda k, gg, pp, lv, ne: agg(
                k, gg, pp, chan, n, packer, live=lv, n_eff=ne))
        return jax.jit(lambda k, gg, pp: agg(k, gg, pp, chan, n, packer))

    return {
        "args": (key, g, p),
        "packer": packer,
        "chan": chan,
        "stream": wrap(ota.ota_aggregate_streaming, False),
        "fold": wrap(ota.ota_aggregate_client_folded, False),
        "stream_f": wrap(ota.ota_aggregate_streaming, True),
        "fold_f": wrap(ota.ota_aggregate_client_folded, True),
        "packed": jax.jit(lambda k, wg: ota.ota_aggregate_packed(
            k, wg, chan, n, packer, bits_mode="supplied")),
    }


# =================================================== streaming aggregator

def test_streaming_matches_client_folded():
    """Same streams, same math: the lax.scan-over-clusters fold equals
    the all-at-once client-folded path. Values agree up to float
    associativity only (the cross-cluster reduction order changes), so
    the bits are pinned EXACTLY (next test) and the values tightly."""
    j = _jitted()
    s = j["stream"](*j["args"])
    c = j["fold"](*j["args"])
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7), s, c)


def test_streaming_matches_client_folded_faulted():
    """Partial participation: dead clusters masked via live, the traced
    n_eff replacing N — both paths implement the same |M∩P|/n_eff
    estimator."""
    j = _jitted()
    live = jnp.asarray([1.0, 0.0])
    n_eff = jnp.float32(1.5)
    s = j["stream_f"](*j["args"], live, n_eff)
    c = j["fold_f"](*j["args"], live, n_eff)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7), s, c)


def test_streaming_stream_bits_exact():
    """The per-cluster streaming draw (stream_range_bits under
    section_gain_key with a traced cluster) is BYTE-identical to the
    corresponding slice of the all-at-once section draw — the chunk-
    truncation rule of DESIGN.md §4, which is what makes resampled /
    streamed rounds consume the same channel."""
    j = _jitted()
    key, packer = j["args"][0], j["packer"]
    folds = ota.packed_section_folds(packer)
    full = ota.section_gain_streams(key, packer, C)       # [(C, L_s)]
    for run in packer.leaf_runs():
        for c in range(C):
            part = ota.stream_range_bits(
                ota.section_gain_key(key, folds[run.section], c),
                run.offset, run.size)
            ref = full[run.section][c, run.offset:run.offset + run.size]
            np.testing.assert_array_equal(
                np.asarray(part), np.asarray(ref),
                err_msg=(f"streaming draw for section {run.section} "
                         f"cluster {c} leaf {run.leaf} diverged from the "
                         f"all-at-once slice"))


def test_streaming_full_participation_bit_equal_legacy():
    """live=1, n_eff=N is BIT-equal to the legacy |M|·N path (live=None)
    — the generalized estimator degrades to eq. 10 exactly, in both the
    streaming and the all-at-once formulation."""
    j = _jitted()
    for plain, faulted in (("stream", "stream_f"), ("fold", "fold_f")):
        a = j[plain](*j["args"])
        b = j[faulted](*j["args"], jnp.ones((C,)), jnp.float32(N))
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), a, b)


def test_streaming_zero_participants_zero_estimate():
    """No live cluster ⇒ the guarded estimator returns exactly 0 on
    every entry (no AWGN-only garbage update) in both paths."""
    j = _jitted()
    for faulted in ("stream_f", "fold_f"):
        out = j[faulted](*j["args"], jnp.zeros((C,)), jnp.float32(0.0))
        for leaf in jax.tree.leaves(out):
            np.testing.assert_array_equal(np.asarray(leaf), 0.0)


def test_streaming_rejects_bad_bits_mode():
    fl, chan, key, g, p, packer = _setup()
    with pytest.raises(ValueError):
        ota.ota_aggregate_streaming(key, g, p, chan, N, packer,
                                    bits_mode="nope")


def test_streaming_hlo_holds_one_cluster():
    """Peak-memory pin: the compiled streaming aggregation contains NO
    (C, L_s) stream/mask buffer for any section and no (C, P) slab — the
    scan body holds one cluster's draw plus the leaf-shaped running sum.
    The all-at-once path compiles exactly such a buffer (positive
    control, so this pin cannot rot into vacuity)."""
    fl, chan, key, g, p, packer = _setup()
    P = packer.size
    lengths = sorted({sec.length for sec in packer.sections})

    def lower(agg):
        return jax.jit(lambda k, gg, pp: agg(
            k, gg, pp, chan, N, packer)).lower(key, g, p).compile().as_text()

    hlo_s = lower(ota.ota_aggregate_streaming)
    hlo_c = lower(ota.ota_aggregate_client_folded)
    hlo_audit.assert_hlo_pins(
        hlo_s,
        hlo_audit.no_cluster_stream_pins(C, lengths + [P, ota.CHUNK]),
        context="streaming aggregation — one-cluster peak (§3.15)")
    hlo_audit.assert_hlo_pins(
        hlo_c, hlo_audit.cluster_chunk_stream_pin(C, ota.CHUNK),
        context="client-folded positive control")


@settings(max_examples=3, deadline=None)
@given(c=st.integers(1, 2), n=st.integers(1, 3))
def test_streaming_triple_equivalence(c, n):
    """sim (client-folded) ≡ streaming ≡ dist (einsum + packed kernel,
    supplied bits) on shared streams, across random (C, N) topologies —
    three formulations of eqs. 3 + 8-10 drawing the same §4 streams."""
    j = _jitted(c, n)
    key, g, p = j["args"]
    s = j["stream"](key, g, p)
    f = j["fold"](key, g, p)
    wg = jax.tree.map(lambda l: jnp.einsum("cn,cn...->c...", p, l), g)
    d = j["packed"](key, wg)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6), s, f)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6), s, d)


# ============================================ participation × sampling

@settings(max_examples=5, deadline=None)
@given(r1=st.floats(0.0, 1.0), r2=st.floats(0.0, 1.0))
def test_participation_monotone_coupling(r1, r2):
    """Shared uniforms ⇒ raising any single rate only removes (or for
    stragglers, only adds) participants — the CRN coupling that makes
    fault sweeps comparable scenario to scenario."""
    lo, hi = min(r1, r2), max(r1, r2)
    key = jax.random.PRNGKey(7)
    base = fault_params(FLConfig(n_clusters=C, n_clients=N, faults=True))
    for knob in ("dropout", "blackout"):
        plo = ota.draw_participation(
            key, base._replace(**{knob: jnp.float32(lo)}), C, N)
        phi = ota.draw_participation(
            key, base._replace(**{knob: jnp.float32(hi)}), C, N)
        assert float(phi.total) <= float(plo.total), (
            f"{knob}: participant count increased with the rate")
        assert bool(jnp.all(phi.part <= plo.part)), (
            f"{knob}: a client joined when the rate rose — coupling broke")
    slo = ota.draw_participation(
        key, base._replace(straggler=jnp.float32(lo)), C, N)
    shi = ota.draw_participation(
        key, base._replace(straggler=jnp.float32(hi)), C, N)
    assert bool(jnp.all(shi.stale >= slo.stale))


@settings(max_examples=5, deadline=None)
@given(c=st.integers(1, 4), n=st.integers(1, 3), m=st.integers(1, 9))
def test_sample_draw_shape_and_determinism(c, n, m):
    """The SAMPLE_FOLD draw is a pure function of the round key: in
    range, dtype-stable, identical across calls, and independent of
    every other stream (it never consumes channel entropy)."""
    key = jax.random.PRNGKey(c * 100 + n * 10 + m)
    ids = ota.draw_client_sample(key, c, n, m)
    assert ids.shape == (c, n) and ids.dtype == jnp.int32
    assert bool(jnp.all((ids >= 0) & (ids < m)))
    np.testing.assert_array_equal(
        np.asarray(ids), np.asarray(ota.draw_client_sample(key, c, n, m)))


# ======================================================== the client bank

def _mk_sampled(fl, population, n_cls=(4, 4)):
    from repro.models.model import build_model
    model = build_model(ModelConfig(family="mlp"))
    return SampledHotaSim(model, fl, TrainConfig(lr=3e-4), list(n_cls),
                          population)


def _sim_batch(c, n, key=None):
    if key is None:
        return (jnp.zeros((c, n, 4, 256)), jnp.zeros((c, n, 4), jnp.int32))
    return (jax.random.normal(jax.random.fold_in(key, 0), (c, n, 4, 256)),
            jax.random.randint(jax.random.fold_in(key, 1), (c, n, 4), 0, 4))


def test_client_bank_init_shapes_and_sentinel():
    fl = FLConfig(n_clusters=2, n_clients=2)
    samp = _mk_sampled(fl, population=5)
    state = samp.init(jax.random.PRNGKey(0))
    for leaf in jax.tree.leaves(state.bank.heads):
        assert leaf.shape[:3] == (2, 2, 5)
    np.testing.assert_array_equal(np.asarray(state.bank.f0),
                                  -np.ones((2, 2, 5), np.float32))


def test_gather_scatter_roundtrip_and_isolation():
    """scatter(gather) is the identity, and a scatter at ids touches NO
    other bank entry — the disjoint-subpopulation guarantee."""
    fl = FLConfig(n_clusters=2, n_clients=2)
    samp = _mk_sampled(fl, population=5)
    bank = samp.init(jax.random.PRNGKey(0)).bank
    ids = jnp.asarray([[4, 0], [2, 2]], jnp.int32)
    heads, head_opt, f0 = gather_clients(bank, ids)
    back = scatter_clients(bank, ids, heads, head_opt, f0)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), bank, back)
    # a real write lands at ids only
    marked = jax.tree.map(lambda l: l + 1.0, heads)
    out = scatter_clients(bank, ids, marked, head_opt, f0)
    leaf0, new0 = (jax.tree.leaves(bank.heads)[0],
                   jax.tree.leaves(out.heads)[0])
    touched = np.zeros((2, 2, 5), bool)
    touched[np.arange(2)[:, None], np.arange(2)[None, :],
            np.asarray(ids)] = True
    diff = np.any(np.asarray(new0 != leaf0).reshape(2, 2, 5, -1), axis=-1)
    np.testing.assert_array_equal(diff, touched)


def test_population_one_round_equals_plain_sim():
    """With M=1 and the bank holding the plain sim's own slot state, a
    sampled round is BIT-identical to the plain round — the
    gather/scatter shell adds nothing to the round math."""
    fl = FLConfig(n_clusters=2, n_clients=2)
    samp = _mk_sampled(fl, population=1)
    key = jax.random.PRNGKey(3)
    sst = samp.init(key)
    plain_state = sst.sim
    bank = ClientBank(
        heads=jax.tree.map(lambda l: l[:, :, None], plain_state.heads),
        head_opt=jax.tree.map(lambda l: l[:, :, None],
                              plain_state.head_opt),
        f0=plain_state.f0[:, :, None])
    sst = sst._replace(bank=bank)
    x, y = _sim_batch(2, 2, jax.random.fold_in(key, 5))
    rk = jax.random.PRNGKey(9)
    new_s, m_s = samp.step(sst, x, y, rk)
    new_p, m_p = samp.sim.step(plain_state, x, y, rk)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), new_s.sim, new_p)
    np.testing.assert_array_equal(np.asarray(m_s["loss"]),
                                  np.asarray(m_p["loss"]))
    np.testing.assert_array_equal(np.asarray(m_s["sample_ids"]),
                                  np.zeros((2, 2), np.int32))


def test_position_determinism_across_populations():
    """THE tentpole pin (DESIGN.md §4, SAMPLE_FOLD): channel and
    participation streams key off the slot position, never the drawn
    ids — so two rounds that gather identical slot state produce
    BIT-identical outputs even though their populations (3 vs 13) and
    drawn ids differ. Growing the population, or resampling, perturbs
    no mask, no AWGN draw, no fault draw."""
    fl = FLConfig(n_clusters=2, n_clients=2)
    key = jax.random.PRNGKey(0)
    sims = [_mk_sampled(fl, population=m) for m in (3, 13)]
    states = [s.init(key) for s in sims]
    # make every member of BOTH banks equal to bank A's member 0, so any
    # drawn id gathers the same slot state
    src = jax.tree.map(lambda l: l[:, :, :1], states[0].bank.heads)
    states = [
        st_._replace(bank=st_.bank._replace(heads=jax.tree.map(
            lambda s, l: jnp.broadcast_to(s, l.shape), src,
            st_.bank.heads)))
        for st_ in states]
    x, y = _sim_batch(2, 2, jax.random.fold_in(key, 5))
    rk = jax.random.PRNGKey(21)
    outs = [s.step(st_, x, y, rk) for s, st_ in zip(sims, states)]
    ids_a, ids_b = (np.asarray(outs[0][1]["sample_ids"]),
                    np.asarray(outs[1][1]["sample_ids"]))
    assert not np.array_equal(ids_a, ids_b), (
        "degenerate test: both populations drew the same ids")
    for field in ("omega", "p", "heads", "f0"):
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=(f"round output {field!r} depends on the drawn "
                         f"ids/population — a stream keyed off the "
                         f"sample draw (DESIGN.md §4 violation)")),
            getattr(outs[0][0].sim, field), getattr(outs[1][0].sim, field))
    np.testing.assert_array_equal(np.asarray(outs[0][1]["loss"]),
                                  np.asarray(outs[1][1]["loss"]))


def test_sampled_f0_latch_and_coverage():
    """Over a few rounds the bank's f0 sentinel flips to a real loss
    exactly for the sampled ids; never-sampled members keep -1."""
    fl = FLConfig(n_clusters=2, n_clients=2)
    samp = _mk_sampled(fl, population=4)
    key = jax.random.PRNGKey(1)
    state = samp.init(key)
    seen = np.zeros((2, 2, 4), bool)
    for r in range(3):
        rk = jax.random.fold_in(key, 100 + r)
        x, y = _sim_batch(2, 2, jax.random.fold_in(rk, 5))
        state, m = samp.step(state, x, y, rk)
        ids = np.asarray(m["sample_ids"])
        seen[np.arange(2)[:, None], np.arange(2)[None, :], ids] = True
        np.testing.assert_array_equal(
            np.asarray(ids), np.asarray(ota.draw_client_sample(
                rk, 2, 2, 4)))
    f0 = np.asarray(state.bank.f0)
    assert np.all(f0[seen] >= 0.0), "a sampled client kept the sentinel"
    assert np.all(f0[~seen] == -1.0), "an unsampled client's f0 moved"


def test_sampled_skip_round_is_bank_identity():
    """dropout=1 ⇒ zero participants ⇒ the round degrades to a bit-exact
    identity on the BANK too (the frozen slot state scatters back
    unchanged), and the skip is reported."""
    fl = FLConfig(n_clusters=2, n_clients=2, faults=True,
                  dropout_rate=1.0)
    samp = _mk_sampled(fl, population=3)
    key = jax.random.PRNGKey(2)
    state = samp.init(key)
    x, y = _sim_batch(2, 2, jax.random.fold_in(key, 5))
    new, m = samp.step(state, x, y, jax.random.PRNGKey(7))
    assert float(m["skipped"]) == 1.0
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state.bank, new.bank)


def test_sim_streaming_gate():
    """fl.ota_streaming=True swaps the sim's aggregation for the
    streaming fold — same streams, so the round agrees with the default
    path to float-associativity tolerance; and the gate composes with
    sampling."""
    key = jax.random.PRNGKey(4)
    x, y = _sim_batch(2, 2, jax.random.fold_in(key, 5))
    rk = jax.random.PRNGKey(6)
    outs = {}
    for streaming in (False, True):
        fl = FLConfig(n_clusters=2, n_clients=2, ota_streaming=streaming)
        samp = _mk_sampled(fl, population=3)
        outs[streaming] = samp.sim.step(samp.sim.init(key), x, y, rk)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        outs[False][0].omega, outs[True][0].omega)
    np.testing.assert_allclose(np.asarray(outs[False][1]["loss"]),
                               np.asarray(outs[True][1]["loss"]),
                               rtol=1e-5, atol=1e-6)
    # sampled + streaming runs end to end
    fl = FLConfig(n_clusters=2, n_clients=2, ota_streaming=True)
    samp = _mk_sampled(fl, population=3)
    state = samp.init(key)
    state, m = samp.step(state, x, y, rk)
    assert np.isfinite(np.asarray(m["loss"])).all()


def test_scenario_bank_over_sampled_sim():
    """The sweep engine composes with sampling unchanged: a ScenarioBank
    over a SampledHotaSim is one vmapped jit, the sample draw shared
    across scenarios (key-only draw ⇒ same ids every scenario)."""
    from repro.core.sweep import ScenarioBank
    fl = FLConfig(n_clusters=2, n_clients=2)
    samp = _mk_sampled(fl, population=4)
    bank = ScenarioBank(samp, [dict(noise_std=0.3), fl])
    states = bank.init(jax.random.PRNGKey(0))
    x, y = _sim_batch(2, 2, jax.random.PRNGKey(5))
    states, m = bank.step(states, x, y, jax.random.PRNGKey(1))
    assert m["loss"].shape[0] == 2
    ids = np.asarray(m["sample_ids"])
    assert ids.shape == (2, 2, 2)
    np.testing.assert_array_equal(ids[0], ids[1])
    for leaf in jax.tree.leaves(states.bank.heads):
        assert leaf.shape[:4] == (2, 2, 2, 4)
