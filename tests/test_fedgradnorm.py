"""FedGradNorm (Alg. 2) invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.common.config import FLConfig
from repro.core.fedgradnorm import (
    fgn_grad_p, fgn_init, fgn_targets, fgn_update, masked_tree_norm,
)

FL = FLConfig(n_clients=3, gamma=0.6, alpha=8e-3)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(2, 8),
    seed=st.integers(0, 1000),
)
def test_weight_sum_constraint(n, seed):
    """Σ_i p_i = N after every update (paper Sec. II constraint)."""
    key = jax.random.PRNGKey(seed)
    p = jnp.ones((n,))
    norms = jax.random.uniform(key, (n,), minval=0.01, maxval=2.0)
    ratios = jax.random.uniform(jax.random.fold_in(key, 1), (n,),
                                minval=0.5, maxval=2.0)
    state = fgn_init(n)
    fl = FLConfig(n_clients=n)
    for _ in range(5):
        p, state, _ = fgn_update(p, norms, ratios, state, fl)
    assert abs(float(jnp.sum(p)) - n) < 1e-4
    assert float(jnp.min(p)) > 0


def test_symmetric_tasks_keep_equal_weights():
    """Identical norms and ratios → gradient of F_grad is identical per
    task → renormalized weights stay equal."""
    p = jnp.ones((3,))
    state = fgn_init(3)
    for _ in range(10):
        p, state, _ = fgn_update(p, jnp.full((3,), 0.7), jnp.ones((3,)),
                                 state, FL)
    np.testing.assert_allclose(np.asarray(p), np.ones(3), atol=1e-5)


def test_slow_task_gains_weight():
    """A task with a higher loss ratio (training slower) must receive a
    larger weight — the core FedGradNorm mechanism the paper relies on
    (Fig. 2d)."""
    p = jnp.ones((3,))
    state = fgn_init(3)
    norms = jnp.array([0.5, 0.5, 0.5])
    ratios = jnp.array([1.8, 1.0, 0.6])   # task 0 slowest
    for _ in range(50):
        p, state, _ = fgn_update(p, norms, ratios, state, FL)
    p = np.asarray(p)
    assert p[0] > p[1] > p[2], p
    assert abs(p.sum() - 3) < 1e-4


def test_fgrad_decreases_on_static_inputs():
    """Repeated Alg.-2 steps on frozen (norms, ratios) minimize F_grad."""
    p = jnp.ones((4,))
    state = fgn_init(4)
    norms = jnp.array([0.2, 0.9, 0.5, 1.4])
    ratios = jnp.array([1.5, 0.8, 1.1, 0.7])
    fl = FLConfig(n_clients=4, alpha=0.02)
    vals = []
    for _ in range(200):
        p, state, fval = fgn_update(p, norms, ratios, state, fl)
        vals.append(float(fval))
    assert vals[-1] < vals[0] * 0.8, (vals[0], vals[-1])


def test_grad_sign_structure():
    """∂F_grad/∂p_i = sign(p_i n_i − Ḡ r_i^γ) n_i (stop-grad on Ḡ, r)."""
    p = jnp.array([1.0, 1.0])
    norms = jnp.array([2.0, 0.1])
    ratios = jnp.array([1.0, 1.0])
    g, fval = fgn_grad_p(p, norms, ratios, gamma=0.6)
    # gbar = mean(p*n) = 1.05; task0: 2.0 > 1.05 -> +n0; task1: 0.1 < 1.05 -> -n1
    assert g[0] > 0 and g[1] < 0
    assert fval > 0


def test_masked_tree_norm_matches_numpy():
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((4,))}
    mask = {"a": jnp.array([[1, 0, 1], [0, 1, 0]], bool),
            "b": jnp.array([1, 1, 0, 0], bool)}
    want = np.sqrt(0**2 + 2**2 + 4**2 + 1 + 1)
    got = float(masked_tree_norm(tree, mask))
    assert abs(got - want) < 1e-5
