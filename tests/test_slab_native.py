"""Single-device units of the slab-native distributed machinery
(DESIGN.md §3.10): slab-view Adam, the fused mask+weighted-apply op on
chunk-quantized stream slices, the stream-range helper, and sweep-aware
bank checkpointing. The multi-device step itself is pinned in
tests/test_dist_slab.py (subprocess, forced devices)."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FLConfig, TrainConfig
from repro.core import ota
from repro.core.paper_setup import paper_mlp_setup
from repro.core.sweep import ScenarioBank
from repro.kernels.ota_channel.ops import ota_mask_weight_apply
from repro.kernels.ota_channel.ref import bits_to_mask
from repro.optim.adam import (
    AdamState, SlabAdamState, adam_init, adam_update, slab_adam_init,
    slab_adam_update, slab_to_tree, tree_to_slab,
)

KEY = jax.random.PRNGKey(0)


def _tree(key):
    ks = jax.random.split(key, 4)
    return {"a": jax.random.normal(ks[0], (17, 9)),
            "b": {"w": jax.random.normal(ks[1], (300,)),
                  "v": jax.random.normal(ks[2], (4, 4, 4))},
            "c": jax.random.normal(ks[3], (1,))}


def test_tree_slab_roundtrip():
    t = _tree(KEY)
    slab = tree_to_slab(t)
    assert slab.ndim == 1 and slab.dtype == jnp.float32
    out = slab_to_tree(slab, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_slab_adam_equals_pytree_adam():
    """Moments-as-slab Adam is the SAME elementwise math as pytree Adam —
    identical trajectories including bias correction and weight decay."""
    params = _tree(KEY)
    st_tree = adam_init(params)
    st_slab = slab_adam_init(params)
    assert st_slab.mu.shape == (sum(l.size for l in jax.tree.leaves(params)),)
    p_tree, p_slab = params, params
    for s in range(5):
        g = _tree(jax.random.fold_in(KEY, s + 1))
        p_tree, st_tree = adam_update(g, st_tree, p_tree, 1e-2,
                                      weight_decay=0.01)
        p_slab, st_slab = slab_adam_update(g, st_slab, p_slab, 1e-2,
                                           weight_decay=0.01)
    for a, b in zip(jax.tree.leaves(p_tree), jax.tree.leaves(p_slab)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(tree_to_slab(st_tree.mu)),
                               np.asarray(st_slab.mu), rtol=1e-6, atol=1e-8)
    assert int(st_slab.step) == 5


def test_slab_adam_accepts_flat_slabs():
    """The distributed step hands slabs straight through (no pytree)."""
    p = jnp.linspace(-1, 1, 2048)
    g = jnp.ones((2048,)) * 0.1
    st = slab_adam_init(p)
    p2, st = slab_adam_update(g, st, p, 1e-2)
    assert isinstance(p2, jax.Array) and p2.shape == p.shape
    p_ref, _ = adam_update(g, AdamState(jnp.zeros((), jnp.int32),
                                        jnp.zeros_like(p), jnp.zeros_like(p)),
                           p, 1e-2)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(p_ref), rtol=1e-6)


@pytest.mark.parametrize("n", [100, 1024, 5000, 8192])
def test_ota_mask_weight_apply_matches_ref(n):
    """Fused kernel main body + jnp ragged remainder == plain jnp on the
    same pre-sliced bit stream, for aligned and ragged sizes."""
    x = jax.random.normal(jax.random.fold_in(KEY, n), (n,))
    bits = jax.random.bits(jax.random.fold_in(KEY, 2 * n + 1), (n,),
                           jnp.uint32)
    sigma2, h_th, w = 0.8, 0.15, 1.7
    # the pallas kernel (interpret mode) and the jnp dispatch compute
    # identical values on the identical pre-sliced stream
    out, mask = ota_mask_weight_apply(x, bits, sigma2, h_th, 1.0, w,
                                      impl="pallas", interpret=True)
    out_j, mask_j = ota_mask_weight_apply(x, bits, sigma2, h_th, 1.0, w,
                                          impl="jnp")
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(mask_j))
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_j),
                               rtol=1e-6, atol=1e-7)
    m_ref = bits_to_mask(bits, sigma2, h_th, 1.0)
    np.testing.assert_array_equal(np.asarray(mask).astype(bool),
                                  np.asarray(m_ref))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(jnp.where(m_ref, w * x, 0.0)),
        rtol=1e-6, atol=1e-7)
    # ota off: all-pass mask, weight still applied
    out_off, mask_off = ota_mask_weight_apply(x, bits, sigma2, h_th, 0.0, w)
    assert np.asarray(mask_off).all()
    np.testing.assert_allclose(np.asarray(out_off), np.asarray(w * x),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("n", [300, 2048])
def test_ota_mask_count_apply_matches_ref(n):
    """The collective-free |M| variant: out = M_me∘(w·x) and
    cnt = Σ_l M_l from every cluster's stream — pallas (interpret) and
    jnp dispatches agree with the plain-jnp construction."""
    from repro.kernels.ota_channel.ops import ota_mask_count_apply
    C = 3
    x = jax.random.normal(jax.random.fold_in(KEY, n), (n,))
    bits = jax.random.bits(jax.random.fold_in(KEY, 3 * n), (C, n),
                           jnp.uint32)
    sig = jnp.asarray([0.5, 1.0, 2.0])
    me = jnp.asarray(1)
    for kwargs in (dict(impl="jnp"), dict(impl="pallas", interpret=True)):
        out, cnt = ota_mask_count_apply(x, bits, me, sig, 0.2, 1.0, 1.3,
                                        **kwargs)
        masks = bits_to_mask(bits, sig.reshape(C, 1), 0.2, 1.0)
        np.testing.assert_allclose(
            np.asarray(cnt),
            np.asarray(jnp.sum(masks.astype(jnp.float32), axis=0)),
            rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(jnp.where(masks[1], 1.3 * x, 0.0)),
            rtol=1e-6, atol=1e-7)


def test_ota_mask_weight_apply_shaped_leaf():
    """Leaf storage is consumed in place via reshape — shaped leaves OK."""
    x = jax.random.normal(KEY, (33, 77))
    bits = jax.random.bits(jax.random.fold_in(KEY, 9), (33 * 77,),
                           jnp.uint32)
    out, mask = ota_mask_weight_apply(x, bits, 1.0, 0.032, 1.0, 2.0)
    assert out.shape == x.shape and mask.shape == x.shape


def test_stream_range_bits_matches_chunked_stream():
    """A [start, start+len) slice of a section stream equals the same
    positions of the full chunked draw — the zero-copy bit source."""
    key = jax.random.fold_in(KEY, 77)
    full = ota._chunked_stream(key, 3 * ota.CHUNK + 500)
    for start, length in [(0, 100), (1000, ota.CHUNK), (ota.CHUNK - 3, 7),
                          (2 * ota.CHUNK + 17, ota.CHUNK + 100)]:
        got = ota.stream_range_bits(key, start, length)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(full[start:start + length]))


def test_stream_range_bits_hlo_draws_covering_chunks_only():
    """Memory pin (§3.10 zero-copy bit source): an intra-chunk range
    compiles ONE chunk draw — no second chunk, no concatenated
    multi-chunk stream. A chunk-spanning range is the positive control
    for the two-chunk shapes, proving the forbids aren't vacuous."""
    from repro.analysis import hlo_audit

    def lower(start, length):
        return jax.jit(lambda k: ota.stream_range_bits(
            k, start, length)).lower(KEY).compile().as_text()

    hlo_audit.assert_hlo_pins(lower(ota.CHUNK + 5, 100), [
        hlo_audit.require_buffer((ota.CHUNK,), dtypes=("u32",),
                                 note="the single covering chunk"),
        hlo_audit.forbid_buffer((2, ota.CHUNK), dtypes=("u32",),
                                note="second chunk drawn for an "
                                     "intra-chunk range"),
        hlo_audit.forbid_buffer((2 * ota.CHUNK,), dtypes=("u32",),
                                note="concatenated two-chunk stream"),
    ], context="stream_range_bits intra-chunk window")
    hlo_audit.assert_hlo_pins(lower(ota.CHUNK - 3, 7), [
        hlo_audit.require_buffer((2, ota.CHUNK), dtypes=("u32",),
                                 note="both covering chunks"),
        hlo_audit.require_buffer((2 * ota.CHUNK,), dtypes=("u32",),
                                 note="concatenated two-chunk stream"),
        hlo_audit.forbid_buffer((3, ota.CHUNK), dtypes=("u32",),
                                note="third chunk for a two-chunk range"),
    ], context="stream_range_bits chunk-spanning positive control")


def test_packed_section_folds_tail_invariant():
    """The ω̃ section keeps PACKED_TAIL_FOLD in EVERY layout, so eq.-5
    consumers re-draw the same stream regardless of the trunk split."""
    from repro.common.flatpack import TreePacker
    tree = {"final": {"w": jnp.zeros((10,))},
            "trunk": {"a": jnp.zeros((5,)), "b": jnp.zeros((2000,))}}
    legacy = TreePacker(tree, tail="final")
    multi = TreePacker(tree, tail="final", sections="toplevel")
    f_legacy = ota.packed_section_folds(legacy)
    f_multi = ota.packed_section_folds(multi)
    assert f_legacy[-1] == ota.PACKED_TAIL_FOLD
    assert f_multi[-1] == ota.PACKED_TAIL_FOLD
    assert f_legacy[0] == ota.PACKED_HEAD_FOLD
    assert all(f >= ota.PACKED_SECTION_FOLD_BASE for f in f_multi[:-1])
    assert len(set(f_multi)) == len(f_multi)     # streams disjoint


@pytest.mark.slow
def test_scenario_bank_checkpoint_restore_equivalence():
    """Sweep-aware checkpointing (DESIGN.md §3.9): save a plain (S,)-
    banked state mid-run, restore, continue — identical to never having
    stopped; a bank with a different S refuses the checkpoint."""
    base_fl = FLConfig(n_clusters=2, n_clients=3)
    sim, batcher = paper_mlp_setup(base_fl, batch=8, n_points=3000)
    scenarios = [dict(), dict(weighting="equal"), dict(sigma2=(0.05, 1.0)),
                 dict(ota=False)]
    bank = ScenarioBank(sim, scenarios)
    batches = [batcher.next_stacked() for _ in range(4)]
    keys = [jax.random.PRNGKey(100 + s) for s in range(4)]

    states = bank.init(jax.random.PRNGKey(0))
    for t in range(2):
        states, _ = bank.step(states, jnp.asarray(batches[t][0]),
                              jnp.asarray(batches[t][1]), keys[t])
    with tempfile.TemporaryDirectory() as d:
        bank.save(d, 2, states)
        from repro.checkpoint.store import checkpoint_metadata
        assert checkpoint_metadata(d, 2)["n_scenarios"] == 4
        restored = bank.restore(d, 2)
        for a, b in zip(jax.tree.leaves(states), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # continue both — bit-identical trajectories
        for t in range(2, 4):
            states, ma = bank.step(states, jnp.asarray(batches[t][0]),
                                   jnp.asarray(batches[t][1]), keys[t])
            restored, mb = bank.step(restored, jnp.asarray(batches[t][0]),
                                     jnp.asarray(batches[t][1]), keys[t])
        for a, b in zip(jax.tree.leaves(states), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        small = ScenarioBank(sim, scenarios[:2])
        with pytest.raises(ValueError, match="scenario"):
            small.restore(d, 2)
