"""Launch-layer units: input specs, microbatch picker, mesh construction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import INPUT_SHAPES
from repro.configs import get_config
from repro.launch.dryrun import _pick_microbatches, active_params
from repro.launch.steps import input_specs


def test_input_specs_train():
    cfg = get_config("stablelm_3b")
    ins = input_specs(cfg, INPUT_SHAPES["train_4k"])
    assert ins["tokens"].shape == (256, 4096)
    assert ins["tokens"].dtype == jnp.int32
    assert ins["labels"].shape == (256, 4096)


def test_input_specs_vlm_embeds():
    cfg = get_config("phi3_vision_4_2b")
    ins = input_specs(cfg, INPUT_SHAPES["prefill_32k"])
    # stubbed vision frontend supplies patch EMBEDDINGS, not token ids
    assert ins["tokens"].shape == (32, 32768, cfg.d_model)
    assert ins["tokens"].dtype == jnp.bfloat16


def test_input_specs_audio_tokens():
    cfg = get_config("musicgen_medium")
    ins = input_specs(cfg, INPUT_SHAPES["train_4k"])
    # EnCodec codes are discrete tokens
    assert ins["tokens"].dtype == jnp.int32


def test_input_specs_decode():
    cfg = get_config("qwen2_5_14b")
    ins = input_specs(cfg, INPUT_SHAPES["decode_32k"])
    assert ins["tokens"].shape == (128, 1)
    assert ins["positions"].shape == (128,)


def test_pick_microbatches_scales_with_model():
    small = get_config("musicgen_medium")
    big = get_config("mixtral_8x22b")
    shape = INPUT_SHAPES["train_4k"]
    assert _pick_microbatches(big, shape, 16) >= _pick_microbatches(small, shape, 16)
    assert _pick_microbatches(small, shape, 16) >= 1


def test_active_params_moe_discount():
    mix = get_config("mixtral_8x22b")
    full = active_params(mix.replace(moe=None))
    act = active_params(mix)
    assert act < 0.5 * 141e9          # top-2 of 8 experts ≈ 39B active
    assert act > 20e9


def test_long500k_skip_flags():
    skip = ["stablelm_3b", "musicgen_medium", "phi3_vision_4_2b",
            "phi3_5_moe_42b", "qwen2_5_14b"]
    run = ["starcoder2_3b", "gemma3_12b", "zamba2_1_2b", "xlstm_1_3b",
           "mixtral_8x22b"]
    for a in skip:
        assert not get_config(a).is_subquadratic, a
    for a in run:
        assert get_config(a).is_subquadratic, a
