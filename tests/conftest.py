"""Shared test fixtures. NOTE: no XLA device-count flags here — unit tests
run single-device; multi-device (dist-path) tests run in subprocesses that
set XLA_FLAGS before importing jax (see test_dist.py).

Also installs a minimal ``hypothesis`` fallback when the real package is
absent (this container): ``@given`` runs each property test over a small
fixed-seed sample of the strategy space instead of erroring at import.
CI installs real hypothesis, so the full property search still runs there.
"""
import functools
import os
import random
import sys
import types

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# keep tests hermetic: never read or write the user's persisted layout
# calibration cache (layout_tune.py honors "" as "persistence off")
os.environ.setdefault("REPRO_LAYOUT_CACHE", "")


# ---------------------------------------------------------------------------
# hypothesis shim (fixed-seed fallback for @given)
# ---------------------------------------------------------------------------

_SHIM_EXAMPLES = 3   # deterministic draws per property test


def _install_hypothesis_shim():
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw   # draw(random.Random) -> value

    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def floats(min_value, max_value, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def booleans():
        return _Strategy(lambda r: bool(r.getrandbits(1)))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: elements[r.randrange(len(elements))])

    def just(value):
        return _Strategy(lambda r: value)

    def tuples(*strategies):
        return _Strategy(lambda r: tuple(s.draw(r) for s in strategies))

    def lists(elements, min_size=0, max_size=10):
        return _Strategy(lambda r: [elements.draw(r)
                                    for _ in range(r.randint(min_size,
                                                             max_size))])

    def given(*_args, **strategies):
        if _args:
            raise TypeError("hypothesis shim supports keyword strategies only")

        def deco(fn):
            import inspect

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for ex in range(_SHIM_EXAMPLES):
                    r = random.Random(f"{fn.__module__}.{fn.__qualname__}:{ex}")
                    drawn = {k: s.draw(r) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            # pytest must not see the strategy params (they'd look like
            # missing fixtures) but MUST still see any real fixture params
            # the test takes alongside @given
            del wrapper.__wrapped__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            wrapper.hypothesis_shim = True
            return wrapper
        return deco

    def settings(*_a, **_kw):
        return lambda fn: fn

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.booleans = booleans
    st_mod.sampled_from = sampled_from
    st_mod.just = just
    st_mod.tuples = tuples
    st_mod.lists = lists

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.HealthCheck = types.SimpleNamespace(too_slow=None)
    hyp_mod.__is_shim__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401  (real package wins when available)
except ImportError:
    _install_hypothesis_shim()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
