"""Shared test fixtures. NOTE: no XLA device-count flags here — unit tests
run single-device; multi-device (dist-path) tests run in subprocesses that
set XLA_FLAGS before importing jax (see test_dist.py)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
