"""The §3.17 static-analysis subsystem, pinned (DESIGN.md §3.17).

Three groups:

* **AST lint rules** — for every rule a fixture snippet where it fires,
  the carve-outs that must NOT fire (eval_shape keys, ``.shape`` reads,
  static-config receivers, runtime indices), and the suppression
  contract (``# repro-lint: allow(rule, reason)`` silences exactly its
  rule; a reason-less allow is itself a violation);
* **stream-registry cross-check** — the pure ``cross_check`` diff under
  perturbations (rename / renumber / missing row / below-floor /
  collision on either side), plus the live tree being in sync;
* **HLO audit library** — pin evaluation against synthetic HLO and the
  shared-parser re-exports.

The real tree is the integration fixture: a clean run over ``src/``
must produce zero violations, and the CLI must exit 0 (and exit 1,
naming file:line and rule, when a scratch file with a bare
``fold_in(key, 42)`` is added to its path list).
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import hlo_audit
from repro.analysis.design_refs import check_design_refs
from repro.analysis.lint import (AST_RULES, RULE_BARE_FOLD, RULE_BARE_SEED,
                                 RULE_HOST_NONDET, RULE_PLATFORM_PIN,
                                 RULE_SUPPRESSION, RULE_TRACED_BRANCH,
                                 lint_paths, lint_source, rules_for_path)
from repro.analysis.stream_registry import (CHANNEL_FLOOR, CodeRegistry,
                                            check_registry, code_registry,
                                            cross_check, design_table,
                                            is_salt_name)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
CORE_PATH = os.path.join("src", "repro", "core", "fixture.py")
REGISTRY = {"NOISE_FOLD", "FINAL_INIT_FOLD", "KLASS_SALT"}


def _lint(src, path=CORE_PATH, registry=REGISTRY):
    return lint_source(path, textwrap.dedent(src), registry)


def _rules(violations):
    return [v.rule for v in violations]


# ------------------------------------------------------------ bare-fold-salt
def test_bare_fold_literal_fires():
    vs = _lint("""\
        import jax

        def f(key):
            return jax.random.fold_in(key, 42)
    """)
    assert _rules(vs) == [RULE_BARE_FOLD]
    assert vs[0].line == 4 and "42" in vs[0].message


def test_bare_fold_literal_expression_fires():
    vs = _lint("import jax\nk = jax.random.fold_in(k0, 7 + 3)\n")
    assert _rules(vs) == [RULE_BARE_FOLD]


def test_fold_unregistered_constant_fires():
    vs = _lint("""\
        import jax
        MY_SECRET_FOLD = 123

        def f(key):
            return jax.random.fold_in(key, MY_SECRET_FOLD)
    """)
    assert _rules(vs) == [RULE_BARE_FOLD]
    assert "MY_SECRET_FOLD" in vs[0].message


def test_fold_registered_constant_ok():
    assert _lint("""\
        import jax
        from repro.core.ota import NOISE_FOLD

        def f(key, klass):
            a = jax.random.fold_in(key, NOISE_FOLD)
            b = jax.random.fold_in(key, ota.FINAL_INIT_FOLD)
            c = jax.random.fold_in(key, KLASS_SALT[klass])
            return a, b, c
    """) == []


def test_fold_runtime_index_ok():
    assert _lint("""\
        import jax

        def f(key, cluster, leaf_idx):
            return jax.random.fold_in(jax.random.fold_in(key, cluster),
                                      leaf_idx + 1)
    """) == []


# ------------------------------------------------------------ bare-prng-seed
def test_prngkey_literal_fires():
    vs = _lint("import jax\nKEY = jax.random.PRNGKey(0)\n")
    assert _rules(vs) == [RULE_BARE_SEED]


def test_prngkey_eval_shape_ok():
    assert _lint("""\
        import jax

        def f(fn):
            return jax.eval_shape(lambda k: fn(k), jax.random.PRNGKey(0))
    """) == []


def test_prngkey_variable_seed_ok():
    assert _lint("""\
        import jax

        def f(seed):
            return jax.random.PRNGKey(seed)
    """) == []


# ------------------------------------------------------------- traced-branch
def test_traced_branch_if_fires():
    vs = _lint("""\
        import jax.numpy as jnp

        def f(chan, g):
            if chan.sigma2 > 0:
                return g
            return jnp.zeros_like(g)
    """)
    assert _rules(vs) == [RULE_TRACED_BRANCH]
    assert ".sigma2" in vs[0].message


def test_traced_branch_ternary_and_assert_fire():
    vs = _lint("""\
        def f(faults, g):
            assert faults.faults_on
            return g if faults.dropout else 0
    """)
    assert sorted(_rules(vs)) == [RULE_TRACED_BRANCH, RULE_TRACED_BRANCH]


def test_traced_branch_shape_read_ok():
    assert _lint("""\
        def f(chan, g):
            if chan.sigma2.shape[0] > 1:
                return g
            return 0
    """) == []


def test_traced_branch_static_config_receiver_ok():
    assert _lint("""\
        def f(fl, cfg, g):
            if fl.sigma2 and cfg.noise_std:
                return g
            return 0
    """) == []


def test_traced_branch_config_class_ok():
    assert _lint("""\
        class FLConfig:
            def validate(self):
                if not self.sigma2:
                    raise ValueError("sigma2 required")
    """) == []


# ------------------------------------------------- import-time-platform-pin
def test_module_scope_backend_fires():
    vs = _lint("import jax\n_ON_TPU = jax.default_backend() == 'tpu'\n")
    assert _rules(vs) == [RULE_PLATFORM_PIN]


def test_trace_time_backend_ok():
    assert _lint("""\
        import jax

        def on_tpu():
            return jax.default_backend() == "tpu"
    """) == []


# ------------------------------------------------------ host-nondeterminism
def test_time_and_np_random_fire_in_core():
    vs = _lint("""\
        import time
        import numpy as np

        def f():
            return time.time() + np.random.rand()
    """)
    assert sorted(_rules(vs)) == [RULE_HOST_NONDET, RULE_HOST_NONDET]


def test_host_nondeterminism_scoped_to_core():
    src = "import time\n\ndef f():\n    return time.time()\n"
    bench_path = os.path.join("src", "repro", "launch", "bench.py")
    assert lint_source(bench_path, src, REGISTRY,
                       rules_for_path(bench_path)) == []


def test_jax_random_not_flagged_as_host_nondeterminism():
    assert _lint("""\
        import jax

        def f(key, shape):
            return jax.random.normal(key, shape)
    """) == []


# --------------------------------------------------------------- suppression
def test_suppression_silences_its_rule():
    assert _lint("""\
        import jax
        # repro-lint: allow(bare-fold-salt, fixture exercising suppression)
        k = jax.random.fold_in(k0, 42)
    """) == []


def test_suppression_on_same_line_silences():
    assert _lint(
        "import jax\n"
        "k = jax.random.fold_in(k0, 42)"
        "  # repro-lint: allow(bare-fold-salt, fixture)\n") == []


def test_suppression_wrong_rule_does_not_silence():
    vs = _lint("""\
        import jax
        # repro-lint: allow(bare-prng-seed, wrong rule named)
        k = jax.random.fold_in(k0, 42)
    """)
    assert _rules(vs) == [RULE_BARE_FOLD]


def test_suppression_without_reason_is_violation():
    vs = _lint("""\
        import jax
        # repro-lint: allow(bare-fold-salt)
        k = jax.random.fold_in(k0, 42)
    """)
    assert sorted(_rules(vs)) == [RULE_SUPPRESSION, RULE_BARE_FOLD]


# --------------------------------------------------- the tree is the fixture
def test_real_src_tree_is_clean():
    """The acceptance bar: zero violations over the real src/ with the
    real registry."""
    reg = code_registry(REPO)
    vs = lint_paths([os.path.join(REPO, "src")], reg.names, repo_root=REPO)
    assert vs == [], "\n".join(v.format() for v in vs)


def test_real_registry_cross_check_clean():
    assert check_registry(REPO) == []


def test_real_design_refs_clean():
    assert [v.format() for v in check_design_refs(REPO)] == []


def test_cli_clean_exit_0():
    r = subprocess.run([sys.executable, "scripts/repro_lint.py"],
                       cwd=REPO, capture_output=True, text=True)
    assert r.returncode == 0, r.stderr


def test_cli_seeded_violation_reported(tmp_path):
    scratch = tmp_path / "scratch_bad.py"
    scratch.write_text(
        "import jax\n\n\ndef f(key):\n"
        "    return jax.random.fold_in(key, 42)\n")
    r = subprocess.run(
        [sys.executable, "scripts/repro_lint.py", str(scratch)],
        cwd=REPO, capture_output=True, text=True)
    assert r.returncode == 1
    assert f"{scratch}:5: {RULE_BARE_FOLD}:" in r.stderr


# ------------------------------------------------------ stream registry diff
_TABLE = textwrap.dedent("""\
    | name | value | class | purpose |
    |------|-------|-------|---------|
    | `NOISE_FOLD` | `0x7FFFFFFF` | channel | AWGN |
    | `FINAL_INIT_FOLD` | `7` | aux | init |
""")


def _code(**scalars):
    reg = CodeRegistry()
    for name, val in scalars.items():
        reg.scalars[name] = val
        reg.homes[name] = "src/repro/core/ota.py"
    return reg


def test_cross_check_in_sync():
    code = _code(NOISE_FOLD=0x7FFFFFFF, FINAL_INIT_FOLD=7)
    assert cross_check(code, design_table(_TABLE)) == []


def test_cross_check_renumbered_code_fails():
    code = _code(NOISE_FOLD=0x7FFFFFFE, FINAL_INIT_FOLD=7)
    msgs = cross_check(code, design_table(_TABLE))
    assert len(msgs) == 1 and "NOISE_FOLD" in msgs[0]
    assert "re-keys" in msgs[0]


def test_cross_check_renamed_code_fails_both_ways():
    code = _code(NOYSE_FOLD=0x7FFFFFFF, FINAL_INIT_FOLD=7)
    msgs = cross_check(code, design_table(_TABLE))
    assert any("NOYSE_FOLD" in m for m in msgs)       # code-only name
    assert any("NOISE_FOLD" in m and "stale" in m for m in msgs)


def test_cross_check_unregistered_constant_fails():
    code = _code(NOISE_FOLD=0x7FFFFFFF, FINAL_INIT_FOLD=7,
                 NEW_SECRET_FOLD=0x7FFF0777)
    msgs = cross_check(code, design_table(_TABLE))
    assert len(msgs) == 1 and "NEW_SECRET_FOLD" in msgs[0]


def test_cross_check_channel_below_floor_fails():
    table = design_table(_TABLE + "| `LOW_FOLD` | `5` | channel | bad |\n")
    code = _code(NOISE_FOLD=0x7FFFFFFF, FINAL_INIT_FOLD=7, LOW_FOLD=5)
    msgs = cross_check(code, table)
    assert any("below" in m and "LOW_FOLD" in m for m in msgs)
    assert CHANNEL_FLOOR == 0x7FFF0000


def test_cross_check_collision_fails():
    table = design_table(
        _TABLE + "| `OTHER_INIT_FOLD` | `7` | aux | dup |\n")
    code = _code(NOISE_FOLD=0x7FFFFFFF, FINAL_INIT_FOLD=7,
                 OTHER_INIT_FOLD=7)
    msgs = cross_check(code, table)
    assert any("collide" in m for m in msgs)


def test_is_salt_name():
    assert is_salt_name("NOISE_FOLD")
    assert is_salt_name("KLASS_SALT")
    assert is_salt_name("PACKED_SECTION_FOLD_BASE")
    assert not is_salt_name("CHUNK_ROWS")
    assert not is_salt_name("noise_fold")
    assert not is_salt_name("FOLDER_NAME")


# ------------------------------------------------------------- HLO audit lib
_HLO = textwrap.dedent("""\
    HloModule m

    %inner (a: f32[4,8]) -> f32[4,8] {
      %a = f32[4,8]{1,0} parameter(0)
      ROOT %d = f32[4,8]{1,0} dynamic-update-slice(%a, %a)
    }

    ENTRY %main (p0: f32[4,8], p1: u32[16]) -> f32[4,8] {
      %p0 = f32[4,8]{1,0} parameter(0)
      %p1 = u32[16]{0} parameter(1)
      ROOT %f = f32[4,8]{1,0} fusion(%p0), kind=kLoop, calls=%inner
    }
""")


def test_buffer_shapes_tokenizes_with_layouts():
    shapes = hlo_audit.buffer_shapes(_HLO)
    assert ("f32", (4, 8)) in shapes
    assert ("u32", (16,)) in shapes
    assert ("f32", (8,)) not in shapes


def test_forbid_buffer_fires_and_passes():
    assert hlo_audit.audit_hlo(
        _HLO, [hlo_audit.forbid_buffer((4, 8), note="the slab")])
    assert hlo_audit.audit_hlo(
        _HLO, [hlo_audit.forbid_buffer((4, 9))]) == []
    # dtype-restricted forbid: u32[4,8] absent even though f32[4,8] exists
    assert hlo_audit.audit_hlo(
        _HLO, [hlo_audit.forbid_buffer((4, 8), dtypes=("u32",))]) == []


def test_require_buffer_positive_control():
    assert hlo_audit.audit_hlo(
        _HLO, [hlo_audit.require_buffer((16,), dtypes=("u32",))]) == []
    msgs = hlo_audit.audit_hlo(
        _HLO, [hlo_audit.require_buffer((999,), dtypes=("u32",),
                                        note="missing control")])
    assert len(msgs) == 1 and "vacuous" in msgs[0]


def test_opcode_pin_sees_fusion_bodies():
    assert hlo_audit.audit_hlo(
        _HLO, [hlo_audit.forbid_opcode("dynamic-update-slice")])
    assert hlo_audit.audit_hlo(
        _HLO, [hlo_audit.forbid_opcode("all-gather")]) == []


def test_assert_hlo_pins_names_every_failure():
    with pytest.raises(AssertionError) as e:
        hlo_audit.assert_hlo_pins(_HLO, [
            hlo_audit.forbid_buffer((4, 8), note="the slab"),
            hlo_audit.forbid_opcode("dynamic-update-slice"),
        ], context="fixture")
    assert "the slab" in str(e.value)
    assert "dynamic-update-slice" in str(e.value)
    assert "fixture" in str(e.value)


def test_canned_pin_sets():
    pins = hlo_audit.no_slab_pins(4, 8)
    assert hlo_audit.audit_hlo(_HLO, pins)        # (4, 8) present -> fails
    assert hlo_audit.audit_hlo(
        _HLO, hlo_audit.no_slab_pins(3, 7)) == []
    assert hlo_audit.audit_hlo(
        _HLO, hlo_audit.no_cluster_stream_pins(4, [8, 8, 9]))
    assert hlo_audit.audit_hlo(
        _HLO, hlo_audit.cluster_chunk_stream_pin(4, 8))   # u32 absent


def test_shared_parser_reexports():
    """repro.analysis and launch/hlo_cost expose the SAME parser objects
    — one regex dialect (satellite: no second copy can drift)."""
    import repro.analysis as analysis
    from repro.launch import hlo_cost
    assert analysis.parse_hlo is hlo_cost.parse_hlo
    assert analysis.analyze is hlo_cost.analyze
    assert analysis.parse_shape_tokens is hlo_cost.parse_shape_tokens
    assert analysis.parse_shape_tokens("f32[4,8]{1,0} u32[16]") == [
        ("f32", (4, 8)), ("u32", (16,))]


def test_hlo_analysis_delegates_to_shared_parser():
    from repro.launch.hlo_analysis import collective_bytes
    hlo = textwrap.dedent("""\
        HloModule m

        ENTRY %main (p0: f32[8]) -> f32[8] {
          %p0 = f32[8]{0} parameter(0)
          ROOT %ar = f32[8]{0} all-reduce(%p0), to_apply=%add
        }
    """)
    assert collective_bytes(hlo) == {"all-reduce": 32.0}
