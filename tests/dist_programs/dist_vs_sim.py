"""Subprocess program: dist path ≡ sim path.

In the error-free, equal-weighted case (ota=False, weighting=equal), both
execution paths reduce to plain hierarchical data-parallel training of the
paper's MLP, so after one identical step from identical initialization the
shared parameters must match to float tolerance. This pins the distributed
shard_map/custom-vjp machinery to the faithful vmap simulator.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.hota_step import make_hota_train_step
from repro.core.sim import HotaSim
from repro.models.model import build_model
from repro.models.params import init_params

C, N, B, D = 2, 2, 4, 256
MAXC = 8
cfg = ModelConfig(family="mlp", compute_dtype="float32")
model = build_model(cfg)
tcfg = TrainConfig(lr=1e-3)

# --- shared init ------------------------------------------------------------
key = jax.random.PRNGKey(0)
omega = {"final": init_params(model.final_specs(), jax.random.fold_in(key, 7)),
         "trunk": init_params(model.trunk_specs(), key)}
head0 = init_params(model.head_specs(MAXC), jax.random.fold_in(key, 9))
x = jax.random.normal(jax.random.fold_in(key, 1), (C, N, B, D))
y = jax.random.randint(jax.random.fold_in(key, 2), (C, N, B), 0, MAXC)

STEPS = 3

# --- sim path ---------------------------------------------------------------
fl_sim = FLConfig(n_clusters=C, n_clients=N, weighting="equal", ota=False,
                  tau_h=1)
sim = HotaSim(model, fl_sim, tcfg, [MAXC] * N)
state = sim.init(jax.random.PRNGKey(123))
state = state._replace(
    omega=omega,
    heads=jax.tree.map(
        lambda h: jnp.broadcast_to(h, (C, N) + h.shape).copy(), head0))
sim_losses = []
for s in range(STEPS):
    state, metrics = sim.step(state, x, y, jax.random.PRNGKey(7 + s))
    sim_losses.append(float(np.asarray(metrics["loss"]).mean()))
sim_omega = jax.tree.map(np.asarray, state.omega)

# --- dist path --------------------------------------------------------------
devs = np.array(jax.devices()).reshape(C, N, 2)
mesh = Mesh(devs, ("cluster", "client", "model"))
fl_dist = FLConfig(n_clusters=C, n_clients=N, weighting="equal", ota=False,
                   tau_h=1, ota_mode="scatter")
init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
    model, mesh, fl_dist, tcfg, loss_kind="cls", n_out=MAXC)
dstate = init_fn(jax.random.PRNGKey(123))
dstate = dstate._replace(
    omega=omega,
    heads=jax.tree.map(
        lambda h: jnp.broadcast_to(h, (C * N,) + h.shape).copy(), head0))
dstate = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                      dstate, state_specs, is_leaf=lambda x: isinstance(x, P))
xflat = jax.device_put(x.reshape(C * N * B, D),
                       NamedSharding(mesh, batch_spec[0]))
yflat = jax.device_put(y.reshape(C * N * B),
                       NamedSharding(mesh, batch_spec[1]))
jstep = jax.jit(step_fn)
dist_losses = []
for s in range(STEPS):
    dstate, dmetrics = jstep(dstate, xflat, yflat, jax.random.PRNGKey(7 + s))
    dist_losses.append(float(dmetrics["loss"]))
dist_omega = jax.tree.map(np.asarray, dstate.omega)

# --- compare ----------------------------------------------------------------
# 1. identical loss trajectories (the strong functional check)
for a, b in zip(sim_losses, dist_losses):
    assert abs(a - b) < 2e-4, (sim_losses, dist_losses)
# 2. parameters match except Adam's ±lr sign flips on ~zero gradients
lr = 1e-3
flat_a = np.concatenate([l.ravel() for l in jax.tree.leaves(sim_omega)])
flat_b = np.concatenate([l.ravel() for l in jax.tree.leaves(dist_omega)])
diff = np.abs(flat_a - flat_b)
frac_flipped = float((diff > lr).mean())
assert diff.max() < 2 * STEPS * lr + 1e-5, diff.max()
assert frac_flipped < 0.05, frac_flipped
print(f"DIST_VS_SIM_OK losses={['%.5f' % l for l in sim_losses]} "
      f"flip_frac={frac_flipped:.4f} max_diff={diff.max():.2e}")
