"""Subprocess program: DistScenarioBank on the 2-D (scenario × client) mesh.

Forced 4 devices. S=4 scenarios × (1 cluster × 2 clients), exercising the
acceptance contract of DESIGN.md §3.10:

* CRN across scenario shards: the bank on a 2-row scenario axis must
  reproduce, per scenario, the bank on a 1-row axis bit-identically at
  float tolerance — scenario placement cannot change a trajectory;
* oracle: each scenario's trajectory equals the plain 1-D distributed
  step driven with that scenario's ChannelParams override;
* sweep-aware checkpointing (DESIGN.md §3.9): save from the 2-row bank
  mid-run, restore into the 1-row bank (different placement), continue
  both — states stay equal; a bank with a different S refuses the
  checkpoint.

Run: python dist_scenario_bank.py   (sets its own XLA_FLAGS)
"""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.channel import channel_params
from repro.core.sweep import DistScenarioBank
from repro.core.hota_step import make_hota_train_step
from repro.launch.mesh import make_dist_scenario_mesh
from repro.models.model import build_model

C, N, B, D = 1, 2, 4, 256
MAXC = 8
S = 4
STEPS = 4
SAVE_AT = 2

cfg = ModelConfig(family="mlp", compute_dtype="float32")
model = build_model(cfg)
tcfg = TrainConfig(lr=1e-3)
fl = FLConfig(n_clusters=C, n_clients=N, noise_std=0.1, tau_h=1)
scenarios = [dict(sigma2=(0.5,)), dict(sigma2=(2.0,)),
             dict(weighting="equal"), dict(ota=False)]

key = jax.random.PRNGKey(0)
xs = [jax.random.normal(jax.random.fold_in(key, 10 + t), (C * N * B, D))
      for t in range(STEPS)]
ys = [jax.random.randint(jax.random.fold_in(key, 50 + t), (C * N * B,), 0,
                         MAXC) for t in range(STEPS)]
keys = [jax.random.PRNGKey(100 + t) for t in range(STEPS)]


def drive(bank, states, t0, t1, collect=False):
    ms = []
    for t in range(t0, t1):
        states, m = bank.step(states, xs[t], ys[t], keys[t])
        ms.append(m)
    # drain before the next drive: banks on different meshes share host
    # devices, and two in-flight executables with rendezvous collectives
    # can interleave their launches in different orders per device —
    # a deadlock on the forced-CPU backend, not a correctness property
    jax.block_until_ready(states)
    return (states, ms) if collect else states


# dist_vs_sim.py's comparator: a handful of near-zero-gradient entries are
# sign-sensitive under Adam's rsqrt (float associativity differs across
# device layouts), each bounded by ~lr per step — so bound the max by the
# Adam step budget and the FRACTION of entries beyond float noise.
def states_close(a, b, tag, atol=1e-5):
    lr = tcfg.lr
    for (ka, la), (_, lb) in zip(
            jax.tree_util.tree_flatten_with_path(a)[0],
            jax.tree_util.tree_flatten_with_path(b)[0]):
        da = np.abs(np.asarray(la, np.float64) - np.asarray(lb, np.float64))
        name = f"{tag} at {jax.tree_util.keystr(ka)}"
        assert da.size == 0 or da.max() < 2 * STEPS * lr + atol, \
            (name, float(da.max()))
        assert da.size == 0 or float((da > atol).mean()) < 1e-4, \
            (name, float((da > atol).mean()))


mesh2 = make_dist_scenario_mesh(C, N, n_scenario_devices=2)   # 2 rows
mesh1 = make_dist_scenario_mesh(C, N, n_scenario_devices=1)   # 1 row
bank2 = DistScenarioBank(model, fl, tcfg, scenarios, mesh2,
                         loss_kind="cls", n_out=MAXC)
bank1 = DistScenarioBank(model, fl, tcfg, scenarios, mesh1,
                         loss_kind="cls", n_out=MAXC)

# --- CRN across scenario shards: 2-row bank == 1-row bank -------------------
st2, ms2 = drive(bank2, bank2.init(jax.random.PRNGKey(123)), 0, STEPS, True)
st1, ms1 = drive(bank1, bank1.init(jax.random.PRNGKey(123)), 0, STEPS, True)
states_close(st2, st1, "2-row vs 1-row bank")
for m2, m1 in zip(ms2, ms1):
    np.testing.assert_allclose(np.asarray(m2["loss"]), np.asarray(m1["loss"]),
                               rtol=1e-5, atol=1e-6)

# --- oracle: per-scenario 1-D distributed step with chan override -----------
fl_mesh = Mesh(np.array(jax.devices())[:C * N].reshape(C, N),
               ("cluster", "client"))
init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
    model, fl_mesh, fl, tcfg, loss_kind="cls", n_out=MAXC)
jstep = jax.jit(step_fn)
for s, sc in enumerate(scenarios):
    import dataclasses
    chan_s = channel_params(dataclasses.replace(fl, **sc), n_clusters=C)
    state = init_fn(jax.random.PRNGKey(123))
    state = jax.tree.map(
        lambda a, spec: jax.device_put(a, NamedSharding(fl_mesh, spec)),
        state, state_specs, is_leaf=lambda z: isinstance(z, P))
    for t in range(STEPS):
        xb = jax.device_put(xs[t], NamedSharding(fl_mesh, batch_spec[0]))
        yb = jax.device_put(ys[t], NamedSharding(fl_mesh, batch_spec[1]))
        state, _ = jstep(state, xb, yb, keys[t], chan_s)
    # drain the oracle chain before scenario_state's cross-shard gathers
    # launch — same in-flight-collectives hazard as drive() above
    jax.block_until_ready(state)
    states_close(bank2.scenario_state(st2, s), state,
                 f"bank scenario {s} vs 1-D oracle", atol=1e-5)

# --- sweep-aware checkpointing: cross-layout restore equivalence ------------
st_mid = drive(bank2, bank2.init(jax.random.PRNGKey(123)), 0, SAVE_AT)
with tempfile.TemporaryDirectory() as d:
    bank2.save(d, SAVE_AT, st_mid)
    restored = bank1.restore(d, SAVE_AT)       # other placement, same state
    states_close(restored, st_mid, "restore round-trip")
    end_a = drive(bank2, st_mid, SAVE_AT, STEPS)
    end_b = drive(bank1, restored, SAVE_AT, STEPS)
    states_close(end_a, end_b, "post-restore trajectory")

    # a bank with a different scenario count must refuse the checkpoint
    bank_s2 = DistScenarioBank(model, fl, tcfg, scenarios[:2], mesh2,
                               loss_kind="cls", n_out=MAXC)
    try:
        bank_s2.restore(d, SAVE_AT)
        raise SystemExit("S-mismatch restore did not raise")
    except ValueError as e:
        assert "scenario" in str(e), e

print(f"DIST_SCENARIO_BANK_OK S={S} steps={STEPS} "
      f"loss={[round(float(v), 4) for v in np.asarray(ms2[-1]['loss'])]}")
