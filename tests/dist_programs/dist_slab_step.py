"""Subprocess program: the slab-native distributed step (DESIGN.md §3.10).

Forced 4-device (2 clusters × 2 clients) mesh. Four pins:

1. slab-native step ≡ per-leaf oracle (``use_pallas_ota=False``) to float
   tolerance over 3 FedGradNorm rounds in the error-free case (the
   channel is inert, so the whole LAN psum → FGN → slab-Adam pipeline
   must agree exactly; slab Adam is elementwise-identical math);
2. with the channel ON, the slab gather's backward ≡ the jnp oracle
   ``packed_omega_aggregate_ref`` on SHARED keys — the section streams,
   inverse-CDF masks, AWGN and the |M|·N guard line up bit-for-bit
   between the distributed kernel path and the single-process reference;
3. zero-copy: the compiled backward materializes NO buffer of the packed
   slab size (the pack's dynamic-update-slice chain is gone — the kernel
   reads leaf storage in place);
4. retrace pin (DESIGN.md §3.11): sweeping ChannelParams VALUES through
   the compiled step never re-traces — TRACE_LOG stays flat — while
   ``ota_mode`` stays static by design (it changes collective structure).

Run: python dist_slab_step.py   (sets its own XLA_FLAGS)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.core.hota_step as hota_step
from repro.analysis import hlo_audit
from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.channel import channel_params
from repro.core.hota import OTACtx, _is_axes
from repro.core.hota_slab import (
    _fsdp_axis_full, make_packed_omega_gather, packed_omega_aggregate_ref,
    packed_omega_key,
)
from repro.core.hota_step import make_hota_train_step
from repro.models.model import build_model
from repro.models.params import abstract_params, init_params, logical_axes
from repro.sharding.mesh_utils import shard_map_compat

C, N, B, D = 2, 2, 4, 256
MAXC = 8
STEPS = 3

cfg = ModelConfig(family="mlp", compute_dtype="float32")
model = build_model(cfg)
tcfg = TrainConfig(lr=1e-3)
devs = np.array(jax.devices()).reshape(C, N)
mesh = Mesh(devs, ("cluster", "client"))

key = jax.random.PRNGKey(0)
x = jax.random.normal(jax.random.fold_in(key, 1), (C * N * B, D))
y = jax.random.randint(jax.random.fold_in(key, 2), (C * N * B,), 0, MAXC)
omega0 = {"final": init_params(model.final_specs(), jax.random.fold_in(key, 7)),
          "trunk": init_params(model.trunk_specs(), key)}


def run(fl):
    init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
        model, mesh, fl, tcfg, loss_kind="cls", n_out=MAXC)
    state = init_fn(jax.random.PRNGKey(123))
    state = state._replace(omega=omega0)
    state = jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        state, state_specs, is_leaf=lambda z: isinstance(z, P))
    xb = jax.device_put(x, NamedSharding(mesh, batch_spec[0]))
    yb = jax.device_put(y, NamedSharding(mesh, batch_spec[1]))
    jstep = jax.jit(step_fn)
    ms = []
    for s in range(STEPS):
        state, m = jstep(state, xb, yb, jax.random.PRNGKey(7 + s))
        ms.append(m)
    return state, ms


# --- 1. slab-native ≡ per-leaf oracle (error-free channel) -------------------
fl_base = dict(n_clusters=C, n_clients=N, weighting="fedgradnorm",
               ota=False, tau_h=1)
st_slab, ms_slab = run(FLConfig(use_pallas_ota=True, **fl_base))
st_leaf, ms_leaf = run(FLConfig(use_pallas_ota=False, **fl_base))
for la, lb in zip(jax.tree.leaves(st_slab.omega),
                  jax.tree.leaves(st_leaf.omega)):
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=2e-5, atol=1e-5, err_msg="omega")
for field in ("p", "fgn_mu", "fgn_nu", "f0"):
    np.testing.assert_allclose(np.asarray(getattr(st_slab, field)),
                               np.asarray(getattr(st_leaf, field)),
                               rtol=2e-5, atol=1e-6, err_msg=field)
for ma, mb in zip(ms_slab, ms_leaf):
    assert abs(float(ma["loss"]) - float(mb["loss"])) < 2e-5
    np.testing.assert_allclose(float(ma["gnorm_mean"]),
                               float(mb["gnorm_mean"]), rtol=2e-5)

# --- 2. channel ON: slab backward ≡ jnp oracle on shared keys ---------------
fl_ota = FLConfig(n_clusters=C, n_clients=N, noise_std=0.3, sigma2=(0.5, 1.5),
                  h_threshold=0.2)
chan = channel_params(fl_ota)
template = {"final": abstract_params(model.final_specs()),
            "trunk": abstract_params(model.trunk_specs())}
axes_list = [a for a in jax.tree.leaves(
    {"final": logical_axes(model.final_specs()),
     "trunk": logical_axes(model.trunk_specs())}, is_leaf=_is_axes)]
n_shards = C * N
gather, packer = make_packed_omega_gather(
    ("client", "cluster"), ("cluster",), N, n_shards, jnp.float32,
    template, axes_list, n_clusters=C)

base_key = jax.random.PRNGKey(42)
slab_key = packed_omega_key(base_key)
p_dev = jax.random.uniform(jax.random.fold_in(base_key, 5), (C, N),
                           jnp.float32, 0.5, 1.5)
cnt = [0]


def _draw(l):
    cnt[0] += 1
    return jax.random.normal(jax.random.fold_in(base_key, 100 + cnt[0]),
                             (C, N) + tuple(l.shape), jnp.float32)


g_full = jax.tree.map(_draw, template)     # per-device full-size cotangents


def local_bwd(g_loc, p_loc):
    """One device's slice of the slab aggregation backward."""
    g_loc = jax.tree.map(lambda l: l[0], g_loc)      # drop device dim
    ctx = OTACtx(p_weight=p_loc.reshape(()), key=slab_key,
                 sigma2=chan.sigma2,    # FULL (C,) — local |M| count
                 h_th=chan.h_threshold, noise_std=chan.noise_std,
                 ota_on=chan.ota_on)
    # zeros shard tree with the true local shard shapes (fwd all-gathers
    # it back to full size; values are irrelevant to the backward)
    shard = jax.tree.unflatten(
        jax.tree.structure(g_loc),
        [jnp.zeros(tuple(s // n_shards if d == _fsdp_axis_full(ax)
                         else s for d, s in enumerate(l.shape)), jnp.float32)
         for l, ax in zip(jax.tree.leaves(g_loc), axes_list)])
    _, vjp = jax.vjp(lambda t: gather(t, ctx), shard)
    (g_shards,) = vjp(g_loc)
    return g_shards


# device (cluster c, client i) consumes g_full[c, i]: the leading device
# dim is split CLIENT-major (the data_axes order), so lay it out as
# [i·C + c] — swapaxes before the reshape
g_dev_major = jax.tree.map(
    lambda l: jnp.swapaxes(l, 0, 1).reshape((N * C,) + l.shape[2:]), g_full)
spec_in = jax.tree.map(lambda l: P(("client", "cluster")), g_dev_major)
out_specs = jax.tree.unflatten(
    jax.tree.structure(template),
    [P(*[("client", "cluster") if d == _fsdp_axis_full(ax) else None
         for d in range(len(l.shape))]) if _fsdp_axis_full(ax) >= 0 else P()
     for l, ax in zip(jax.tree.leaves(template), axes_list)])

jf = jax.jit(shard_map_compat(
    local_bwd, mesh=mesh,
    in_specs=(spec_in, P("cluster", "client")),
    out_specs=out_specs,
    axis_names={"cluster", "client"}))
ghat = jf(g_dev_major, p_dev)

wg = jax.tree.map(lambda l: jnp.einsum("cn,cn...->c...", p_dev, l), g_full)
ghat_ref = packed_omega_aggregate_ref(wg, slab_key, chan, N, packer)
for (ka, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(ghat)[0],
                           jax.tree_util.tree_flatten_with_path(ghat_ref)[0]):
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-5,
        err_msg=f"slab bwd vs oracle at {jax.tree_util.keystr(ka)}")

# --- 3. zero-copy: no slab-sized buffer in the compiled backward ------------
hlo = jf.lower(g_dev_major, p_dev).compile().as_text()
P_slab = packer.size
hlo_audit.assert_hlo_pins(hlo, [
    hlo_audit.forbid_buffer((P_slab,), dtypes=("f32",),
                            note="full (P,) slab — zero-copy regressed"),
    hlo_audit.forbid_buffer((C, P_slab), dtypes=("f32",),
                            note="(C, P) slab"),
    hlo_audit.forbid_opcode(
        "dynamic-update-slice",
        note="pack-style scatter chain in the slab backward"),
], context="slab backward zero-copy (§3.10)")

# --- 4. retrace pin: chan VALUES never re-trace (ota_mode is static) --------
fl_tr = FLConfig(n_clusters=C, n_clients=N, weighting="fedgradnorm",
                 noise_std=0.1, tau_h=1)
init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
    model, mesh, fl_tr, tcfg, loss_kind="cls", n_out=MAXC)
state = init_fn(jax.random.PRNGKey(123))
state = jax.tree.map(
    lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
    state, state_specs, is_leaf=lambda z: isinstance(z, P))
xb = jax.device_put(x, NamedSharding(mesh, batch_spec[0]))
yb = jax.device_put(y, NamedSharding(mesh, batch_spec[1]))
jstep = jax.jit(step_fn)
chans = [channel_params(FLConfig(n_clusters=C, n_clients=N,
                                 sigma2=(s2, 2 * s2), noise_std=0.1))
         for s2 in (0.25, 1.0, 4.0)]
state, _ = jstep(state, xb, yb, jax.random.PRNGKey(1), chans[0])
n_traces_after_first = len(hota_step.TRACE_LOG)
for i, ch in enumerate(chans):
    state, _ = jstep(state, xb, yb, jax.random.PRNGKey(2 + i), ch)
assert len(hota_step.TRACE_LOG) == n_traces_after_first, (
    "sweeping ChannelParams values re-traced the step: "
    f"{n_traces_after_first} -> {len(hota_step.TRACE_LOG)}")

print(f"DIST_SLAB_OK steps={STEPS} "
      f"loss={float(ms_slab[-1]['loss']):.4f} "
      f"slab_P={P_slab} traces={n_traces_after_first}")
