"""Subprocess program: ShardedScenarioBank on a forced 2-device CPU mesh.

Checks, at S=16 over two forced host devices:

1. sharded bank == plain vmap bank, leaf for leaf (states AND metrics) —
   putting the scenario axis on the mesh changes placement, not values;
2. common random numbers survive sharding: scenario i (device 0) and
   scenario i+8 (device 1) differ only in weighting, so their first-round
   masked grad norms must be BIT-identical across the shard boundary;
3. sharded bank == the sequential per-scenario HotaSim oracle (spot-checked
   on scenarios from both shards — the full S=8 oracle sweep lives in
   tests/test_sweep.py; transitively check 1 extends it to the bank).

Run: python sweep_sharded.py   (sets its own XLA_FLAGS)
"""
import dataclasses
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import FLConfig, TrainConfig
from repro.core.paper_setup import paper_mlp_setup
from repro.core.sim import HotaSim
from repro.core.sweep import ScenarioBank, ShardedScenarioBank

C, N, S, STEPS = 2, 3, 16, 2
assert len(jax.devices()) == 2, jax.devices()

base_fl = FLConfig(n_clusters=C, n_clients=N)
sim, batcher = paper_mlp_setup(base_fl, batch=8, n_points=3000)

# scenarios 0-7 sweep channel knobs under dynamic weighting; 8-15 are the
# SAME channel knobs under equal weighting -> pair (i, i+8) spans the two
# shards and differs only in the weighting gate (the CRN probe)
half = [
    dict(),
    dict(sigma2=(0.05, 1.0)),
    dict(sigma2=(2.0, 0.75)),
    dict(sigma2=(0.25, 0.75)),
    dict(noise_std=3.0),
    dict(noise_std=0.25),
    dict(ota=False),
    dict(sigma2=(1.5, 0.1), noise_std=2.0),
]
scenarios = [dict(sc) for sc in half] + \
    [dict(sc, weighting="equal") for sc in half]

key0 = jax.random.PRNGKey(0)
batches = [batcher.next_stacked() for _ in range(STEPS)]
step_keys = [jax.random.PRNGKey(100 + s) for s in range(STEPS)]

vbank = ScenarioBank(sim, scenarios)
sbank = ShardedScenarioBank(sim, scenarios)
assert sbank.n_scenarios == S
shard_spec = jax.tree.leaves(sbank.chan_bank)[0].sharding.spec
assert tuple(shard_spec) == ("scenario",), shard_spec

# an odd S cannot split over the 2-device scenario mesh
try:
    ShardedScenarioBank(sim, scenarios[:3])
except ValueError as e:
    assert "S=3" in str(e) and "2-device" in str(e), e
else:
    raise AssertionError("S=3 on 2 devices should have been rejected")

vstates, sstates = vbank.init(key0), sbank.init(key0)
vms, sms = [], []
for (x, y), k in zip(batches, step_keys):
    x, y = jnp.asarray(x), jnp.asarray(y)
    vstates, vm = vbank.step(vstates, x, y, k)
    sstates, sm = sbank.step(sstates, x, y, k)
    vms.append(vm)
    sms.append(sm)

# --- 1. sharded == vmap ----------------------------------------------------
for vm, sm in zip(vms, sms):
    for a, b in zip(jax.tree.leaves(vm), jax.tree.leaves(sm)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
for a, b in zip(jax.tree.leaves(vstates), jax.tree.leaves(sstates)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-7)

# --- 2. CRN across the shard boundary -------------------------------------
norms = np.asarray(sms[0]["grad_norms"])          # (S, C, N)
for i in range(8):
    np.testing.assert_array_equal(norms[i], norms[i + 8])
p = np.asarray(sms[0]["p"])
np.testing.assert_allclose(p[8:], 1.0)            # equal shard: p stays 1
assert not np.allclose(p[:8], 1.0)                # dynamic shard adapted

# --- 3. sequential oracle, scenarios from both shards ----------------------
n_cls = [int(c) for c in sim.n_classes]
for s in (0, 5, 10, 15):
    fl_s = dataclasses.replace(base_fl, **scenarios[s])
    seq = HotaSim(sim.model, fl_s, TrainConfig(lr=3e-4), n_cls)
    st = seq.init(key0)
    for t, ((x, y), k) in enumerate(zip(batches, step_keys)):
        st, m = seq.step(st, jnp.asarray(x), jnp.asarray(y), k)
        for a, b in zip(jax.tree.leaves(m),
                        jax.tree.leaves(
                            jax.tree.map(lambda z: z[s], sms[t]))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-5)
    for a, b in zip(jax.tree.leaves(st),
                    jax.tree.leaves(sbank.scenario_state(sstates, s))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)

print(f"SWEEP_SHARDED_OK S={S} devices=2 steps={STEPS}")
