"""Subprocess program: the SECTIONED distributed backward (DESIGN.md
§3.16) on a forced 4-device (2 clusters × 2 clients) mesh.

Pins:

1. the sectioned gather backward (per-section collect → one-section-
   deferred finalize, double-buffered) is BIT-identical to the full-slab
   schedule for every composed mode: count_mode ∈ {psum, local} ×
   max_section_rows ∈ {0, 8} — the section pipeline changes stream
   lifetime and psum grouping, never a per-leaf value;
2. the sectioned backward ≡ the jnp oracle ``packed_omega_aggregate_ref``
   on shared keys (float tolerance — the oracle differs at fusion level);
3. end-to-end: ``make_hota_train_step`` with ``fl.ota_sectioned=True``
   tracks the full-slab step over 2 FedGradNorm rounds (the whole round
   path accepts the sectioned schedule, not just the isolated gather);
4. the distributed step REJECTS ``fl.ota_streaming`` by name — the
   simulator engine must never be silently inert here.

Run: python dist_sectioned.py   (sets its own XLA_FLAGS)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.channel import channel_params
from repro.core.hota import OTACtx, _is_axes
from repro.core.hota_slab import (
    _fsdp_axis_full, make_packed_omega_gather, packed_omega_aggregate_ref,
    packed_omega_key,
)
from repro.core.hota_step import make_hota_train_step
from repro.models.model import build_model
from repro.models.params import abstract_params, init_params, logical_axes
from repro.sharding.mesh_utils import shard_map_compat

C, N, B, D = 2, 2, 4, 256
MAXC = 8

cfg = ModelConfig(family="mlp", compute_dtype="float32")
model = build_model(cfg)
tcfg = TrainConfig(lr=1e-3)
devs = np.array(jax.devices()).reshape(C, N)
mesh = Mesh(devs, ("cluster", "client"))

fl_ota = FLConfig(n_clusters=C, n_clients=N, noise_std=0.3,
                  sigma2=(0.5, 1.5), h_threshold=0.2)
chan = channel_params(fl_ota)
template = {"final": abstract_params(model.final_specs()),
            "trunk": abstract_params(model.trunk_specs())}
axes_list = [a for a in jax.tree.leaves(
    {"final": logical_axes(model.final_specs()),
     "trunk": logical_axes(model.trunk_specs())}, is_leaf=_is_axes)]
n_shards = C * N

base_key = jax.random.PRNGKey(42)
slab_key = packed_omega_key(base_key)
p_dev = jax.random.uniform(jax.random.fold_in(base_key, 5), (C, N),
                           jnp.float32, 0.5, 1.5)
cnt = [0]


def _draw(l):
    cnt[0] += 1
    return jax.random.normal(jax.random.fold_in(base_key, 100 + cnt[0]),
                             (C, N) + tuple(l.shape), jnp.float32)


g_full = jax.tree.map(_draw, template)
g_dev_major = jax.tree.map(
    lambda l: jnp.swapaxes(l, 0, 1).reshape((N * C,) + l.shape[2:]), g_full)
spec_in = jax.tree.map(lambda l: P(("client", "cluster")), g_dev_major)
out_specs = jax.tree.unflatten(
    jax.tree.structure(template),
    [P(*[("client", "cluster") if d == _fsdp_axis_full(ax) else None
         for d in range(len(l.shape))]) if _fsdp_axis_full(ax) >= 0 else P()
     for l, ax in zip(jax.tree.leaves(template), axes_list)])


def build_bwd(count_mode, max_section_rows, sectioned):
    gather, packer = make_packed_omega_gather(
        ("client", "cluster"), ("cluster",), N, n_shards, jnp.float32,
        template, axes_list, n_clusters=C, count_mode=count_mode,
        max_section_rows=max_section_rows, sectioned=sectioned)

    def local_bwd(g_loc, p_loc):
        g_loc = jax.tree.map(lambda l: l[0], g_loc)
        ctx = OTACtx(p_weight=p_loc.reshape(()), key=slab_key,
                     sigma2=chan.sigma2, h_th=chan.h_threshold,
                     noise_std=chan.noise_std, ota_on=chan.ota_on)
        shard = jax.tree.unflatten(
            jax.tree.structure(g_loc),
            [jnp.zeros(tuple(s // n_shards if d == _fsdp_axis_full(ax)
                             else s for d, s in enumerate(l.shape)),
                       jnp.float32)
             for l, ax in zip(jax.tree.leaves(g_loc), axes_list)])
        _, vjp = jax.vjp(lambda t: gather(t, ctx), shard)
        (g_shards,) = vjp(g_loc)
        return g_shards

    return jax.jit(shard_map_compat(
        local_bwd, mesh=mesh,
        in_specs=(spec_in, P("cluster", "client")),
        out_specs=out_specs,
        axis_names={"cluster", "client"})), packer


# --- 1. sectioned ≡ full-slab backward, BITWISE, composed modes -------------
# (psum, 0) is the legacy default; (local, 8) composes the platform
# count fold with a split layout — the two corners exercise every
# branch pair without compiling the full product on 4 host CPUs
for count_mode, msr in (("psum", 0), ("local", 8)):
        f_full, packer = build_bwd(count_mode, msr, sectioned=False)
        f_sec, _ = build_bwd(count_mode, msr, sectioned=True)
        a = jax.tree.map(np.asarray, f_full(g_dev_major, p_dev))
        b = jax.tree.map(np.asarray, f_sec(g_dev_major, p_dev))
        for (ka, la), (_, lb) in zip(
                jax.tree_util.tree_flatten_with_path(a)[0],
                jax.tree_util.tree_flatten_with_path(b)[0]):
            np.testing.assert_array_equal(
                la, lb,
                err_msg=(f"sectioned != full-slab at "
                         f"{jax.tree_util.keystr(ka)} "
                         f"(count_mode={count_mode}, msr={msr})"))

# --- 2. sectioned backward ≡ jnp oracle on shared keys ----------------------
f_sec, packer = build_bwd("psum", 0, sectioned=True)
ghat = f_sec(g_dev_major, p_dev)
wg = jax.tree.map(lambda l: jnp.einsum("cn,cn...->c...", p_dev, l), g_full)
ghat_ref = packed_omega_aggregate_ref(wg, slab_key, chan, N, packer)
for (ka, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(ghat)[0],
                           jax.tree_util.tree_flatten_with_path(ghat_ref)[0]):
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-5,
        err_msg=f"sectioned bwd vs oracle at {jax.tree_util.keystr(ka)}")

# --- 3. end-to-end train step: sectioned tracks full-slab -------------------
key = jax.random.PRNGKey(0)
x = jax.random.normal(jax.random.fold_in(key, 1), (C * N * B, D))
y = jax.random.randint(jax.random.fold_in(key, 2), (C * N * B,), 0, MAXC)
omega0 = {"final": init_params(model.final_specs(), jax.random.fold_in(key, 7)),
          "trunk": init_params(model.trunk_specs(), key)}


def run(fl, steps=2):
    init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
        model, mesh, fl, tcfg, loss_kind="cls", n_out=MAXC)
    state = init_fn(jax.random.PRNGKey(123))._replace(omega=omega0)
    state = jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        state, state_specs, is_leaf=lambda z: isinstance(z, P))
    xb = jax.device_put(x, NamedSharding(mesh, batch_spec[0]))
    yb = jax.device_put(y, NamedSharding(mesh, batch_spec[1]))
    jstep = jax.jit(step_fn)
    for s in range(steps):
        state, m = jstep(state, xb, yb, jax.random.PRNGKey(7 + s))
    return state, m


fl_kw = dict(n_clusters=C, n_clients=N, noise_std=0.3, sigma2=(0.5, 1.5),
             h_threshold=0.2, tau_h=1)
# max_section_rows RE-KEYS the trunk streams (§4 split rule), so both
# runs share the split layout — they differ ONLY in the engine schedule
st_full, m_full = run(FLConfig(max_section_rows=8, **fl_kw))
st_sec, m_sec = run(FLConfig(ota_sectioned=True, max_section_rows=8,
                             **fl_kw))
for la, lb in zip(jax.tree.leaves(st_full.omega),
                  jax.tree.leaves(st_sec.omega)):
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=1e-6, atol=1e-7,
                               err_msg="end-to-end omega diverged")

# --- 4. fl.ota_streaming is rejected by name in the distributed step --------
try:
    make_hota_train_step(model, mesh,
                         FLConfig(ota_streaming=True, **fl_kw), tcfg,
                         loss_kind="cls", n_out=MAXC)
    raise SystemExit("fl.ota_streaming was accepted by the distributed step")
except ValueError as e:
    assert "ota_streaming" in str(e) and "ota_sectioned" in str(e), e

print(f"DIST_SECTIONED_OK sections={len(packer.sections)} "
      f"loss={float(m_sec['loss']):.4f}")
