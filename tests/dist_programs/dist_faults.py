"""Subprocess program: fault injection on the distributed slab engine
(DESIGN.md §3.14). Forced 4-device (2 clusters × 2 clients) mesh; a
second phase rebuilds a (2-scenario × 1×2) mesh for the fault bank.

Pins:

1. zero-rate faults reproduce the legacy (faults=False) trajectory to
   float tolerance (the fault trace adds the guard psum + freeze select,
   so XLA refuses bit-exactness — the skip path, which is the §3.14
   contract, IS bit-exact, see pin 2);
2. total blackout ⇒ every round skipped and the whole HotaState — omega,
   slab Adam moments, FGN state, per-client head state — is bit-exactly
   frozen; only the step counter advances;
3. sweeping FaultParams VALUES through the compiled step never re-traces;
4. DistScenarioBank threads a fault bank: per-scenario skipped/participant
   metrics on the 2-D (scenario × client) mesh.

Run: python dist_faults.py   (sets its own XLA_FLAGS)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.core.hota_step as hota_step
from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.channel import fault_params
from repro.core.hota_step import make_hota_train_step
from repro.models.model import build_model

C, N, B, D = 2, 2, 4, 256
MAXC = 8

model = build_model(ModelConfig(family="mlp", compute_dtype="float32"))
tcfg = TrainConfig(lr=1e-3)
devs = np.array(jax.devices()).reshape(C, N)
mesh = Mesh(devs, ("cluster", "client"))

key = jax.random.PRNGKey(0)
x = jax.random.normal(jax.random.fold_in(key, 1), (C * N * B, D))
y = jax.random.randint(jax.random.fold_in(key, 2), (C * N * B,), 0, MAXC)

base = dict(n_clusters=C, n_clients=N, weighting="fedgradnorm",
            noise_std=0.1, tau_h=1, use_pallas_ota=True)


def make(fl):
    init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
        model, mesh, fl, tcfg, loss_kind="cls", n_out=MAXC)
    state = init_fn(jax.random.PRNGKey(123))
    state = jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        state, state_specs, is_leaf=lambda z: isinstance(z, P))
    xb = jax.device_put(x, NamedSharding(mesh, batch_spec[0]))
    yb = jax.device_put(y, NamedSharding(mesh, batch_spec[1]))
    return jax.jit(step_fn), state, xb, yb


def drive(jstep, state, xb, yb, faults=None, n_steps=2):
    ms = []
    for s in range(n_steps):
        if faults is None:
            state, m = jstep(state, xb, yb, jax.random.PRNGKey(7 + s))
        else:
            state, m = jstep(state, xb, yb, jax.random.PRNGKey(7 + s),
                             None, faults)
        ms.append(m)
    # drain before the caller launches another chain: concurrent in-flight
    # executables with rendezvous collectives can exhaust the forced-CPU
    # device thread pool and deadlock (see dist_scenario_bank.py)
    jax.block_until_ready(state)
    return state, ms


# --- 1. zero-rate fault path ≈ legacy trajectory ----------------------------
jstep_l, st_l, xb, yb = make(FLConfig(**base))
st_legacy, ms_legacy = drive(jstep_l, st_l, xb, yb)
fl_f = FLConfig(faults=True, **base)
jstep_f, st_f, xb, yb = make(fl_f)
st_zero, ms_zero = drive(jstep_f, st_f, xb, yb)
for (ka, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(st_legacy)[0],
        jax.tree_util.tree_flatten_with_path(st_zero)[0]):
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7,
        err_msg=f"zero-rate faults diverged at {jax.tree_util.keystr(ka)}")
assert float(ms_zero[-1]["skipped"]) == 0.0, ms_zero[-1]
assert float(ms_zero[-1]["n_participants"]) == C * N, ms_zero[-1]
print("zero-rate parity OK")

# --- 2. total blackout: bit-exact identity round ----------------------------
fp_black = fault_params(FLConfig(faults=True, blackout_rate=1.0, **base))
st_black, ms_black = drive(jstep_f, st_f, xb, yb, faults=fp_black)
assert all(float(m["skipped"]) == 1.0 for m in ms_black), ms_black
assert float(ms_black[-1]["n_participants"]) == 0.0, ms_black[-1]
for (ka, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(st_f)[0],
        jax.tree_util.tree_flatten_with_path(st_black)[0]):
    path = jax.tree_util.keystr(ka)
    if "step" in path:
        continue
    np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b),
        err_msg=f"blackout round mutated state at {path}")
assert int(st_black.step) == int(st_f.step) + 2
print("blackout identity OK")

# --- 3. fault VALUES never re-trace -----------------------------------------
fp_part = fault_params(FLConfig(faults=True, dropout_rate=0.5,
                                straggler_rate=0.5, **base))
n0 = len(hota_step.TRACE_LOG)
st_cur = st_f
for i, fp in enumerate([fp_part, fp_black, fault_params(fl_f)]):
    st_cur, _ = jstep_f(st_cur, xb, yb, jax.random.PRNGKey(20 + i),
                        None, fp)
assert len(hota_step.TRACE_LOG) == n0, (n0, len(hota_step.TRACE_LOG))
jax.block_until_ready(st_cur)      # drain before the 2-D-mesh bank phase
print("fault no-retrace OK")

# --- 4. DistScenarioBank fault bank on the 2-D mesh -------------------------
from repro.core.sweep import DistScenarioBank
from repro.launch.mesh import make_dist_scenario_mesh

mesh2 = make_dist_scenario_mesh(1, 2)        # 2 scenario rows × (1 × 2)
fl_d = FLConfig(n_clusters=1, n_clients=2, faults=True, noise_std=0.1,
                weighting="fedgradnorm", tau_h=1)
bank = DistScenarioBank(model, fl_d, tcfg,
                        [dict(dropout_rate=0.0), dict(blackout_rate=1.0)],
                        mesh2, loss_kind="cls", n_out=MAXC)
states = bank.init(jax.random.PRNGKey(0))
tok = jax.random.normal(jax.random.PRNGKey(1), (2 * B, D))
lab = jax.random.randint(jax.random.PRNGKey(2), (2 * B,), 0, MAXC)
for r in range(2):
    states, dm = bank.step(states, tok, lab, jax.random.PRNGKey(3 + r))
assert dm["skipped"].shape == (2,), dm["skipped"].shape
assert float(dm["skipped"][0]) == 0.0 and float(dm["skipped"][1]) == 1.0, \
    np.asarray(dm["skipped"])
assert float(dm["n_participants"][0]) == 2.0, np.asarray(dm["n_participants"])
print("dist fault bank OK")

print("DIST_FAULTS_OK")
