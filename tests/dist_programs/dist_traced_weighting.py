"""Subprocess program: the traced weighting gate of the distributed step.

``make_hota_train_step``'s step_fn takes an optional traced ChannelParams;
its ``fgn_on`` gate selects dynamic vs. equal weighting INSIDE one
compiled step. This program pins the gate to the statically-baked
behavior in both directions on the 8-device (2x2x2) mesh:

* a step factory built from weighting="fedgradnorm", driven with a chan
  override carrying fgn_on=0, must reproduce the factory built from
  weighting="equal" running on its defaults — and vice versa.

Run: python dist_traced_weighting.py   (sets its own XLA_FLAGS)
"""
import dataclasses
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.channel import channel_params
from repro.core.hota_step import make_hota_train_step
from repro.models.model import build_model

C, N, B, D = 2, 2, 4, 256
MAXC = 8
STEPS = 3

cfg = ModelConfig(family="mlp", compute_dtype="float32")
model = build_model(cfg)
tcfg = TrainConfig(lr=1e-3)
devs = np.array(jax.devices()).reshape(C, N, 2)
mesh = Mesh(devs, ("cluster", "client", "model"))

fl_fgn = FLConfig(n_clusters=C, n_clients=N, weighting="fedgradnorm",
                  noise_std=0.1, tau_h=1)
fl_eq = dataclasses.replace(fl_fgn, weighting="equal")

key = jax.random.PRNGKey(0)
x = jax.random.normal(jax.random.fold_in(key, 1), (C * N * B, D))
y = jax.random.randint(jax.random.fold_in(key, 2), (C * N * B,), 0, MAXC)


def run(fl_static, chan_override):
    init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
        model, mesh, fl_static, tcfg, loss_kind="cls", n_out=MAXC)
    state = init_fn(jax.random.PRNGKey(123))
    state = jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        state, state_specs, is_leaf=lambda z: isinstance(z, P))
    xb = jax.device_put(x, NamedSharding(mesh, batch_spec[0]))
    yb = jax.device_put(y, NamedSharding(mesh, batch_spec[1]))
    jstep = jax.jit(step_fn)
    ms = []
    for s in range(STEPS):
        state, m = jstep(state, xb, yb, jax.random.PRNGKey(7 + s),
                         chan_override)
        ms.append(m)
    return state, ms


def compare(tag, a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-6, atol=1e-7, err_msg=tag)


chan_eq = channel_params(fl_eq, n_clusters=C)
chan_fgn = channel_params(fl_fgn, n_clusters=C)

# the weighting gate is TRACED: a step factory baked from either static
# config, driven with the other weighting's ChannelParams, must
# reproduce the factory whose static config matches those params
st_a, ms_a = run(fl_fgn, chan_eq)
st_b, ms_b = run(fl_eq, chan_eq)
compare("fgn_factory+eq_chan vs eq_factory+eq_chan", st_a, st_b)
compare("metrics", ms_a, ms_b)
assert all(float(m["p_mean"]) == 1.0 for m in ms_a)   # gate off: p stays 1

st_c, ms_c = run(fl_eq, chan_fgn)
st_d, ms_d = run(fl_fgn, chan_fgn)
compare("eq_factory+fgn_chan vs fgn_factory+fgn_chan", st_c, st_d)
compare("metrics", ms_c, ms_d)
# the gate really turned Alg. 2 on: weights moved off 1
assert not np.allclose(np.asarray(ms_c[-1]["p_min"]), 1.0)

# chan=None (knobs baked from the factory's FLConfig) is the same math —
# XLA may fold the constants into different fusions, so compare the loss
# trajectory at float tolerance rather than params bitwise
_, ms_def = run(fl_fgn, None)
for m_def, m_arg in zip(ms_def, ms_d):
    assert abs(float(m_def["loss"]) - float(m_arg["loss"])) < 2e-4
    assert abs(float(m_def["p_mean"]) - float(m_arg["p_mean"])) < 1e-4

# gate-flip schedule: turning FGN off mid-run FREEZES p (and the FGN
# Adam state/t) exactly like the sim's fgn_update_gated — it must NOT
# reset p to 1 or keep ticking the bias-correction step
init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
    model, mesh, fl_fgn, tcfg, loss_kind="cls", n_out=MAXC)
state = init_fn(jax.random.PRNGKey(123))
state = jax.tree.map(
    lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
    state, state_specs, is_leaf=lambda z: isinstance(z, P))
xb = jax.device_put(x, NamedSharding(mesh, batch_spec[0]))
yb = jax.device_put(y, NamedSharding(mesh, batch_spec[1]))
jstep = jax.jit(step_fn)
for s in range(2):
    state, _ = jstep(state, xb, yb, jax.random.PRNGKey(7 + s), chan_fgn)
p_after_fgn = np.asarray(state.p)
t_after_fgn = int(state.fgn_t)
assert t_after_fgn == 2 and not np.allclose(p_after_fgn, 1.0)
state, _ = jstep(state, xb, yb, jax.random.PRNGKey(9), chan_eq)
np.testing.assert_array_equal(np.asarray(state.p), p_after_fgn)
assert int(state.fgn_t) == t_after_fgn

print(f"DIST_TRACED_WEIGHTING_OK steps={STEPS} "
      f"p_range=[{float(ms_c[-1]['p_min']):.4f},"
      f"{float(ms_c[-1]['p_max']):.4f}]")
