"""Subprocess program: the distributed HOTA step trains a small dense model
on an 8-device (2 clusters x 2 clients x 2 model) mesh; loss must decrease
and FedGradNorm weights must stay normalized. Exercised in both ota modes.

Run: XLA_FLAGS="--xla_force_host_platform_device_count=8" python dist_train_step.py <mode>
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.hota_step import make_hota_train_step
from repro.models.model import build_model

mode = sys.argv[1] if len(sys.argv) > 1 else "scatter"
mb = int(sys.argv[2]) if len(sys.argv) > 2 else 1

devs = np.array(jax.devices()).reshape(2, 2, 2)
mesh = Mesh(devs, ("cluster", "client", "model"))

cfg = ModelConfig(family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab_size=128, attn_block_q=16,
                  attn_block_kv=16, remat_policy="nothing_saveable",
                  compute_dtype="float32")
model = build_model(cfg)
fl = FLConfig(n_clusters=2, n_clients=2, noise_std=0.1, ota_mode=mode,
              microbatches=mb)
init_fn, step_fn, state_specs, batch_spec = make_hota_train_step(
    model, mesh, fl, TrainConfig(lr=1e-3), loss_kind="lm")

state = init_fn(jax.random.PRNGKey(0))
state = jax.tree.map(lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                     state, state_specs, is_leaf=lambda x: isinstance(x, P))
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 128)
labs = jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, 128)
toks = jax.device_put(toks, NamedSharding(mesh, batch_spec[0]))
labs = jax.device_put(labs, NamedSharding(mesh, batch_spec[1]))

jstep = jax.jit(step_fn)
losses = []
for i in range(8):
    state, m = jstep(state, toks, labs, jax.random.PRNGKey(42))
    losses.append(float(m["loss"]))
    psum = float(m["p_mean"]) * 2

assert losses[-1] < losses[0], losses
assert np.isfinite(losses).all(), losses
assert abs(psum - 2.0) < 1e-3, psum
print(f"DIST_TRAIN_OK mode={mode} mb={mb} loss {losses[0]:.4f}->{losses[-1]:.4f}")
