"""Paper-scale simulator (Alg. 1) behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core.sim import HotaSim, masked_cls_loss
from repro.data.federated import FederatedBatcher
from repro.data.radcom import (
    N_CLASSES, RadComConfig, TASKS, client_partition, make_radcom_dataset,
)
from repro.models.model import build_model


def _make_sim(weighting="fedgradnorm", C=2, N=3, ota=True, noise=0.5,
              sigma2=()):
    data = make_radcom_dataset(RadComConfig(n_points=6000))
    parts = client_partition(data, C, N)
    batcher = FederatedBatcher(parts, 16)
    n_cls = [N_CLASSES[TASKS[i % 3]] for i in range(N)]
    model = build_model(ModelConfig(family="mlp"))
    fl = FLConfig(n_clusters=C, n_clients=N, weighting=weighting, ota=ota,
                  noise_std=noise, sigma2=sigma2)
    sim = HotaSim(model, fl, TrainConfig(lr=3e-4), n_cls)
    return sim, batcher


def _run(sim, batcher, steps, seed=0):
    state = sim.init(jax.random.PRNGKey(seed))
    losses = []
    for s in range(steps):
        x, y = batcher.next_stacked()
        state, m = sim.step(state, jnp.asarray(x), jnp.asarray(y),
                            jax.random.PRNGKey(100 + s))
        losses.append(np.asarray(m["loss"]).mean())
    return state, np.array(losses), m


@pytest.mark.slow
def test_training_reduces_loss():
    sim, batcher = _make_sim()
    _, losses, m = _run(sim, batcher, 25)
    assert losses[-5:].mean() < losses[:5].mean()
    assert np.isfinite(losses).all()


@pytest.mark.slow
def test_weights_stay_normalized():
    sim, batcher = _make_sim()
    state, _, m = _run(sim, batcher, 10)
    p = np.asarray(m["p"])
    np.testing.assert_allclose(p.sum(axis=1), 3.0, rtol=1e-4)
    assert (p > 0).all()


@pytest.mark.slow
def test_equal_weighting_keeps_p_one():
    sim, batcher = _make_sim(weighting="equal")
    _, _, m = _run(sim, batcher, 5)
    np.testing.assert_allclose(np.asarray(m["p"]), 1.0)


def test_masked_cls_loss_ignores_padded_classes():
    logits = jnp.array([[2.0, 1.0, -1.0, 99.0]])   # class 3 is padding
    labels = jnp.array([0])
    l_masked = masked_cls_loss(logits, labels, jnp.array(3))
    l_full = masked_cls_loss(logits, labels, jnp.array(4))
    assert float(l_masked) < float(l_full)        # 99-logit padding excluded


@pytest.mark.slow
def test_ota_off_equals_noiseless_aggregation():
    """fl.ota=False must remove both mask and noise: two runs with
    different noise_std give identical trajectories."""
    sim1, b1 = _make_sim(ota=False, noise=5.0)
    sim2, b2 = _make_sim(ota=False, noise=0.0)
    s1, l1, _ = _run(sim1, b1, 3)
    s2, l2, _ = _run(sim2, b2, 3)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
