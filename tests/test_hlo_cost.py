"""HLO cost-model unit tests (the roofline extractor's parser)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze, parse_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = _compile(lambda x, y: x @ y, a, b)
    t = analyze(c.as_text())
    assert t.flops == 2 * 64 * 128 * 32, t.flops


def test_scan_trip_count_multiplies():
    L = 7

    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    ws = jax.ShapeDtypeStruct((L, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    t = analyze(_compile(f, ws, x).as_text())
    assert t.flops == L * 2 * 8 * 32 * 32, t.flops


def test_nested_scan_multiplies():
    def f(ws, x):
        def outer(h, w):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), None
            h3, _ = jax.lax.scan(inner, h, None, length=3)
            return h3, None
        h, _ = jax.lax.scan(outer, x, ws)
        return h

    ws = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    t = analyze(_compile(f, ws, x).as_text())
    assert t.flops == 5 * 3 * 2 * 4 * 16 * 16, t.flops


def test_bytes_nonzero_and_major_subset():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = _compile(lambda x: jnp.tanh(x @ x) @ x, a, )
    t = analyze(c.as_text())
    assert t.bytes > 0
    assert 0 < t.bytes_major <= t.bytes


def test_parser_finds_entry():
    a = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    comps, entry = parse_hlo(_compile(lambda x: x + 1, a).as_text())
    assert entry is not None and entry in comps
