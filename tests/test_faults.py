"""Fault injection + graceful degradation (DESIGN.md §3.14).

Covers the PART_FOLD reserved stream domain (§4), the |M∩P| estimator's
bit-exact no-fault identity, zero-participant / guard-tripped rounds
degrading to identity steps in both sim engines, CRN and monotone
coupling of the participation draws, fault-knob no-retrace, the
RoundGuard checkpoint recovery loop, and the atomic checkpoint save.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.core import ota
from repro.core.channel import FaultParams, fault_params, stack_fault_params

C, N = 2, 2


def _key_data(k):
    return tuple(np.asarray(jax.random.key_data(k)).tolist()
                 if hasattr(jax.random, "key_data")
                 else np.asarray(k).tolist())


def _mk_sim(fl):
    from repro.core.sim import HotaSim
    from repro.models.model import build_model
    model = build_model(ModelConfig(family="mlp"))
    return HotaSim(model, fl, TrainConfig(lr=3e-4), [4, 4])


def _batch(key=None):
    if key is None:
        return jnp.zeros((C, N, 4, 256)), jnp.zeros((C, N, 4), jnp.int32)
    x = jax.random.normal(jax.random.fold_in(key, 0), (C, N, 4, 256))
    y = jax.random.randint(jax.random.fold_in(key, 1), (C, N, 4), 0, 4)
    return x, y


def _leaves_except_step(state):
    return [(jax.tree_util.keystr(kp), l) for kp, l in
            jax.tree_util.tree_flatten_with_path(state)[0]
            if "step" not in jax.tree_util.keystr(kp)]


# ======================================================== PART_FOLD (§4)

def test_part_fold_reserved_and_disjoint():
    """PART_FOLD is a pinned reserved fold domain, disjoint from every
    channel stream fold — resampling participation can never perturb the
    gain/noise streams (CRN across fault scenarios)."""
    from repro.core.hota_slab import PACKED_OMEGA_FOLD
    assert ota.PART_FOLD == 0x7FFF0004
    k = jax.random.PRNGKey(3)
    pk = ota.participation_key(k)
    assert _key_data(pk) == _key_data(jax.random.fold_in(k, ota.PART_FOLD))
    reserved = {ota.NOISE_FOLD, ota.PACKED_HEAD_FOLD, ota.PACKED_TAIL_FOLD,
                ota.PACKED_SECTION_FOLD_BASE, ota.SIM_CHAN_FOLD,
                ota.PART_FOLD, PACKED_OMEGA_FOLD}
    assert len(reserved) == 7                    # all domains distinct
    for fold in sorted(reserved - {ota.PART_FOLD}) + [0, 1, 17, 999]:
        assert _key_data(jax.random.fold_in(k, fold)) != _key_data(pk)
    # section folds BASE+s can never reach PART_FOLD for any real layout
    assert not (ota.PACKED_SECTION_FOLD_BASE <= ota.PART_FOLD
                < ota.PACKED_SECTION_FOLD_BASE + 0xF0)


def test_sim_step_draws_participation_from_reserved_fold(monkeypatch):
    """Behavioral pin: a faulted sim round calls ota.participation_key
    on the round key exactly once; a fault-free round never does."""
    calls = []
    orig = ota.participation_key

    def spy(k):
        calls.append(k)
        return orig(k)

    monkeypatch.setattr(ota, "participation_key", spy)
    x, y = _batch()
    sim = _mk_sim(FLConfig(n_clusters=C, n_clients=N, faults=True))
    sim.step(sim.init(jax.random.PRNGKey(0)), x, y, jax.random.PRNGKey(9))
    assert len(calls) == 1
    calls.clear()
    sim0 = _mk_sim(FLConfig(n_clusters=C, n_clients=N))
    sim0.step(sim0.init(jax.random.PRNGKey(0)), x, y, jax.random.PRNGKey(9))
    assert len(calls) == 0


# ========================================== participation draw semantics

def test_draw_participation_no_fault_identity():
    fp = fault_params(FLConfig(n_clusters=C, n_clients=N, faults=True))
    p = ota.draw_participation(jax.random.PRNGKey(0), fp, C, N)
    np.testing.assert_array_equal(np.asarray(p.part), np.ones((C, N)))
    np.testing.assert_array_equal(np.asarray(p.stale), np.zeros((C, N)))
    np.testing.assert_array_equal(np.asarray(p.live), np.ones((C,)))
    assert float(p.n_eff) == N and float(p.total) == C * N


def test_draw_participation_gate_off_ignores_rates():
    """faults_on < 0.5 (the faults=False baked FaultParams) makes every
    rate inert — full participation no matter the knob values."""
    fp = fault_params(FLConfig(n_clusters=C, n_clients=N))._replace(
        dropout=jnp.float32(1.0), blackout=jnp.float32(1.0))
    p = ota.draw_participation(jax.random.PRNGKey(0), fp, C, N)
    np.testing.assert_array_equal(np.asarray(p.part), np.ones((C, N)))


def test_draw_participation_monotone_coupling():
    """Same key, rising dropout rate: the participant set only shrinks
    (the draws are shared uniforms compared against the rate), so fault
    sweeps are monotone-coupled — variance-reduced like the CRN channel
    sweeps."""
    key = jax.random.PRNGKey(7)
    base = FLConfig(n_clusters=4, n_clients=8, faults=True)
    prev = None
    for rate in (0.0, 0.3, 0.6, 0.9, 1.0):
        fp = fault_params(dataclasses.replace(base, dropout_rate=rate))
        part = np.asarray(ota.draw_participation(key, fp, 4, 8).part)
        if prev is not None:
            assert np.all(part <= prev), (rate, part, prev)
        prev = part
    assert prev.sum() == 0                       # rate 1.0: nobody left


def test_participation_resampling_preserves_channel_streams():
    """CRN: the channel key and participation key live in disjoint fold
    domains of the SAME round key, so changing fault rates moves the
    participation draw but not one bit of the gain/noise streams."""
    key = jax.random.PRNGKey(11)
    ck = ota.sim_channel_key(key)
    assert _key_data(ck) != _key_data(ota.participation_key(key))
    fl = FLConfig(n_clusters=C, n_clients=N, faults=True)
    fp_a = fault_params(fl)
    fp_b = fault_params(dataclasses.replace(fl, dropout_rate=0.7,
                                            blackout_rate=0.3))
    pa = ota.draw_participation(key, fp_a, C, N)
    pb = ota.draw_participation(key, fp_b, C, N)
    assert not np.array_equal(np.asarray(pa.part), np.asarray(pb.part))
    # the underlying uniforms are rate-independent: rate 0 vs rate 1
    # draw the SAME uniforms (verified via the monotone coupling above),
    # and the channel key is untouched by construction
    assert _key_data(ota.sim_channel_key(key)) == _key_data(ck)


def _grad_tree(key, scale=1.0):
    ks = [jax.random.fold_in(key, i) for i in range(4)]
    return {"final": {"w": jax.random.normal(ks[0], (C, N, 40, 8)) * scale,
                      "b": jax.random.normal(ks[1], (C, N, 8)) * scale},
            "trunk": {"fc0": {
                "w": jax.random.normal(ks[2], (C, N, 30, 50)) * scale,
                "b": jax.random.normal(ks[3], (C, N, 50)) * scale}}}


def _packer(tree):
    from repro.common.flatpack import packer_for
    template = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape[2:], l.dtype), tree)
    return packer_for(template, tail="final", sections="toplevel")


def test_all_blocked_and_all_dropped_is_zero():
    """Every cluster dead (live = 0) ⇒ the |M∩P| estimate is exactly 0
    in both the per-leaf estimator and the client-folded kernel."""
    from repro.core.channel import channel_params
    key = jax.random.PRNGKey(0)
    g = _grad_tree(key)
    chan = channel_params(FLConfig(n_clusters=C, n_clients=N,
                                   noise_std=0.1))
    live0, n_eff0 = jnp.zeros((C,)), jnp.float32(0.0)
    wg = jax.tree.map(lambda l: jnp.sum(l, axis=1), g)   # (C, ...) sums
    out = ota.ota_aggregate_tree(key, wg, chan, N, live=live0,
                                 n_eff=n_eff0)
    for leaf in jax.tree.leaves(out):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.zeros_like(np.asarray(leaf)))
    got = ota.ota_aggregate_client_folded(key, g, jnp.ones((C, N)), chan,
                                          N, _packer(g), live=live0,
                                          n_eff=n_eff0)
    for leaf in jax.tree.leaves(got):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.zeros_like(np.asarray(leaf)))


def test_estimator_full_participation_bit_exact():
    """live=1, n_eff=N is bit-identical to the legacy eq.-10 estimator —
    the generalization costs nothing when no fault fires."""
    from repro.core.channel import channel_params
    key = jax.random.PRNGKey(5)
    g = _grad_tree(key)
    chan = channel_params(FLConfig(n_clusters=C, n_clients=N,
                                   noise_std=0.2, h_threshold=0.1))
    p_w = jax.random.uniform(jax.random.fold_in(key, 2), (C, N), None,
                             0.5, 1.5)
    packer = _packer(g)
    legacy = ota.ota_aggregate_client_folded(key, g, p_w, chan, N, packer)
    general = ota.ota_aggregate_client_folded(
        key, g, p_w, chan, N, packer, live=jnp.ones((C,)),
        n_eff=jnp.float32(N))
    for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(general)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ============================================ degradation: identity step

@pytest.mark.parametrize("use_pallas_ota", [False, True],
                         ids=["per-leaf", "slab"])
def test_zero_participant_round_is_identity(use_pallas_ota):
    """Total blackout ⇒ the round is a bit-exact identity step in BOTH
    sim engines: params, Adam moments, FGN state all frozen; only the
    step counter advances (mirrors the fgn_on gate-off contract)."""
    fl = FLConfig(n_clusters=C, n_clients=N, faults=True, noise_std=0.1,
                  use_pallas_ota=use_pallas_ota)
    sim = _mk_sim(fl)
    st0 = sim.init(jax.random.PRNGKey(0))
    x, y = _batch(jax.random.PRNGKey(1))
    fp = fault_params(dataclasses.replace(fl, blackout_rate=1.0))
    st, m = sim.step(st0, x, y, jax.random.PRNGKey(2), faults=fp)
    assert float(m["skipped"]) == 1.0
    assert float(m["n_participants"]) == 0.0
    for (pa, a), (_, b) in zip(_leaves_except_step(st0),
                               _leaves_except_step(st)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"blackout mutated {pa}")
    assert int(st.step) == int(st0.step) + 1


@pytest.mark.parametrize("use_pallas_ota", [False, True],
                         ids=["per-leaf", "slab"])
def test_guard_tripped_round_is_identity(use_pallas_ota):
    """spike_norm=0 trips the divergence guard on any non-zero gradient:
    full participation, yet the round degrades to the same bit-exact
    identity step."""
    fl = FLConfig(n_clusters=C, n_clients=N, faults=True,
                  use_pallas_ota=use_pallas_ota, spike_norm=0.0)
    sim = _mk_sim(fl)
    st0 = sim.init(jax.random.PRNGKey(0))
    x, y = _batch(jax.random.PRNGKey(1))
    st, m = sim.step(st0, x, y, jax.random.PRNGKey(2))
    assert float(m["skipped"]) == 1.0
    assert float(m["n_participants"]) == C * N   # the guard, not faults
    for (pa, a), (_, b) in zip(_leaves_except_step(st0),
                               _leaves_except_step(st)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"guard trip mutated {pa}")


def test_zero_rate_faults_matches_legacy():
    """The fault path at zero rates reproduces the legacy (faults=False)
    trajectory: mathematically identical (live=1, n_eff=N, discount=1 is
    the eq.-10 estimator, and the kernel layer IS bit-exact — see
    test_estimator_full_participation_bit_exact), to float tolerance
    end-to-end because the fault trace adds the guard-sum + freeze
    select, which changes XLA's fusion choices at the ulp level."""
    x, y = _batch(jax.random.PRNGKey(1))
    fl0 = FLConfig(n_clusters=C, n_clients=N, noise_std=0.1)
    fl1 = dataclasses.replace(fl0, faults=True)
    sims = [_mk_sim(fl0), _mk_sim(fl1)]
    states = [s.init(jax.random.PRNGKey(0)) for s in sims]
    for r in range(2):
        states = [s.step(st, x, y, jax.random.PRNGKey(2 + r))[0]
                  for s, st in zip(sims, states)]
    legacy = {p: l for p, l in _leaves_except_step(states[0])}
    faulted = {p: l for p, l in _leaves_except_step(states[1])}
    for p, l in legacy.items():
        np.testing.assert_allclose(
            np.asarray(l), np.asarray(faulted[p]), rtol=1e-5, atol=1e-7,
            err_msg=f"zero-rate fault path diverged at {p}")


def test_fault_knob_values_never_retrace():
    """FaultParams are traced knobs: sweeping VALUES through one jitted
    sim step re-traces nothing (the §3.11 contract, extended to §3.14)."""
    fl = FLConfig(n_clusters=C, n_clients=N, faults=True)
    sim = _mk_sim(fl)
    st = sim.init(jax.random.PRNGKey(0))
    x, y = _batch()
    traces = []

    @jax.jit
    def step(st, x, y, k, fp):
        traces.append(1)
        return sim.step_with_channel(st, x, y, k, sim.chan, faults=fp)

    fps = [fault_params(dataclasses.replace(fl, dropout_rate=r,
                                            spike_norm=s))
           for r, s in ((0.0, float("inf")), (0.5, 10.0), (1.0, 0.0))]
    for i, fp in enumerate(fps):
        st2, _ = step(st, x, y, jax.random.PRNGKey(i), fp)
    assert len(traces) == 1, f"fault values re-traced: {len(traces)} traces"


# ======================================================== scenario banks

def test_fault_scenario_bank_sweeps_in_one_trace():
    from repro.core.sweep import ScenarioBank
    fl = FLConfig(n_clusters=C, n_clients=N, faults=True)
    sim = _mk_sim(fl)
    bank = ScenarioBank(sim, [dict(dropout_rate=0.0),
                              dict(blackout_rate=1.0),
                              fault_params(dataclasses.replace(
                                  fl, straggler_rate=1.0))])
    states = bank.init(jax.random.PRNGKey(0))
    x, y = _batch(jax.random.PRNGKey(1))
    states, m = bank.step(states, x, y, jax.random.PRNGKey(2))
    assert m["skipped"].shape == (3,)
    assert float(m["skipped"][0]) == 0.0
    assert float(m["skipped"][1]) == 1.0         # blackout: all skipped
    assert float(m["n_participants"][0]) == C * N


def test_fault_knob_rejected_on_gateless_bank():
    """A scenario varying a fault knob over a faults=False base would be
    silently inert — the bank refuses to build it."""
    from repro.core.sweep import ScenarioBank
    sim = _mk_sim(FLConfig(n_clusters=C, n_clients=N))
    with pytest.raises(ValueError, match="faults=True"):
        ScenarioBank(sim, [dict(dropout_rate=0.5)])
    with pytest.raises(ValueError, match="faults=True"):
        ScenarioBank(sim, [fault_params(
            FLConfig(n_clusters=C, n_clients=N, faults=True))])


def test_stack_fault_params_banks_like_channel_params():
    fl = FLConfig(n_clusters=C, n_clients=N, faults=True)
    bank = stack_fault_params([
        fault_params(dataclasses.replace(fl, dropout_rate=r))
        for r in (0.0, 0.25, 0.5)])
    assert bank.dropout.shape == (3,)
    np.testing.assert_allclose(np.asarray(bank.dropout),
                               [0.0, 0.25, 0.5])
    assert isinstance(bank, FaultParams)


# ========================================= RoundGuard checkpoint recovery

def test_round_guard_restores_after_patience(tmp_path):
    """Integration: a wedged run (spike guard trips every round) is
    rolled back to the latest checkpoint after K consecutive skips."""
    from repro.checkpoint.store import save_checkpoint
    from repro.launch.train import RoundGuard
    fl = FLConfig(n_clusters=C, n_clients=N, faults=True, spike_norm=0.0)
    sim = _mk_sim(fl)
    st0 = sim.init(jax.random.PRNGKey(0))
    x, y = _batch(jax.random.PRNGKey(1))
    ckpt = str(tmp_path / "ck")
    save_checkpoint(ckpt, 0, jax.tree.map(np.asarray, st0))
    guard = RoundGuard(ckpt, jax.eval_shape(sim.init,
                                            jax.random.PRNGKey(0)),
                       patience=3)
    st = st0
    restores = []
    for r in range(4):
        st, m = sim.step(st, x, y, jax.random.PRNGKey(2 + r))
        assert float(m["skipped"]) == 1.0
        st, restored = guard.observe(m["skipped"], st)
        restores.append(restored)
    assert restores == [False, False, True, False]
    assert guard.n_restores == 1
    # st is the state AFTER one more skipped round on the restored
    # checkpoint copy: compare against st0 advanced by one identity step
    st_ref, _ = sim.step(st0, x, y, jax.random.PRNGKey(2 + 3))
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_flatten_with_path(st_ref)[0],
            jax.tree_util.tree_flatten_with_path(st)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"post-restore at {pa}")


def test_round_guard_clean_round_resets_streak(tmp_path):
    from repro.launch.train import RoundGuard
    guard = RoundGuard(str(tmp_path / "none"), {"a": np.zeros(2)},
                       patience=2)
    s = {"a": np.ones(2)}
    for skipped in (1.0, 0.0, 1.0):
        out, restored = guard.observe(skipped, s)
        assert out is s and not restored
    assert guard.streak == 1
    # no checkpoint on disk: hitting patience keeps the live state
    out, restored = guard.observe(1.0, s)
    assert out is s and not restored and guard.streak == 0


# ============================================== atomic checkpoint saves

def test_checkpoint_save_is_atomic_under_crash(tmp_path, monkeypatch):
    """A crash mid-save must leave no dir that latest_step/restore would
    pick up — the manifest lands last inside a temp dir and one
    os.replace publishes it."""
    import repro.checkpoint.store as store
    d = str(tmp_path / "ck")
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    store.save_checkpoint(d, 1, tree)
    assert store.latest_step(d) == 1

    real_packb = store.msgpack.packb

    def boom(*a, **kw):
        raise RuntimeError("simulated crash before manifest write")

    monkeypatch.setattr(store.msgpack, "packb", boom)
    with pytest.raises(RuntimeError, match="simulated crash"):
        store.save_checkpoint(d, 2, {"w": tree["w"] * 2})
    # torn save: arr files exist in the temp dir, but no step_2 dir and
    # latest_step still reports the last COMPLETE checkpoint
    assert not os.path.isdir(os.path.join(d, "step_00000002"))
    assert store.latest_step(d) == 1
    restored = store.restore_checkpoint(d, 1, tree)
    np.testing.assert_array_equal(restored["w"], tree["w"])

    monkeypatch.setattr(store.msgpack, "packb", real_packb)
    store.save_checkpoint(d, 2, {"w": tree["w"] * 2})   # reuses temp dir
    assert store.latest_step(d) == 2
    got = store.restore_checkpoint(d, 2, tree)
    np.testing.assert_array_equal(got["w"], tree["w"] * 2)


def test_latest_step_skips_manifestless_dirs(tmp_path):
    import repro.checkpoint.store as store
    d = str(tmp_path / "ck")
    store.save_checkpoint(d, 3, {"w": np.zeros(2, np.float32)})
    os.makedirs(os.path.join(d, "step_00000009"))    # torn pre-atomic dir
    assert store.latest_step(d) == 3


# ================================================= dist engine (slow)

@pytest.mark.slow
def test_dist_faults():
    """Subprocess (8 host devices): zero-rate parity, blackout identity,
    fault no-retrace, and the fault scenario bank on the dist engine."""
    from tests.test_dist import _run
    out = _run("dist_faults.py")
    assert "DIST_FAULTS_OK" in out
