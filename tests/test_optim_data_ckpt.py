"""Substrate tests: optimizer math, schedules, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.federated import FederatedBatcher
from repro.data.lm import synthetic_lm_batches
from repro.data.radcom import (
    N_CLASSES, RadComConfig, TASKS, client_partition, make_radcom_dataset,
)
from repro.optim import (
    adam_init, adam_update, clip_by_global_norm, cosine_decay,
    linear_warmup_cosine,
)


# ----------------------------------------------------------------- optimizer
def test_adam_matches_reference_formula():
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.1, -0.3])}
    st_ = adam_init(p)
    p1, st_ = adam_update(g, st_, p, lr=0.01)
    # step 1: mhat = g, vhat = g², delta = g/(|g|+eps) = sign(g)
    want = np.array([1.0, -2.0]) - 0.01 * np.sign([0.1, -0.3])
    np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-5)


def test_adam_converges_quadratic():
    p = {"w": jnp.array([5.0, -3.0])}
    st_ = adam_init(p)
    for _ in range(500):
        g = {"w": 2 * p["w"]}
        p, st_ = adam_update(g, st_, p, lr=0.05)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 6.0) < 1e-5
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4


def test_schedules():
    s = linear_warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.array(0))) == 0.0
    assert abs(float(s(jnp.array(10))) - 1.0) < 1e-5
    c = cosine_decay(1.0, 100, final_frac=0.1)
    assert float(c(jnp.array(100))) <= 0.11


# ---------------------------------------------------------------------- data
def test_radcom_schema():
    data = make_radcom_dataset(RadComConfig(n_points=5000))
    assert data["x"].shape == (5000, 256)
    assert data["modulation"].max() < 6
    assert data["signal"].max() < 8
    assert set(np.unique(data["anomaly"])) <= {0, 1}
    # anomaly definition: SNR < -4 dB
    np.testing.assert_array_equal(data["anomaly"],
                                  (data["snr_db"] < -4).astype(np.int64))


def test_client_partition_tasks_distinct_within_cluster():
    data = make_radcom_dataset(RadComConfig(n_points=3000))
    parts = client_partition(data, 2, 3)
    for cluster in parts:
        tasks = [c["task"] for c in cluster]
        assert tasks == list(TASKS)          # distinct tasks (paper Sec. II)
        for c in cluster:
            assert c["y"].max() < N_CLASSES[c["task"]]


def test_batcher_flatten_client_major():
    data = make_radcom_dataset(RadComConfig(n_points=3000))
    parts = client_partition(data, 2, 2)
    b = FederatedBatcher(parts, 4)
    x, y = b.next_stacked()
    assert x.shape == (2, 2, 4, 256)
    flat = FederatedBatcher.flatten(x)
    np.testing.assert_array_equal(flat[:4], x[0, 0])
    np.testing.assert_array_equal(flat[4:8], x[0, 1])


def test_lm_batches_deterministic():
    it1 = synthetic_lm_batches(1000, 2, 16, seed=3)
    it2 = synthetic_lm_batches(1000, 2, 16, seed=3)
    t1, l1 = next(it1)
    t2, l2 = next(it2)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(l1, l2)
    assert t1.max() < 1000


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(6.0).reshape(2, 3),
            "opt": {"mu": jnp.ones((4,), jnp.float32),
                    "step": jnp.array(7, jnp.int32)}}
    d = str(tmp_path)
    save_checkpoint(d, 42, tree, {"note": "test"})
    assert latest_step(d) == 42
    like = jax.tree.map(jnp.zeros_like, tree)
    back = restore_checkpoint(d, 42, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
