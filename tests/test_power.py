"""Power-constraint math (paper eq. 4) vs Monte Carlo."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.power import (
    calibrate_h_threshold, expected_entry_power, inv_h2_truncated_mean,
    pass_rate,
)


@settings(max_examples=10, deadline=None)
@given(sigma2=st.floats(0.5, 2.0), h_th=st.floats(0.01, 0.5),
       seed=st.integers(0, 100))
def test_truncated_inverse_moment_matches_monte_carlo(sigma2, h_th, seed):
    n = 400_000
    h = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n,))) \
        * np.sqrt(sigma2)
    mask = h * h >= h_th
    mc = np.where(mask, 1.0 / np.maximum(h * h, 1e-20), 0.0).mean()
    closed = float(inv_h2_truncated_mean(h_th, sigma2))
    assert abs(mc - closed) / closed < 0.08, (mc, closed)


def test_power_decreases_with_threshold():
    vals = [float(expected_entry_power(1.0, 1.0, t, 1.0))
            for t in (0.001, 0.01, 0.032, 0.1, 1.0)]
    assert all(a > b for a, b in zip(vals, vals[1:])), vals


def test_calibration_inverts_power():
    p_budget = 2.5
    th = calibrate_h_threshold(p_budget, [1.0, 1.1, 0.9], [1.0, 1.0, 1.0],
                               1.0, n_entries=1)
    from repro.core.power import expected_transmit_power
    got = float(expected_transmit_power([1.0, 1.1, 0.9], [1.0] * 3,
                                        th, 1.0, 1))
    assert abs(got - p_budget) / p_budget < 1e-3, (got, float(th))


def test_papers_threshold_sparsification_level():
    """H_th = 3.2e-2 at σ²=1 transmits ~85.8% of entries (2Q(0.179))."""
    rate = float(pass_rate(3.2e-2, 1.0))
    assert abs(rate - 0.858) < 0.005, rate


def test_zero_threshold_power_diverges():
    """Inverting arbitrarily deep fades costs unbounded power — the reason
    the paper thresholds at all."""
    small = float(expected_entry_power(1.0, 1.0, 1e-10, 1.0))
    ref = float(expected_entry_power(1.0, 1.0, 3.2e-2, 1.0))
    assert small > 100 * ref
