"""Config-zoo abstract layout smoke suite (DESIGN.md §3.16).

Every config in ``src/repro/configs`` — including the multi-billion-
parameter ones — is checked at its FULL size without materializing a
single weight: the omega template comes out of ``jax.eval_shape`` over
the real ``init_params``, and everything downstream (the toplevel
``TreePacker``, the stream-fold schedule, the ``leaf_runs`` zero-copy
partition, the ``max_section_rows`` peak bound) is static metadata.
This is the pin that the section-streaming engine's layout invariants
hold for the whole zoo, not just the shapes the unit tests happen to
build.
"""
from __future__ import annotations

import jax
import pytest

from repro.common.flatpack import TreePacker
from repro.configs import ARCH_IDS, get_config
from repro.core.ota import (PACKED_SECTION_FOLD_BASE, PACKED_TAIL_FOLD,
                            packed_section_folds)
from repro.kernels.slab import LANE, ROW_QUANTUM, round_up
from repro.models.model import build_model
from repro.models.params import init_params

# splits most real layer stacks (524k elements) while staying far above
# the coalescer's thresholds — a working billion-parameter budget knob
SPLIT_ROWS = 4096


def _abstract_template(arch: str):
    """The {final, trunk} omega template of ``arch`` at FULL size, via
    jax.eval_shape over the real initializers — no weight memory."""
    model = build_model(get_config(arch))

    def init(key):
        return {"final": init_params(model.final_specs(), key),
                "trunk": init_params(model.trunk_specs(), key)}
    return jax.eval_shape(init, jax.random.PRNGKey(0))


@pytest.fixture(scope="module", params=ARCH_IDS)
def packed(request):
    template = _abstract_template(request.param)
    packer = TreePacker(template, tail="final", sections="toplevel")
    split = TreePacker(template, tail="final", sections="toplevel",
                       max_section_rows=SPLIT_ROWS)
    return request.param, template, packer, split


def test_fold_schedule(packed):
    """One distinct stream fold per section; the ω̃ tail keeps
    PACKED_TAIL_FOLD in every layout (eq.-5 stream stability)."""
    _, _, packer, split = packed
    for pk in (packer, split):
        folds = packed_section_folds(pk)
        assert len(folds) == len(pk.sections) > 1
        assert len(set(folds)) == len(folds), "stream folds must be unique"
        assert pk.sections[-1].name == pk.tail_name
        assert folds[-1] == PACKED_TAIL_FOLD
        for sec, fold in zip(pk.sections[:-1], folds[:-1]):
            assert fold == PACKED_SECTION_FOLD_BASE + sec.index


def test_leaf_runs_partition(packed):
    """leaf_runs is an exact partition: every leaf exactly once, runs
    inside their section, sizes matching the slots, sections tiling the
    slab in order."""
    _, template, packer, split = packed
    leaves = jax.tree.leaves(template)
    for pk in (packer, split):
        runs = pk.leaf_runs()
        assert sorted(r.leaf for r in runs) == list(range(len(leaves)))
        by_section = {}
        for r in runs:
            sec = pk.sections[r.section]
            assert 0 <= r.offset and r.offset + r.size <= sec.length
            assert r.size == pk.slots[r.leaf].size
            by_section.setdefault(r.section, []).append(r)
        for s, sec in enumerate(pk.sections):
            assert tuple(r.leaf for r in by_section.get(s, [])) \
                == sec.leaf_indices
        # sections tile [0, P) in order, ROW_QUANTUM-aligned
        off = 0
        for sec in pk.sections:
            assert sec.start == off and sec.start % ROW_QUANTUM == 0
            assert sec.length % ROW_QUANTUM == 0
            off += sec.length
        assert off == pk.size


def test_split_peak_rows_bound(packed):
    """The documented §4 split rule: peak live section ≤
    max(max_section_rows, ceil(largest_leaf / LANE)) rows — the
    memory-budget guarantee the sectioned engine relies on — and the
    split changes only the partition, never where data lives."""
    _, template, packer, split = packed
    largest = max(r.size for r in packer.leaf_runs())
    bound = max(SPLIT_ROWS, round_up(largest, ROW_QUANTUM) // LANE)
    assert split.peak_section_rows() <= bound
    assert split.peak_section_rows() <= packer.peak_section_rows()
    # a zoo config big enough to split must actually split
    if packer.peak_section_rows() > bound:
        assert len(split.sections) > len(packer.sections)
    # layout-only transform: identical slab, identical leaf offsets
    assert split.size == packer.size
    assert split.slots == packer.slots
