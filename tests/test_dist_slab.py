"""Slab-native distributed path tests (DESIGN.md §3.10). Each runs in a
subprocess so it can claim 4 host devices before jax initializes (the
main pytest process stays single-device) — same harness as test_dist.py."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, "..", "src"))


def _run(program: str, *args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist_programs", program), *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, (
        f"{program} {args} failed:\nSTDOUT:{proc.stdout[-3000:]}\n"
        f"STDERR:{proc.stderr[-3000:]}")
    return proc.stdout


@pytest.mark.slow
def test_dist_slab_step():
    """Slab step == per-leaf oracle; channel-on == jnp oracle on shared
    keys; zero-copy HLO pin; ChannelParams values never retrace."""
    out = _run("dist_slab_step.py")
    assert "DIST_SLAB_OK" in out


@pytest.mark.slow
def test_dist_scenario_bank():
    """2-D (scenario × client) bank: CRN across scenario shards, 1-D step
    oracle per scenario, cross-layout checkpoint restore-equivalence."""
    out = _run("dist_scenario_bank.py")
    assert "DIST_SCENARIO_BANK_OK" in out
