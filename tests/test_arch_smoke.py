"""Per-assigned-architecture smoke tests (assignment deliverable f):
instantiate the REDUCED variant of each family and run one forward + one
train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model, init_params, lm_loss
from repro.optim import adam_init, adam_update

ARCHS = [a for a in ARCH_IDS if a != "paper_mlp"]


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = init_params(m.backbone_specs(), jax.random.PRNGKey(0))
    head = init_params(m.head_specs(), jax.random.PRNGKey(1))
    B, S = 2, 32
    if cfg.modality == "vision":
        inputs = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
    else:
        inputs = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                    cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                                cfg.vocab_size)

    logits, aux, _ = m.forward_logits(params, head, inputs, mode="train")
    assert logits.shape == (B, S, cfg.vocab_size), arch
    assert np.all(np.isfinite(np.asarray(logits))), arch

    def loss_fn(p, h):
        lg, aux, _ = m.forward_logits(p, h, inputs, mode="train")
        return lm_loss(lg, labels) + aux

    (l0), grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(params, head)
    gp, gh = grads
    opt = adam_init(params)
    params2, _ = adam_update(gp, opt, params, 1e-3)
    l1 = loss_fn(params2, head)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1)), arch
    # one Adam step on this batch should reduce this batch's loss
    assert float(l1) < float(l0) + 1e-4, (arch, float(l0), float(l1))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyper-parameters."""
    spec = {
        "starcoder2_3b": (30, 3072, 24, 2, 12288, 49152),
        "stablelm_3b": (32, 2560, 32, 32, 6912, 50304),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
        "phi3_vision_4_2b": (32, 3072, 32, 32, 8192, 32064),
        "gemma3_12b": (48, 3840, 16, 8, 15360, 262144),
        "zamba2_1_2b": (38, 2048, 32, 32, 8192, 32000),
        "phi3_5_moe_42b": (32, 4096, 32, 8, 6400, 32064),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "qwen2_5_14b": (48, 5120, 40, 8, 13824, 152064),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, (arch, got, spec)


def test_moe_configs_expert_counts():
    assert get_config("phi3_5_moe_42b").moe.n_experts == 16
    assert get_config("mixtral_8x22b").moe.n_experts == 8
    assert get_config("phi3_5_moe_42b").moe.top_k == 2
    assert get_config("zamba2_1_2b").ssm.d_state == 64
