"""Sharded sweep engine + traced-weighting distributed step.

Each heavy check runs in a subprocess so it can force multiple host
devices before jax initializes (the main pytest process stays
single-device); the light checks (bank validation, error messages) run
in-process on the default device.
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, "..", "src"))


def _run(program: str, *args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist_programs", program), *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, (
        f"{program} {args} failed:\nSTDOUT:{proc.stdout[-3000:]}\n"
        f"STDERR:{proc.stderr[-3000:]}")
    return proc.stdout


@pytest.mark.slow
def test_sharded_bank_matches_vmap_and_oracle():
    """S=16 sharded over 2 forced CPU devices: sharded == vmap == the
    sequential per-scenario oracle, and CRN holds across shards."""
    out = _run("sweep_sharded.py")
    assert "SWEEP_SHARDED_OK" in out


@pytest.mark.slow
def test_traced_weighting_matches_static_step():
    """One compiled distributed step serves both weightings: driving the
    fgn-built step with an equal-weighting ChannelParams reproduces the
    equal-built step, and vice versa."""
    out = _run("dist_traced_weighting.py")
    assert "DIST_TRACED_WEIGHTING_OK" in out


def test_traced_fields_error_names_both_values():
    """The bank's static-mismatch rejection must name the offending field
    AND both differing values, so a failing sweep config is debuggable
    from the message alone."""
    from repro.common.config import FLConfig
    from repro.core.paper_setup import paper_mlp_setup
    from repro.core.sweep import ScenarioBank

    sim, _ = paper_mlp_setup(FLConfig(n_clusters=2, n_clients=3),
                             batch=8, n_points=3000)
    with pytest.raises(ValueError) as exc:
        ScenarioBank(sim, [dict(ota_mode="naive")])
    msg = str(exc.value)
    assert "ota_mode" in msg            # the field
    assert "'naive'" in msg             # the scenario's value
    assert "'scatter'" in msg           # the bank's base value
    with pytest.raises(ValueError) as exc:
        ScenarioBank(sim, [dict(gamma=0.9)])
    msg = str(exc.value)
    assert "gamma" in msg and "0.9" in msg and "0.6" in msg
