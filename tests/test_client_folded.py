"""Slab-native simulator channel (DESIGN.md §3.12): the client-folded
zero-copy OTA aggregation vs the per-leaf/packed oracles on shared bit
streams, the sim-vs-distributed stream-schedule pin, the SIM_CHAN_FOLD
reserved-domain pin, and the HLO assertion that the new sim step
allocates no (C, P) slab-sized buffer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import hlo_audit
from repro.common.config import FLConfig, ModelConfig, TrainConfig
from repro.common.flatpack import packer_for
from repro.core import ota
from repro.core.channel import channel_params, stack_channel_params
from repro.kernels.ota_channel.ops import ota_client_fold_apply
from repro.kernels.ota_channel.ref import bits_to_gaussian, bits_to_mask

C, N = 3, 2


def _grad_tree(key, C, N, scale=1.0):
    """A raw per-client gradient pytree in the sim's omega layout —
    leaves (C, N, *shape), several trunk layer stacks."""
    ks = [jax.random.fold_in(key, i) for i in range(6)]
    return {
        "final": {"w": jax.random.normal(ks[0], (C, N, 40, 8)) * scale,
                  "b": jax.random.normal(ks[1], (C, N, 8)) * scale},
        "trunk": {"fc0": {"w": jax.random.normal(ks[2], (C, N, 30, 50)) * scale,
                          "b": jax.random.normal(ks[3], (C, N, 50)) * scale},
                  "fc1": {"w": jax.random.normal(ks[4], (C, N, 50, 40)) * scale,
                          "b": jax.random.normal(ks[5], (C, N, 40)) * scale}},
    }


def _template(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[2:], l.dtype),
                        tree)


def _packer(tree):
    return packer_for(_template(tree), tail="final", sections="toplevel")


# ----------------------------------------------------------------- oracle
def test_client_folded_matches_einsum_plus_packed():
    """Client-folded == einsum("cn,cn...->c...") followed by the packed
    kernel on the SAME multi-section layout — the weighted tree is
    mathematically folded in, not re-derived from different streams."""
    fl = FLConfig(n_clusters=C, n_clients=N, sigma2=(0.5, 1.0, 2.0),
                  noise_std=0.7)
    chan = channel_params(fl)
    key = jax.random.PRNGKey(11)
    g = _grad_tree(jax.random.fold_in(key, 1), C, N)
    p = jax.random.uniform(jax.random.fold_in(key, 2), (C, N), jnp.float32,
                           0.5, 1.5)
    packer = _packer(g)

    ghat = ota.ota_aggregate_client_folded(key, g, p, chan, N, packer)
    wg = jax.tree.map(lambda l: jnp.einsum("cn,cn...->c...", p, l), g)
    oracle = ota.ota_aggregate_packed(key, wg, chan, N, packer)
    for (kp, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(ghat)[0],
                               jax.tree_util.tree_flatten_with_path(oracle)[0]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=jax.tree_util.keystr(kp))


def test_client_folded_matches_per_leaf_oracle_on_shared_streams():
    """Decode the SAME section streams into per-leaf masks/noise and run
    the seed per-leaf estimator (ota_aggregate_leaf) on the einsum'd
    weighted tree — the client-folded path must reproduce it."""
    fl = FLConfig(n_clusters=C, n_clients=N, sigma2=(0.25, 0.5, 1.0),
                  noise_std=0.4)
    chan = channel_params(fl)
    key = jax.random.PRNGKey(5)
    g = _grad_tree(jax.random.fold_in(key, 1), C, N)
    p = jax.random.uniform(jax.random.fold_in(key, 2), (C, N), jnp.float32,
                           0.5, 1.5)
    packer = _packer(g)

    ghat = ota.ota_aggregate_client_folded(key, g, p, chan, N, packer)

    bits = ota.packed_gain_bits(key, packer, C)              # (C, P)
    nbits = ota.packed_noise_bits(key, packer)
    sig = chan.sigma2.reshape(C, 1)
    mask_tree = packer.unpack(
        bits_to_mask(bits, sig, chan.h_threshold, chan.ota_on)
        .astype(jnp.float32))
    noise_tree = packer.unpack(bits_to_gaussian(nbits, 1.0)
                               * chan.noise_std * chan.ota_on)
    wg = jax.tree.map(lambda l: jnp.einsum("cn,cn...->c...", p, l), g)
    oracle = jax.tree.map(
        lambda w, m, z: ota.ota_aggregate_leaf(w, m > 0.5, z, N),
        wg, mask_tree, noise_tree)
    for a, b in zip(jax.tree.leaves(ghat), jax.tree.leaves(oracle)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_client_folded_ota_off_is_weighted_mean():
    """ota=False: all-pass masks, zero AWGN -> ĝ = Σ_l Σ_n p·g / (C·N)."""
    fl = FLConfig(n_clusters=C, n_clients=N, noise_std=7.0, ota=False)
    chan = channel_params(fl)
    g = _grad_tree(jax.random.PRNGKey(3), C, N)
    p = jax.random.uniform(jax.random.PRNGKey(4), (C, N), jnp.float32,
                           0.5, 1.5)
    packer = _packer(g)
    ghat = ota.ota_aggregate_client_folded(jax.random.PRNGKey(8), g, p,
                                           chan, N, packer)
    for a, l in zip(jax.tree.leaves(ghat), jax.tree.leaves(g)):
        ref = np.einsum("cn,cn...->...", np.asarray(p), np.asarray(l)) / (C * N)
        np.testing.assert_allclose(np.asarray(a), ref, rtol=1e-5, atol=1e-6)


def test_client_folded_all_blocked_is_exact_zero():
    """σ² → 0 with H_th > 0: |M| = 0 everywhere -> exactly 0, never
    noise/(cnt·N), never NaN."""
    fl = FLConfig(n_clusters=C, n_clients=N, h_threshold=0.5, noise_std=5.0,
                  sigma2=(1e-14,))
    chan = channel_params(fl)
    g = jax.tree.map(lambda l: jnp.full_like(l, 1e6),
                     _grad_tree(jax.random.PRNGKey(0), C, N))
    p = jnp.full((C, N), 2.0)
    packer = _packer(g)
    ghat = ota.ota_aggregate_client_folded(jax.random.PRNGKey(13), g, p,
                                           chan, N, packer)
    for leaf in jax.tree.leaves(ghat):
        arr = np.asarray(leaf)
        assert np.isfinite(arr).all()
        np.testing.assert_array_equal(arr, np.zeros_like(arr))


def test_client_folded_composes_with_scenario_vmap():
    """Under an (S≥4,)-batched ChannelParams bank with a shared key/grads
    (the ScenarioBank contract), every row equals its unbanked run."""
    base = FLConfig(n_clusters=C, n_clients=N)
    bank = stack_channel_params([
        channel_params(base),
        channel_params(FLConfig(n_clusters=C, n_clients=N,
                                sigma2=(0.05, 1.0, 1.0))),
        channel_params(FLConfig(n_clusters=C, n_clients=N, ota=False)),
        channel_params(FLConfig(n_clusters=C, n_clients=N, noise_std=3.0)),
    ])
    key = jax.random.PRNGKey(21)
    g = _grad_tree(jax.random.fold_in(key, 1), C, N)
    p = jax.random.uniform(jax.random.fold_in(key, 2), (C, N), jnp.float32,
                           0.5, 1.5)
    packer = _packer(g)
    banked = jax.vmap(
        lambda ch: ota.ota_aggregate_client_folded(key, g, p, ch, N, packer)
    )(bank)
    for s in range(4):
        one = ota.ota_aggregate_client_folded(
            key, g, p, jax.tree.map(lambda x: x[s], bank), N, packer)
        for a, b in zip(jax.tree.leaves(one),
                        jax.tree.leaves(jax.tree.map(lambda x: x[s], banked))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(3, 5000), seed=st.integers(0, 99),
       noise=st.floats(0.0, 3.0))
def test_client_fold_kernel_matches_jnp_property(n, seed, noise):
    """ota_client_fold_apply: the Pallas kernel (interpret, main body +
    ragged jnp remainder) == the jnp dispatch on identical pre-sliced
    streams — the kernel-level contract for arbitrary leaf sizes."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (C, N, n))
    p = jax.random.uniform(jax.random.fold_in(key, 1), (C, N), jnp.float32,
                           0.5, 1.5)
    bits = jax.random.bits(jax.random.fold_in(key, 2), (C, n), jnp.uint32)
    nbits = jax.random.bits(jax.random.fold_in(key, 3), (n,), jnp.uint32)
    sig = jnp.asarray([0.25, 1.0, 2.0])
    a = ota_client_fold_apply(g, p, bits, nbits, sig, 0.1, noise, 1.0, N,
                              impl="jnp")
    b = ota_client_fold_apply(g, p, bits, nbits, sig, 0.1, noise, 1.0, N,
                              impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_client_folded_rejects_mismatched_tree():
    """A grads tree that does not match the packer template (beyond its
    (C, N) batch axes) raises the readable leaf-path error."""
    g = _grad_tree(jax.random.PRNGKey(0), C, N)
    packer = _packer(g)
    fl = FLConfig(n_clusters=C, n_clients=N, sigma2=(1.0,))
    chan = channel_params(fl)
    bad = dict(g)
    bad["final"] = {"w": g["final"]["w"][:, :, :10, :],  # wrong leaf shape
                    "b": g["final"]["b"]}
    with pytest.raises(ValueError, match="client-folded"):
        ota.ota_aggregate_client_folded(jax.random.PRNGKey(1), bad,
                                        jnp.ones((C, N)), chan, N, packer)


def test_packed_supplied_equals_fused_on_multisection_layout():
    """ota_aggregate_packed's supplied-bits mode (the ScenarioBank hoist)
    must reproduce the fused in-kernel draw on a ``sections="toplevel"``
    packer — the generalized per-section schedule, not the old head/tail
    pair, on BOTH sides."""
    fl = FLConfig(n_clusters=C, n_clients=N, sigma2=(0.5, 1.0, 2.0),
                  noise_std=0.8)
    chan = channel_params(fl)
    key = jax.random.PRNGKey(31)
    g = _grad_tree(jax.random.fold_in(key, 1), C, N)
    wg = jax.tree.map(lambda l: jnp.sum(l, axis=1), g)
    packer = _packer(g)
    a = ota.ota_aggregate_packed(key, wg, chan, N, packer)
    b = ota.ota_aggregate_packed(key, wg, chan, N, packer,
                                 bits_mode="supplied")
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-6, atol=1e-7)


# --------------------------------------------------- stream-schedule pins
def test_sim_and_dist_schedules_draw_identical_bits():
    """The generalized packed schedule (packed_gain_bits/packed_noise_bits
    over ``packed_section_folds``) must produce, section for section, the
    exact streams the slab-native distributed engine draws via
    section_gain_key/section_noise_key (repro.core.hota_slab's scheme) —
    sim and distributed paths see identical bits for identical layouts."""
    g = _grad_tree(jax.random.PRNGKey(0), C, N)
    packer = _packer(g)
    key = jax.random.PRNGKey(77)
    folds = ota.packed_section_folds(packer)
    assert len(folds) == len(packer.sections) > 2     # truly multi-section
    gain_slab = np.asarray(ota.packed_gain_bits(key, packer, C))
    noise_slab = np.asarray(ota.packed_noise_bits(key, packer))
    for sec in packer.sections:
        # the distributed engine's draw (hota_slab count_mode="local")
        dist_bits = np.stack([np.asarray(ota._chunked_stream(
            ota.section_gain_key(key, folds[sec.index], c), sec.length))
            for c in range(C)])
        np.testing.assert_array_equal(
            gain_slab[:, sec.start:sec.start + sec.length], dist_bits)
        dist_nbits = np.asarray(ota._chunked_stream(
            ota.section_noise_key(key, folds[sec.index]), sec.length))
        np.testing.assert_array_equal(
            noise_slab[sec.start:sec.start + sec.length], dist_nbits)


def test_legacy_tail_layout_streams_unchanged():
    """The generalized schedule is bit-identical to the PR-2 head/tail
    derivation on two-section layouts — no silent re-draw of every
    existing figure."""
    g = _grad_tree(jax.random.PRNGKey(0), C, N)
    packer = packer_for(_template(g), tail="final")       # legacy layout
    key = jax.random.PRNGKey(4)
    bits = np.asarray(ota.packed_gain_bits(key, packer, C))
    head = np.asarray(ota._section_bits(key, ota.PACKED_HEAD_FOLD, C,
                                        packer.head_len))
    tail = np.asarray(ota._section_bits(key, ota.PACKED_TAIL_FOLD, C,
                                        packer.tail_len))
    np.testing.assert_array_equal(bits, np.concatenate([head, tail], -1))
    nk = ota.noise_key(key)
    nbits = np.asarray(ota.packed_noise_bits(key, packer))
    nhead = np.asarray(ota._chunked_stream(
        jax.random.fold_in(nk, ota.PACKED_HEAD_FOLD), packer.head_len))
    ntail = np.asarray(ota._chunked_stream(
        jax.random.fold_in(nk, ota.PACKED_TAIL_FOLD), packer.tail_len))
    np.testing.assert_array_equal(nbits, np.concatenate([nhead, ntail]))


# ------------------------------------------------------ SIM_CHAN_FOLD pin
def _key_data(k):
    return tuple(np.asarray(jax.random.key_data(k)).tolist()
                 if hasattr(jax.random, "key_data")
                 else np.asarray(k).tolist())


def test_sim_chan_fold_reserved_and_disjoint():
    """The sim's per-round channel key derives from a named reserved
    fold (DESIGN.md §4) — pinned so a future fold of the step key cannot
    silently collide with the channel streams."""
    assert ota.SIM_CHAN_FOLD == 0x7FFF0003
    k = jax.random.PRNGKey(3)
    ck = ota.sim_channel_key(k)
    assert _key_data(ck) == _key_data(
        jax.random.fold_in(k, ota.SIM_CHAN_FOLD))
    reserved = {ota.NOISE_FOLD, ota.PACKED_HEAD_FOLD, ota.PACKED_TAIL_FOLD,
                ota.PACKED_SECTION_FOLD_BASE, ota.SIM_CHAN_FOLD}
    assert len(reserved) == 5                    # all five domains distinct
    for fold in (0, 1, 17, 999, ota.NOISE_FOLD, ota.PACKED_HEAD_FOLD,
                 ota.PACKED_TAIL_FOLD):
        assert _key_data(jax.random.fold_in(k, fold)) != _key_data(ck)


def test_sim_step_derives_channel_key_from_reserved_fold(monkeypatch):
    """Behavioral pin: tracing one sim round calls ota.sim_channel_key on
    the step key (not a bare literal fold)."""
    from repro.core.sim import HotaSim
    from repro.models.model import build_model
    calls = []
    orig = ota.sim_channel_key

    def spy(k):
        calls.append(k)
        return orig(k)

    monkeypatch.setattr(ota, "sim_channel_key", spy)
    model = build_model(ModelConfig(family="mlp"))
    fl = FLConfig(n_clusters=2, n_clients=2)
    sim = HotaSim(model, fl, TrainConfig(lr=3e-4), [4, 4])
    st_ = sim.init(jax.random.PRNGKey(0))
    x = jnp.zeros((2, 2, 4, 256))
    y = jnp.zeros((2, 2, 4), jnp.int32)
    sim.step(st_, x, y, jax.random.PRNGKey(9))
    assert len(calls) == 1


# ----------------------------------------------------------- sim HLO pin
def test_sim_packed_step_allocates_no_slab_buffer():
    """The slab-native sim step (use_pallas_ota=True) must compile with
    NO (C, P)- or (P,)-sized buffer, f32 or u32 — neither the einsum'd
    weighted slab, nor a pack copy, nor a slab-wide bit draw exists
    (mirror of the hota_slab assertion in dist_programs/dist_slab_step).
    The (L,) slab-view Adam moments (L = raw param count < P) are the
    allowed flat state."""
    from repro.core.sim import HotaSim
    from repro.models.model import build_model
    Cc, Nn = 2, 2
    model = build_model(ModelConfig(family="mlp"))
    fl = FLConfig(n_clusters=Cc, n_clients=Nn, noise_std=0.4)
    sim = HotaSim(model, fl, TrainConfig(lr=3e-4), [4, 4])
    st_ = sim.init(jax.random.PRNGKey(0))
    x = jnp.zeros((Cc, Nn, 4, 256))
    y = jnp.zeros((Cc, Nn, 4), jnp.int32)
    packer = packer_for(st_.omega, tail="final", sections="toplevel")
    P = packer.size
    L = sum(int(l.size) for l in jax.tree.leaves(st_.omega))
    assert L < P                  # padding makes the sizes distinguishable
    f = jax.jit(lambda s, xx, yy, k, ch: sim.step_with_channel(
        s, xx, yy, k, ch))
    hlo = f.lower(st_, x, y, jax.random.PRNGKey(1),
                  sim.chan).compile().as_text()
    hlo_audit.assert_hlo_pins(
        hlo, hlo_audit.no_slab_pins(Cc, P, note="packed/weighted slab"),
        context="compiled sim step — slab-native channel (§3.12)")
