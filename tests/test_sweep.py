"""ScenarioBank: vectorized sweep vs sequential oracle + common random numbers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FLConfig, TrainConfig
from repro.core import ota
from repro.core.channel import channel_params, stack_channel_params
from repro.core.paper_setup import paper_mlp_setup
from repro.core.sim import HotaSim
from repro.core.sweep import ScenarioBank

C, N = 2, 3


def _setup(base_fl: FLConfig):
    sim, batcher = paper_mlp_setup(base_fl, batch=8, n_points=3000)
    n_cls = [int(c) for c in sim.n_classes]
    return sim, batcher, sim.model, n_cls


SCENARIOS = [
    dict(),                                        # baseline fading MAC + FGN
    dict(weighting="equal"),                       # Fig. 2 naive baseline
    dict(sigma2=(0.05, 1.0)),                      # Fig. 3 bad channel
    dict(sigma2=(2.0, 0.75)),                      # Fig. 4 diverse sigma
    dict(sigma2=(0.25, 0.75), weighting="equal"),
    dict(noise_std=3.0),
    dict(ota=False),                               # error-free baseline
    dict(ota=False, weighting="equal"),
]


@pytest.mark.slow
def test_bank_matches_sequential_oracle():
    """A single-jit bank of 8 scenarios must reproduce 8 sequential
    per-scenario HotaSim runs leaf-for-leaf (states AND metrics)."""
    base_fl = FLConfig(n_clusters=C, n_clients=N)
    sim, batcher, model, n_cls = _setup(base_fl)
    bank = ScenarioBank(sim, SCENARIOS)
    assert bank.n_scenarios == 8

    steps = 3
    key0 = jax.random.PRNGKey(0)
    batches = [batcher.next_stacked() for _ in range(steps)]
    step_keys = [jax.random.PRNGKey(100 + s) for s in range(steps)]

    states = bank.init(key0)
    bank_ms = []
    for (x, y), k in zip(batches, step_keys):
        states, m = bank.step(states, jnp.asarray(x), jnp.asarray(y), k)
        bank_ms.append(m)

    for s, overrides in enumerate(SCENARIOS):
        fl_s = dataclasses.replace(base_fl, **overrides)
        seq = HotaSim(model, fl_s, TrainConfig(lr=3e-4), n_cls)
        st = seq.init(key0)
        for t, ((x, y), k) in enumerate(zip(batches, step_keys)):
            st, m = seq.step(st, jnp.asarray(x), jnp.asarray(y), k)
            for a, b in zip(jax.tree.leaves(m),
                            jax.tree.leaves(
                                jax.tree.map(lambda z: z[s], bank_ms[t]))):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=2e-4, atol=1e-5)
        for a, b in zip(jax.tree.leaves(st),
                        jax.tree.leaves(bank.scenario_state(states, s))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-5)


def test_common_random_numbers_share_channel_masks():
    """Two scenarios differing ONLY in weighting draw identical channel
    masks from the shared per-step key — the CRN guarantee behind paired
    dynamic-vs-equal comparisons."""
    fl_dyn = FLConfig(n_clusters=C, n_clients=N, weighting="fedgradnorm")
    fl_eq = dataclasses.replace(fl_dyn, weighting="equal")
    bank = stack_channel_params([channel_params(fl_dyn),
                                 channel_params(fl_eq)])
    tree = {"w": jnp.zeros((16, 8)), "b": jnp.zeros((8,))}
    key = jax.random.PRNGKey(42)
    masks = jax.vmap(lambda ch: ota.final_layer_masks(key, tree, ch))(bank)
    for leaf in jax.tree.leaves(masks):
        np.testing.assert_array_equal(np.asarray(leaf[0]),
                                      np.asarray(leaf[1]))
        # and the masks are non-trivial (some pass, some blocked)
        frac = np.asarray(leaf[0], np.float32).mean()
        assert 0.0 < frac < 1.0


@pytest.mark.slow
def test_crn_equalizes_grad_norms_across_weighting():
    """End-to-end CRN: from identical init, the first round's masked grad
    norms must be bit-identical between the dynamic and equal scenarios
    (same data, same gains, same masks — only the p-update differs)."""
    base_fl = FLConfig(n_clusters=C, n_clients=N)
    sim, batcher, _, _ = _setup(base_fl)
    bank = ScenarioBank(sim, [dict(), dict(weighting="equal")])
    states = bank.init(jax.random.PRNGKey(1))
    # drive via run(): metrics come back stacked (T, S, ...)
    states, hist = bank.run(states, [batcher.next_stacked()],
                            [jax.random.PRNGKey(7)])
    m = jax.tree.map(lambda a: a[0], hist)
    norms = np.asarray(m["grad_norms"])           # (S, C, N)
    np.testing.assert_array_equal(norms[0], norms[1])
    # the weighting gate did diverge p
    p = np.asarray(m["p"])
    assert not np.allclose(p[0], p[1])
    np.testing.assert_allclose(p[1], 1.0)


def test_bank_rejects_static_mismatch():
    base_fl = FLConfig(n_clusters=C, n_clients=N)
    sim, _, _, _ = _setup(base_fl)
    with pytest.raises(ValueError, match="n_clients"):
        ScenarioBank(sim, [dict(), dict(n_clients=N + 1)])
    # non-traced knobs are rejected too, not silently dropped
    with pytest.raises(ValueError, match="ota_mode"):
        ScenarioBank(sim, [dict(ota_mode="naive")])
