"""Section-layout coalescing + autotuner pins (DESIGN.md §3.13): slot
offsets are threshold-invariant, threshold=0 is bit-identical to the
uncoalesced layout (stream pin), the client-folded engine matches the
per-leaf oracle on a coalesced layout's shared streams, the calibration
bench returns a usable LayoutChoice, checkpoints refuse a cross-layout
restore, and the TPU/CPU dispatch resolves at trace time."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.config import FLConfig
from repro.common.flatpack import ROW_QUANTUM, TreePacker, packer_for
from repro.common.layout_tune import (
    DEFAULT_THRESHOLDS, LayoutChoice, apply_layout, calibrate_layout,
    layout_of, packer_for_layout, tune_layout,
)
from repro.core import ota
from repro.core.channel import channel_params
from repro.kernels.ota_channel.ref import bits_to_gaussian, bits_to_mask

C, N = 3, 2


def _template():
    """Many small top-level trunk groups — the coalescing target."""
    t = {"final": {"w": jax.ShapeDtypeStruct((40, 8), jnp.float32),
                   "b": jax.ShapeDtypeStruct((8,), jnp.float32)}}
    t["trunk"] = {f"fc{i}": {"w": jax.ShapeDtypeStruct((10 + i, 9), jnp.float32),
                             "b": jax.ShapeDtypeStruct((9,), jnp.float32)}
                  for i in range(6)}
    return t


def _grad_tree(key, template):
    leaves, treedef = jax.tree.flatten(template)
    return jax.tree.unflatten(treedef, [
        jax.random.normal(jax.random.fold_in(key, i), (C, N) + l.shape)
        for i, l in enumerate(leaves)])


# ------------------------------------------------------------ coalescing
@settings(max_examples=12, deadline=None)
@given(rows=st.integers(0, 2 * ROW_QUANTUM // 128), seed=st.integers(0, 50))
def test_coalesced_roundtrip_and_offsets_property(rows, seed):
    """ANY min_section_rows: unpack∘pack == identity, and every leaf's
    slab offset is IDENTICAL to the uncoalesced layout — coalescing only
    re-partitions sections, it never moves bytes."""
    template = _template()
    p0 = packer_for(template, tail="final", sections="toplevel")
    pk = packer_for(template, tail="final", sections="toplevel",
                    min_section_rows=rows)
    assert pk.slots == p0.slots
    assert pk.head_len == p0.head_len and pk.tail_len == p0.tail_len
    tree = jax.tree.map(
        lambda l: jax.random.normal(jax.random.fold_in(
            jax.random.PRNGKey(seed), l.shape[0]), l.shape),
        template)
    back = pk.unpack(pk.pack(tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_coalesced_sections_partition_head_exactly():
    """Sections tile [0, head_len) disjointly, each ROW_QUANTUM-aligned,
    tail still its own LAST section, and the merged section count shrinks
    monotonically as the threshold grows."""
    template = _template()
    counts = []
    for rows in (0, 8, 64, 1024):
        pk = packer_for(template, tail="final", sections="toplevel",
                        min_section_rows=rows)
        off = 0
        for sec in pk.sections[:-1]:
            assert sec.start == off and sec.length % ROW_QUANTUM == 0
            off += sec.length
        assert off == pk.head_len
        assert pk.sections[-1].name == "final"
        assert pk.sections[-1].start == pk.head_len
        counts.append(len(pk.sections))
    assert counts[0] >= counts[1] >= counts[2] >= counts[3]
    assert counts[-1] == 2          # one merged trunk section + tail


def test_threshold_zero_is_bit_identical_stream_pin():
    """min_section_rows=0 must reproduce today's layout EXACTLY: same
    cached packer object, same section folds, same gain bits."""
    template = _template()
    p_default = packer_for(template, tail="final", sections="toplevel")
    p_zero = packer_for(template, tail="final", sections="toplevel",
                        min_section_rows=0)
    assert p_zero is p_default      # cache key identity for the default
    assert ota.packed_section_folds(p_zero) == \
        ota.packed_section_folds(p_default)
    key = jax.random.PRNGKey(7)
    np.testing.assert_array_equal(
        np.asarray(ota.packed_gain_bits(key, p_zero, C)),
        np.asarray(ota.packed_gain_bits(key, p_default, C)))


def test_coalesced_folds_follow_post_merge_section_index():
    """Fold-after-coalescing rule (§4): section s draws under
    PACKED_SECTION_FOLD_BASE + s where s is the POST-merge index — the
    tail keeps PACKED_TAIL_FOLD in every layout."""
    pk = packer_for(_template(), tail="final", sections="toplevel",
                    min_section_rows=1024)
    folds = ota.packed_section_folds(pk)
    assert folds[-1] == ota.PACKED_TAIL_FOLD
    assert folds[:-1] == [ota.PACKED_SECTION_FOLD_BASE + s
                          for s in range(len(pk.sections) - 1)]


def test_clientfold_matches_per_leaf_oracle_on_coalesced_layout():
    """The client-folded engine on a COALESCED layout == the per-leaf
    estimator fed masks/noise decoded from the same coalesced streams."""
    template = _template()
    pk = packer_for(template, tail="final", sections="toplevel",
                    min_section_rows=64)
    fl = FLConfig(n_clusters=C, n_clients=N, sigma2=(0.25, 0.5, 1.0),
                  noise_std=0.4)
    chan = channel_params(fl)
    key = jax.random.PRNGKey(5)
    g = _grad_tree(jax.random.fold_in(key, 1), template)
    p = jax.random.uniform(jax.random.fold_in(key, 2), (C, N), jnp.float32,
                           0.5, 1.5)
    ghat = ota.ota_aggregate_client_folded(key, g, p, chan, N, pk)
    bits = ota.packed_gain_bits(key, pk, C)
    nbits = ota.packed_noise_bits(key, pk)
    sig = chan.sigma2.reshape(C, 1)
    mask_tree = pk.unpack(
        bits_to_mask(bits, sig, chan.h_threshold, chan.ota_on)
        .astype(jnp.float32))
    noise_tree = pk.unpack(bits_to_gaussian(nbits, 1.0)
                           * chan.noise_std * chan.ota_on)
    wg = jax.tree.map(lambda l: jnp.einsum("cn,cn...->c...", p, l), g)
    oracle = jax.tree.map(
        lambda w, m, z: ota.ota_aggregate_leaf(w, m > 0.5, z, N),
        wg, mask_tree, noise_tree)
    for a, b in zip(jax.tree.leaves(ghat), jax.tree.leaves(oracle)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_final_layer_masks_packed_invariant_to_coalescing():
    """Eq.-5 masks come off the tail stream (PACKED_TAIL_FOLD), which no
    coalescing threshold touches — identical masks at any threshold."""
    template = _template()
    fl = FLConfig(n_clusters=C, n_clients=N, sigma2=(0.25, 0.5, 1.0),
                  h_threshold=0.9)
    chan = channel_params(fl)
    key = jax.random.PRNGKey(9)
    ref = ota.final_layer_masks_packed(
        key, chan, packer_for(template, tail="final", sections="toplevel"))
    got = ota.final_layer_masks_packed(
        key, chan, packer_for(template, tail="final", sections="toplevel",
                              min_section_rows=1024))
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_min_section_rows_requires_toplevel():
    with pytest.raises(ValueError, match="min_section_rows"):
        TreePacker(_template(), tail="final", sections="tail",
                   min_section_rows=8)


def test_chunk_leaf_map_keeps_zero_size_leaves():
    """Regression: a zero-size leaf used to vanish from chunk_leaf_map
    ((offset + size - 1) // chunk underflows when size == 0)."""
    template = {"final": {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)},
                "trunk": {"fc0": {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32),
                                  "empty": jax.ShapeDtypeStruct((0,), jnp.float32),
                                  "b": jax.ShapeDtypeStruct((8,), jnp.float32)}}}
    pk = packer_for(template, tail="final", sections="toplevel")
    seen = {r.leaf for per in pk.chunk_leaf_map(131072).values()
            for _, runs in per for r in runs}
    assert seen == set(range(len(pk.slots)))
    tree = jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), template)
    back = pk.unpack(pk.pack(tree))
    assert jax.tree.leaves(back)[
        jax.tree.leaves(template).index(template["trunk"]["fc0"]["empty"])
    ].shape == (0,)


# -------------------------------------------------------------- autotuner
def test_calibrate_layout_reports_all_candidates():
    choice, report = calibrate_layout(_template(), C, N, iters=1)
    layouts = {r["layout"] for r in report}
    assert "perleaf" in layouts
    assert "slab/sections=tail/min_section_rows=0" in layouts
    for t in DEFAULT_THRESHOLDS:
        assert f"slab/sections=toplevel/min_section_rows={t}" in layouts
    assert choice.describe() in layouts
    assert min(report, key=lambda r: r["us"])["choice"] == choice


def test_tune_layout_cache_and_apply_roundtrip():
    template = _template()
    c1 = tune_layout(template, C, N, iters=1)
    c2 = tune_layout(template, C, N, iters=1)   # cached — no re-timing
    assert c1 == c2
    fl = apply_layout(FLConfig(n_clusters=C, n_clients=N), c1)
    assert layout_of(fl) == c1
    assert LayoutChoice.from_metadata(c1.to_metadata()) == c1
    if c1.engine == "slab":
        pk = packer_for_layout(template, c1)
        assert pk is packer_for(template, tail="final",
                                sections=c1.sections,
                                min_section_rows=c1.min_section_rows)
    else:
        with pytest.raises(ValueError, match="per-leaf"):
            packer_for_layout(template, c1)


def test_tune_layout_disk_cache_roundtrip(tmp_path):
    """The persisted calibration cache (keyed by template hash) answers a
    cold-process tune without re-timing: seed the file with a sentinel
    choice no measurement would pick, clear the in-memory cache, and the
    sentinel must come back verbatim. A stale/corrupt entry re-measures
    instead of crashing."""
    import repro.common.layout_tune as lt

    template = _template()
    path = str(tmp_path / "layout_tune.json")
    h = lt.template_hash(template, C, N)
    sentinel = LayoutChoice("slab", "tail", 0)
    lt._store_disk_cache(path, {h: sentinel.to_metadata()})
    lt._TUNE_CACHE.clear()
    try:
        got = tune_layout(template, C, N, iters=1, cache_path=path)
        assert got == sentinel
        # memory cache now holds it too — second call never touches disk
        assert tune_layout(template, C, N, iters=1,
                           cache_path=str(tmp_path / "gone.json")) == sentinel
        # corrupt entry -> fall back to measuring (any valid choice is fine)
        lt._store_disk_cache(path, {h: {"engine": "warp-drive"}})
        lt._TUNE_CACHE.clear()
        measured = tune_layout(template, C, N, iters=1, cache_path=path)
        assert isinstance(measured, LayoutChoice)
        # a different template hashes differently: its entry is untouched
        assert lt.template_hash(template, C, N + 1) != h
    finally:
        lt._TUNE_CACHE.clear()   # drop sentinel so later tests re-measure


# ------------------------------------------------- checkpoint layout pin
def test_restore_refuses_cross_layout_checkpoint(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    tree = {"w": jnp.arange(6.0).reshape(2, 3)}
    saved = LayoutChoice("slab", "toplevel", 256)
    save_checkpoint(str(tmp_path), 3, tree,
                    {"layout": saved.to_metadata()})
    # same layout restores fine
    back = restore_checkpoint(str(tmp_path), 3, tree,
                              expected_layout=saved.to_metadata())
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
    other = LayoutChoice("slab", "toplevel", 0)
    with pytest.raises(ValueError) as ei:
        restore_checkpoint(str(tmp_path), 3, tree,
                           expected_layout=other.to_metadata())
    msg = str(ei.value)
    assert "min_section_rows': 256" in msg and "min_section_rows': 0" in msg
    # a legacy checkpoint with no layout metadata still restores
    save_checkpoint(str(tmp_path), 4, tree, {})
    restore_checkpoint(str(tmp_path), 4, tree,
                       expected_layout=saved.to_metadata())


def test_restore_leaf_count_mismatch_raises_value_error(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    save_checkpoint(str(tmp_path), 0, {"a": jnp.zeros(3), "b": jnp.ones(2)})
    with pytest.raises(ValueError, match="2 leaves.*has 1"):
        restore_checkpoint(str(tmp_path), 0, {"a": jnp.zeros(3)})


# ------------------------------------------------- trace-time dispatch
def test_on_tpu_resolves_at_trace_time(monkeypatch):
    """No import-time _ON_TPU pin anywhere: faking the backend AFTER
    import flips the dispatch."""
    from repro.kernels import slab
    from repro.kernels.masked_gradnorm import ops as mg_ops
    from repro.kernels.ota_channel import ops as oc_ops

    for mod in (slab, oc_ops, mg_ops):
        assert not hasattr(mod, "_ON_TPU")
    assert slab.on_tpu() is False           # CPU test host
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert slab.on_tpu() is True
    assert oc_ops.on_tpu() is True and mg_ops.on_tpu() is True


def test_interpret_default_matches_explicit_on_cpu():
    """interpret=None resolves from the live backend inside the op: on
    this CPU host it must take exactly the interpret=True path."""
    from repro.kernels.ota_channel.ops import ota_channel

    x = jax.random.normal(jax.random.PRNGKey(0), (640,))
    key = jax.random.PRNGKey(1)
    a_out, a_mask = ota_channel(x, key, 0.5, 0.1)
    b_out, b_mask = ota_channel(x, key, 0.5, 0.1, interpret=True)
    np.testing.assert_array_equal(np.asarray(a_out), np.asarray(b_out))
    np.testing.assert_array_equal(np.asarray(a_mask), np.asarray(b_mask))
