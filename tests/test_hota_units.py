"""Single-device units for the distributed HOTA machinery (no mesh)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hota import (
    KLASS_SALT, _fsdp_axis, build_axes_registry, fold_tags,
)
from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.models.params import logical_axes


def test_fsdp_axis_selection():
    assert _fsdp_axis(("embed", "mlp")) == 0
    assert _fsdp_axis(("layer", "embed", "mlp")) == 0      # layer stripped
    assert _fsdp_axis(("vocab", "embed")) == 1
    assert _fsdp_axis(("mlp",)) == -1                      # replicated
    assert _fsdp_axis(("heads", "head_dim")) == -1


def test_fold_tags_unique_per_leaf_and_layer():
    key = jax.random.PRNGKey(0)
    seen = set()
    for klass in ("layers", "embed", "final"):
        for tag in (0, 1, 5):
            for leaf in (0, 1, 2):
                k = fold_tags(key, klass, (tag,), leaf)
                seen.add(tuple(np.asarray(jax.random.key_data(k)).tolist())
                         if hasattr(jax.random, "key_data")
                         else tuple(np.asarray(k).tolist()))
    assert len(seen) == 3 * 3 * 3


@pytest.mark.parametrize("arch", ["starcoder2_3b", "zamba2_1_2b",
                                  "xlstm_1_3b", "gemma3_12b",
                                  "phi3_5_moe_42b"])
def test_registry_covers_trunk_leaves(arch):
    """Every hook call site's leaf count must match the registry — a
    mismatch would silently mis-key the channel draws."""
    model = build_model(get_smoke_config(arch))
    reg = build_axes_registry(model)
    ax = logical_axes(model.trunk_specs())
    is_ax = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)

    def count(tree):
        return len(jax.tree.leaves(tree, is_leaf=is_ax))

    cfg = model.cfg
    if cfg.family in ("dense", "moe"):
        key = "layers" if "layers" in ax else "global"
        assert len(reg["layers"]) == count(ax[key])
        assert len(reg["embed"]) == 1
    elif cfg.family == "hybrid":
        assert len(reg["mamba"]) == count(ax["mamba"])
        assert len(reg["shared_attn"]) == count(ax["shared_attn"])
        assert len(reg["shared_mlp"]) == count(ax["shared_mlp"])
    elif cfg.family == "xlstm":
        assert len(reg["mlstm"]) == count(ax["mlstm"])
        assert len(reg["slstm"]) == count(ax["slstm"])
    assert len(reg["final"]) == len(jax.tree.leaves(
        logical_axes(model.final_specs()), is_leaf=is_ax))


def test_full_transmission_mask_region_structure():
    """Scatter-mode full mask = concat of region masks along the FSDP axis
    (must match the gather backward's per-region draws)."""
    from repro.core.hota import (channel_mask_for, full_transmission_mask,
                                 region_mask_key)
    key = jax.random.PRNGKey(3)
    shape, axis, n_reg = (8, 6), 0, 4
    # no cluster axes in single-device test: use empty tuple via monkeypatch
    # of cluster_index — instead exercise with cluster_axes=() shim:
    import repro.core.hota as hota

    def fake_cluster_index(axes):
        return 0
    orig = hota.cluster_index
    hota.cluster_index = fake_cluster_index
    try:
        full = full_transmission_mask(key, shape, axis, n_reg, 1.0, 0.032,
                                      jnp.float32(1.0), (), True)
        pieces = [
            channel_mask_for(region_mask_key(key, r), (2, 6), 1.0, 0.032,
                             jnp.float32(1.0), ())
            for r in range(n_reg)
        ]
        ref = jnp.concatenate(pieces, axis=0)
        np.testing.assert_array_equal(np.asarray(full), np.asarray(ref))
    finally:
        hota.cluster_index = orig
