"""Property tests for the OTA channel model (paper eqs. 3, 7-10)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.common.config import FLConfig
from repro.core import ota
from repro.core.channel import channel_params


def test_channel_inversion_cancellation():
    """Faithful path (β = p/H then ×H on the MAC) must equal the fast path
    (p·g masked) exactly — the paper's power-allocation design."""
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (1000,))
    h = ota.sample_gain(jax.random.fold_in(key, 1), g.shape, 1.0)
    mask = ota.gain_mask(h, 0.032)
    p_i = jnp.float32(1.3)
    x = ota.transmit_signal(p_i, g, h, mask)         # β ∘ g
    received = jnp.where(mask, h * x, 0.0)           # MAC applies H
    fast = jnp.where(mask, p_i * g, 0.0)
    np.testing.assert_allclose(np.asarray(received), np.asarray(fast),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(10, 3000),
    sigma2=st.floats(0.25, 4.0),
    h_th=st.floats(0.0, 0.5),
    seed=st.integers(0, 10_000),
)
def test_mask_rate_matches_gaussian_theory(n, sigma2, h_th, seed):
    """P(|H|² ≥ th) = 2(1 − Φ(√th/σ)) — statistical property of eq. (7)."""
    from math import erf, sqrt
    key = jax.random.PRNGKey(seed)
    h = ota.sample_gain(key, (n, 64), sigma2)
    mask = ota.gain_mask(h, h_th)
    rate = float(mask.mean())
    phi = 0.5 * (1 + erf(sqrt(h_th) / sqrt(sigma2) / sqrt(2)))
    expected = 2 * (1 - phi)
    se = (expected * (1 - expected) / (n * 64)) ** 0.5
    assert abs(rate - expected) < max(6 * se, 0.02), (rate, expected)


def test_estimator_exact_when_noiseless_allpass():
    """With z=0 and all channels above threshold, ĝ = mean over clusters of
    (Σ_i p_i g_i)/N — eq. (10) reduces to the weighted average."""
    C, N = 4, 3
    key = jax.random.PRNGKey(1)
    weighted = jax.random.normal(key, (C, 50))       # already Σ_i p_i g_i
    masks = jnp.ones((C, 50), bool)
    ghat = ota.ota_aggregate_leaf(weighted, masks, jnp.zeros(50), N)
    np.testing.assert_allclose(np.asarray(ghat),
                               np.asarray(weighted.mean(0) * C / (C * N)),
                               rtol=1e-6)


def test_estimator_guard_zero_contributors():
    """|M_k(j)| = 0 entries are estimated as 0, never NaN/inf (guard on
    eq. (10))."""
    C, N = 3, 2
    weighted = jnp.ones((C, 10))
    masks = jnp.zeros((C, 10), bool)
    noise = jnp.ones(10) * 5.0
    ghat = ota.ota_aggregate_leaf(weighted, masks, noise, N)
    np.testing.assert_array_equal(np.asarray(ghat), np.zeros(10))


def test_ota_aggregate_tree_respects_per_cluster_sigma():
    """σ² → 0 forces a cluster's mask empty (|H|² < th a.s.), so that
    cluster never contributes."""
    fl = FLConfig(n_clusters=2, n_clients=1, h_threshold=0.05,
                  noise_std=0.0, sigma2=(1e-12, 1.0))
    chan = channel_params(fl)
    # cluster 0 transmits huge values; they must be masked out
    weighted = {"w": jnp.stack([jnp.full((200,), 1e6), jnp.ones((200,))])}
    ghat = ota.ota_aggregate_tree(jax.random.PRNGKey(3), weighted, chan,
                                  fl.n_clients)
    assert float(jnp.max(jnp.abs(ghat["w"]))) < 1e5


def test_tree_estimator_zero_when_all_below_threshold():
    """|M_k| = 0 everywhere (every gain below H_th): ĝ must be exactly 0 on
    every leaf — never NaN/inf — even with noise present (eq. 10 guard)."""
    fl = FLConfig(n_clusters=3, n_clients=2, h_threshold=0.5,
                  noise_std=5.0, sigma2=(1e-14,))
    chan = channel_params(fl)
    weighted = {"w": jnp.full((3, 100), 1e6), "b": jnp.ones((3, 4, 4))}
    ghat = ota.ota_aggregate_tree(jax.random.PRNGKey(11), weighted, chan,
                                  fl.n_clients)
    for leaf in jax.tree.leaves(ghat):
        arr = np.asarray(leaf)
        assert np.isfinite(arr).all()
        np.testing.assert_array_equal(arr, np.zeros_like(arr))


def test_ota_off_equals_plain_weighted_mean():
    """ota=False removes mask AND noise: ĝ = (Σ_l Σ_i p_i g_i) / (C·N) — a
    plain weighted mean over all C·N clients (error-free baseline)."""
    fl = FLConfig(n_clusters=4, n_clients=3, noise_std=7.0, ota=False)
    chan = channel_params(fl)
    key = jax.random.PRNGKey(5)
    weighted = {"w": jax.random.normal(key, (4, 64)),
                "b": jax.random.normal(jax.random.fold_in(key, 1), (4, 8, 2))}
    ghat = ota.ota_aggregate_tree(jax.random.PRNGKey(2), weighted, chan,
                                  fl.n_clients)
    for g, wg in zip(jax.tree.leaves(ghat), jax.tree.leaves(weighted)):
        ref = np.asarray(wg).sum(axis=0) / (fl.n_clusters * fl.n_clients)
        np.testing.assert_allclose(np.asarray(g), ref, rtol=1e-6, atol=1e-7)


def test_final_layer_masks_consistent_with_keys():
    """FGN masks (eq. 5) must reproduce the masks the transmission draws
    for the same leaves (same fold-in scheme)."""
    fl = FLConfig(n_clusters=2, n_clients=2)
    chan = channel_params(fl)
    tree = {"a": jnp.zeros((64,)), "b": jnp.zeros((8, 8))}
    key = jax.random.PRNGKey(9)
    masks1 = ota.final_layer_masks(key, tree, chan)
    masks2 = ota.final_layer_masks(key, tree, chan)
    for l1, l2 in zip(jax.tree.leaves(masks1), jax.tree.leaves(masks2)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    rate = float(jnp.concatenate(
        [m.reshape(-1).astype(jnp.float32)
         for m in jax.tree.leaves(masks1)]).mean())
    assert 0.7 < rate < 0.95   # th=0.032, sigma=1 -> ~0.858
