"""TreePacker: layout contract + round-trip properties (the flat-packed
OTA engine's foundation — see repro/common/flatpack.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.flatpack import TreePacker, packer_for
from repro.kernels.slab import LANE, ROW_QUANTUM, pad_to_lanes, slab_rows

TREE = {
    "final": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
    "trunk": {"fc0": {"w": jnp.full((5, 7), 2.0), "b": jnp.zeros((7,))},
              "fc1": {"w": jnp.full((2, 3), 3.0)}},
}


def test_roundtrip_exact():
    p = TreePacker(TREE, tail="final")
    slab = p.pack(TREE)
    assert slab.shape == (p.size,)
    out = p.unpack(slab)
    for a, b in zip(jax.tree.leaves(TREE), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_layout_alignment_contract():
    p = TreePacker(TREE, tail="final")
    # lane-aligned slab, lane-aligned sections (kernel (rows, 128) view)
    assert p.size % ROW_QUANTUM == 0
    assert p.head_len % ROW_QUANTUM == 0
    assert p.tail_len % ROW_QUANTUM == 0
    assert p.size == p.head_len + p.tail_len
    assert p.n_rows * LANE == p.size


def test_final_leaves_are_contiguous_tail():
    """The last-shared-layer params must occupy one contiguous tail slice
    (final_layer_masks_packed slices exactly this)."""
    p = TreePacker(TREE, tail="final")
    slab = p.pack(TREE)
    tail = p.tail_slice(slab)
    assert tail.shape == (p.tail_len,)
    flat_final = jnp.concatenate(
        [l.reshape(-1) for l in jax.tree.leaves(TREE["final"])])
    np.testing.assert_array_equal(np.asarray(tail[:flat_final.size]),
                                  np.asarray(flat_final))
    # and the padding after the tail leaves is zero
    np.testing.assert_array_equal(np.asarray(tail[flat_final.size:]), 0.0)


def test_unpack_tail_matches_subtree():
    p = TreePacker(TREE, tail="final")
    tail = p.tail_slice(p.pack(TREE))
    sub = p.unpack_tail(tail)
    assert jax.tree.structure(sub) == jax.tree.structure(TREE["final"])
    for a, b in zip(jax.tree.leaves(TREE["final"]), jax.tree.leaves(sub)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pack_preserves_leading_batch_axes():
    """(C, ...) leaves (the per-cluster weighted grads) pack to (C, P)."""
    C = 3
    batched = jax.tree.map(
        lambda l: jnp.stack([l * (c + 1) for c in range(C)]), TREE)
    p = TreePacker(TREE, tail="final")
    slab = p.pack(batched)
    assert slab.shape == (C, p.size)
    for c in range(C):
        np.testing.assert_array_equal(
            np.asarray(slab[c]),
            np.asarray(p.pack(jax.tree.map(lambda l: l[c], batched))))


def test_packer_cache_hits():
    a = packer_for(TREE, tail="final")
    b = packer_for(jax.tree.map(jnp.zeros_like, TREE), tail="final")
    assert a is b
    c = packer_for(TREE, tail=None)
    assert c is not a and c.tail_len == 0 and c.head_len == c.size


def test_no_tail_packs_everything_in_head():
    p = TreePacker(TREE["trunk"], tail="final")   # key absent -> all head
    assert p.tail_len == 0
    out = p.unpack(p.pack(TREE["trunk"]))
    for a, b in zip(jax.tree.leaves(TREE["trunk"]), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=20, deadline=None)
@given(
    shapes=st.lists(
        st.tuples(st.integers(1, 17), st.integers(1, 23)), min_size=1,
        max_size=6),
    final_n=st.integers(1, 50),
    seed=st.integers(0, 99),
)
def test_roundtrip_property(shapes, final_n, seed):
    key = jax.random.PRNGKey(seed)
    tree = {
        "final": {"w": jax.random.normal(key, (final_n,))},
        "trunk": {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), s)
                  for i, s in enumerate(shapes)},
    }
    p = packer_for(tree, tail="final")
    slab = p.pack(tree)
    assert slab.shape[-1] % ROW_QUANTUM == 0
    out = p.unpack(slab)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_slab_helpers_roundtrip():
    x = jnp.arange(1000.0).reshape(10, 100)
    slab, n = pad_to_lanes(x)
    assert n == 1000 and slab.shape == (slab_rows(1000), LANE)
    assert slab.shape[0] % 8 == 0
    np.testing.assert_array_equal(
        np.asarray(slab.reshape(-1)[:n].reshape(x.shape)), np.asarray(x))
