"""TreePacker: layout contract + round-trip properties (the flat-packed
OTA engine's foundation — see repro/common/flatpack.py), including the
multi-section / zero-copy layout of DESIGN.md §3.10 and the edge cases
(empty tail, mixed dtypes, single leaf, non-contiguous tail key)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.common.flatpack import (
    TreePacker, check_tree_matches_packer, packer_for,
)
from repro.kernels.slab import LANE, ROW_QUANTUM, pad_to_lanes, slab_rows

TREE = {
    "final": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
    "trunk": {"fc0": {"w": jnp.full((5, 7), 2.0), "b": jnp.zeros((7,))},
              "fc1": {"w": jnp.full((2, 3), 3.0)}},
}


def test_roundtrip_exact():
    p = TreePacker(TREE, tail="final")
    slab = p.pack(TREE)
    assert slab.shape == (p.size,)
    out = p.unpack(slab)
    for a, b in zip(jax.tree.leaves(TREE), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_layout_alignment_contract():
    p = TreePacker(TREE, tail="final")
    # lane-aligned slab, lane-aligned sections (kernel (rows, 128) view)
    assert p.size % ROW_QUANTUM == 0
    assert p.head_len % ROW_QUANTUM == 0
    assert p.tail_len % ROW_QUANTUM == 0
    assert p.size == p.head_len + p.tail_len
    assert p.n_rows * LANE == p.size


def test_final_leaves_are_contiguous_tail():
    """The last-shared-layer params must occupy one contiguous tail slice
    (final_layer_masks_packed slices exactly this)."""
    p = TreePacker(TREE, tail="final")
    slab = p.pack(TREE)
    tail = p.tail_slice(slab)
    assert tail.shape == (p.tail_len,)
    flat_final = jnp.concatenate(
        [l.reshape(-1) for l in jax.tree.leaves(TREE["final"])])
    np.testing.assert_array_equal(np.asarray(tail[:flat_final.size]),
                                  np.asarray(flat_final))
    # and the padding after the tail leaves is zero
    np.testing.assert_array_equal(np.asarray(tail[flat_final.size:]), 0.0)


def test_unpack_tail_matches_subtree():
    p = TreePacker(TREE, tail="final")
    tail = p.tail_slice(p.pack(TREE))
    sub = p.unpack_tail(tail)
    assert jax.tree.structure(sub) == jax.tree.structure(TREE["final"])
    for a, b in zip(jax.tree.leaves(TREE["final"]), jax.tree.leaves(sub)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pack_preserves_leading_batch_axes():
    """(C, ...) leaves (the per-cluster weighted grads) pack to (C, P)."""
    C = 3
    batched = jax.tree.map(
        lambda l: jnp.stack([l * (c + 1) for c in range(C)]), TREE)
    p = TreePacker(TREE, tail="final")
    slab = p.pack(batched)
    assert slab.shape == (C, p.size)
    for c in range(C):
        np.testing.assert_array_equal(
            np.asarray(slab[c]),
            np.asarray(p.pack(jax.tree.map(lambda l: l[c], batched))))


def test_packer_cache_hits():
    a = packer_for(TREE, tail="final")
    b = packer_for(jax.tree.map(jnp.zeros_like, TREE), tail="final")
    assert a is b
    c = packer_for(TREE, tail=None)
    assert c is not a and c.tail_len == 0 and c.head_len == c.size


def test_no_tail_packs_everything_in_head():
    p = TreePacker(TREE["trunk"], tail="final")   # key absent -> all head
    assert p.tail_len == 0
    out = p.unpack(p.pack(TREE["trunk"]))
    for a, b in zip(jax.tree.leaves(TREE["trunk"]), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=20, deadline=None)
@given(
    shapes=st.lists(
        st.tuples(st.integers(1, 17), st.integers(1, 23)), min_size=1,
        max_size=6),
    final_n=st.integers(1, 50),
    seed=st.integers(0, 99),
)
def test_roundtrip_property(shapes, final_n, seed):
    key = jax.random.PRNGKey(seed)
    tree = {
        "final": {"w": jax.random.normal(key, (final_n,))},
        "trunk": {f"l{i}": jax.random.normal(jax.random.fold_in(key, i), s)
                  for i, s in enumerate(shapes)},
    }
    p = packer_for(tree, tail="final")
    slab = p.pack(tree)
    assert slab.shape[-1] % ROW_QUANTUM == 0
    out = p.unpack(slab)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_toplevel_sections_layout_contract():
    """Multi-section layout: one ROW_QUANTUM-aligned section per layer
    stack (depth-≤2 path prefix), tail last, every leaf ROW_QUANTUM-
    aligned inside its section."""
    p = TreePacker(TREE, tail="final", sections="toplevel")
    names = [s.name for s in p.sections]
    assert names == ["trunk/fc0", "trunk/fc1", "final"]   # tail last
    off = 0
    for s in p.sections:
        assert s.start == off and s.length % ROW_QUANTUM == 0
        off += s.length
    assert off == p.size
    for run in p.leaf_runs():
        assert run.offset % ROW_QUANTUM == 0         # zero-copy contract
    # round-trip still exact (padding between leaves stays zero)
    out = p.unpack(p.pack(TREE))
    for a, b in zip(jax.tree.leaves(TREE), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # tail slice/unpack agree with the legacy layout's contract
    sub = p.unpack_tail(p.tail_slice(p.pack(TREE)))
    for a, b in zip(jax.tree.leaves(TREE["final"]), jax.tree.leaves(sub)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunk_leaf_map_partitions_leaves():
    """The chunk->leaf map covers every leaf run exactly, in order."""
    p = TreePacker(TREE, tail="final", sections="toplevel")
    cmap = p.chunk_leaf_map(ROW_QUANTUM)
    seen = set()
    for sec_idx, per_chunk in cmap.items():
        for j, runs in per_chunk:
            for run in runs:
                assert run.section == sec_idx
                assert run.offset < (j + 1) * ROW_QUANTUM
                assert run.offset + run.size > j * ROW_QUANTUM
                seen.add(run.leaf)
    assert seen == set(range(len(jax.tree.leaves(TREE))))


def test_legacy_layout_unchanged_by_sections_param():
    """sections='tail' (the default) must keep PR-2's exact offsets."""
    a = TreePacker(TREE, tail="final")
    b = TreePacker(TREE, tail="final", sections="tail")
    assert a.slots == b.slots and a.size == b.size
    assert [s[:4] for s in a.sections] == [s[:4] for s in b.sections]


def test_empty_tail_subtree():
    """A tail key with no leaves: no tail section, everything head."""
    tree = {"final": {}, "trunk": TREE["trunk"]}
    for sections in ("tail", "toplevel"):
        p = TreePacker(tree, tail="final", sections=sections)
        assert p.tail_len == 0 and p.head_len == p.size
        assert all(s.name != "final" for s in p.sections)
        out = p.unpack(p.pack(tree))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mixed_dtypes_rejected_with_clear_error():
    tree = {"final": {"w": jnp.zeros((3,), jnp.float32)},
            "trunk": {"w": jnp.zeros((3,), jnp.bfloat16)}}
    with pytest.raises(ValueError) as e:
        TreePacker(tree, tail="final")
    msg = str(e.value)
    assert "uniform leaf dtype" in msg and "bfloat16" in msg \
        and "float32" in msg
    # the offending leaves are named
    assert "trunk" in msg and "w" in msg
    with pytest.raises(ValueError):
        packer_for(tree, tail="final")


def test_single_leaf_tree():
    """A bare array (no container) packs as one head section."""
    x = jnp.arange(300.0)
    for sections in ("tail", "toplevel"):
        p = TreePacker(x, tail="final", sections=sections)
        assert p.tail_len == 0 and p.size == ROW_QUANTUM
        np.testing.assert_array_equal(np.asarray(p.unpack(p.pack(x))),
                                      np.asarray(x))
        assert len(p.sections) == 1 and p.sections[0].leaf_indices == (0,)


def test_non_contiguous_tail_name():
    """The tail key need not flatten last — its leaves still form the
    contiguous tail slice (the layout reorders, unpack restores)."""
    tree = {"a_first": jnp.ones((5,)),
            "final": {"w": jnp.arange(6.0)},       # flattens in the middle
            "z_last": jnp.full((7,), 3.0)}
    for sections in ("tail", "toplevel"):
        p = TreePacker(tree, tail="final", sections=sections)
        slab = p.pack(tree)
        tail = p.tail_slice(slab)
        np.testing.assert_array_equal(np.asarray(tail[:6]),
                                      np.arange(6.0))
        out = p.unpack(slab)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_check_tree_matches_packer_names_leaf_and_section():
    p = TreePacker(TREE, tail="final", sections="toplevel")
    check_tree_matches_packer(p, TREE, "ok tree")        # no raise
    bad_shape = jax.tree.map(lambda l: l, TREE)
    bad_shape["trunk"]["fc1"]["w"] = jnp.zeros((9, 9))
    with pytest.raises(ValueError) as e:
        check_tree_matches_packer(p, bad_shape, "gradient pytree")
    msg = str(e.value)
    assert "fc1" in msg and "section" in msg and "(9, 9)" in msg
    bad_struct = {"final": TREE["final"],
                  "trunk": {"fc0": TREE["trunk"]["fc0"]}}   # fc1 missing
    with pytest.raises(ValueError) as e:
        check_tree_matches_packer(p, bad_struct, "gradient pytree")
    assert "missing" in str(e.value) or "fc1" in str(e.value)


def test_packed_final_gather_mismatch_error_is_readable():
    """The distributed packed-ω̃ gather raises with leaf path + expected
    section on a wrong pytree, not an opaque shape error."""
    from repro.core.hota import OTACtx, make_packed_final_gather
    template = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32),
                "b": jax.ShapeDtypeStruct((4,), jnp.float32)}
    gather = make_packed_final_gather(
        ("client", "cluster"), ("cluster",), 2, 4, jnp.float32,
        [("embed", "mlp"), ("mlp",)], template=template)
    ctx = OTACtx(*(jnp.zeros(()) for _ in range(6)))
    wrong = {"w": jnp.zeros((8, 4)), "extra": jnp.zeros((3,))}
    with pytest.raises(ValueError) as e:
        jax.eval_shape(gather, wrong, ctx)
    msg = str(e.value)
    assert "packed final gather" in msg and ("extra" in msg or "b" in msg)


def test_slab_helpers_roundtrip():
    x = jnp.arange(1000.0).reshape(10, 100)
    slab, n = pad_to_lanes(x)
    assert n == 1000 and slab.shape == (slab_rows(1000), LANE)
    assert slab.shape[0] % 8 == 0
    np.testing.assert_array_equal(
        np.asarray(slab.reshape(-1)[:n].reshape(x.shape)), np.asarray(x))
