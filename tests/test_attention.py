"""Blocked online-softmax attention vs the naive oracle (+ hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers as L


def _qkv(key, b, sq, h, kv, d):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, sq, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, sq, kv, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [None, 16, 48])
@pytest.mark.parametrize("bq,bkv", [(16, 16), (32, 64), (64, 32)])
def test_blocked_matches_naive(window, bq, bkv):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 128, 4, 2, 32)
    pos = jnp.arange(128)
    ref = L.naive_attention(q, k, v, pos_q=pos, pos_kv=pos, window=window)
    out = L.blocked_attention(q, k, v, pos_q=pos, pos_kv=pos, window=window,
                              block_q=bq, block_kv=bkv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    sq_blocks=st.integers(1, 4),
    heads=st.sampled_from([(4, 1), (4, 2), (4, 4), (8, 2)]),
    d=st.sampled_from([16, 32]),
    window=st.sampled_from([None, 8, 24]),
)
def test_blocked_matches_naive_property(b, sq_blocks, heads, d, window):
    h, kv = heads
    sq = 32 * sq_blocks
    q, k, v = _qkv(jax.random.PRNGKey(sq + h + d), b, sq, h, kv, d)
    pos = jnp.arange(sq)
    ref = L.naive_attention(q, k, v, pos_q=pos, pos_kv=pos, window=window)
    out = L.blocked_attention(q, k, v, pos_q=pos, pos_kv=pos, window=window,
                              block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_blocked_attention_grads_match():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 64, 2, 2, 16)
    pos = jnp.arange(64)

    def f_blocked(q):
        return jnp.sum(L.blocked_attention(q, k, v, pos_q=pos, pos_kv=pos,
                                           block_q=16, block_kv=16) ** 2)

    def f_naive(q):
        return jnp.sum(L.naive_attention(q, k, v, pos_q=pos, pos_kv=pos) ** 2)

    g1 = jax.grad(f_blocked)(q)
    g2 = jax.grad(f_naive)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-4)


def test_decode_attention_ring_buffer_eviction():
    """A ring cache with window w must ignore evicted (stale) positions."""
    b, w, kv, d = 1, 8, 2, 16
    key = jax.random.PRNGKey(2)
    k_cache = jax.random.normal(key, (b, w, kv, d))
    v_cache = jax.random.normal(jax.random.fold_in(key, 1), (b, w, kv, d))
    # slots hold positions 8..15 (pos 16 incoming; slot 0 stale pos 8 usable:
    # diff = 16-8 = 8 not < 8 -> masked)
    pos_tab = jnp.arange(8, 16)[None, :]
    q = jax.random.normal(jax.random.fold_in(key, 2), (b, 1, 4, d))
    out = L.decode_attention(q, k_cache, v_cache,
                             pos_q=jnp.array([16]), pos_kv=pos_tab, window=w)
    # manual: only positions 9..15 attendable
    mask = (jnp.array([16])[:, None] - pos_tab) < w
    assert bool(mask[0, 0]) is False and bool(mask[0, 1]) is True
    assert np.all(np.isfinite(np.asarray(out)))
