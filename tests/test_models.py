"""Model-zoo behaviour: forward/grad sanity + prefill/decode consistency
for every backbone family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import (
    HybridConfig, ModelConfig, MoEConfig, SSMConfig, XLSTMConfig,
)
from repro.models import build_model, init_params, lm_loss

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=128, attn_block_q=16, attn_block_kv=16,
            remat_policy="none", compute_dtype="float32")

FAMILY_CONFIGS = {
    "dense-gqa": ModelConfig(family="dense", **BASE),
    "dense-swa": ModelConfig(family="dense", sliding_window=16, **BASE),
    "dense-qkvbias": ModelConfig(family="dense", qkv_bias=True, **BASE),
    "gemma3-style": ModelConfig(family="dense", local_global_ratio=2,
                                local_window=16,
                                **{**BASE, "n_layers": 6}),
    "moe": ModelConfig(family="dense", moe=MoEConfig(n_experts=4, top_k=2),
                       **BASE),
    "mamba2": ModelConfig(family="ssm",
                          ssm=SSMConfig(d_state=16, head_dim=16, chunk_size=8),
                          **BASE),
    "xlstm": ModelConfig(family="xlstm", xlstm=XLSTMConfig(slstm_every=4),
                         **{**BASE, "n_layers": 8}),
    "zamba2-hybrid": ModelConfig(
        family="hybrid",
        ssm=SSMConfig(d_state=16, head_dim=16, chunk_size=8),
        hybrid=HybridConfig(attn_every=2, shared_attn_n_heads=4,
                            shared_attn_n_kv=2),
        sliding_window=16, **{**BASE, "n_layers": 5}),
}


@pytest.fixture(params=list(FAMILY_CONFIGS))
def family_cfg(request):
    return request.param, FAMILY_CONFIGS[request.param]


def _setup(cfg):
    m = build_model(cfg)
    params = init_params(m.backbone_specs(), jax.random.PRNGKey(0))
    head = init_params(m.head_specs(), jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                              cfg.vocab_size)
    return m, params, head, toks


def test_forward_shapes_and_finite(family_cfg):
    name, cfg = family_cfg
    m, params, head, toks = _setup(cfg)
    logits, aux, _ = m.forward_logits(params, head, toks, mode="train")
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits))), name
    assert np.isfinite(float(aux))


def test_grads_finite_nonzero(family_cfg):
    name, cfg = family_cfg
    m, params, head, toks = _setup(cfg)

    def loss_fn(p):
        lg, aux, _ = m.forward_logits(p, head, toks, mode="train")
        return lm_loss(lg, toks) + aux

    g = jax.grad(loss_fn)(params)
    total = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
                for x in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0, name


def test_decode_matches_full_forward(family_cfg):
    """prefill(S) + decode(1) must agree with a full inference pass on S+1
    tokens (up to bf16 cache rounding). The reference is a prefill — the
    same inference semantics the incremental path implements (train-only
    behaviours like MoE capacity dropping are legitimately absent)."""
    name, cfg = family_cfg
    m, params, head, toks = _setup(cfg)
    extra = jax.random.randint(jax.random.PRNGKey(3), (2, 1), 0,
                               cfg.vocab_size)
    toks2 = jnp.concatenate([toks, extra], axis=1)
    full, _, _ = m.forward_logits(params, head, toks2,
                                  positions=jnp.arange(33), mode="prefill")
    _, _, cache = m.forward_logits(params, head, toks,
                                   positions=jnp.arange(32), mode="prefill")
    pos = jnp.full((2,), 32, jnp.int32)
    dec, _, _ = m.forward_logits(params, head, toks2[:, 32:], positions=pos,
                                 mode="decode", cache=cache)
    err = float(jnp.max(jnp.abs(full[:, -1] - dec[:, 0])))
    assert err < 0.02, (name, err)


def test_causality(family_cfg):
    """Changing a future token must not change past logits."""
    name, cfg = family_cfg
    m, params, head, toks = _setup(cfg)
    logits1, _, _ = m.forward_logits(params, head, toks, mode="train")
    toks_mut = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    logits2, _, _ = m.forward_logits(params, head, toks_mut, mode="train")
    np.testing.assert_allclose(np.asarray(logits1[:, :-1]),
                               np.asarray(logits2[:, :-1]),
                               rtol=2e-4, atol=2e-4)


def test_embeds_input_vlm_path():
    """Vision/audio stub: float embeddings input instead of token ids."""
    cfg = FAMILY_CONFIGS["dense-gqa"].replace(modality="vision")
    m = build_model(cfg)
    params = init_params(m.backbone_specs(), jax.random.PRNGKey(0))
    head = init_params(m.head_specs(), jax.random.PRNGKey(1))
    embeds = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    logits, _, _ = m.forward_logits(params, head, embeds, mode="train")
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
