"""Sharding rules + FL mesh refinement (no multi-device needed: meshes can
be built abstractly over a device list of 1 for spec logic via mock)."""
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

import jax
from repro.sharding.rules import SERVE_RULES, TRAIN_RULES, spec_for
from repro.sharding.mesh_utils import fl_view


class FakeMesh:
    """Duck-typed mesh for spec_for (axis names + shape only)."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.zeros(shape)


MESH = FakeMesh((16, 16), ("data", "model"))
FLMESH = FakeMesh((4, 4, 16), ("cluster", "client", "model"))
PODMESH = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_embed_fsdp_mlp_tp():
    spec = spec_for(("embed", "mlp"), TRAIN_RULES, (2560, 6912), MESH)
    assert spec == P("data", "model")


def test_divisibility_fallback():
    # kv_heads = 2 cannot shard over model=16 -> replicated
    spec = spec_for(("embed", "kv_heads", "head_dim"), TRAIN_RULES,
                    (3072, 2, 128), MESH)
    assert spec == P("data", None, None)


def test_exclusivity_no_axis_reuse():
    # expert takes model; mlp must NOT also get model
    spec = spec_for(("expert", "embed", "mlp"), TRAIN_RULES,
                    (16, 4096, 6400), MESH)
    assert spec == P("model", "data", None)


def test_expert_indivisible_falls_through():
    # 8 experts don't divide 16; expert tries data (16) also no ->
    # replicated; embed takes data, mlp takes model
    spec = spec_for(("expert", "embed", "mlp"), TRAIN_RULES,
                    (8, 6144, 16384), MESH)
    assert spec == P(None, "data", "model")


def test_data_translates_to_cluster_client():
    spec = spec_for(("embed", "mlp"), TRAIN_RULES, (2560, 6912), FLMESH)
    assert spec == P(("cluster", "client"), "model")


def test_batch_over_pod_and_data():
    spec = spec_for(("batch", "seq"), TRAIN_RULES, (256, 4096), PODMESH)
    assert spec == P(("pod", "data"), None)


def test_serve_cache_seq_over_model():
    spec = spec_for(("batch", "cache_seq", "kv_heads", "head_dim"),
                    SERVE_RULES, (128, 32768, 8, 128), MESH)
    assert spec == P("data", "model", None, None)


def test_fl_view_preserves_device_order():
    import jax
    devs = np.array(jax.devices())
    if devs.size < 1:
        pytest.skip("no devices")
    mesh = Mesh(devs[:1].reshape(1, 1), ("data", "model"))
    ref = fl_view(mesh, 1)
    assert ref.axis_names == ("cluster", "client", "model")
    assert ref.devices.flatten()[0] == mesh.devices.flatten()[0]
