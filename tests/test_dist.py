"""Distributed-path tests. Each runs in a subprocess so it can claim 8
host devices before jax initializes (the main pytest process stays
single-device)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, "..", "src"))


def _run(program: str, *args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist_programs", program), *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, (
        f"{program} {args} failed:\nSTDOUT:{proc.stdout[-3000:]}\n"
        f"STDERR:{proc.stderr[-3000:]}")
    return proc.stdout


@pytest.mark.slow
@pytest.mark.parametrize("mode,mb", [("scatter", "1"), ("scatter", "2"),
                                     ("naive", "1")])
def test_dist_train_step(mode, mb):
    out = _run("dist_train_step.py", mode, mb)
    assert "DIST_TRAIN_OK" in out


@pytest.mark.slow
def test_dist_matches_sim():
    out = _run("dist_vs_sim.py")
    assert "DIST_VS_SIM_OK" in out
