"""MoE routing invariants (capacity-based top-2 dispatch)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.common.config import ModelConfig, MoEConfig
from repro.models.moe import _route, moe_apply, moe_specs
from repro.models.params import init_params


def _cfg(e=4, k=2, cf=1.25):
    return ModelConfig(family="dense", n_layers=1, d_model=32, n_heads=2,
                       n_kv_heads=2, d_ff=48, vocab_size=64,
                       moe=MoEConfig(n_experts=e, top_k=k,
                                     capacity_factor=cf))


def test_route_topk_support():
    logits = jax.random.normal(jax.random.PRNGKey(0), (10, 8))
    gates, mask, weights = _route(logits, 2)
    assert np.all(np.asarray(mask.sum(-1)) == 2)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    # gates supported only on the top-2 experts
    assert np.all(np.asarray(gates)[np.asarray(mask) == 0] == 0)


@settings(max_examples=15, deadline=None)
@given(e=st.sampled_from([2, 4, 8]), seed=st.integers(0, 100))
def test_moe_output_finite_and_shape(e, seed):
    cfg = _cfg(e=e)
    specs = moe_specs(cfg)
    p = init_params(specs, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, 32))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    assert float(aux) >= 0


def test_moe_aux_loss_uniformity_bound():
    """Switch aux loss: E·Σ f_e·P_e ≥ 1 with equality iff uniform — scaled
    by aux_loss_weight."""
    cfg = _cfg(e=4)
    specs = moe_specs(cfg)
    p = init_params(specs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    _, aux = moe_apply(p, x, cfg)
    assert float(aux) >= cfg.moe.aux_loss_weight * 0.99


def test_capacity_drops_tokens_when_overloaded():
    """With capacity_factor << 1 some tokens must be dropped (combine
    contributes zero), output == residual for dropped tokens."""
    cfg = _cfg(e=2, k=1, cf=0.1)
    specs = moe_specs(cfg)
    p = init_params(specs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32))
    y, _ = moe_apply(p, x, cfg)
    deltas = np.asarray(jnp.abs(y - x).sum(-1))[0]
    assert (deltas < 1e-6).sum() > 0        # some tokens untouched (dropped)
    assert (deltas > 1e-6).sum() > 0        # some tokens routed
